//! **End-to-end GAN training** through the full three-layer stack: the
//! complete alternating-SGD train step (generator fwd, discriminator fwd,
//! both losses, both gradients, SGD update) was written in JAX
//! (`python/compile/model.py::gan_train_step`), AOT-lowered to one HLO
//! module, and is driven here — from Rust, via PJRT — for a few hundred
//! steps on synthetic 32×32 data. Python never runs.
//!
//! Expected behaviour (logged): the discriminator loss falls as D learns
//! to separate real/fake; the generator loss rises-then-oscillates as the
//! two networks compete; all values stay finite. The loss curve is
//! recorded in EXPERIMENTS.md §E2E-train.
//!
//! Run: `cargo run --release --example train_gan [steps]`

use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use huge2::tensor::Tensor;
use std::time::Instant;

const BATCH: usize = 16;
const Z: usize = 32;

/// Synthetic "dataset": smooth class-conditional blobs in [-1, 1] — enough
/// structure for D to learn and G to chase (stands in for CIFAR-100;
/// DESIGN.md §2 substitution table).
fn synth_batch(rng: &mut Rng) -> Tensor {
    let mut data = vec![0.0f32; BATCH * 32 * 32 * 3];
    for b in 0..BATCH {
        let cx = 8.0 + 16.0 * rng.next_f32();
        let cy = 8.0 + 16.0 * rng.next_f32();
        let hue = rng.next_f32();
        for y in 0..32 {
            for x in 0..32 {
                let d2 = ((x as f32 - cx).powi(2)
                    + (y as f32 - cy).powi(2)) / 40.0;
                let v = (-d2).exp() * 2.0 - 1.0;
                let off = ((b * 32 + y) * 32 + x) * 3;
                data[off] = v * hue;
                data[off + 1] = v * (1.0 - hue);
                data[off + 2] = v * 0.5;
            }
        }
    }
    Tensor::from_vec(&[BATCH, 32, 32, 3], data)
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let dir = std::path::PathBuf::from("artifacts");
    anyhow::ensure!(dir.join("manifest.txt").exists(),
                    "run `make artifacts` first");
    let rt = RuntimeHandle::spawn(dir)?;

    // Initial parameters from the seeded init artifact, so Rust starts at
    // exactly the same point as the python model would.
    println!("compiling init + train-step modules...");
    let t0 = Instant::now();
    let mut params = rt.run("tiny_gan_init", vec![])?;
    let n_params = params.len();
    rt.warm("tiny_gan_step")?;
    println!("ready in {:.1?}; {} parameter tensors, {} total elements",
             t0.elapsed(), n_params,
             params.iter().map(|t| t.len()).sum::<usize>());

    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let mut curve = Vec::new();
    for step in 0..steps {
        let z: Vec<f32> =
            (0..BATCH * Z).map(|_| rng.next_normal()).collect();
        let mut inputs = params.clone();
        inputs.push(Tensor::from_vec(&[BATCH, Z], z));
        inputs.push(synth_batch(&mut rng));
        let mut out = rt.run("tiny_gan_step", inputs)?;
        let loss_d = out.pop().unwrap().data()[0];
        let loss_g = out.pop().unwrap().data()[0];
        params = out; // updated parameters
        anyhow::ensure!(loss_g.is_finite() && loss_d.is_finite(),
                        "loss diverged at step {step}");
        if step % 25 == 0 || step == steps - 1 {
            println!("step {step:>4}  loss_G {loss_g:>8.4}  \
                      loss_D {loss_d:>8.4}  ({:.0} ms/step)",
                     t0.elapsed().as_millis() as f64 / (step + 1) as f64);
            curve.push((step, loss_g, loss_d));
        }
    }
    let (s0, _, d0) = curve[0];
    let (_, _, d_last) = curve[curve.len() - 1];
    println!("\ntrained {steps} steps in {:.1}s \
              ({:.0} ms/step, batch {BATCH})",
             t0.elapsed().as_secs_f64(),
             t0.elapsed().as_millis() as f64 / steps as f64);
    println!("discriminator loss: {d0:.4} (step {s0}) → {d_last:.4} \
              (final) — {}",
             if d_last < d0 { "learning ✓" } else { "no improvement ✗" });
    Ok(())
}
