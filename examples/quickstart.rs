//! Quickstart: one Table-1 deconvolution layer through the three engines.
//!
//! Shows the core HUGE² identity: the naive zero-insertion baseline, the
//! pure-Rust decomposed+untangled engine, and (if `make artifacts` has
//! run) the AOT-compiled JAX/Pallas kernel all produce the same output —
//! the fast ones just skip the zeros.
//!
//! Run: `cargo run --release --example quickstart`

use huge2::bench_util::{fmt_dur, measure, Table};
use huge2::config::layer_by_name;
use huge2::deconv::{baseline, huge2 as engine};
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use huge2::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    // DCGAN DC3: 16x16x256 -> 32x32x128, 5x5 kernel, stride 2
    let layer = layer_by_name("dcgan_dc3").unwrap();
    println!("layer {}: {}x{}x{} -> {}x{}x{}", layer.name, layer.h,
             layer.h, layer.c_in, layer.h_out(), layer.h_out(),
             layer.c_out);

    let mut rng = Rng::new(2024);
    let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
    let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                          &mut rng).scale(0.02);
    let p = layer.deconv_params();

    // 1. naive baseline: inflate with zeros, im2col, one big GEMM
    let t_base = measure(1, 5,
                         || { baseline::conv2d_transpose(&x, &k, &p); });
    let y_base = baseline::conv2d_transpose(&x, &k, &p);

    // 2. HUGE2: decompose (once, at "model load") + untangled tap GEMMs
    let patterns = engine::decompose(&k, &p);
    let t_fast = measure(1, 5, || {
        engine::conv2d_transpose_with(&x, &patterns, layer.k, layer.k, &p);
    });
    let y_fast = engine::conv2d_transpose_with(&x, &patterns, layer.k,
                                              layer.k, &p);

    let mut t = Table::new(&["engine", "median", "speedup", "max |Δ|"]);
    t.row(&["baseline (zero-insert + im2col)".into(),
            fmt_dur(t_base.median), "1.00x".into(), "-".into()]);
    t.row(&["huge2 (decompose + untangle)".into(), fmt_dur(t_fast.median),
            format!("{:.2}x", t_base.median_s() / t_fast.median_s()),
            format!("{:.2e}", y_fast.max_abs_diff(&y_base))]);

    // 3. the AOT JAX/Pallas kernel through PJRT, if artifacts exist
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.txt").exists() {
        let rt = RuntimeHandle::spawn(dir)?;
        rt.warm("dcgan_dc3_huge2")?;
        let y = rt.run("dcgan_dc3_huge2", vec![x.clone(), k.clone()])?;
        let t_pjrt = measure(1, 3, || {
            rt.run("dcgan_dc3_huge2", vec![x.clone(), k.clone()]).unwrap();
        });
        t.row(&["pallas kernel via PJRT (interpret)".into(),
                fmt_dur(t_pjrt.median), "-".into(),
                format!("{:.2e}", y[0].max_abs_diff(&y_base))]);
    } else {
        eprintln!("(run `make artifacts` to include the PJRT/Pallas row)");
    }
    t.print();

    assert!(y_fast.allclose(&y_base, 1e-4));
    println!("\nchecksum(huge2 output) = {:#x}", y_fast.checksum());
    println!("OK: all engines agree.");
    Ok(())
}
