//! **End-to-end driver** (DESIGN.md E2E-serve): load the AOT DCGAN
//! generator, run the full serving engine — router → bounded queue →
//! dynamic batcher → PJRT worker — under an open-loop Poisson workload,
//! and report latency/throughput percentiles.
//!
//! This is the deployment shape of the paper's system: Python never runs;
//! the Rust binary loads `artifacts/*.hlo.txt` (JAX/Pallas HUGE² kernels,
//! compiled once by `make artifacts`) and serves image-generation
//! requests.
//!
//! Run: `cargo run --release --example serve_dcgan [rate] [n_requests]`

use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Payload};
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use huge2::trace::poisson;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3.0);
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);

    let dir = std::path::PathBuf::from("artifacts");
    anyhow::ensure!(dir.join("manifest.txt").exists(),
                    "run `make artifacts` first");

    let cfg = EngineConfig {
        workers: 1,
        max_batch: 8,
        batch_timeout_us: 50_000,
        batch_buckets: vec![1, 4, 8],
        queue_depth: 64,
        ..EngineConfig::default()
    };
    println!("loading + compiling DCGAN generator artifacts \
              (buckets 1/4/8)...");
    let t0 = Instant::now();
    let rt = Arc::new(RuntimeHandle::spawn(dir)?);
    let mut eng = Engine::new(cfg);
    eng.register_pjrt("dcgan", "dcgan_gen", rt, 1, 7)?;
    println!("ready in {:?} (XLA compile included)\n", t0.elapsed());

    println!("open-loop Poisson workload: {rate} req/s, {n} requests");
    let arrivals = poisson(rate, n, 1234);
    let t0 = Instant::now();
    let mut rng = Rng::new(5);
    let mut pending = Vec::new();
    let mut rejected = 0;
    for a in &arrivals {
        let wait = a.at.saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let z: Vec<f32> = (0..100).map(|_| rng.next_normal()).collect();
        match eng.submit("dcgan", Payload::latent(z, vec![])) {
            Ok(rx) => pending.push(rx),
            Err(_) => rejected += 1,
        }
    }
    let mut lats: Vec<Duration> = Vec::new();
    let mut batch_sizes = Vec::new();
    let mut checksum = 0u64;
    let mut first_images: Vec<huge2::tensor::Tensor> = Vec::new();
    for rx in pending {
        let r = rx.recv()??; // outer: channel; inner: typed ServeError
        assert_eq!(r.output.shape(), &[1, 64, 64, 3]);
        // tanh range sanity on the actual generated pixels
        assert!(r.output.data().iter().all(|v| v.abs() <= 1.0));
        checksum ^= r.output.checksum();
        if first_images.len() < 4 {
            first_images.push(r.output.clone());
        }
        lats.push(r.latency);
        batch_sizes.push(r.batch_size);
    }
    let wall = t0.elapsed();
    lats.sort_unstable();
    let q = |p: f64| lats[((lats.len() as f64 * p) as usize)
                          .min(lats.len() - 1)];

    println!("\n== results ==");
    println!("completed {}/{n} ({rejected} rejected by backpressure)",
             lats.len());
    println!("wall time {:.2}s → {:.2} img/s", wall.as_secs_f64(),
             lats.len() as f64 / wall.as_secs_f64());
    println!("latency  p50 {:?}  p90 {:?}  p99 {:?}  max {:?}",
             q(0.50), q(0.90), q(0.99), lats[lats.len() - 1]);
    println!("mean batch size {:.2} (buckets 1/4/8)",
             eng.counters.mean_batch_size());
    println!("exec-time histogram: {}", eng.exec_hist.summary());
    println!("output checksum {checksum:#x}");

    // dump a sample montage — the engine's actual product
    if !first_images.is_empty() {
        let (n, h, w) = (first_images.len(), 64, 64);
        let mut data = Vec::with_capacity(n * h * w * 3);
        for img in &first_images {
            data.extend_from_slice(img.data());
        }
        let batch = huge2::tensor::Tensor::from_vec(&[n, h, w, 3], data);
        let tiled = huge2::tensor::image::montage(&batch, 2);
        let path = std::path::Path::new("samples.ppm");
        huge2::tensor::image::write_ppm(&tiled, path)?;
        println!("wrote {} ({}x{} montage of {n} samples)",
                 path.display(), tiled.shape()[2], tiled.shape()[1]);
    }
    eng.shutdown();
    Ok(())
}
