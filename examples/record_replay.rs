//! **Record/replay quickstart** (DESIGN.md §7): record a native DCGAN
//! serve session to a JSONL trace, then replay the bit-identical
//! workload through a freshly built engine and verify every output
//! checksum. The CLI equivalent:
//!
//! ```text
//! huge2 serve --native --record t.jsonl
//! huge2 replay t.jsonl --timing fast
//! ```
//!
//! Run: `cargo run --release --example record_replay [n_requests]`

use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Model};
use huge2::gan::Generator;
use huge2::replay::{Recorder, Replayer, Timing, TraceHeader, TraceSink};
use huge2::rng::Rng;
use huge2::trace::poisson;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let seed = 7u64;
    let trace_path = std::path::PathBuf::from("replay_demo.jsonl");
    let cfg = EngineConfig {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 5_000,
        ..EngineConfig::default()
    };

    // --- record: sink installed before the model registers ---
    let sink = Arc::new(TraceSink::new());
    let mut eng = Engine::new(cfg.clone());
    eng.set_trace_sink(sink.clone())?;
    let gen = Arc::new(Generator::dcgan(seed));
    let z_dim = gen.z_dim;
    eng.register_native(Model::native("dcgan", gen, 0))?;

    println!("recording {n} requests (native DCGAN, Poisson 20/s)...");
    let arrivals = poisson(20.0, n, 99);
    let t0 = Instant::now();
    let mut rng = Rng::new(1);
    let mut pending = Vec::new();
    for a in &arrivals {
        let wait = a.at.saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let z: Vec<f32> = (0..z_dim).map(|_| rng.next_normal()).collect();
        pending.push(eng.submit("dcgan",
                                huge2::coordinator::Payload::latent(
                                    z, vec![]))?);
    }
    for rx in pending {
        rx.recv()??; // outer: channel; inner: typed ServeError
    }
    println!("recorded in {:.2}s", t0.elapsed().as_secs_f64());
    eng.shutdown(); // workers flush their trace events before join

    let rec = Recorder::from_parts(
        TraceHeader {
            model: "dcgan".into(),
            backend: "native".into(),
            seed,
            z_dim,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        },
        sink,
    );
    let n_events = rec.save(&trace_path)?;
    println!("wrote {n_events} events to {}", trace_path.display());

    // --- replay: fresh engine, weights rebuilt from the trace header ---
    let rp = Replayer::load(&trace_path)?;
    let mut eng = Engine::new(cfg);
    eng.register_native(Model::native(
        "dcgan",
        Arc::new(Generator::dcgan(rp.header().seed)),
        0,
    ))?;
    println!("replaying {} arrivals in fast mode...", rp.arrival_count());
    let report = rp.run(&eng, Timing::Fast)?;
    eng.shutdown();
    println!("{}", report.summary());
    match report.first_divergence() {
        None => {
            println!("OK: deterministic — every recorded checksum \
                      reproduced bit-for-bit.");
            Ok(())
        }
        Some(d) => anyhow::bail!("diverged: {d}"),
    }
}
