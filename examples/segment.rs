//! Semantic segmentation **end-to-end** through the `seg` subsystem (the
//! paper's second motivating domain, §1/§2.1.2): build a [`SegNet`] from
//! dilated-conv layer configs (atrous spatial pyramid at dilations
//! 1/2/4/8), compare the naive zero-dilated-kernel engine with HUGE²
//! untangling per pyramid branch, then serve the net through the
//! coordinator — submit an image request, get a class-argmax mask back.
//!
//! Run: `cargo run --release --example segment`

use huge2::bench_util::{fmt_dur, Table};
use huge2::config::{segnet, EngineConfig};
use huge2::coordinator::{Engine as Coordinator, Model, Payload};
use huge2::deconv::Engine;
use huge2::rng::Rng;
use huge2::seg::SegNet;
use huge2::tensor::Tensor;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // --- build: weights seeded, kernels tap-packed at load time ---
    let net = Arc::new(SegNet::new(&segnet(), 7));
    let in_shape = net.in_shape();
    let mut rng = Rng::new(11);
    let x = Tensor::randn(&in_shape, &mut rng);
    println!("segnet: input {in_shape:?}, {} classes, ASPP dilations {:?} \
              ('same' padding)\n",
             net.n_classes(),
             net.aspp.iter().map(|l| l.cfg.params.dilation)
                 .collect::<Vec<_>>());

    // --- per-branch timing table: baseline vs HUGE² untangled ---
    let trunk_out = {
        let mut h = x.clone();
        for l in &net.trunk {
            h = l.forward(&h, Engine::Huge2).relu();
        }
        h
    };
    let mut t = Table::new(&["dilation", "baseline", "huge2", "speedup",
                             "max |Δ|"]);
    let mut pyr_base: Option<Tensor> = None;
    let mut pyr_fast: Option<Tensor> = None;
    for l in &net.aspp {
        let [base, fast, speedup, diff] =
            huge2::seg::layer_timing_cells(l, &trunk_out);
        t.row(&[format!("d={}", l.cfg.params.dilation), base, fast,
                speedup, diff]);
        let yb = l.forward(&trunk_out, Engine::Baseline);
        let yf = l.forward(&trunk_out, Engine::Huge2);
        pyr_base = Some(match pyr_base {
            None => yb,
            Some(acc) => acc.add(&yb),
        });
        pyr_fast = Some(match pyr_fast {
            None => yf,
            Some(acc) => acc.add(&yf),
        });
    }
    t.print();
    let (pb, pf) = (pyr_base.unwrap(), pyr_fast.unwrap());
    assert!(pf.allclose(&pb, 1e-3));
    println!("\npyramid sum agrees across engines (max |Δ| = {:.2e})",
             pf.max_abs_diff(&pb));

    // --- serve: the same net through the multi-task coordinator ---
    let cfg = EngineConfig {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 2_000,
        ..EngineConfig::default()
    };
    let mut eng = Coordinator::new(cfg);
    eng.register_native(Model::native_seg("segnet", net.clone()))?;
    println!("\nserving 'segnet' natively; submitting 4 image requests...");
    let mut pending = Vec::new();
    for i in 0..4u64 {
        let img = Tensor::randn(&in_shape, &mut Rng::new(100 + i));
        pending.push(eng.submit("segnet", Payload::image(img, 100 + i))?);
    }
    for rx in pending {
        let r = rx.recv()??; // outer: channel; inner: typed ServeError
        let mut hist = vec![0usize; net.n_classes()];
        for &v in r.output.data() {
            hist[v as usize] += 1;
        }
        println!("  mask {:?} in {} (batch {}): class histogram {hist:?}",
                 r.output.shape(), fmt_dur(r.latency), r.batch_size);
    }
    eng.shutdown();

    // --- the AOT Pallas pyramid, if compiled: the only Rust-side check
    // of the `atrous_pyramid` artifact, kept from the pre-seg-subsystem
    // example (its fixed geometry: 33×33×32 input, 3×3×32×32 kernels,
    // dilations 1/2/4/8) ---
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.txt").exists() {
        let mut rng = Rng::new(11);
        let (h, c, n) = (33, 32, 32);
        let xa = Tensor::randn(&[1, h, h, c], &mut rng);
        let ks: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[3, 3, c, n], &mut rng).scale(0.05))
            .collect();
        let mut want: Option<Tensor> = None;
        for (k, d) in ks.iter().zip([1usize, 2, 4, 8]) {
            let p = huge2::deconv::DilatedParams::new(d, 1, d);
            let y = huge2::deconv::baseline::conv2d_dilated(&xa, k, &p);
            want = Some(match want {
                None => y,
                Some(acc) => acc.add(&y),
            });
        }
        let want = want.unwrap();
        let rt = huge2::runtime::RuntimeHandle::spawn(dir)?;
        let mut inputs = vec![xa];
        inputs.extend(ks);
        let y = rt.run("atrous_pyramid", inputs)?;
        println!("\nPJRT pallas pyramid agrees: max |Δ| = {:.2e}",
                 y[0].max_abs_diff(&want));
        assert!(y[0].allclose(&want, 1e-3));
    }
    println!("OK");
    Ok(())
}
