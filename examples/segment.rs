//! Semantic-segmentation-style workload (the paper's second motivating
//! domain, §1/§2.1.2): an atrous spatial pyramid — parallel dilated
//! convolutions at dilations 1/2/4/8 — over a feature map, comparing the
//! naive zero-dilated-kernel engine with HUGE² untangling, and (if
//! artifacts exist) the AOT JAX/Pallas pyramid through PJRT.
//!
//! Run: `cargo run --release --example segment`

use huge2::bench_util::{fmt_dur, measure, Table};
use huge2::deconv::{baseline, dilated, DilatedParams};
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use huge2::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let (h, c, n) = (33, 32, 32);
    let mut rng = Rng::new(11);
    let x = Tensor::randn(&[1, h, h, c], &mut rng);
    let ks: Vec<Tensor> = (0..4)
        .map(|_| Tensor::randn(&[3, 3, c, n], &mut rng).scale(0.05))
        .collect();
    let dils = [1usize, 2, 4, 8];

    println!("atrous pyramid over {h}x{h}x{c}, dilations {dils:?} \
              ('same' padding)\n");
    let mut t = Table::new(&["dilation", "baseline", "huge2", "speedup",
                             "max |Δ|"]);
    let mut pyr_base: Option<Tensor> = None;
    let mut pyr_fast: Option<Tensor> = None;
    for (k, &d) in ks.iter().zip(&dils) {
        let p = DilatedParams::new(d, 1, d);
        let tb = measure(1, 5, || { baseline::conv2d_dilated(&x, k, &p); });
        let tf = measure(1, 5, || { dilated::conv2d_dilated(&x, k, &p); });
        let yb = baseline::conv2d_dilated(&x, k, &p);
        let yf = dilated::conv2d_dilated(&x, k, &p);
        t.row(&[
            format!("d={d}"),
            fmt_dur(tb.median),
            fmt_dur(tf.median),
            format!("{:.2}x", tb.median_s() / tf.median_s()),
            format!("{:.2e}", yf.max_abs_diff(&yb)),
        ]);
        pyr_base = Some(match pyr_base {
            None => yb,
            Some(acc) => acc.add(&yb),
        });
        pyr_fast = Some(match pyr_fast {
            None => yf,
            Some(acc) => acc.add(&yf),
        });
    }
    t.print();
    let (pb, pf) = (pyr_base.unwrap(), pyr_fast.unwrap());
    assert!(pf.allclose(&pb, 1e-3));
    println!("\npyramid sum agrees across engines \
              (max |Δ| = {:.2e})", pf.max_abs_diff(&pb));

    // the AOT pallas pyramid, if compiled
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.txt").exists() {
        let rt = RuntimeHandle::spawn(dir)?;
        let mut inputs = vec![x.clone()];
        inputs.extend(ks.iter().cloned());
        let y = rt.run("atrous_pyramid", inputs)?;
        // the artifact's pyramid uses dilations (1,2,4,8) too
        println!("PJRT pallas pyramid agrees: max |Δ| = {:.2e}",
                 y[0].max_abs_diff(&pb));
        assert!(y[0].allclose(&pb, 1e-3));
    }
    println!("OK");
    Ok(())
}
