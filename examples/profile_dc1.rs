//! Profiling harness for the perf pass: runs one Table-1 layer's HUGE²
//! engine in a tight loop so `perf record -g` gets clean samples.
//!
//! Usage: `perf record -g ./target/release/examples/profile_dc1 dcgan_dc1 30`
//! (found §Perf iteration 3: 61 % of cycles in the scalar micro-kernel
//! before `target-cpu=native`).

use huge2::config::layer_by_name;
use huge2::deconv::huge2 as engine;
use huge2::rng::Rng;
use huge2::tensor::Tensor;
fn main() {
    let layer = layer_by_name(&std::env::args().nth(1).unwrap_or("dcgan_dc1".into())).unwrap();
    let mut rng = Rng::new(42);
    let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
    let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out], &mut rng);
    let p = layer.deconv_params();
    let patterns = engine::decompose(&k, &p);
    let iters: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(30);
    for _ in 0..iters {
        std::hint::black_box(engine::conv2d_transpose_with(&x, &patterns, layer.k, layer.k, &p));
    }
}
