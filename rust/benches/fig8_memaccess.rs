//! Reproduces **Figure 8 (left)** — memory-access reduction — via the
//! instrumented cache simulator, and **Figure 7 (left)** — embedded-GPU
//! speedup — via the TX2 roofline model (no CUDA device here; this column
//! is an ESTIMATE and labelled as such — DESIGN.md §2).
//!
//! Paper claims: 30–70 % access reduction, larger on deeper (data-bound)
//! layers; ~10× GPU speedup.
//!
//! Run: `cargo bench --bench fig8_memaccess`

use huge2::bench_util::Table;
use huge2::config::{dilated_workloads, table1};
use huge2::memsim::counter::trace_dilated;
use huge2::memsim::{trace_layer, EngineKind, GpuModel};

fn main() {
    println!("\n== Fig 8 (left): memory accesses, baseline vs HUGE2 ==");
    println!("(TX2-like hierarchy: 32KiB/2-way L1, 2MiB/16-way L2, \
              64B lines)\n");
    let mut t = Table::new(&["layer", "base accesses", "huge2 accesses",
                             "reduction", "base DRAM KB", "huge2 DRAM KB",
                             "paper(≈)"]);
    for l in table1() {
        let b = trace_layer(&l, EngineKind::Baseline);
        let h = trace_layer(&l, EngineKind::Huge2);
        let red = 100.0
            * (1.0 - h.hierarchy.scalar_accesses as f64
               / b.hierarchy.scalar_accesses as f64);
        t.row(&[
            l.name.into(),
            b.hierarchy.scalar_accesses.to_string(),
            h.hierarchy.scalar_accesses.to_string(),
            format!("{red:.1}%"),
            (b.dram_bytes / 1024).to_string(),
            (h.dram_bytes / 1024).to_string(),
            "30-70%".into(),
        ]);
    }
    t.print();

    println!("\n== dilated-conv workloads (segmentation / §2.1.2) ==\n");
    let mut t = Table::new(&["workload", "base accesses", "huge2 accesses",
                             "reduction"]);
    for (name, h, c, n, r, p) in dilated_workloads() {
        let b = trace_dilated(h, c, n, r, &p, EngineKind::Baseline);
        let f = trace_dilated(h, c, n, r, &p, EngineKind::Huge2);
        t.row(&[
            name.into(),
            b.hierarchy.scalar_accesses.to_string(),
            f.hierarchy.scalar_accesses.to_string(),
            format!("{:.1}%",
                    100.0 * (1.0 - f.hierarchy.scalar_accesses as f64
                             / b.hierarchy.scalar_accesses as f64)),
        ]);
    }
    t.print();

    println!("\n== Fig 7 (left): embedded-GPU speedup (roofline ESTIMATE, \
              TX2 parameters) ==\n");
    let model = GpuModel::default();
    let mut t = Table::new(&["layer", "t_base est", "t_huge2 est",
                             "speedup", "baseline bound", "paper(≈)"]);
    for l in table1() {
        let e = model.estimate(&l);
        t.row(&[
            l.name.into(),
            format!("{:.3}ms", e.t_baseline_s * 1e3),
            format!("{:.3}ms", e.t_huge2_s * 1e3),
            format!("{:.1}x", e.speedup),
            if e.baseline_compute_bound { "compute" } else { "memory" }
                .into(),
            "~10x".into(),
        ]);
    }
    t.print();
    println!("\nNOTE: GPU column is an analytical estimate (no CUDA \
              device in this environment); the CPU columns above and in \
              fig7_speedup are measured.");
}
