//! Reproduces **Figure 8 (right)**: GAN-training speedup on "several
//! typical layers" (paper §4.2.1) — both cases the paper covers:
//!
//! * **dilated derivative maps convolving the input** — the discriminator
//!   weight gradient (§3.2.3, Fig. 6 step 3): naive engines materialise
//!   the stride-dilated derivative kernel (zeros included); HUGE²
//!   untangles each tap into a `(C,N) += Xᵀ·dY` GEMM.
//! * **derivative maps stridedly convolving input tensors** — the
//!   generator input gradient, which *is* a transposed convolution, so it
//!   exercises the Fig.-7 engines on backward shapes.
//!
//! Run: `cargo bench --bench fig8_training`

use huge2::bench_util::{fmt_dur, measure_budget, Table};
use huge2::deconv::{grad, DeconvParams};
use huge2::rng::Rng;
use huge2::tensor::Tensor;
use std::time::Duration;

/// Discriminator layers of the CIFAR DCGAN (32→16→8→4), batch 4.
const DISC_LAYERS: &[(&str, usize, usize, usize)] = &[
    // (name, h_in, c_in, c_out); 5x5, stride 2, pad 2
    ("disc_l1_32x32", 32, 3, 64),
    ("disc_l2_16x16", 16, 64, 128),
    ("disc_l3_8x8", 8, 128, 256),
];

fn main() {
    let budget = Duration::from_secs_f64(
        std::env::var("BENCH_BUDGET_S")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.5),
    );
    let b = 4; // minibatch
    println!("\n== Fig 8 (right) case 1: discriminator weight gradient \
              (dilated derivative maps) ==\n");
    let mut t = Table::new(&["layer", "baseline", "huge2", "speedup"]);
    for &(name, h, c, n) in DISC_LAYERS {
        let mut rng = Rng::new(h as u64);
        let x = Tensor::randn(&[b, h, h, c], &mut rng);
        let oh = (h + 4 - 5) / 2 + 1;
        let dy = Tensor::randn(&[b, oh, oh, n], &mut rng);

        let base = measure_budget(budget, || {
            std::hint::black_box(grad::weight_grad_baseline(
                &x, &dy, 5, 5, 2, 2));
        });
        let fast = measure_budget(budget, || {
            std::hint::black_box(grad::weight_grad_huge2(
                &x, &dy, 5, 5, 2, 2));
        });
        t.row(&[
            name.into(),
            fmt_dur(base.median),
            fmt_dur(fast.median),
            format!("{:.2}x", base.median_s() / fast.median_s()),
        ]);
        // correctness guard
        let a = grad::weight_grad_baseline(&x, &dy, 5, 5, 2, 2);
        let f = grad::weight_grad_huge2(&x, &dy, 5, 5, 2, 2);
        assert!(a.allclose(&f, 1e-2), "{name} diverged: {}",
                a.max_abs_diff(&f));
    }
    t.print();

    println!("\n== Fig 8 (right) case 2: generator input gradient \
              (strided convolution of derivative maps) ==\n");
    let mut t = Table::new(&["layer", "baseline", "huge2", "speedup"]);
    for &(name, h, c, n) in DISC_LAYERS {
        let mut rng = Rng::new(h as u64 + 99);
        let p = DeconvParams::new(2, 2, 1);
        let oh = (h + 4 - 5) / 2 + 1;
        let k = Tensor::randn(&[5, 5, c, n], &mut rng);
        let dy = Tensor::randn(&[b, oh, oh, n], &mut rng);

        let base = measure_budget(budget, || {
            std::hint::black_box(grad::input_grad_baseline(&dy, &k, &p));
        });
        let fast = measure_budget(budget, || {
            std::hint::black_box(grad::input_grad_huge2(&dy, &k, &p));
        });
        t.row(&[
            name.into(),
            fmt_dur(base.median),
            fmt_dur(fast.median),
            format!("{:.2}x", base.median_s() / fast.median_s()),
        ]);
    }
    t.print();
    println!("\npaper: training speedups on selected layers, same \
              decomposition/untangling machinery as inference.");
}
