//! Ablation benches for the design choices DESIGN.md calls out — beyond
//! the paper's own figures:
//!
//! 1. **Three engines** per Table-1 layer: zero-insertion baseline (the
//!    paper's Alg.-1 naive emulation), DarkNet's output-side col2im
//!    formulation (no zero-MACs, but overlapped scatter), and HUGE².
//!    Separates the zero-skipping win from the scatter/locality win.
//! 2. **Multi-core scaling** (the paper's CPU is 4-core): HUGE²'s
//!    race-free polyphase parallelism vs the baseline's GEMM-only
//!    parallelism.
//! 3. **Stride sweep**: decomposition gain vs the stride² MAC bound.
//! 4. **Batch sweep** on the native engine (serving batch economics).
//!
//! Run: `cargo bench --bench ablations`

use huge2::bench_util::{fmt_dur, measure_budget, Table};
use huge2::config::{dcgan_layers, table1};
use huge2::deconv::{baseline, col2im_baseline, huge2 as engine, parallel,
                    DeconvParams};
use huge2::gan::{Engine as GanEngine, Generator};
use huge2::rng::Rng;
use huge2::tensor::Tensor;
use std::time::Duration;

fn budget() -> Duration {
    Duration::from_secs_f64(
        std::env::var("BENCH_BUDGET_S")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0),
    )
}

fn main() {
    three_engines();
    multicore();
    stride_sweep();
    batch_sweep();
}

fn three_engines() {
    println!("\n== ablation 1: zero-insertion vs col2im vs HUGE2 ==\n");
    let mut t = Table::new(&["layer", "zero-insert", "col2im", "huge2",
                             "vs zero-ins", "vs col2im"]);
    for layer in table1() {
        let mut rng = Rng::new(layer.h as u64);
        let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
        let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                              &mut rng);
        let p = layer.deconv_params();
        let b1 = measure_budget(budget(), || {
            std::hint::black_box(baseline::conv2d_transpose(&x, &k, &p));
        });
        let b2 = measure_budget(budget(), || {
            std::hint::black_box(
                col2im_baseline::conv2d_transpose(&x, &k, &p));
        });
        let patterns = engine::decompose(&k, &p);
        let f = measure_budget(budget(), || {
            std::hint::black_box(engine::conv2d_transpose_with(
                &x, &patterns, layer.k, layer.k, &p));
        });
        t.row(&[
            layer.name.into(),
            fmt_dur(b1.median),
            fmt_dur(b2.median),
            fmt_dur(f.median),
            format!("{:.2}x", b1.median_s() / f.median_s()),
            format!("{:.2}x", b2.median_s() / f.median_s()),
        ]);
        // correctness: all three agree
        let y1 = baseline::conv2d_transpose(&x, &k, &p);
        let y2 = col2im_baseline::conv2d_transpose(&x, &k, &p);
        let y3 = engine::conv2d_transpose(&x, &k, &p);
        assert!(y1.allclose(&y3, 1e-2) && y2.allclose(&y3, 1e-2));
    }
    t.print();
    println!("(col2im does no zero-MACs — the remaining HUGE2 edge over it \
              is pure access-pattern/scatter, the §2.2 claim)");
}

fn multicore() {
    println!("\n== ablation 2: multi-core scaling (paper testbed: 4-core \
              A57) ==\n");
    let mut t = Table::new(&["layer", "threads", "baseline-mt", "huge2-mt",
                             "speedup"]);
    for layer in &dcgan_layers()[1..3] {
        let mut rng = Rng::new(layer.h as u64 + 7);
        let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
        let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                              &mut rng);
        let p = layer.deconv_params();
        let patterns = engine::decompose(&k, &p);
        for threads in [1usize, 2, 4] {
            let b = measure_budget(budget(), || {
                std::hint::black_box(
                    parallel::baseline_conv2d_transpose_mt(
                        &x, &k, &p, threads));
            });
            let f = measure_budget(budget(), || {
                std::hint::black_box(parallel::huge2_conv2d_transpose_mt(
                    &x, &patterns, layer.k, layer.k, &p, threads));
            });
            t.row(&[
                layer.name.into(),
                threads.to_string(),
                fmt_dur(b.median),
                fmt_dur(f.median),
                format!("{:.2}x", b.median_s() / f.median_s()),
            ]);
        }
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let got = parallel::huge2_conv2d_transpose_mt(&x, &patterns,
                                                      layer.k, layer.k,
                                                      &p, 4);
        assert!(got.allclose(&want, 1e-3));
    }
    t.print();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("(huge2's patterns parallelise with zero synchronisation — \
              disjoint polyphases, §3.1. This container exposes {cores} \
              core(s); thread-scaling is only observable on multi-core \
              hardware — on 1 vCPU the rows above measure threading \
              overhead, not speedup.)");
}

fn stride_sweep() {
    println!("\n== ablation 3: speedup vs stride (MAC bound = stride²) \
              ==\n");
    let mut t = Table::new(&["stride", "baseline", "huge2", "speedup",
                             "MAC bound"]);
    for stride in [2usize, 3, 4] {
        let (h, c, n) = (12, 64, 64);
        let r = 2 * stride + 1; // kernel covering every phase
        let p = DeconvParams::new(stride, stride, 1);
        let mut rng = Rng::new(stride as u64);
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let b = measure_budget(budget(), || {
            std::hint::black_box(baseline::conv2d_transpose(&x, &k, &p));
        });
        let patterns = engine::decompose(&k, &p);
        let f = measure_budget(budget(), || {
            std::hint::black_box(engine::conv2d_transpose_with(
                &x, &patterns, r, r, &p));
        });
        let (naive, eff) = engine::mac_counts(h, h, c, n, r, r, &p);
        t.row(&[
            stride.to_string(),
            fmt_dur(b.median),
            fmt_dur(f.median),
            format!("{:.2}x", b.median_s() / f.median_s()),
            format!("{:.2}x", naive as f64 / eff as f64),
        ]);
    }
    t.print();
}

fn batch_sweep() {
    println!("\n== ablation 4: native-engine batch economics ==\n");
    let gen = Generator::cgan(7);
    let mut t = Table::new(&["batch", "total", "per-image"]);
    for b in [1usize, 4, 8, 16] {
        let mut rng = Rng::new(b as u64);
        let z = Tensor::randn(&[b, 110], &mut rng);
        let m = measure_budget(budget(), || {
            std::hint::black_box(gen.forward(&z, GanEngine::Huge2));
        });
        t.row(&[
            b.to_string(),
            fmt_dur(m.median),
            fmt_dur(m.median / b as u32),
        ]);
    }
    t.print();
}
