//! End-to-end serving bench: the L3 engine over the AOT JAX/Pallas
//! artifacts, with a **batching ablation** (DESIGN.md §5 E2E-serve) and a
//! **replay-driven regression workload** (DESIGN.md §7): a recorded trace
//! re-drives the bit-identical workload every run, so throughput deltas
//! are attributable to engine changes, not workload noise.
//!
//! Measures closed-loop throughput and open-loop latency with the dynamic
//! batcher on (max_batch 8, 20 ms window) vs off (max_batch 1), plus the
//! native pure-Rust engine for reference.
//!
//! Run: `cargo bench --bench serving` (the replay section always runs;
//! the PJRT sections need `make artifacts`).

use huge2::bench_util::{fmt_dur, Table};
use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Model, Payload};
use huge2::gan::Generator;
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Machine-readable results collector: every phase that measures a
/// per-batch cost records `(phase, ns/batch, GFLOP/s, alloc B/batch)`
/// here, and `main` writes them to `BENCH_10.json` alongside the human
/// tables (0.0 = metric not applicable to that phase).
static BENCH_JSON: Mutex<Vec<(String, f64, f64, f64)>> =
    Mutex::new(Vec::new());

fn bench_record(phase: &str, ns_per_batch: f64, gflops: f64,
                alloc_b_per_batch: f64) {
    BENCH_JSON.lock().unwrap().push(
        (phase.to_string(), ns_per_batch, gflops, alloc_b_per_batch));
}

fn write_bench_json() {
    let rows = BENCH_JSON.lock().unwrap();
    let mut s = String::from("{\n");
    for (i, (phase, ns, gf, ab)) in rows.iter().enumerate() {
        s.push_str(&format!(
            "  \"{phase}\": {{\"ns_per_batch\": {ns:.0}, \
             \"gflops\": {gf:.3}, \"alloc_bytes_per_batch\": {ab:.0}}}{}\n",
            if i + 1 == rows.len() { "" } else { "," }));
    }
    s.push_str("}\n");
    match std::fs::write("BENCH_10.json", &s) {
        Ok(()) => println!("\nmachine-readable results: BENCH_10.json \
                            ({} phase(s))", rows.len()),
        Err(e) => eprintln!("\nBENCH_10.json not written: {e}"),
    }
}

/// Effective FLOPs of one generator forward (2 × HUGE² MACs: the
/// projection GEMM plus every transpose layer's pattern GEMMs).
fn gan_flops_per_image(gen: &Generator) -> f64 {
    use huge2::deconv::huge2 as engine2;
    let (zt, hid) = gen.proj.dims2();
    let mut macs = (zt * hid) as f64;
    for l in &gen.layers {
        let (_, eff) = engine2::mac_counts(
            l.cfg.h, l.cfg.h, l.cfg.c_in, l.cfg.c_out, l.cfg.k, l.cfg.k,
            &l.cfg.deconv_params());
        macs += eff as f64;
    }
    2.0 * macs
}

/// Closed-loop: `clients` threads each fire `per_client` back-to-back
/// requests; returns (throughput img/s, p50 µs, p95 µs, mean batch).
fn closed_loop(eng: &Arc<Engine>, model: &str, z_dim: usize,
               clients: usize, per_client: usize) -> (f64, u64, u64, f64) {
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let eng = eng.clone();
        let model = model.to_string();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64 + 1);
            let mut lats = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let z: Vec<f32> =
                    (0..z_dim).map(|_| rng.next_normal()).collect();
                match eng.generate(&model, z, vec![]) {
                    Ok(r) => lats.push(r.latency.as_micros() as u64),
                    Err(_) => {} // backpressure: closed loop just retries
                }
            }
            lats
        }));
    }
    let mut lats: Vec<u64> = joins
        .into_iter()
        .flat_map(|j| j.join().unwrap())
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_unstable();
    let n = lats.len().max(1);
    (
        lats.len() as f64 / wall,
        lats.get(n / 2).copied().unwrap_or(0),
        lats.get((n * 95 / 100).min(n - 1)).copied().unwrap_or(0),
        eng.counters.mean_batch_size(),
    )
}

/// Workspace-reuse phase (DESIGN.md §9): the same tiny-cGAN batch
/// workload run with a **fresh workspace per batch** (the pre-refactor
/// allocation behavior: every batch pays its scratch allocations) vs
/// **one reused workspace** (steady state: pool misses only during the
/// warmup batch). Reports allocations/batch before vs after, and
/// asserts the outputs are bit-identical.
fn workspace_reuse_phase(quick: bool) {
    use huge2::gan::Engine as GanEngine;
    use huge2::workspace::Workspace;

    let batches = if quick { 4 } else { 16 };
    let batch = 4usize;
    let gen = Generator::tiny_cgan(9);
    let mut rng = Rng::new(3);
    let zs: Vec<huge2::tensor::Tensor> = (0..batches)
        .map(|_| {
            let data: Vec<f32> =
                (0..batch * 8).map(|_| rng.next_normal()).collect();
            huge2::tensor::Tensor::from_vec(&[batch, 8], data)
        })
        .collect();

    println!("\n== workspace reuse: allocations/batch, fresh-per-batch \
              (before) vs reused pool (after) ==\n");
    let mut t = Table::new(&["mode", "batches", "alloc B/batch",
                             "miss/batch", "wall", "checksum"]);

    // before: a fresh workspace per batch — every batch re-allocates
    let mut fresh_bytes = 0u64;
    let mut fresh_misses = 0u64;
    let mut fresh_sum = 0u64;
    let t0 = Instant::now();
    for z in &zs {
        let ws = Workspace::new();
        let out = gen.forward_ws(z, GanEngine::Huge2, &mut ws.handle());
        fresh_sum ^= out.checksum();
        let c = ws.counters();
        fresh_bytes += c.bytes_allocated;
        fresh_misses += c.pool_misses;
    }
    let t_fresh = t0.elapsed();
    t.row(&[
        "fresh per batch (before)".into(),
        batches.to_string(),
        format!("{}", fresh_bytes / batches as u64),
        format!("{:.1}", fresh_misses as f64 / batches as f64),
        fmt_dur(t_fresh),
        format!("{fresh_sum:016x}"),
    ]);

    // after: one reused workspace — warmup batch allocates, rest hit
    let ws = Workspace::new();
    let mut hnd = ws.handle();
    let mut reused_sum = 0u64;
    let t0 = Instant::now();
    reused_sum ^= gen.forward_ws(&zs[0], GanEngine::Huge2, &mut hnd)
        .checksum();
    let warm = ws.counters();
    for z in &zs[1..] {
        reused_sum ^= gen.forward_ws(z, GanEngine::Huge2, &mut hnd)
            .checksum();
    }
    let t_reused = t0.elapsed();
    let steady = ws.counters();
    let steady_batches = (batches - 1).max(1) as u64;
    t.row(&[
        "reused pool (after)".into(),
        batches.to_string(),
        format!("{} (warmup {})",
                (steady.bytes_allocated - warm.bytes_allocated)
                    / steady_batches,
                warm.bytes_allocated),
        format!("{:.1}",
                (steady.pool_misses - warm.pool_misses) as f64
                    / steady_batches as f64),
        fmt_dur(t_reused),
        format!("{reused_sum:016x}"),
    ]);
    t.print();
    let gflops = gan_flops_per_image(&gen) * batch as f64;
    bench_record("workspace_fresh",
                 t_fresh.as_nanos() as f64 / batches as f64,
                 gflops * batches as f64 / t_fresh.as_nanos() as f64,
                 fresh_bytes as f64 / batches as f64);
    bench_record("workspace_reused",
                 t_reused.as_nanos() as f64 / batches as f64,
                 gflops * batches as f64 / t_reused.as_nanos() as f64,
                 (steady.bytes_allocated - warm.bytes_allocated) as f64
                     / steady_batches as f64);
    assert_eq!(fresh_sum, reused_sum,
               "pooled batches must be bit-identical to fresh");
    assert_eq!(steady.bytes_allocated, warm.bytes_allocated,
               "steady batches must not allocate");
    println!("(steady-state allocations/batch must be 0 — the \
              workspace_stack.rs regression test pins the same \
              invariant through the serving engine)");
}

/// Plan-prepack phase (DESIGN.md §10): the same tiny-cGAN batch
/// workload run with **legacy per-forward packing** — every batch
/// re-decomposes the kernels and packs the tap panels inside the
/// engine call, as a serving path without compiled plans would — vs
/// the **prepack-once compiled plan** executing through a reused
/// workspace. Reports ns/batch, B packed per batch, and workspace
/// alloc B/batch; asserts the two strategies are bit-identical.
fn plan_prepack_phase(quick: bool) {
    use huge2::deconv::huge2 as engine2;
    use huge2::gan::Engine as GanEngine;
    use huge2::plan::ExecPlan;
    use huge2::workspace::Workspace;

    let batches = if quick { 4 } else { 16 };
    let batch = 4usize;
    let gen = Generator::tiny_cgan(11);
    let mut rng = Rng::new(5);
    let zs: Vec<huge2::tensor::Tensor> = (0..batches)
        .map(|_| {
            let data: Vec<f32> =
                (0..batch * 8).map(|_| rng.next_normal()).collect();
            huge2::tensor::Tensor::from_vec(&[batch, 8], data)
        })
        .collect();
    let plan = ExecPlan::for_generator(&gen, GanEngine::Huge2);

    println!("\n== compiled plans: prepack-once vs legacy per-forward \
              packing ==\n");
    let mut t = Table::new(&["mode", "batches", "ns/batch",
                             "packed B/batch", "alloc B/batch",
                             "checksum"]);

    // legacy: the pre-plan API decomposes + packs B on every forward
    let legacy_forward = |z: &huge2::tensor::Tensor| {
        let (b, zd) = z.dims2();
        let (_, hid) = gen.proj.dims2();
        let mut cur = vec![0.0f32; b * hid];
        huge2::gemm::sgemm(b, hid, zd, z.data(), gen.proj.data(),
                           &mut cur, false);
        let f = &gen.layers[0].cfg;
        let mut x = huge2::tensor::Tensor::from_vec(
            &[b, f.h, f.h, f.c_in], cur).relu();
        let n = gen.layers.len();
        for (i, l) in gen.layers.iter().enumerate() {
            let y = engine2::conv2d_transpose(&x, &l.kernel,
                                              &l.cfg.deconv_params());
            x = if i == n - 1 { y.tanh() } else { y.relu() };
        }
        x
    };
    let mut legacy_sum = 0u64;
    let t0 = Instant::now();
    for z in &zs {
        legacy_sum ^= legacy_forward(z).checksum();
    }
    let t_legacy = t0.elapsed();
    t.row(&[
        "legacy (pack every forward)".into(),
        batches.to_string(),
        format!("{}", t_legacy.as_nanos() as u64 / batches as u64),
        plan.prepacked_bytes().to_string(),
        "fresh scratch".into(),
        format!("{legacy_sum:016x}"),
    ]);

    // plan: packed once at compile; steady batches reuse the pool
    let ws = Workspace::new();
    let mut hnd = ws.handle();
    let mut plan_sum = 0u64;
    let t0 = Instant::now();
    plan_sum ^= plan.run(&zs[0], &mut hnd).checksum();
    let warm = ws.counters();
    for z in &zs[1..] {
        plan_sum ^= plan.run(z, &mut hnd).checksum();
    }
    let t_plan = t0.elapsed();
    let steady = ws.counters();
    let steady_batches = (batches - 1).max(1) as u64;
    t.row(&[
        "plan (prepack once)".into(),
        batches.to_string(),
        format!("{}", t_plan.as_nanos() as u64 / batches as u64),
        "0".into(),
        format!("{}",
                (steady.bytes_allocated - warm.bytes_allocated)
                    / steady_batches),
        format!("{plan_sum:016x}"),
    ]);
    t.print();
    let gflops = gan_flops_per_image(&gen) * batch as f64;
    bench_record("plan_legacy_pack",
                 t_legacy.as_nanos() as f64 / batches as f64,
                 gflops * batches as f64 / t_legacy.as_nanos() as f64,
                 0.0);
    bench_record("plan_prepacked",
                 t_plan.as_nanos() as f64 / batches as f64,
                 gflops * batches as f64 / t_plan.as_nanos() as f64,
                 (steady.bytes_allocated - warm.bytes_allocated) as f64
                     / steady_batches as f64);
    assert_eq!(legacy_sum, plan_sum,
               "prepack-once plan must be bit-identical to per-forward \
                packing");
    assert_eq!(steady.bytes_allocated, warm.bytes_allocated,
               "steady plan batches must not allocate");
    println!("(plan compiled once at model load: {} prepacked bytes, \
              digest {:016x}, ws high-water {}B at batch {batch})",
             plan.prepacked_bytes(), plan.engine_digest(),
             4 * plan.high_water_elems(batch));
}

/// Instrumentation-overhead phase (DESIGN.md §12): the identical
/// closed-loop tiny-cGAN workload served twice — `instrument = false`
/// vs the default-armed observability layer (stage spans + flight
/// recorder) — reporting throughput/latency for both and the relative
/// cost. Also re-checks the zero-steady-state-allocation invariant with
/// instrumentation on: span stamping must never touch the workspace.
fn instrumentation_overhead_phase(quick: bool) {
    let per_client = if quick { 8 } else { 32 };
    let clients = 4usize;
    let run = |instrument: bool| -> (f64, u64, u64, f64) {
        let cfg = EngineConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 500,
            instrument,
            ..EngineConfig::default()
        };
        let mut eng = Engine::new(cfg);
        eng.register_native(Model::native(
            "tiny", Arc::new(Generator::tiny_cgan(13)), 0)).unwrap();
        let eng = Arc::new(eng);
        // warmup: populate the workspace pool before timing
        closed_loop(&eng, "tiny", 8, clients, 2);
        let warm = eng.workspace_counters();
        let out = closed_loop(&eng, "tiny", 8, clients, per_client);
        let steady = eng.workspace_counters();
        assert_eq!(steady.bytes_allocated, warm.bytes_allocated,
                   "instrument={instrument}: steady-state serving \
                    allocated fresh slabs");
        if instrument {
            assert!(eng.observability().flight.pushed() > 0,
                    "armed run must record span events");
        }
        out
    };

    println!("\n== observability overhead: instrument off vs on (stage \
              spans + flight recorder, DESIGN.md §12) ==\n");
    let mut t = Table::new(&["config", "img/s", "p50", "p95",
                             "mean batch"]);
    let off = run(false);
    let on = run(true);
    for (label, r) in [("instrument = false", off),
                       ("instrument = true (default)", on)] {
        t.row(&[
            label.into(),
            format!("{:.2}", r.0),
            fmt_dur(std::time::Duration::from_micros(r.1)),
            fmt_dur(std::time::Duration::from_micros(r.2)),
            format!("{:.2}", r.3),
        ]);
    }
    t.print();
    let overhead = off.0 / on.0.max(1e-9) - 1.0;
    println!("instrumentation throughput cost: {:+.1}% (armed hooks are \
              one bool branch + atomics per stage)", 100.0 * overhead);
    // lenient: span stamping is tens of ns against a forward pass of
    // hundreds of µs — double-digit overhead means a hot-path regression
    assert!(overhead < 0.10,
            "observability overhead {:.1}% exceeds the 10% budget",
            100.0 * overhead);
}

/// Recording-overhead phase (DESIGN.md §13): encode one synthetic
/// serving trace (tiny z=8 mix — arrivals, enqueues, batches,
/// responses, checkpoints every 256 events) through both codecs and
/// report bytes/event and ns/event for JSONL vs binary. Asserts the
/// binary trace is ≥4× smaller and that the binary writer's reused
/// scratch buffer stops growing after warmup (zero steady-state
/// allocations in the recording sink).
fn recording_overhead_phase(quick: bool) {
    use huge2::replay::{binary, codec, window};
    use huge2::replay::{ArrivalPayload, EventBody, TraceEvent,
                        TraceHeader};

    let target = if quick { 2_000 } else { 20_000 };
    let mut rng = Rng::new(17);
    let mut events: Vec<TraceEvent> = Vec::with_capacity(target + 64);
    let mut t_us = 0u64;
    let mut id = 1u64;
    while events.len() < target {
        // one dynamic batch: 4 arrivals+enqueues, the batch pair, then
        // the per-request responses — the shape a real serve run records
        let mut ids = Vec::with_capacity(4);
        for _ in 0..4 {
            let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
            t_us += 120;
            events.push(TraceEvent {
                t_us,
                body: EventBody::RequestArrival {
                    id,
                    model: "tiny".into(),
                    payload: ArrivalPayload::Latent { z, cond: vec![] },
                    priority: Default::default(),
                },
            });
            t_us += 3;
            events.push(TraceEvent {
                t_us,
                body: EventBody::Enqueue { id, depth: ids.len() + 1 },
            });
            ids.push(id);
            id += 1;
        }
        t_us += 40;
        events.push(TraceEvent {
            t_us,
            body: EventBody::BatchFormed { ids: ids.clone() },
        });
        t_us += 900;
        events.push(TraceEvent {
            t_us,
            body: EventBody::BatchExecuted {
                ids: ids.clone(),
                bucket: 4,
                exec_us: 900,
            },
        });
        for (k, &rid) in ids.iter().enumerate() {
            t_us += 5;
            events.push(TraceEvent {
                t_us,
                body: EventBody::Response {
                    id: rid,
                    batch_size: 4,
                    bucket: 4,
                    latency_us: 1_000 + k as u64,
                    checksum: rng.next_u64(),
                },
            });
        }
    }
    let events = window::insert_checkpoints(&events, 256);
    let n = events.len();
    let header = TraceHeader {
        model: "tiny".into(),
        backend: "native".into(),
        seed: 17,
        z_dim: 8,
        cond_dim: 0,
        task: "generate".into(),
        net: String::new(),
        engine_digest: String::new(),
        fleet: Vec::new(),
    };

    // JSONL: one heap String per event, UTF-8 decimal floats
    let t0 = Instant::now();
    let mut jsonl_bytes = 0u64;
    for e in &events {
        jsonl_bytes +=
            std::hint::black_box(codec::encode_event(e)).len() as u64 + 1;
    }
    let t_jsonl = t0.elapsed();

    // binary: one reused scratch buffer through the streaming writer.
    // Byte counts come from a counting sink; the warmup pass populates
    // the scratch, the timed pass must not grow it.
    struct CountWriter(u64);
    impl std::io::Write for CountWriter {
        fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
            self.0 += b.len() as u64;
            Ok(b.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    let mut hdr_buf = Vec::new();
    binary::encode_header_into(&mut hdr_buf, &header);
    let mut w = binary::BinaryWriter::new(CountWriter(0), &header)
        .unwrap();
    for e in &events {
        w.event(e).unwrap(); // warmup: grows scratch to the high-water
    }
    let warm_cap = w.scratch_capacity();
    let t0 = Instant::now();
    for e in &events {
        w.event(e).unwrap();
    }
    let t_bin = t0.elapsed();
    assert_eq!(w.scratch_capacity(), warm_cap,
               "binary sink scratch grew after warmup — the recording \
                path allocated in steady state");
    let total = w.finish().unwrap().0;
    let bin_bytes = (total - hdr_buf.len() as u64) / 2; // two passes

    println!("\n== recording overhead: JSONL vs binary codec ({n} \
              events, checkpoints every 256, DESIGN.md §13) ==\n");
    let mut t = Table::new(&["codec", "bytes/event", "ns/event",
                             "total"]);
    for (label, bytes, dur) in [("jsonl", jsonl_bytes, t_jsonl),
                                ("binary", bin_bytes, t_bin)] {
        t.row(&[
            label.into(),
            format!("{:.1}", bytes as f64 / n as f64),
            format!("{}", dur.as_nanos() as u64 / n as u64),
            format!("{:.1} KiB", bytes as f64 / 1024.0),
        ]);
    }
    t.print();
    let ratio = jsonl_bytes as f64 / bin_bytes.max(1) as f64;
    println!("binary is {ratio:.1}x smaller (budget: >=4x); steady-state \
              sink allocations: 0 (scratch capacity pinned at {warm_cap} \
              B)");
    assert!(jsonl_bytes >= 4 * bin_bytes,
            "binary codec misses the 4x size budget: {jsonl_bytes} \
             jsonl vs {bin_bytes} binary bytes");
}

/// Replay-driven regression entry: record one bursty native serve run,
/// then re-drive the identical workload twice in fast mode against fresh
/// engines. Divergence aborts the bench — a perf number from an engine
/// that changed its outputs is not a regression measurement.
fn replay_regression(quick: bool) {
    use huge2::replay::{Recorder, Replayer, Timing, TraceHeader,
                        TraceSink};
    use huge2::trace::bursty;

    let n = if quick { 16 } else { 64 };
    let seed = 42u64;
    let build = |sink: Option<Arc<TraceSink>>| -> Engine {
        let mut e = Engine::new(EngineConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 2_000,
            ..EngineConfig::default()
        });
        if let Some(s) = sink {
            e.set_trace_sink(s).unwrap();
        }
        let gen = Generator::tiny_cgan(seed);
        e.register_native(Model::native("tiny", Arc::new(gen), 0))
            .unwrap();
        e
    };

    println!("\n== replay-driven regression workload (record once, \
              verified replay) ==\n");
    let sink = Arc::new(TraceSink::new());
    let eng = build(Some(sink.clone()));
    let arrivals = bursty(8, 50.0, n, 7);
    let t0 = Instant::now();
    let mut rng = Rng::new(1);
    let mut pending = Vec::new();
    for a in &arrivals {
        let wait = a.at.saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        let payload = huge2::coordinator::Payload::latent(z, vec![]);
        if let Ok(rx) = eng.submit("tiny", payload) {
            pending.push(rx);
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let t_record = t0.elapsed();
    eng.shutdown();
    let rec = Recorder::from_parts(
        TraceHeader {
            model: "tiny".into(),
            backend: "native".into(),
            seed,
            z_dim: 8,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        },
        sink,
    );
    let path = std::env::temp_dir().join(format!(
        "huge2_serving_bench_{}.jsonl",
        std::process::id()
    ));
    let n_events = rec.save(&path).unwrap();

    let rp = Replayer::load(&path).unwrap();
    let mut t = Table::new(&["phase", "requests", "wall", "img/s",
                             "verified"]);
    t.row(&[
        "record (bursty, open-loop)".into(),
        arrivals.len().to_string(),
        fmt_dur(t_record),
        format!("{:.1}",
                arrivals.len() as f64 / t_record.as_secs_f64()),
        format!("{n_events} events"),
    ]);
    for run in 1..=2 {
        let eng = build(None);
        let report = rp.run(&eng, Timing::Fast).unwrap();
        eng.shutdown();
        assert!(report.is_clean(), "replay diverged: {}",
                report.first_divergence().unwrap());
        t.row(&[
            format!("replay #{run} (fast)"),
            report.requests.to_string(),
            fmt_dur(report.wall),
            format!("{:.1}",
                    report.requests as f64 / report.wall.as_secs_f64()),
            format!("{}/{} checksums", report.matched, report.compared),
        ]);
    }
    t.print();
    std::fs::remove_file(&path).ok();
    println!("(bit-identical workload each run; divergence aborts — \
              pin perf regressions to engine changes, not noise)");
}

/// Segmentation serving regression: record a native seg run, re-drive it
/// twice in fast mode — same discipline as [`replay_regression`], over
/// the dilated-conv path (image payloads, trace format v2).
fn seg_replay_regression(quick: bool) {
    use huge2::config::tiny_segnet;
    use huge2::coordinator::Payload;
    use huge2::replay::{Recorder, Replayer, Timing, TraceHeader,
                        TraceSink};
    use huge2::rng::Rng;
    use huge2::seg::SegNet;
    use huge2::tensor::Tensor;

    let n = if quick { 16 } else { 64 };
    let seed = 21u64;
    let build = |sink: Option<Arc<TraceSink>>| -> Engine {
        let mut e = Engine::new(EngineConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 2_000,
            ..EngineConfig::default()
        });
        if let Some(s) = sink {
            e.set_trace_sink(s).unwrap();
        }
        let net = Arc::new(SegNet::new(&tiny_segnet(), seed));
        e.register_native(Model::native_seg("seg", net)).unwrap();
        e
    };

    println!("\n== segmentation replay regression (image payloads, \
              trace v2) ==\n");
    // geometry from the config, not hardcoded — a tiny_segnet change
    // must not silently turn this phase into a 0-request no-op
    let in_shape = SegNet::new(&tiny_segnet(), seed).in_shape();
    let sink = Arc::new(TraceSink::new());
    let eng = build(Some(sink.clone()));
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n as u64 {
        let img_seed = 900 + i;
        let img = Tensor::randn(&in_shape, &mut Rng::new(img_seed));
        if let Ok(rx) = eng.submit("seg", Payload::image(img, img_seed)) {
            pending.push(rx);
        }
    }
    assert!(!pending.is_empty(), "no seg requests were admitted");
    for rx in pending {
        let _ = rx.recv();
    }
    let t_record = t0.elapsed();
    eng.shutdown();
    let rec = Recorder::from_parts(
        TraceHeader {
            model: "seg".into(),
            backend: "native".into(),
            seed,
            z_dim: 0,
            cond_dim: 0,
            task: "segment".into(),
            net: "tiny_segnet".into(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        },
        sink,
    );
    let path = std::env::temp_dir().join(format!(
        "huge2_seg_bench_{}.jsonl",
        std::process::id()
    ));
    let n_events = rec.save(&path).unwrap();
    println!("recorded {n} seg requests ({n_events} events) in {}",
             fmt_dur(t_record));

    let rp = Replayer::load(&path).unwrap();
    for run in 1..=2 {
        let eng = build(None);
        let report = rp.run(&eng, Timing::Fast).unwrap();
        eng.shutdown();
        assert!(report.is_clean(), "seg replay diverged: {}",
                report.first_divergence().unwrap());
        println!("replay #{run} (fast): {} requests, {}/{} checksums, {}",
                 report.requests, report.matched, report.compared,
                 fmt_dur(report.wall));
    }
    std::fs::remove_file(&path).ok();
}

/// Microkernel phase (DESIGN.md §14): single-threaded GEMM throughput
/// per available ISA tier, over a square compute-bound shape and a
/// skinny deconv-tap shape. The scalar row is the baseline every other
/// row is compared against — the "x scalar" column IS the
/// SIMD-vs-scalar speedup the dispatcher buys. Checksums double as an
/// equivalence spot-check: scalar and avx2 must match bit-for-bit
/// (avx2+fma is ulp-bounded, so its checksum may differ).
fn microkernel_phase(quick: bool) {
    use huge2::gemm::{self, Isa};

    let reps = if quick { 2 } else { 8 };
    println!("\n== GEMM microkernel: ISA dispatch (active: {}) ==\n",
             gemm::active_isa().name());
    let mut t = Table::new(&["shape", "isa", "time/rep", "GFLOP/s",
                             "x scalar", "checksum"]);
    for &(m, n, k) in &[(256usize, 256usize, 256usize), (1024, 64, 128)] {
        let mut rng = Rng::new(0x6e3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let flops = 2.0 * (m * n * k) as f64;
        let mut scalar_ns = 0.0f64;
        for isa in gemm::available_isas() {
            let mut c = vec![0.0f32; m * n];
            // warm up once so page faults and detection are off-clock
            gemm::sgemm_isa(isa, m, n, k, &a, &b, &mut c, false);
            let t0 = Instant::now();
            for _ in 0..reps {
                gemm::sgemm_isa(isa, m, n, k, &a, &b, &mut c, false);
                std::hint::black_box(&c);
            }
            let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
            if isa == Isa::Scalar {
                scalar_ns = ns;
            }
            let sum = c.iter().fold(0u64, |h, v| {
                h.wrapping_mul(0x100000001b3).wrapping_add(
                    v.to_bits() as u64)
            });
            t.row(&[
                format!("{m}x{n}x{k}"),
                isa.name().into(),
                fmt_dur(std::time::Duration::from_nanos(ns as u64)),
                format!("{:.2}", flops / ns),
                format!("{:.2}x", scalar_ns / ns),
                format!("{sum:016x}"),
            ]);
        }
    }
    t.print();
}

/// Autotuned-plan phase (DESIGN.md §15): the same batch workload run
/// under the heuristic `Auto` plan vs the memsim-scored tuned plan
/// (reference calibration, so the phase is deterministic). Reports
/// ns/batch for both — the measured heuristic-vs-tuned column of
/// BENCH_9.json — and asserts the two plans' outputs agree (allclose:
/// tuned selections may legally change FP summation order).
fn tuned_plan_phase(quick: bool) {
    use huge2::tune::{tune_plan, Calibration};
    use huge2::workspace::Workspace;

    let (gen, name) = if quick {
        (Generator::tiny_cgan(19), "tiny_cgan")
    } else {
        (Generator::dcgan(19), "dcgan")
    };
    let batches = if quick { 4 } else { 8 };
    let batch = 4usize;
    let auto = gen.plan();
    let cal = Calibration::reference();
    let art = tune_plan(auto, name, &cal);
    let tuned = art.apply(auto).expect("freshly tuned plan must apply");

    println!("\n== autotuned plan vs Auto heuristic ({name}, reference \
              calibration, DESIGN.md §15) ==\n");
    let mut rng = Rng::new(23);
    let zs: Vec<huge2::tensor::Tensor> = (0..batches)
        .map(|_| {
            let data: Vec<f32> = (0..batch * auto.in_elems())
                .map(|_| rng.next_normal())
                .collect();
            huge2::tensor::Tensor::from_vec(&[batch, auto.in_elems()],
                                            data)
        })
        .collect();
    let run = |plan: &huge2::plan::ExecPlan| {
        let ws = Workspace::new();
        let mut hnd = ws.handle();
        let mut last = plan.run(&zs[0], &mut hnd); // warmup
        let warm = ws.counters();
        let t0 = Instant::now();
        for z in &zs {
            last = plan.run(z, &mut hnd);
        }
        let wall = t0.elapsed();
        let steady = ws.counters();
        (wall, last,
         (steady.bytes_allocated - warm.bytes_allocated) as f64
             / batches as f64)
    };

    let (t_auto, out_auto, alloc_auto) = run(auto);
    let (t_tuned, out_tuned, alloc_tuned) = run(&tuned);
    let gflops = gan_flops_per_image(&gen) * batch as f64;
    let mut t = Table::new(&["plan", "ns/batch", "GFLOP/s",
                             "alloc B/batch", "digest"]);
    for (label, wall, alloc, digest) in [
        ("auto heuristic", t_auto, alloc_auto, auto.engine_digest()),
        ("tuned (memsim argmin)", t_tuned, alloc_tuned,
         tuned.engine_digest()),
    ] {
        t.row(&[
            label.into(),
            format!("{}", wall.as_nanos() as u64 / batches as u64),
            format!("{:.2}",
                    gflops * batches as f64 / wall.as_nanos() as f64),
            format!("{alloc:.0}"),
            format!("{digest:016x}"),
        ]);
    }
    t.print();
    bench_record("serve_auto",
                 t_auto.as_nanos() as f64 / batches as f64,
                 gflops * batches as f64 / t_auto.as_nanos() as f64,
                 alloc_auto);
    bench_record("serve_tuned",
                 t_tuned.as_nanos() as f64 / batches as f64,
                 gflops * batches as f64 / t_tuned.as_nanos() as f64,
                 alloc_tuned);
    println!("{} of {} step(s) re-tuned; speedup {:.2}x (ties keep the \
              heuristic, so a tuned plan is never *selected* to be \
              slower under the model)",
             art.n_differs(), art.steps.len(),
             t_auto.as_secs_f64() / t_tuned.as_secs_f64().max(1e-12));
    assert!(out_tuned.allclose(&out_auto, 1e-4),
            "tuned plan diverged from the heuristic plan's outputs");
}


/// Continuous-batching phase (DESIGN.md §16): the identical bursty
/// open-loop workload served with the windowed batcher (`continuous =
/// false`: a formed batch closes its window, later arrivals wait for
/// the next one) vs continuous batching (`continuous = true`: freed
/// batch slots are refilled from the queue immediately; carried-over
/// rows keep their original arrival anchor for EDF ordering). Outputs
/// must be bit-identical per request — batch composition is a latency
/// decision, never a numerics decision.
fn continuous_batching_phase(quick: bool) {
    use huge2::trace::bursty;

    let n = if quick { 16 } else { 64 };
    let seed = 31u64;
    let run = |continuous: bool| -> (f64, u64, u64, f64, Vec<u64>) {
        let mut eng = Engine::new(EngineConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 2_000,
            continuous,
            ..EngineConfig::default()
        });
        eng.register_native(Model::native(
            "tiny", Arc::new(Generator::tiny_cgan(seed)), 0)).unwrap();
        let eng = Arc::new(eng);
        let arrivals = bursty(8, 50.0, n, 7);
        let t0 = Instant::now();
        let mut rng = Rng::new(1);
        let mut pending = Vec::new();
        for a in &arrivals {
            let wait = a.at.saturating_sub(t0.elapsed());
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
            let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
            if let Ok(rx) = eng.submit("tiny", Payload::latent(z, vec![]))
            {
                pending.push(rx);
            }
        }
        let mut lats = Vec::new();
        let mut sums = Vec::new();
        for rx in pending {
            if let Ok(Ok(r)) = rx.recv() {
                lats.push(r.latency.as_micros() as u64);
                sums.push(r.output.checksum());
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mb = eng.counters.mean_batch_size();
        lats.sort_unstable();
        let len = lats.len().max(1);
        (lats.len() as f64 / wall,
         lats.get(len / 2).copied().unwrap_or(0),
         lats.get((len * 95 / 100).min(len - 1)).copied().unwrap_or(0),
         mb, sums)
    };

    println!("\n== continuous vs windowed batching (bursty open loop, \
              DESIGN.md §16) ==\n");
    let mut t = Table::new(&["batcher", "img/s", "p50", "p95",
                             "mean batch"]);
    let (w_thr, w_p50, w_p95, w_mb, w_sums) = run(false);
    let (c_thr, c_p50, c_p95, c_mb, c_sums) = run(true);
    for (label, thr, p50, p95, mb) in [
        ("windowed (continuous = false)", w_thr, w_p50, w_p95, w_mb),
        ("continuous (default)", c_thr, c_p50, c_p95, c_mb),
    ] {
        t.row(&[
            label.into(),
            format!("{thr:.2}"),
            fmt_dur(std::time::Duration::from_micros(p50)),
            fmt_dur(std::time::Duration::from_micros(p95)),
            format!("{mb:.2}"),
        ]);
    }
    t.print();
    bench_record("batch_windowed", 1e9 / w_thr.max(1e-9), 0.0, 0.0);
    bench_record("batch_continuous", 1e9 / c_thr.max(1e-9), 0.0, 0.0);
    // same submit order + same weights: the k-th request must produce
    // the same image regardless of how batches were composed
    assert_eq!(w_sums, c_sums,
               "continuous batching changed request outputs — batch \
                composition must be numerics-invariant");
    println!("(ns/request recorded to BENCH_10.json; continuous refill \
              should close the gap bursty windows leave open)");
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let per_client = if quick { 2 } else { 6 };

    microkernel_phase(quick);
    workspace_reuse_phase(quick);
    plan_prepack_phase(quick);
    tuned_plan_phase(quick);
    instrumentation_overhead_phase(quick);
    recording_overhead_phase(quick);
    continuous_batching_phase(quick);
    replay_regression(quick);
    seg_replay_regression(quick);
    write_bench_json();

    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("\nPJRT serving sections need artifacts: run \
                   `make artifacts`");
        return;
    }

    println!("\n== E2E serving: DCGAN generator (PJRT, JAX/Pallas HUGE2 \
              kernels, interpret-mode CPU) ==\n");
    let mut t = Table::new(&["config", "throughput img/s", "p50", "p95",
                             "mean batch"]);

    // bucket 4 is the throughput-optimal compiled batch on this backend
    // (measured: b1 0.60 s/img, b4 0.30 s/img, b8 0.36 s/img)
    for (label, max_batch, timeout_us, buckets) in [
        ("batching OFF (b=1)", 1usize, 1u64, vec![1usize]),
        ("batching ON (b≤4, 20ms)", 4, 20_000, vec![1, 4]),
    ] {
        let cfg = EngineConfig {
            workers: 1,
            max_batch,
            batch_timeout_us: timeout_us,
            batch_buckets: buckets,
            ..EngineConfig::default()
        };
        let rt = Arc::new(RuntimeHandle::spawn(dir.clone()).unwrap());
        let mut eng = Engine::new(cfg);
        eng.register_pjrt("dcgan", "dcgan_gen", rt, 1, 7).unwrap();
        let eng = Arc::new(eng);
        let (thr, p50, p95, mb) =
            closed_loop(&eng, "dcgan", 100, 4, per_client);
        t.row(&[
            label.into(),
            format!("{thr:.2}"),
            fmt_dur(std::time::Duration::from_micros(p50)),
            fmt_dur(std::time::Duration::from_micros(p95)),
            format!("{mb:.2}"),
        ]);
    }

    // native pure-rust engine reference (cGAN geometry for speed)
    {
        let cfg = EngineConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 2_000,
            ..EngineConfig::default()
        };
        let mut eng = Engine::new(cfg);
        let gen = Arc::new(Generator::cgan(7));
        eng.register_native(Model::native("cgan", gen, 10)).unwrap();
        let eng = Arc::new(eng);
        // conditioned requests need cond one-hots — use generate directly
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..4u64 {
            let eng = eng.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(c + 50);
                for _ in 0..per_client {
                    let z: Vec<f32> =
                        (0..100).map(|_| rng.next_normal()).collect();
                    let mut y = vec![0.0f32; 10];
                    y[rng.next_below(10)] = 1.0;
                    eng.generate("cgan", z, y).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        t.row(&[
            "native rust cGAN (ref)".into(),
            format!("{:.2}", (4 * per_client) as f64 / wall),
            format!("{}", eng.exec_hist.summary().split(' ').next()
                    .unwrap_or("")),
            "-".into(),
            format!("{:.2}", eng.counters.mean_batch_size()),
        ]);
    }
    t.print();
    println!("\n(batching ON should beat OFF on throughput; PJRT numbers \
              are interpret-mode Pallas on CPU — structural, not TPU \
              wallclock. Native row is the pure-rust HUGE2 engine.)");
}
