//! End-to-end serving bench: the L3 engine over the AOT JAX/Pallas
//! artifacts, with a **batching ablation** (DESIGN.md §5 E2E-serve).
//!
//! Measures closed-loop throughput and open-loop latency with the dynamic
//! batcher on (max_batch 8, 20 ms window) vs off (max_batch 1), plus the
//! native pure-Rust engine for reference.
//!
//! Run: `cargo bench --bench serving` (needs `make artifacts`).

use huge2::bench_util::{fmt_dur, Table};
use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Model};
use huge2::gan::Generator;
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use std::sync::Arc;
use std::time::Instant;

/// Closed-loop: `clients` threads each fire `per_client` back-to-back
/// requests; returns (throughput img/s, p50 µs, p95 µs, mean batch).
fn closed_loop(eng: &Arc<Engine>, model: &str, z_dim: usize,
               clients: usize, per_client: usize) -> (f64, u64, u64, f64) {
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let eng = eng.clone();
        let model = model.to_string();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64 + 1);
            let mut lats = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let z: Vec<f32> =
                    (0..z_dim).map(|_| rng.next_normal()).collect();
                match eng.generate(&model, z, vec![]) {
                    Ok(r) => lats.push(r.latency.as_micros() as u64),
                    Err(_) => {} // backpressure: closed loop just retries
                }
            }
            lats
        }));
    }
    let mut lats: Vec<u64> = joins
        .into_iter()
        .flat_map(|j| j.join().unwrap())
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_unstable();
    let n = lats.len().max(1);
    (
        lats.len() as f64 / wall,
        lats.get(n / 2).copied().unwrap_or(0),
        lats.get((n * 95 / 100).min(n - 1)).copied().unwrap_or(0),
        eng.counters.mean_batch_size(),
    )
}

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("serving bench needs artifacts: run `make artifacts`");
        return;
    }
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let per_client = if quick { 2 } else { 6 };

    println!("\n== E2E serving: DCGAN generator (PJRT, JAX/Pallas HUGE2 \
              kernels, interpret-mode CPU) ==\n");
    let mut t = Table::new(&["config", "throughput img/s", "p50", "p95",
                             "mean batch"]);

    // bucket 4 is the throughput-optimal compiled batch on this backend
    // (measured: b1 0.60 s/img, b4 0.30 s/img, b8 0.36 s/img)
    for (label, max_batch, timeout_us, buckets) in [
        ("batching OFF (b=1)", 1usize, 1u64, vec![1usize]),
        ("batching ON (b≤4, 20ms)", 4, 20_000, vec![1, 4]),
    ] {
        let cfg = EngineConfig {
            workers: 1,
            max_batch,
            batch_timeout_us: timeout_us,
            batch_buckets: buckets,
            ..EngineConfig::default()
        };
        let rt = Arc::new(RuntimeHandle::spawn(dir.clone()).unwrap());
        let mut eng = Engine::new(cfg);
        eng.register_pjrt("dcgan", "dcgan_gen", rt, 1, 7).unwrap();
        let eng = Arc::new(eng);
        let (thr, p50, p95, mb) =
            closed_loop(&eng, "dcgan", 100, 4, per_client);
        t.row(&[
            label.into(),
            format!("{thr:.2}"),
            fmt_dur(std::time::Duration::from_micros(p50)),
            fmt_dur(std::time::Duration::from_micros(p95)),
            format!("{mb:.2}"),
        ]);
    }

    // native pure-rust engine reference (cGAN geometry for speed)
    {
        let cfg = EngineConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 2_000,
            ..EngineConfig::default()
        };
        let mut eng = Engine::new(cfg);
        let gen = Arc::new(Generator::cgan(7));
        eng.register_native(Model::native("cgan", gen, 10)).unwrap();
        let eng = Arc::new(eng);
        // conditioned requests need cond one-hots — use generate directly
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..4u64 {
            let eng = eng.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(c + 50);
                for _ in 0..per_client {
                    let z: Vec<f32> =
                        (0..100).map(|_| rng.next_normal()).collect();
                    let mut y = vec![0.0f32; 10];
                    y[rng.next_below(10)] = 1.0;
                    eng.generate("cgan", z, y).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        t.row(&[
            "native rust cGAN (ref)".into(),
            format!("{:.2}", (4 * per_client) as f64 / wall),
            format!("{}", eng.exec_hist.summary().split(' ').next()
                    .unwrap_or("")),
            "-".into(),
            format!("{:.2}", eng.counters.mean_batch_size()),
        ]);
    }
    t.print();
    println!("\n(batching ON should beat OFF on throughput; PJRT numbers \
              are interpret-mode Pallas on CPU — structural, not TPU \
              wallclock. Native row is the pure-rust HUGE2 engine.)");
}
