//! Reproduces **Figure 7 (right)**: per-layer inference speedup of the
//! HUGE² engine over the DarkNet-style naive baseline on CPU, for every
//! Table-1 layer of DCGAN and cGAN.
//!
//! Paper claim: ~5× on a 4-core Cortex-A57; shallower layers are more
//! compute-bound (speedup tracks the 4× MAC reduction + GEMM efficiency),
//! deeper layers gain more from the memory side.
//!
//! Run: `cargo bench --bench fig7_speedup`

use huge2::bench_util::{fmt_dur, measure_budget, Table};
use huge2::config::table1;
use huge2::deconv::{baseline, huge2 as engine};
use huge2::rng::Rng;
use huge2::tensor::Tensor;
use std::time::Duration;

fn main() {
    let budget = Duration::from_secs_f64(
        std::env::var("BENCH_BUDGET_S")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2.0),
    );
    println!("\n== Fig 7 (right): CPU inference speedup, batch 1 ==");
    println!("(budget {}s/engine/layer; median of adaptive samples)\n",
             budget.as_secs_f64());

    let mut table = Table::new(&["layer", "gan", "baseline", "huge2",
                                 "speedup", "paper(≈)"]);
    let mut geo = 1.0f64;
    let mut count = 0;
    for layer in table1() {
        let mut rng = Rng::new(layer.h as u64 * 31 + layer.c_in as u64);
        let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
        let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                              &mut rng);
        let p = layer.deconv_params();

        let base = measure_budget(budget, || {
            std::hint::black_box(baseline::conv2d_transpose(&x, &k, &p));
        });
        // model-load-time decomposition excluded (serving engines
        // decompose once) — same treatment as the baseline's weights
        let patterns = engine::decompose(&k, &p);
        let fast = measure_budget(budget, || {
            std::hint::black_box(engine::conv2d_transpose_with(
                &x, &patterns, layer.k, layer.k, &p));
        });

        let speedup = base.median_s() / fast.median_s();
        geo *= speedup;
        count += 1;
        table.row(&[
            layer.name.into(),
            layer.gan.into(),
            fmt_dur(base.median),
            fmt_dur(fast.median),
            format!("{speedup:.2}x"),
            "3-6x".into(),
        ]);
    }
    table.print();
    println!("\ngeometric-mean speedup: {:.2}x  (paper: ~5x on 4-core \
              Cortex-A57)", geo.powf(1.0 / count as f64));

    // correctness guard: a bench that silently diverges is worthless
    let layer = &table1()[2];
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
    let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                          &mut rng);
    let p = layer.deconv_params();
    let a = baseline::conv2d_transpose(&x, &k, &p);
    let b = engine::conv2d_transpose(&x, &k, &p);
    assert!(a.allclose(&b, 1e-3), "engines diverged: {}",
            a.max_abs_diff(&b));
    println!("correctness: engines agree (max |Δ| = {:.2e})",
             a.max_abs_diff(&b));
}
