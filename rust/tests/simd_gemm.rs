//! SIMD-vs-scalar GEMM equivalence grid (DESIGN.md §14).
//!
//! The micro-kernel dispatches per ISA tier at runtime, so every tier
//! must agree with the portable scalar kernel on the same shape sweep
//! the deconv engines exercise:
//!
//! * `Avx2` (mul+add) is **bit-identical** to `Scalar` — same
//!   per-element rounding in the same k-order, checked with `assert_eq`
//!   on the raw f32 bits.
//! * `Avx2Fma` contracts each multiply-add to a single rounding, so it
//!   is only **ulp-bounded** against scalar; checked against a naive
//!   triple loop with the house `tol * sqrt(k)` error model.
//!
//! On hosts without AVX2 the vector cases skip (scalar is always
//! available and is trivially identical to itself).

use huge2::gemm::{self, Isa};
use huge2::rng::Rng;

/// Shape sweep: micro-tile boundaries (MR=4, NR=16), macro-tile
/// boundaries (MC=128, NC=1024 is too big to sweep — KC=256 captures
/// the k-blocking), plus engine-style skinny/ragged shapes from the
/// DCGAN/CGAN tap GEMMs.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),       // degenerate
    (4, 16, 8),      // exactly one full micro-tile
    (3, 15, 8),      // pure edge tile
    (5, 17, 9),      // full tile + 1-wide edges on both axes
    (8, 32, 256),    // KC boundary, all full tiles
    (131, 37, 259),  // MC/KC boundaries + ragged edges
    (64, 128, 100),  // dcgan-ish tap GEMM (ho*wo x c_out, k=c_in)
    (256, 3, 128),   // skinny-N (few output channels)
    (2, 200, 33),    // skinny-M (tiny spatial, wide channels)
];

fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Naive ijk triple loop — the rounding-order-free reference for the
/// tolerance-bounded comparisons.
fn naive(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

fn run(isa: Isa, m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
       accumulate: bool, seed_c: &[f32]) -> Vec<f32> {
    let mut c = seed_c.to_vec();
    gemm::sgemm_isa(isa, m, n, k, a, b, &mut c, accumulate);
    c
}

#[test]
fn avx2_bit_identical_to_scalar_across_grid() {
    if !gemm::available_isas().contains(&Isa::Avx2) {
        eprintln!("skip: no AVX2 on this host");
        return;
    }
    let mut rng = Rng::new(0x513d);
    for &(m, n, k) in SHAPES {
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        for accumulate in [false, true] {
            let seed: Vec<f32> = fill(&mut rng, m * n);
            let cs = run(Isa::Scalar, m, n, k, &a, &b, accumulate, &seed);
            let cv = run(Isa::Avx2, m, n, k, &a, &b, accumulate, &seed);
            // bit-exact: compare raw bits, not within-epsilon
            for (i, (s, v)) in cs.iter().zip(&cv).enumerate() {
                assert_eq!(s.to_bits(), v.to_bits(),
                           "{m}x{n}x{k} acc={accumulate} elem {i}: \
                            scalar {s} vs avx2 {v}");
            }
        }
    }
}

#[test]
fn fma_tier_is_ulp_bounded_against_naive() {
    if !gemm::available_isas().contains(&Isa::Avx2Fma) {
        eprintln!("skip: no AVX2+FMA on this host");
        return;
    }
    let mut rng = Rng::new(0xf31a);
    for &(m, n, k) in SHAPES {
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let reference = naive(m, n, k, &a, &b);
        let zeros = vec![0.0f32; m * n];
        let cf = run(Isa::Avx2Fma, m, n, k, &a, &b, false, &zeros);
        let cs = run(Isa::Scalar, m, n, k, &a, &b, false, &zeros);
        let tol = 1e-5 * (k as f32).sqrt();
        for i in 0..m * n {
            assert!((cf[i] - reference[i]).abs() < tol,
                    "{m}x{n}x{k} fma elem {i}: {} vs naive {}",
                    cf[i], reference[i]);
            // FMA drops one rounding per multiply-add, so it must sit
            // at least as close to scalar as the blanket tolerance
            assert!((cf[i] - cs[i]).abs() < tol,
                    "{m}x{n}x{k} fma-vs-scalar elem {i}");
        }
    }
}

#[test]
fn every_available_tier_matches_naive() {
    let mut rng = Rng::new(0xa55a);
    for isa in gemm::available_isas() {
        for &(m, n, k) in SHAPES {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let zeros = vec![0.0f32; m * n];
            let c = run(isa, m, n, k, &a, &b, false, &zeros);
            let reference = naive(m, n, k, &a, &b);
            let tol = 1e-4 * (k as f32).sqrt().max(1.0);
            for i in 0..m * n {
                assert!((c[i] - reference[i]).abs() < tol,
                        "{} {m}x{n}x{k} elem {i}: {} vs {}",
                        isa.name(), c[i], reference[i]);
            }
        }
    }
}

/// The thread-sweep the deconv engines use runs ISA dispatch through
/// the pooled prepacked path — pin that every tier agrees there too,
/// bit-exactly for the non-FMA tiers (the engines rely on this for the
/// plan-vs-legacy bit-identity grid).
#[test]
fn prepacked_path_matches_flat_path_per_tier() {
    let mut rng = Rng::new(0x9ac4);
    for &(m, n, k) in &[(5, 17, 9), (64, 128, 100), (131, 37, 259)] {
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let pb = gemm::PackedB::pack(k, n, &b);
        let ws = huge2::workspace::Workspace::new();
        let mut hnd = ws.handle();
        let mut c_pre = vec![0.0f32; m * n];
        gemm::sgemm_prepacked_with(&mut hnd, m, &a, k, &pb,
                                   &mut c_pre, false);
        let mut c_flat = vec![0.0f32; m * n];
        gemm::sgemm_isa(gemm::active_isa(), m, n, k, &a, &b,
                        &mut c_flat, false);
        for i in 0..m * n {
            assert_eq!(c_pre[i].to_bits(), c_flat[i].to_bits(),
                       "{m}x{n}x{k} elem {i}: prepacked {} vs flat {}",
                       c_pre[i], c_flat[i]);
        }
    }
}
