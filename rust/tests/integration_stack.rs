//! Integration tests across the full stack: config → gan → deconv →
//! runtime → coordinator. PJRT-dependent tests skip gracefully when
//! `make artifacts` hasn't run (CI without python).

use huge2::config::{dcgan_layers, table1, EngineConfig, LayerConfig};
use huge2::coordinator::Engine;
use huge2::deconv::{baseline, grad, huge2 as engine};
use huge2::gan::{Discriminator, Engine as GanEngine, Generator};
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use huge2::tensor::Tensor;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Shrink a Table-1 stack's channels by `f`, keeping geometry + chaining.
fn shrink(layers: Vec<LayerConfig>, f: usize) -> Vec<LayerConfig> {
    let mut out: Vec<LayerConfig> = Vec::new();
    for l in layers {
        let c_in = out.last().map(|p: &LayerConfig| p.c_out)
            .unwrap_or_else(|| (l.c_in / f).max(1));
        let c_out = if l.c_out <= 3 { l.c_out } else { (l.c_out / f).max(1) };
        out.push(LayerConfig { c_in, c_out, ..l });
    }
    out
}

#[test]
fn every_table1_layer_agrees_across_engines() {
    // full-geometry, channel-shrunk sweep of every Table-1 row
    for layer in table1() {
        let c = (layer.c_in / 16).max(1);
        let n = if layer.c_out <= 3 { layer.c_out }
                else { (layer.c_out / 16).max(1) };
        let mut rng = Rng::new(layer.h as u64);
        let x = Tensor::randn(&[1, layer.h, layer.h, c], &mut rng);
        let k = Tensor::randn(&[layer.k, layer.k, c, n], &mut rng);
        let p = layer.deconv_params();
        let a = baseline::conv2d_transpose(&x, &k, &p);
        let b = engine::conv2d_transpose(&x, &k, &p);
        assert_eq!(a.shape(), &[1, layer.h_out(), layer.h_out(), n],
                   "{}", layer.name);
        assert!(a.allclose(&b, 1e-3), "{}: {}", layer.name,
                a.max_abs_diff(&b));
    }
}

#[test]
fn full_dcgan_pipeline_generates_valid_images() {
    let gen = Generator::new(shrink(dcgan_layers(), 16), 32, 0,
                             &mut Rng::new(5));
    let mut rng = Rng::new(6);
    let z = Tensor::randn(&[2, 32], &mut rng);
    let img = gen.forward(&z, GanEngine::Huge2);
    assert_eq!(img.shape(), &[2, 64, 64, 3]);
    assert!(img.data().iter().all(|v| v.is_finite() && v.abs() <= 1.0));
    // and the discriminator consumes what the generator produces (32x32)
    let d = Discriminator::new(&[3, 8, 16, 32], &mut rng);
    let img32 = Tensor::randn(&[2, 32, 32, 3], &mut rng).tanh();
    let (logits, _) = d.forward(&img32);
    assert_eq!(logits.shape(), &[2, 1]);
}

#[test]
fn training_grads_compose_with_forward() {
    // one manual SGD step on a conv layer decreases the loss
    let mut rng = Rng::new(8);
    let (st, pad) = (2, 2);
    let x = Tensor::randn(&[2, 8, 8, 3], &mut rng);
    let mut k = Tensor::randn(&[5, 5, 3, 4], &mut rng).scale(0.1);
    let target = Tensor::randn(&[2, 4, 4, 4], &mut rng);
    let loss = |k: &Tensor| -> f32 {
        let y = baseline::conv2d(&x, k, st, pad);
        y.sub(&target).data().iter().map(|d| d * d).sum::<f32>()
    };
    let l0 = loss(&k);
    for _ in 0..5 {
        let y = baseline::conv2d(&x, &k, st, pad);
        let dy = y.sub(&target).scale(2.0);
        let g = grad::weight_grad_huge2(&x, &dy, 5, 5, st, pad);
        k = k.sub(&g.scale(1e-3));
    }
    let l1 = loss(&k);
    assert!(l1 < l0, "SGD with huge2 gradients must descend: {l0} -> {l1}");
}

#[test]
fn pjrt_generator_matches_native_generator_shapes() {
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(RuntimeHandle::spawn(dir).unwrap());
    let mut eng = Engine::new(EngineConfig {
        workers: 1,
        max_batch: 4,
        batch_timeout_us: 1000,
        batch_buckets: vec![1, 4],
        ..EngineConfig::default()
    });
    eng.register_pjrt("dcgan", "dcgan_gen", rt, 1, 7).unwrap();
    let mut rng = Rng::new(9);
    let z: Vec<f32> = (0..100).map(|_| rng.next_normal()).collect();
    let r = eng.generate("dcgan", z, vec![]).unwrap();
    assert_eq!(r.output.shape(), &[1, 64, 64, 3]);
    assert!(r.output.data().iter().all(|v| v.abs() <= 1.0));
    eng.shutdown();
}

#[test]
fn pjrt_cgan_conditioning_round_trip() {
    let Some(dir) = artifacts() else { return };
    let rt = Arc::new(RuntimeHandle::spawn(dir).unwrap());
    let mut eng = Engine::new(EngineConfig {
        workers: 1,
        max_batch: 4,
        batch_timeout_us: 1000,
        batch_buckets: vec![1, 4],
        ..EngineConfig::default()
    });
    eng.register_pjrt("cgan", "cgan_gen", rt, 2, 11).unwrap();
    let mut rng = Rng::new(10);
    let z: Vec<f32> = (0..100).map(|_| rng.next_normal()).collect();
    let mut y = vec![0.0f32; 10];
    y[3] = 1.0;
    let r = eng.generate("cgan", z.clone(), y).unwrap();
    assert_eq!(r.output.shape(), &[1, 32, 32, 3]);
    // different class -> different image (conditioning actually wired)
    let mut y2 = vec![0.0f32; 10];
    y2[7] = 1.0;
    let r2 = eng.generate("cgan", z, y2).unwrap();
    assert!(r.output.max_abs_diff(&r2.output) > 1e-6,
            "conditioning must affect the output");
    eng.shutdown();
}

#[test]
fn pjrt_train_step_decreases_d_loss() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::spawn(dir).unwrap();
    let mut params = rt.run("tiny_gan_init", vec![]).unwrap();
    let mut rng = Rng::new(12);
    let mut first_d = None;
    let mut last_d = 0.0;
    for _ in 0..8 {
        let z: Vec<f32> =
            (0..16 * 32).map(|_| rng.next_normal()).collect();
        let real = Tensor::randn(&[16, 32, 32, 3], &mut rng).tanh();
        let mut inputs = params.clone();
        inputs.push(Tensor::from_vec(&[16, 32], z));
        inputs.push(real);
        let mut out = rt.run("tiny_gan_step", inputs).unwrap();
        let loss_d = out.pop().unwrap().data()[0];
        let _loss_g = out.pop().unwrap();
        params = out;
        assert!(loss_d.is_finite());
        first_d.get_or_insert(loss_d);
        last_d = loss_d;
    }
    assert!(last_d < first_d.unwrap(),
            "D loss should fall: {:?} -> {last_d}", first_d.unwrap());
}
