//! Record → replay integration tests plus codec property tests
//! (hand-rolled, seeded via `rng::Rng` — proptest is not in the vendor
//! set).
//!
//! * codec: encode→decode == identity over randomized event streams,
//!   including adversarial strings and raw-bit floats (NaNs included).
//! * integration: a recorded native-engine serve run replays in fast
//!   mode with zero divergence; tampering with the trace (checksum bit,
//!   latent bit, malformed line) is detected and names the first
//!   mismatching event.

use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Model, Payload, Priority};
use huge2::gan::Generator;
use huge2::replay::{codec, ArrivalPayload, Divergence, EventBody,
                    Replayer, Timing, TraceEvent, TraceHeader, TraceSink};
use huge2::rng::Rng;
use std::sync::Arc;

const Z_DIM: usize = 8;

/// Tiny native engine (cGAN geometry at 1/8 channels — fast on CPU),
/// bit-reproducible from `seed`.
fn tiny_engine(seed: u64, sink: Option<Arc<TraceSink>>) -> Engine {
    tiny_engine_depth(seed, sink, 64)
}

fn tiny_engine_depth(seed: u64, sink: Option<Arc<TraceSink>>,
                     queue_depth: usize) -> Engine {
    let cfg = EngineConfig {
        workers: 2,
        queue_depth,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    if let Some(s) = sink {
        e.set_trace_sink(s).unwrap();
    }
    let gen = Generator::tiny_cgan(seed);
    assert_eq!(gen.z_dim, Z_DIM);
    e.register_native(Model::native("tiny", Arc::new(gen), 0)).unwrap();
    e
}

fn header(seed: u64) -> TraceHeader {
    TraceHeader {
        model: "tiny".into(),
        backend: "native".into(),
        seed,
        z_dim: Z_DIM,
        cond_dim: 0,
        task: "generate".into(),
        net: String::new(),
        engine_digest: String::new(),
        fleet: Vec::new(),
    }
}

/// Record a serve run of `n` requests; returns the captured events.
fn record_run(seed: u64, n: usize) -> Vec<TraceEvent> {
    let sink = Arc::new(TraceSink::new());
    let eng = tiny_engine(seed, Some(sink.clone()));
    let mut rng = Rng::new(1234);
    let mut pending = Vec::new();
    for _ in 0..n {
        let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
        pending.push(eng.submit("tiny", Payload::latent(z, vec![]))
            .unwrap());
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    eng.shutdown();
    sink.snapshot()
}

#[test]
fn record_then_fast_replay_is_divergence_free() {
    let events = record_run(5, 24);
    let responses = events
        .iter()
        .filter(|e| matches!(e.body, EventBody::Response { .. }))
        .count();
    assert_eq!(responses, 24, "recording must capture every response");

    let rp = Replayer::from_parts(header(5), events);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.requests, 24);
    assert_eq!(report.compared, 24);
    assert_eq!(report.matched, 24);
    assert_eq!(report.extra_responses, 0);
}

#[test]
fn fast_replay_survives_tiny_queue_backpressure() {
    // recorded against a deep queue; replayed flat-out against a 2-deep
    // queue — the replayer must absorb backpressure by draining, not
    // report deterministic requests as missing
    let events = record_run(5, 24);
    let rp = Replayer::from_parts(header(5), events);
    let eng = tiny_engine_depth(5, None, 2);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, 24);
}

#[test]
fn faithful_replay_is_also_divergence_free() {
    // back-to-back recording ⇒ near-zero recorded offsets, so faithful
    // pacing stays fast enough for a unit test while exercising the path
    let events = record_run(9, 8);
    let rp = Replayer::from_parts(header(9), events);
    let eng = tiny_engine(9, None);
    let report = rp.run(&eng, Timing::Faithful).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, 8);
}

#[test]
fn replay_against_wrong_weights_diverges() {
    let events = record_run(5, 6);
    let rp = Replayer::from_parts(header(5), events);
    let eng = tiny_engine(6, None); // different weight seed
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(!report.is_clean(),
            "different weights must not reproduce checksums");
    assert!(matches!(report.first_divergence().unwrap(),
                     Divergence::ChecksumMismatch { .. }));
}

#[test]
fn tampered_checksum_names_first_mismatching_event() {
    let mut events = record_run(5, 8);
    let (idx, tampered_id) = events
        .iter()
        .enumerate()
        .find_map(|(i, e)| match &e.body {
            EventBody::Response { id, .. } => Some((i, *id)),
            _ => None,
        })
        .expect("recording has responses");
    if let EventBody::Response { checksum, .. } = &mut events[idx].body {
        *checksum ^= 1; // single-bit tamper
    }

    let rp = Replayer::from_parts(header(5), events);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    let d = report.first_divergence().expect("tamper must be detected");
    match d {
        Divergence::ChecksumMismatch { event_index, id, recorded,
                                       replayed } => {
            assert_eq!(*event_index, idx);
            assert_eq!(*id, tampered_id);
            assert_eq!(recorded ^ replayed, 1);
        }
        other => panic!("expected ChecksumMismatch, got {other:?}"),
    }
    // the report names the event a CLI user can find in the file
    assert!(d.to_string().contains(&format!("event #{idx}")),
            "{d}");
}

#[test]
fn tampered_latent_changes_the_output() {
    let mut events = record_run(5, 6);
    for e in &mut events {
        if let EventBody::RequestArrival {
            payload: ArrivalPayload::Latent { z, .. }, ..
        } = &mut e.body
        {
            z[0] += 0.5;
            break;
        }
    }
    let rp = Replayer::from_parts(header(5), events);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(!report.is_clean(),
            "a perturbed latent must fail checksum verification");
}

#[test]
fn truncated_latent_surfaces_as_typed_validation_divergence() {
    let mut events = record_run(5, 4);
    let mut victim = None;
    for e in &mut events {
        if let EventBody::RequestArrival {
            id, payload: ArrivalPayload::Latent { z, .. }, ..
        } = &mut e.body
        {
            z.pop(); // now fails Model::validate on replay
            victim = Some(*id);
            break;
        }
    }
    let victim = victim.unwrap();
    let rp = Replayer::from_parts(header(5), events);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    // the recording answered this id; the replay's typed validation
    // reject is its outcome — and the divergence names the kind
    assert!(report
        .divergences
        .iter()
        .any(|d| matches!(d, Divergence::ResponseBecameFailure {
                              id, kind, .. }
                          if *id == victim && kind == "validation")),
            "divergences: {:?}", report.divergences);
}

/// Failure determinism (trace v3): a trace that records a typed
/// failure replays cleanly iff the replay fails the same request with
/// the same kind — here a latent that deterministically fails
/// validation, paired with its recorded `Failed` event.
#[test]
fn recorded_failure_kind_verifies_on_replay() {
    let bad_arrival = |id: u64, t_us: u64| TraceEvent {
        t_us,
        body: EventBody::RequestArrival {
            id,
            model: "tiny".into(),
            payload: ArrivalPayload::Latent {
                z: vec![0.0; Z_DIM - 1], // wrong width: always rejected
                cond: vec![],
            },
            priority: Priority::default(),
        },
    };
    let failed = |id: u64, t_us: u64, kind: &str| TraceEvent {
        t_us,
        body: EventBody::Failed {
            id,
            kind: kind.into(),
            reason: "recorded failure".into(),
        },
    };

    // matching kind → clean, and the failure counts as verified
    let rp = Replayer::from_parts(
        header(5), vec![bad_arrival(0, 0), failed(0, 1, "validation")]);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!((report.compared, report.matched), (1, 1));

    // different recorded kind → FailureMismatch naming both sides
    let rp = Replayer::from_parts(
        header(5), vec![bad_arrival(0, 0), failed(0, 1, "batch_failed")]);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert_eq!(report.divergences.len(), 1);
    match &report.divergences[0] {
        Divergence::FailureMismatch { recorded_kind, replayed, .. } => {
            assert_eq!(recorded_kind, "batch_failed");
            assert_eq!(replayed, "validation");
        }
        other => panic!("expected FailureMismatch, got {other:?}"),
    }

    // a request the recording *rejected at submit* (Reject event, no
    // terminal outcome) that the replay also refuses is agreement —
    // clean, and NOT reported as an extra response
    let reject = TraceEvent {
        t_us: 1,
        body: EventBody::Reject {
            id: 0,
            reason: "validation: z has 7 dims".into(),
        },
    };
    let rp = Replayer::from_parts(header(5),
                                  vec![bad_arrival(0, 0), reject]);
    let eng = tiny_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "{:?}", report.divergences);
    assert_eq!(report.extra_responses, 0,
               "a matching reject on both sides is not an extra");
}

#[test]
fn corrupted_trace_file_is_rejected_at_load() {
    let events = record_run(5, 4);
    let dir = std::env::temp_dir();
    let path = dir.join(format!("huge2_replay_corrupt_{}.jsonl",
                                std::process::id()));
    codec::write_trace(&path, &header(5), &events).unwrap();
    // sanity: the pristine file loads
    assert!(Replayer::load(&path).is_ok());

    // tamper: break a checksum's hex encoding
    let text = std::fs::read_to_string(&path).unwrap();
    let broken = text.replacen("\"checksum\":\"", "\"checksum\":\"zz", 1);
    assert_ne!(broken, text, "fixture must contain a response");
    std::fs::write(&path, &broken).unwrap();
    let err = Replayer::load(&path).unwrap_err().to_string();
    assert!(err.contains(".jsonl:"), "error names the line: {err}");

    // tamper: truncate mid-line
    let cut = &text[..text.len() - 5];
    std::fs::write(&path, cut).unwrap();
    assert!(Replayer::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

// --------------------------------------------------------------- property

const STRING_PALETTE: &[char] = &[
    'a', 'b', 'Z', '"', '\\', '\n', '\t', '{', '}', '[', ']', ':', ',',
    ' ', 'µ', '☃',
];

fn random_string(rng: &mut Rng) -> String {
    let len = rng.next_below(12);
    (0..len)
        .map(|_| STRING_PALETTE[rng.next_below(STRING_PALETTE.len())])
        .collect()
}

/// Raw-bit floats: hits NaNs, infinities, subnormals, -0.0.
fn random_floats(rng: &mut Rng) -> Vec<f32> {
    let len = rng.next_below(6);
    (0..len).map(|_| f32::from_bits(rng.next_u64() as u32)).collect()
}

fn random_ids(rng: &mut Rng) -> Vec<u64> {
    let len = 1 + rng.next_below(8);
    (0..len).map(|_| rng.next_u64()).collect()
}

fn random_priority(rng: &mut Rng) -> Priority {
    Priority::from_rank(rng.next_below(3) as u8).unwrap()
}

fn random_event(rng: &mut Rng, t_us: u64) -> TraceEvent {
    let body = match rng.next_below(11) {
        0 => EventBody::RequestArrival {
            id: rng.next_u64(),
            model: random_string(rng),
            payload: ArrivalPayload::Latent {
                z: random_floats(rng),
                cond: random_floats(rng),
            },
            priority: random_priority(rng),
        },
        6 => EventBody::RequestArrival {
            id: rng.next_u64(),
            model: random_string(rng),
            payload: ArrivalPayload::Image {
                shape: (0..4).map(|_| 1 + rng.next_below(64)).collect(),
                seed: rng.next_u64(),
                checksum: rng.next_u64(),
            },
            priority: random_priority(rng),
        },
        1 => EventBody::Enqueue {
            id: rng.next_u64(),
            depth: rng.next_below(1 << 16),
        },
        2 => EventBody::Reject {
            id: rng.next_u64(),
            reason: random_string(rng),
        },
        3 => EventBody::BatchFormed { ids: random_ids(rng) },
        4 => EventBody::BatchExecuted {
            ids: random_ids(rng),
            bucket: 1 + rng.next_below(64),
            exec_us: rng.next_u64() >> 16,
        },
        7 => EventBody::Failed {
            id: rng.next_u64(),
            kind: ["validation", "backpressure", "batch_failed",
                   "shutdown"][rng.next_below(4)].to_string(),
            reason: random_string(rng),
        },
        8 => EventBody::Shed {
            id: rng.next_u64(),
            class: random_priority(rng),
        },
        9 => EventBody::Evict {
            model: random_string(rng),
            bytes: rng.next_u64() >> 16,
        },
        10 => EventBody::Reload {
            model: random_string(rng),
            bytes: rng.next_u64() >> 16,
            digest: rng.next_u64(),
        },
        _ => EventBody::Response {
            id: rng.next_u64(),
            batch_size: 1 + rng.next_below(64),
            bucket: 1 + rng.next_below(64),
            latency_us: rng.next_u64() >> 16,
            checksum: rng.next_u64(),
        },
    };
    TraceEvent { t_us, body }
}

#[test]
fn codec_round_trip_identity_over_random_streams() {
    let mut rng = Rng::new(2024);
    for case in 0..100 {
        let n = 1 + rng.next_below(30);
        let mut t = 0u64;
        for _ in 0..n {
            t += rng.next_below(10_000) as u64;
            let e = random_event(&mut rng, t);
            let line = codec::encode_event(&e);
            let back = codec::decode_event(&line)
                .unwrap_or_else(|err| panic!("case {case}: {err}\n{line}"));
            // NaN != NaN under PartialEq: identity is judged on the wire
            // encoding, which is bit-pattern-faithful.
            assert_eq!(codec::encode_event(&back), line, "case {case}");
        }
    }
}

#[test]
fn codec_file_round_trip_over_random_stream() {
    let mut rng = Rng::new(77);
    let mut t = 0u64;
    let events: Vec<TraceEvent> = (0..200)
        .map(|_| {
            t += rng.next_below(500) as u64;
            random_event(&mut rng, t)
        })
        .collect();
    let path = std::env::temp_dir().join(format!(
        "huge2_replay_prop_{}.jsonl",
        std::process::id()
    ));
    codec::write_trace(&path, &header(1), &events).unwrap();
    let (h, back) = codec::read_trace(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(h, header(1));
    assert_eq!(back.len(), events.len());
    for (a, b) in back.iter().zip(&events) {
        assert_eq!(codec::encode_event(a), codec::encode_event(b));
    }
}
