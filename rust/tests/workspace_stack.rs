//! Workspace-stack integration tests (DESIGN.md §9): the
//! zero-steady-state-allocation invariant over the full serving engine,
//! pooled-vs-fresh bit-identity at the model level, and a concurrent
//! multi-model record→replay soak exercising workspace reuse under real
//! worker interleaving.

use huge2::config::{tiny_segnet, EngineConfig};
use huge2::coordinator::{Engine, Model, Payload};
use huge2::deconv::Engine as Eng;
use huge2::gan::Generator;
use huge2::replay::{EventBody, Replayer, Timing, TraceHeader, TraceSink};
use huge2::rng::Rng;
use huge2::seg::SegNet;
use huge2::tensor::Tensor;
use huge2::workspace::Workspace;
use std::sync::Arc;

// ------------------------------------------------- model-level identity

/// Generator + SegNet forwards through a dirty (NaN-poisoned, reused)
/// workspace must be bit-identical to the fresh-allocation twin, for
/// both engines.
#[test]
fn model_forwards_bit_identical_through_dirty_workspace() {
    let ws = Workspace::new();

    let gen = Generator::tiny_cgan(5);
    let z = Tensor::randn(&[3, 8], &mut Rng::new(2));
    for engine in [Eng::Huge2, Eng::Baseline] {
        let fresh = gen.forward(&z, engine);
        for round in 0..2 {
            ws.poison(f32::NAN);
            let pooled = gen.forward_ws(&z, engine, &mut ws.handle());
            assert_eq!(pooled.checksum(), fresh.checksum(),
                       "generator {engine:?} round {round}");
        }
    }

    let net = SegNet::new(&tiny_segnet(), 7);
    let mut img_data = Vec::new();
    for s in [20u64, 21] {
        img_data.extend(Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(s))
            .into_vec());
    }
    let x = Tensor::from_vec(&[2, 9, 9, 2], img_data);
    for over in [None, Some(Eng::Huge2), Some(Eng::Baseline)] {
        let fresh = net.forward_with(&x, over);
        ws.poison(f32::NAN);
        let pooled = net.forward_ws(&x, over, &mut ws.handle());
        assert_eq!(pooled.checksum(), fresh.checksum(), "segnet {over:?}");
    }

    let c = ws.counters();
    assert!(c.pool_hits > 0, "models must actually reuse pooled buffers");
}

/// [`ExecPlan::run`] steady state is pure slab reuse: after one warmup
/// batch per plan, repeated runs of the stored GAN plan and the seg
/// serving plan (argmax head included) through one handle must not
/// allocate — `bytes_allocated`/`pool_misses` exactly flat, every
/// steady checkout a pool hit (DESIGN.md §10).
#[test]
fn exec_plan_steady_state_zero_alloc() {
    use huge2::plan::ExecPlan;

    let ws = Workspace::new();
    let gen = Generator::tiny_cgan(5);
    let net = SegNet::new(&tiny_segnet(), 5);
    let serve: ExecPlan = net.plan().with_argmax_head(net.n_classes());
    let z = Tensor::randn(&[4, 8], &mut Rng::new(9));
    let mut img_data = Vec::new();
    for s in [60u64, 61] {
        img_data.extend(Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(s))
            .into_vec());
    }
    let x = Tensor::from_vec(&[2, 9, 9, 2], img_data);

    let mut hnd = ws.handle();
    let img0 = gen.plan().run(&z, &mut hnd);
    let mask0 = serve.run(&x, &mut hnd);
    assert_eq!(img0.shape(), &[4, 32, 32, 3]);
    assert_eq!(mask0.shape(), &[2, 9, 9, 1]);
    let warm = ws.counters();
    assert!(warm.pool_misses > 0, "warmup must populate the pool");

    for round in 0..8 {
        let img = gen.plan().run(&z, &mut hnd);
        let mask = serve.run(&x, &mut hnd);
        assert_eq!(img.checksum(), img0.checksum(), "round {round}");
        assert_eq!(mask.checksum(), mask0.checksum(), "round {round}");
    }
    let steady = ws.counters();
    assert_eq!(steady.bytes_allocated, warm.bytes_allocated,
               "steady ExecPlan::run allocated fresh slabs: \
                warm={warm:?} steady={steady:?}");
    assert_eq!(steady.pool_misses, warm.pool_misses,
               "pool misses after warmup: warm={warm:?} \
                steady={steady:?}");
    assert_eq!(steady.pool_hits - warm.pool_hits,
               steady.checkouts - warm.checkouts,
               "every steady checkout must be a pool hit");
}

// ------------------------------------------- steady-state allocation

fn mixed_engine(workers: usize) -> Engine {
    let cfg = EngineConfig {
        workers,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    e.register_native(Model::native(
        "tiny", Arc::new(Generator::tiny_cgan(5)), 0)).unwrap();
    e.register_native(Model::native_seg(
        "seg", Arc::new(SegNet::new(&tiny_segnet(), 5)))).unwrap();
    e
}

fn gen_once(e: &Engine, rng: &mut Rng) {
    let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
    let r = e.generate("tiny", z, vec![]).unwrap();
    assert_eq!(r.output.shape(), &[1, 32, 32, 3]);
}

fn seg_once(e: &Engine, seed: u64) {
    let img = Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(seed));
    let r = e.segment("seg", img, seed).unwrap();
    assert_eq!(r.output.shape(), &[1, 9, 9, 1]);
}

/// The headline regression test: serve batches through engine + workers,
/// snapshot the workspace counters after a warmup batch per worker, and
/// assert `bytes_allocated` does not grow afterwards — pool misses
/// happen only during warmup; steady-state serving is allocation-free.
#[test]
fn steady_state_serving_is_allocation_free() {
    let e = mixed_engine(1);
    let mut rng = Rng::new(40);
    // warmup: one batch per model's worker (plus one spare round)
    for _ in 0..2 {
        gen_once(&e, &mut rng);
        seg_once(&e, 800);
    }
    let warm = e.workspace_counters();
    assert!(warm.pool_misses > 0, "warmup must populate the pool");

    // ≥ 8 steady batches per model — counters must stay flat
    for i in 0..8u64 {
        gen_once(&e, &mut rng);
        seg_once(&e, 810 + i);
    }
    let steady = e.workspace_counters();
    assert_eq!(steady.bytes_allocated, warm.bytes_allocated,
               "steady-state serving allocated fresh slabs: \
                warm={warm:?} steady={steady:?}");
    assert_eq!(steady.pool_misses, warm.pool_misses,
               "pool misses after warmup: warm={warm:?} steady={steady:?}");
    assert!(steady.checkouts > warm.checkouts,
            "steady batches must run through the pool");
    assert_eq!(steady.pool_hits - warm.pool_hits,
               steady.checkouts - warm.checkouts,
               "every steady checkout must be a pool hit");
    e.shutdown();
}

// ----------------------------------------- concurrent multi-model soak

/// Record a seeded mixed generate+segment stream driven concurrently
/// against two models, then fast-replay the trace and assert zero
/// divergence — workspace reuse under real worker interleaving must not
/// perturb a single output bit.
#[test]
#[ignore = "long concurrent soak; CI release job runs it via -- --ignored"]
fn concurrent_mixed_soak_replays_divergence_free() {
    let per_model = 24usize;
    let build = |sink: Option<Arc<TraceSink>>| {
        let cfg = EngineConfig {
            workers: 2,
            queue_depth: 256,
            max_batch: 4,
            batch_timeout_us: 500,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        if let Some(s) = sink {
            e.set_trace_sink(s).unwrap();
        }
        e.register_native(Model::native(
            "tiny", Arc::new(Generator::tiny_cgan(5)), 0)).unwrap();
        e.register_native(Model::native_seg(
            "seg", Arc::new(SegNet::new(&tiny_segnet(), 5)))).unwrap();
        e
    };

    let sink = Arc::new(TraceSink::new());
    let eng = Arc::new(build(Some(sink.clone())));
    std::thread::scope(|s| {
        let e = eng.clone();
        s.spawn(move || {
            let mut rng = Rng::new(91);
            let mut pending = Vec::new();
            for _ in 0..per_model {
                let z: Vec<f32> =
                    (0..8).map(|_| rng.next_normal()).collect();
                pending.push(e.submit("tiny", Payload::latent(z, vec![]))
                    .unwrap());
            }
            for rx in pending {
                rx.recv().unwrap().unwrap();
            }
        });
        let e = eng.clone();
        s.spawn(move || {
            let mut pending = Vec::new();
            for i in 0..per_model as u64 {
                let seed = 700 + i;
                let img = Tensor::randn(&[1, 9, 9, 2],
                                        &mut Rng::new(seed));
                pending.push(e.submit("seg", Payload::image(img, seed))
                    .unwrap());
            }
            for rx in pending {
                rx.recv().unwrap().unwrap();
            }
        });
    });
    let events = sink.snapshot();
    Arc::into_inner(eng).expect("submitters done").shutdown();
    let responses = events
        .iter()
        .filter(|e| matches!(e.body, EventBody::Response { .. }))
        .count();
    assert_eq!(responses, 2 * per_model);

    let header = TraceHeader {
        model: "tiny".into(),
        backend: "native".into(),
        seed: 5,
        z_dim: 8,
        cond_dim: 0,
        task: "generate".into(),
        net: "tiny_segnet".into(),
        engine_digest: String::new(),
        fleet: Vec::new(),
    };
    let rp = Replayer::from_parts(header, sink.snapshot());
    for run in 1..=2 {
        let eng = build(None);
        let report = rp.run(&eng, Timing::Fast).unwrap();
        eng.shutdown();
        assert!(report.is_clean(), "soak replay #{run} diverged: {:?}",
                report.divergences);
        assert_eq!(report.matched, 2 * per_model, "replay #{run}");
    }
}
