//! Segmentation stack integration tests: config → seg net → coordinator
//! → record/replay (trace format v2), plus the v1 backward-compat rule
//! (DESIGN.md §8).

use huge2::config::{tiny_segnet, EngineConfig};
use huge2::coordinator::{Engine, Model, Payload};
use huge2::deconv::Engine as Eng;
use huge2::gan::{Forward, Generator};
use huge2::replay::{codec, ArrivalPayload, EventBody, Replayer, Timing,
                    TraceEvent, TraceHeader, TraceSink};
use huge2::rng::Rng;
use huge2::seg::SegNet;
use huge2::tensor::Tensor;
use std::sync::Arc;

fn seg_engine(seed: u64, sink: Option<Arc<TraceSink>>) -> Engine {
    let cfg = EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    if let Some(s) = sink {
        e.set_trace_sink(s).unwrap();
    }
    let net = Arc::new(SegNet::new(&tiny_segnet(), seed));
    e.register_native(Model::native_seg("seg", net)).unwrap();
    e
}

fn seg_header(seed: u64) -> TraceHeader {
    TraceHeader {
        model: "seg".into(),
        backend: "native".into(),
        seed,
        z_dim: 0,
        cond_dim: 0,
        task: "segment".into(),
        net: "tiny_segnet".into(),
        engine_digest: String::new(),
        fleet: Vec::new(),
    }
}

/// Record a seg serve run of `n` image requests; returns the events.
fn record_seg_run(seed: u64, n: usize) -> Vec<TraceEvent> {
    let sink = Arc::new(TraceSink::new());
    let eng = seg_engine(seed, Some(sink.clone()));
    let shape = [1usize, 9, 9, 2];
    let mut pending = Vec::new();
    for i in 0..n as u64 {
        let img_seed = 500 + i;
        let img = Tensor::randn(&shape, &mut Rng::new(img_seed));
        pending.push(eng.submit("seg", Payload::image(img, img_seed))
            .unwrap());
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    eng.shutdown();
    sink.snapshot()
}

#[test]
fn forward_trait_spans_both_model_families() {
    // the shared Forward surface: baseline and HUGE² agree for any model
    fn engines_agree<M: Forward>(m: &M, x: &Tensor) {
        let a = m.forward(x, Eng::Huge2);
        let b = m.forward(x, Eng::Baseline);
        assert_eq!(a.shape(), m.out_shape(x.shape()[0]).as_slice());
        assert!(a.allclose(&b, 1e-4), "diff {}", a.max_abs_diff(&b));
    }
    let mut rng = Rng::new(3);
    let gen = Generator::tiny_cgan(5);
    let z = Tensor::randn(&[2, 8], &mut rng);
    engines_agree(&gen, &z);
    let net = SegNet::new(&tiny_segnet(), 5);
    let mut img_data = Vec::new();
    for s in [10u64, 11] {
        img_data.extend(Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(s))
            .into_vec());
    }
    let imgs = Tensor::from_vec(&[2, 9, 9, 2], img_data);
    engines_agree(&net, &imgs);
}

#[test]
fn seg_forward_is_thread_count_invariant() {
    // same weights, same input, different per-layer thread counts →
    // bit-identical logits (the invariance fast replay relies on)
    let mut cfg_mt = tiny_segnet();
    for l in cfg_mt.trunk.iter_mut().chain(cfg_mt.aspp.iter_mut()) {
        l.threads = 3;
    }
    let a = SegNet::new(&tiny_segnet(), 9);
    let b = SegNet::new(&cfg_mt, 9);
    let x = Tensor::randn(&[2, 9, 9, 2], &mut Rng::new(4));
    assert_eq!(a.forward(&x).checksum(), b.forward(&x).checksum());
}

#[test]
fn seg_record_then_fast_replay_is_divergence_free() {
    let events = record_seg_run(5, 16);
    let responses = events
        .iter()
        .filter(|e| matches!(e.body, EventBody::Response { .. }))
        .count();
    assert_eq!(responses, 16);
    // image arrivals were captured as (shape, seed, checksum), not pixels
    assert!(events.iter().any(|e| matches!(
        &e.body,
        EventBody::RequestArrival {
            payload: ArrivalPayload::Image { .. }, ..
        })));

    let rp = Replayer::from_parts(seg_header(5), events);
    let eng = seg_engine(5, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, 16);
}

#[test]
fn seg_replay_against_wrong_weights_diverges() {
    let events = record_seg_run(5, 6);
    let rp = Replayer::from_parts(seg_header(5), events);
    let eng = seg_engine(6, None); // different weight seed
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(!report.is_clean(),
            "different weights must not reproduce mask checksums");
}

#[test]
fn non_canonical_image_is_rejected_at_record_time() {
    // a tensor that is not Tensor::randn(shape, Rng::new(seed)) cannot
    // be stored as (shape, seed, checksum); recording must reject it at
    // submit — the fault site — instead of minting an unreplayable trace
    let sink = Arc::new(TraceSink::new());
    let eng = seg_engine(5, Some(sink.clone()));
    let mut img = Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(42));
    img.data_mut()[0] += 1.0; // no longer the canonical synthesis
    let err = eng.submit("seg", Payload::image(img.clone(), 42))
        .unwrap_err().to_string();
    assert!(err.contains("canonical synthesis"), "{err}");
    // the same canonical image IS recordable...
    let ok = Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(42));
    eng.submit("seg", Payload::image(ok, 42)).unwrap().recv().unwrap()
        .unwrap();
    eng.shutdown();
    // ...and without a sink, non-canonical images serve fine
    let eng = seg_engine(5, None);
    eng.submit("seg", Payload::image(img, 42)).unwrap().recv().unwrap()
        .unwrap();
    eng.shutdown();
}

/// The trace header's engine-selection digest pins the compiled plan's
/// per-layer engine choices (DESIGN.md §10): a matching digest replays
/// cleanly, a tampered one is a hard error before any compute — the
/// guard that keeps `Engine::Auto` deterministic across heuristic
/// changes.
#[test]
fn tampered_engine_digest_fails_replay() {
    let events = record_seg_run(5, 4);
    let eng = seg_engine(5, None);
    let digest = eng.plan_digest("seg")
        .expect("native seg model has a plan digest");

    // correct digest: the gate passes and the replay is clean
    let good = TraceHeader {
        engine_digest: format!("{digest:016x}"),
        ..seg_header(5)
    };
    let rp = Replayer::from_parts(good, events.clone());
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);

    // tampered digest: hard error naming the mismatch, no requests run
    let bad = TraceHeader {
        engine_digest: format!("{:016x}", digest ^ 1),
        ..seg_header(5)
    };
    let rp = Replayer::from_parts(bad, events);
    let eng = seg_engine(5, None);
    let err = rp.run(&eng, Timing::Fast).unwrap_err().to_string();
    eng.shutdown();
    assert!(err.contains("digest mismatch"), "{err}");

    // malformed digest hex is rejected too
    let ugly = TraceHeader {
        engine_digest: "not-hex".into(),
        ..seg_header(5)
    };
    let rp = Replayer::from_parts(ugly, Vec::new());
    let eng = seg_engine(5, None);
    let err = rp.run(&eng, Timing::Fast).unwrap_err().to_string();
    eng.shutdown();
    assert!(err.contains("not a u64 hex"), "{err}");
}

#[test]
fn tampered_input_checksum_fails_reconstruction() {
    let mut events = record_seg_run(5, 4);
    for e in &mut events {
        if let EventBody::RequestArrival {
            payload: ArrivalPayload::Image { checksum, .. }, ..
        } = &mut e.body
        {
            *checksum ^= 1;
            break;
        }
    }
    let rp = Replayer::from_parts(seg_header(5), events);
    let eng = seg_engine(5, None);
    let err = rp.run(&eng, Timing::Fast).unwrap_err().to_string();
    eng.shutdown();
    assert!(err.contains("reconstruction checksum mismatch"), "{err}");
}

#[test]
fn seg_trace_file_round_trips_through_codec() {
    let events = record_seg_run(7, 5);
    let path = std::env::temp_dir().join(format!(
        "huge2_seg_trace_{}.jsonl",
        std::process::id()
    ));
    codec::write_trace(&path, &seg_header(7), &events).unwrap();
    let rp = Replayer::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(rp.header(), &seg_header(7));
    assert_eq!(rp.arrival_count(), 5);
    let eng = seg_engine(7, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
}

// ------------------------------------------------------------- v1 compat

/// A v1 GAN trace (recorded before trace format v2 existed) must still
/// load and replay cleanly: v1 headers decode with task="generate" and
/// latent arrival events are byte-identical across versions.
#[test]
fn v1_gan_trace_still_replays_cleanly() {
    // record a latent workload with today's engine...
    let cfg = EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let build = || Engine::new(cfg.clone());
    let sink = Arc::new(TraceSink::new());
    let mut eng = build();
    eng.set_trace_sink(sink.clone()).unwrap();
    eng.register_native(Model::native(
        "tiny", Arc::new(Generator::tiny_cgan(5)), 0)).unwrap();
    let mut rng = Rng::new(1234);
    let mut pending = Vec::new();
    for _ in 0..8 {
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        pending.push(eng.submit("tiny", Payload::latent(z, vec![]))
            .unwrap());
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    eng.shutdown();

    // ...then write it as a *v1* file: v1 header line + the event lines
    // (latent events encode identically in v1 and v2)
    let path = std::env::temp_dir().join(format!(
        "huge2_v1_trace_{}.jsonl",
        std::process::id()
    ));
    let mut text = String::from(
        "{\"huge2_trace\":1,\"model\":\"tiny\",\"backend\":\"native\",\
         \"seed\":5,\"z_dim\":8,\"cond_dim\":0}\n");
    for e in sink.snapshot() {
        text.push_str(&codec::encode_event(&e));
        text.push('\n');
    }
    std::fs::write(&path, &text).unwrap();

    let rp = Replayer::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(rp.header().task, "generate");
    assert_eq!(rp.header().net, "");
    assert_eq!(rp.arrival_count(), 8);
    let mut eng = build();
    eng.register_native(Model::native(
        "tiny", Arc::new(Generator::tiny_cgan(rp.header().seed)), 0))
        .unwrap();
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "v1 trace diverged: {:?}",
            report.divergences);
    assert_eq!(report.matched, 8);
}
