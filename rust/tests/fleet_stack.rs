//! Fleet serving stack integration tests (DESIGN.md §16): per-model
//! admission with priority classes, load shedding, LRU weight
//! residency under a shared byte budget, the two-level outcome
//! conservation invariant, and the fleet trace round trip (format v5).
//!
//! * a two-model, three-priority recording with at least one LRU
//!   eviction and at least one shed replays divergence-free through
//!   the engine-digest, fleet-roster and fingerprint gates;
//! * a tampered fleet-roster digest is a hard error before compute;
//! * `submitted == completed + rejected + failed` holds fleet-wide AND
//!   per model after a randomized priority soak with displacement
//!   shedding and continuous mid-soak eviction, with `shed ⊆ rejected`
//!   at both levels and `Interactive` never shed.

use huge2::config::{tiny_segnet, EngineConfig};
use huge2::coordinator::{Engine, Model, Payload, Priority, ServeError,
                         ServeResult};
use huge2::gan::Generator;
use huge2::replay::{binary, window, EventBody, Replayer, Timing,
                    TraceHeader, TraceSink};
use huge2::rng::Rng;
use huge2::seg::SegNet;
use huge2::tensor::Tensor;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Arc};

const Z_DIM: usize = 8;
const SEED: u64 = 11;
const SEG_SHAPE: [usize; 4] = [1, 9, 9, 2];

/// Two-model fleet on one engine: "gen" (tiny cGAN) beside "seg"
/// (tiny SegNet). A 1-byte residency budget keeps at most one model's
/// prepacked plan resident at a time (a single over-budget model still
/// serves, by overcommit), so every gen↔seg switch is an LRU eviction
/// plus a digest-checked reload.
fn fleet_engine(queue_depth: usize, budget: usize,
                sink: Option<Arc<TraceSink>>) -> Engine {
    let cfg = EngineConfig {
        workers: 1,
        queue_depth,
        max_batch: 2,
        batch_timeout_us: 200,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    if let Some(s) = sink {
        e.set_trace_sink(s).unwrap();
    }
    e.set_resident_budget(budget).unwrap();
    e.register_native(Model::native(
        "gen", Arc::new(Generator::tiny_cgan(SEED)), 0)).unwrap();
    e.register_native(Model::native_seg(
        "seg", Arc::new(SegNet::new(&tiny_segnet(), SEED)))).unwrap();
    e
}

fn latent(rng: &mut Rng) -> Payload {
    Payload::latent((0..Z_DIM).map(|_| rng.next_normal()).collect(),
                    vec![])
}

fn image(seed: u64) -> Payload {
    Payload::image(Tensor::randn(&SEG_SHAPE, &mut Rng::new(seed)), seed)
}

/// Trace v5 fleet header: "gen" is the primary model, "seg" rides in
/// the roster — both digests pinned from the recording engine.
fn fleet_header(eng: &Engine) -> TraceHeader {
    TraceHeader {
        model: "gen".into(),
        backend: "native".into(),
        seed: SEED,
        z_dim: Z_DIM,
        cond_dim: 0,
        task: "generate".into(),
        net: "tiny_cgan".into(),
        engine_digest: format!("{:016x}",
                               eng.plan_digest("gen").unwrap()),
        fleet: vec![("seg".into(),
                     format!("{:016x}",
                             eng.plan_digest("seg").unwrap()))],
    }
}

// ------------------------------------------------ fleet round trip

/// The fleet acceptance round trip: serve two models across all three
/// priority classes while a 1-byte residency budget forces evictions,
/// flood one queue until admission sheds, then record → save (binary
/// v5) → load → replay. The replay engine gets a deep queue, so every
/// *completed* recording outcome completes again (sheds are
/// load-dependent admission refusals: the replay legitimately admits
/// what the recording shed, surfaced as extras, never divergence).
#[test]
fn fleet_record_replay_round_trip_with_eviction_and_shed() {
    let sink = Arc::new(TraceSink::with_checkpoints(8));
    let eng = fleet_engine(2, 1, Some(sink.clone()));
    let header = fleet_header(&eng);
    let mut rng = Rng::new(99);

    // steady phase: interleave the two models one request at a time —
    // every switch evicts the peer's plan and reloads under the digest
    let mut completed = 0usize;
    for i in 0..10u64 {
        let class = [Priority::Interactive, Priority::Batch,
                     Priority::Background][(i % 3) as usize];
        let (model, payload) = if i % 2 == 0 {
            ("gen", latent(&mut rng))
        } else {
            ("seg", image(1000 + i))
        };
        let rx = eng.submit_with(model, payload, class).unwrap();
        rx.recv().unwrap().unwrap();
        completed += 1;
    }

    // shed phase: background flood against one depth-2 queue — the
    // submit loop outpaces the single worker within a few iterations
    let mut accepted = Vec::new();
    let mut shed_direct = 0usize;
    for _ in 0..10_000 {
        match eng.submit_with("gen", latent(&mut rng),
                              Priority::Background) {
            Ok(rx) => accepted.push(rx),
            Err(ServeError::Shed { class }) => {
                assert_eq!(class, Priority::Background);
                shed_direct += 1;
                break;
            }
            Err(e) => panic!("unexpected refusal: {e}"),
        }
    }
    assert_eq!(shed_direct, 1, "flood must shed");
    for rx in accepted {
        // same-class flood: no displacement, every accepted row serves
        rx.recv().unwrap().unwrap();
        completed += 1;
    }

    let counters = eng.counters.clone();
    let gen_c = eng.model_counters("gen").unwrap();
    let seg_c = eng.model_counters("seg").unwrap();
    let res = eng.residency().unwrap().clone();
    eng.shutdown();

    // ≥1 eviction + ≥1 digest-checked reload under the 1-byte budget
    assert!(res.evictions() >= 1, "{res:?}");
    assert!(res.reloads() >= 1, "{res:?}");
    // conservation at shutdown, fleet-wide and per model
    for (who, c) in [("fleet", &counters), ("gen", &gen_c),
                     ("seg", &seg_c)] {
        assert_eq!(c.in_flight(), 0, "conservation violated for {who}");
        assert!(c.shed.load(Relaxed) <= c.rejected.load(Relaxed),
                "shed must be a subset of rejected for {who}");
    }
    assert_eq!(counters.shed.load(Relaxed) as usize, shed_direct);
    assert_eq!(gen_c.shed.load(Relaxed) as usize, shed_direct);
    assert_eq!(seg_c.shed.load(Relaxed), 0);

    // the trace carries the new v5 events and an intact chain
    let events = sink.snapshot();
    assert!(events.iter()
        .any(|e| matches!(e.body, EventBody::Shed { .. })));
    assert!(events.iter()
        .any(|e| matches!(e.body, EventBody::Evict { .. })));
    assert!(events.iter()
        .any(|e| matches!(e.body, EventBody::Reload { .. })));
    window::verify_fingerprints(&events).unwrap();

    // binary v5 round trip through disk, then replay through the
    // primary-digest + fleet-roster gates
    let path = std::env::temp_dir().join(format!(
        "huge2_fleet_trace_{}.bin",
        std::process::id()
    ));
    binary::write_trace(&path, &header, &events).unwrap();
    let rp = Replayer::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(rp.header(), &header);

    let replay_eng = fleet_engine(256, 1, None);
    let replay_res = replay_eng.residency().unwrap().clone();
    let report = rp.run(&replay_eng, Timing::Fast).unwrap();
    replay_eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, completed);
    // the deep-queue replay admitted what the recording shed
    assert_eq!(report.extra_responses, shed_direct);
    // replay re-evicted under its own budget; reloads re-verified the
    // same pinned digests the roster gate checked up front
    assert!(replay_res.reloads() >= 1, "{replay_res:?}");
}

/// A tampered fleet-roster digest must fail replay *before* any
/// compute, naming the roster model — same contract as the primary
/// engine-digest gate.
#[test]
fn tampered_fleet_roster_digest_fails_replay() {
    let sink = Arc::new(TraceSink::new());
    let eng = fleet_engine(8, 0, Some(sink.clone()));
    let good = fleet_header(&eng);
    let mut rng = Rng::new(5);
    eng.submit("gen", latent(&mut rng)).unwrap().recv().unwrap()
        .unwrap();
    eng.submit("seg", image(42)).unwrap().recv().unwrap().unwrap();
    eng.shutdown();
    let events = sink.snapshot();

    // intact roster digests: clean
    let rp = Replayer::from_parts(good.clone(), events.clone());
    let eng = fleet_engine(8, 0, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);

    // flipped roster digest: hard error naming the fleet model
    let mut bad = good;
    let seg_digest = u64::from_str_radix(&bad.fleet[0].1, 16).unwrap();
    bad.fleet[0].1 = format!("{:016x}", seg_digest ^ 1);
    let rp = Replayer::from_parts(bad, events);
    let eng = fleet_engine(8, 0, None);
    let err = rp.run(&eng, Timing::Fast).unwrap_err().to_string();
    eng.shutdown();
    assert!(err.contains("fleet") && err.contains("seg"), "{err}");
}

// -------------------------------------------------- conservation soak

/// The two-level conservation invariant under a randomized priority
/// soak: four client threads flood two depth-3 queues with a random
/// model/class mix plus deterministic validation faults and
/// unknown-model submits, while the 1-byte residency budget evicts
/// and reloads continuously. Afterwards every submission is accounted
/// for exactly once — fleet-wide and per model — `shed ⊆ rejected` at
/// both levels, and no `Interactive` request was ever shed.
#[test]
fn conservation_holds_per_model_and_fleet_under_priority_soak() {
    let eng = Arc::new(fleet_engine(3, 1, None));
    let client = Arc::new(huge2::metrics::Counters::new());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let eng = eng.clone();
        let client = client.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(4000 + t);
            let mut pending: Vec<(Priority,
                                  mpsc::Receiver<ServeResult>)> =
                Vec::new();
            let drain =
                |pending: &mut Vec<(Priority,
                                    mpsc::Receiver<ServeResult>)>| {
                    for (class, rx) in pending.drain(..) {
                        match rx.recv().expect("terminal outcome") {
                            Ok(_) => {
                                client.completed.fetch_add(1, Relaxed);
                            }
                            Err(ServeError::Shed { class: c }) => {
                                // displacement victims are always a
                                // strictly lower class than the
                                // arrival that displaced them
                                assert_eq!(c, class);
                                assert_ne!(c, Priority::Interactive);
                                client.rejected.fetch_add(1, Relaxed);
                            }
                            Err(_) => {
                                client.failed.fetch_add(1, Relaxed);
                            }
                        }
                    }
                };
            for i in 0..60u64 {
                let class = [Priority::Interactive, Priority::Batch,
                             Priority::Background][rng.next_below(3)];
                let (model, payload) = match rng.next_below(8) {
                    // deterministic validation fault: bad latent width
                    0 => ("gen",
                          Payload::latent(vec![0.0; Z_DIM + 1],
                                          vec![])),
                    // unknown model: a fleet-only reject
                    1 => ("nope", latent(&mut rng)),
                    n if n % 2 == 0 => ("gen", latent(&mut rng)),
                    _ => ("seg", image(7000 + t * 1000 + i)),
                };
                client.submitted.fetch_add(1, Relaxed);
                match eng.submit_with(model, payload, class) {
                    Ok(rx) => pending.push((class, rx)),
                    Err(e) => {
                        if let ServeError::Shed { class: c } = e {
                            assert_eq!(c, class);
                            assert_ne!(c, Priority::Interactive);
                        }
                        client.rejected.fetch_add(1, Relaxed);
                    }
                }
                // burst without draining to provoke displacement and
                // direct sheds, then drain so the soak makes progress
                if pending.len() >= 16 {
                    drain(&mut pending);
                }
            }
            drain(&mut pending);
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // every client-side submission got exactly one terminal outcome
    let total = client.submitted.load(Relaxed);
    assert_eq!(total, 240);
    assert_eq!(client.completed.load(Relaxed)
                   + client.rejected.load(Relaxed)
                   + client.failed.load(Relaxed),
               total);

    // engine-side conservation: fleet-wide and per model
    let gen_c = eng.model_counters("gen").unwrap();
    let seg_c = eng.model_counters("seg").unwrap();
    assert_eq!(eng.counters.submitted.load(Relaxed), total);
    for (who, c) in [("fleet", &eng.counters), ("gen", &gen_c),
                     ("seg", &seg_c)] {
        assert_eq!(c.in_flight(), 0,
                   "conservation violated for {who}: submitted={} \
                    completed={} rejected={} failed={}",
                   c.submitted.load(Relaxed),
                   c.completed.load(Relaxed),
                   c.rejected.load(Relaxed), c.failed.load(Relaxed));
        assert!(c.shed.load(Relaxed) <= c.rejected.load(Relaxed),
                "shed must be a subset of rejected for {who}");
    }
    // unknown-model rejects counted fleet-wide only: the per-model
    // ledgers cover exactly the submissions that resolved to a model
    assert!(gen_c.submitted.load(Relaxed)
                + seg_c.submitted.load(Relaxed) <= total);
    // the depth-3 queues under a 4-thread flood must actually shed,
    // and both models completed work despite continuous eviction
    assert!(eng.counters.shed.load(Relaxed) > 0,
            "soak produced no sheds — queues never saturated");
    assert!(gen_c.completed.load(Relaxed) > 0);
    assert!(seg_c.completed.load(Relaxed) > 0);
    let res = eng.residency().unwrap().clone();
    assert!(res.evictions() >= 1, "no mid-soak eviction: {res:?}");
    assert!(res.reloads() >= 1, "{res:?}");
    Arc::into_inner(eng).expect("soak threads done").shutdown();
}
