//! Property-based tests over the deconvolution engines (hand-rolled
//! generator loop — the vendor set has no proptest; every case is seeded
//! and reproducible from the printed seed).
//!
//! Invariants:
//!  * HUGE² == baseline on random legal transposed-conv configs
//!  * untangled dilated == naive dilated on random configs
//!  * decomposition partitions the kernel taps exactly
//!  * MAC accounting: huge2 ≤ naive, equality iff stride == 1

use huge2::deconv::{axis_pattern, baseline, dilated, huge2 as engine,
                    parallel, polyphase_len, DeconvParams, DilatedParams};
use huge2::rng::Rng;
use huge2::tensor::Tensor;

const CASES: usize = 120;

fn rand_dims(rng: &mut Rng) -> (usize, usize, usize, usize) {
    (
        2 + rng.next_below(7),  // h in 2..9
        1 + rng.next_below(6),  // c
        1 + rng.next_below(6),  // n
        1 + rng.next_below(5),  // r in 1..6
    )
}

#[test]
fn transpose_engines_agree_on_random_configs() {
    let mut rng = Rng::new(0xdeadbeef);
    let mut tested = 0;
    while tested < CASES {
        let seed = rng.next_u64();
        let mut r2 = Rng::new(seed);
        let (h, c, n, r) = rand_dims(&mut r2);
        let stride = 1 + r2.next_below(3);
        let pad = r2.next_below(r);
        let out_pad = r2.next_below(stride.max(1));
        let p = DeconvParams::new(stride, pad, out_pad);
        if (h - 1) * stride + r + out_pad <= 2 * pad {
            continue; // empty output
        }
        let x = Tensor::randn(&[1, h, h, c], &mut r2);
        let k = Tensor::randn(&[r, r, c, n], &mut r2);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let got = engine::conv2d_transpose(&x, &k, &p);
        assert!(got.allclose(&want, 1e-3),
                "seed {seed:#x}: h={h} c={c} n={n} r={r} {p:?} \
                 diff={}", got.max_abs_diff(&want));
        tested += 1;
    }
}

#[test]
fn dilated_engines_agree_on_random_configs() {
    let mut rng = Rng::new(0xfeedface);
    let mut tested = 0;
    while tested < CASES {
        let seed = rng.next_u64();
        let mut r2 = Rng::new(seed);
        let (mut h, c, n, r) = rand_dims(&mut r2);
        h += 6; // dilated kernels need room
        let d = 1 + r2.next_below(4);
        let stride = 1 + r2.next_below(2);
        let pad = r2.next_below(2 * d);
        let p = DilatedParams::new(d, stride, pad);
        if h + 2 * pad < p.eff_kernel(r) {
            continue;
        }
        let x = Tensor::randn(&[1, h, h, c], &mut r2);
        let k = Tensor::randn(&[r, r, c, n], &mut r2);
        let want = baseline::conv2d_dilated(&x, &k, &p);
        let got = dilated::conv2d_dilated(&x, &k, &p);
        assert!(got.allclose(&want, 1e-3),
                "seed {seed:#x}: h={h} c={c} n={n} r={r} {p:?} \
                 diff={}", got.max_abs_diff(&want));
        tested += 1;
    }
}

#[test]
fn dilated_property_grid_all_engines() {
    // Deterministic grid over kernel size × dilation × stride × padding
    // ("valid" and "same"), covering stride>1 explicitly. All four
    // implementations must agree with the naive baseline, and the three
    // untangled variants (strided, prepacked, multi-threaded) must be
    // bit-identical to each other — that equivalence is what licenses
    // swapping them freely under recorded serving traces.
    let mut rng = Rng::new(0x5e6);
    for r in [1usize, 3] {
        for d in [1usize, 2, 3] {
            for stride in [1usize, 2] {
                let same = d * (r - 1) / 2; // 'same' when stride == 1
                for pad in [0usize, same] {
                    let p = DilatedParams::new(d, stride, pad);
                    let h = p.eff_kernel(r) + 6;
                    let (c, n) = (3, 4);
                    let x = Tensor::randn(&[2, h, h, c], &mut rng);
                    let k = Tensor::randn(&[r, r, c, n], &mut rng);
                    let want = baseline::conv2d_dilated(&x, &k, &p);
                    let got = dilated::conv2d_dilated(&x, &k, &p);
                    assert!(got.allclose(&want, 1e-3),
                            "r={r} d={d} stride={stride} pad={pad} \
                             diff={}", got.max_abs_diff(&want));
                    if stride == 1 && pad == same {
                        assert_eq!(got.shape(), x.shape()[..3].iter()
                            .chain(&[n]).copied().collect::<Vec<_>>()
                            .as_slice(), "'same' keeps spatial dims");
                    }
                    let taps = dilated::pack_taps(&k);
                    let packed = dilated::conv2d_dilated_with(&x, &taps, &p);
                    let mt = parallel::conv2d_dilated_mt(&x, &taps, &p, 3);
                    assert_eq!(packed.checksum(), got.checksum(),
                               "prepacked r={r} d={d} stride={stride} \
                                pad={pad}");
                    assert_eq!(mt.checksum(), got.checksum(),
                               "mt r={r} d={d} stride={stride} pad={pad}");
                }
            }
        }
    }
}

#[test]
fn patterns_partition_taps_and_outputs() {
    let mut rng = Rng::new(0xabcdef);
    for _ in 0..400 {
        let r = 1 + rng.next_below(7);
        let stride = 1 + rng.next_below(4);
        let pad = rng.next_below(r);
        // taps across patterns partition the kernel rows exactly
        let taps: usize = (0..stride)
            .map(|phi| axis_pattern(r, stride, pad, phi).taps)
            .sum();
        assert_eq!(taps, r, "r={r} stride={stride} pad={pad}");
        // polyphases partition any output length
        let total = 1 + rng.next_below(64);
        let s: usize = (0..stride)
            .map(|phi| polyphase_len(total, stride, phi))
            .sum();
        assert_eq!(s, total);
    }
}

#[test]
fn mac_counts_never_increase() {
    let mut rng = Rng::new(0x123456);
    for _ in 0..300 {
        let h = 2 + rng.next_below(30);
        let r = 1 + rng.next_below(6);
        let stride = 1 + rng.next_below(4);
        let pad = rng.next_below(r);
        let out_pad = rng.next_below(stride);
        let p = DeconvParams::new(stride, pad, out_pad);
        if (h - 1) * stride + r + out_pad <= 2 * pad {
            continue;
        }
        let (naive, eff) = engine::mac_counts(h, h, 8, 8, r, r, &p);
        assert!(eff <= naive, "h={h} r={r} {p:?}");
        if stride == 1 {
            assert_eq!(eff, naive, "stride 1 has nothing to skip");
        }
    }
}

#[test]
fn batch_equals_per_image_loop() {
    // processing a batch == processing each image separately
    let mut rng = Rng::new(0x777);
    let p = DeconvParams::new(2, 2, 1);
    let b = 3;
    let x = Tensor::randn(&[b, 5, 5, 4], &mut rng);
    let k = Tensor::randn(&[5, 5, 4, 3], &mut rng);
    let all = engine::conv2d_transpose(&x, &k, &p);
    let (_, ho, wo, n) = all.dims4();
    for bi in 0..b {
        let xi = Tensor::from_vec(
            &[1, 5, 5, 4],
            x.data()[bi * 100..(bi + 1) * 100].to_vec(),
        );
        let yi = engine::conv2d_transpose(&xi, &k, &p);
        let want = &all.data()[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        let diff = yi
            .data()
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-5, "batch {bi} diff {diff}");
    }
}

#[test]
fn linearity_of_the_operator() {
    // deconv(a·x1 + x2) == a·deconv(x1) + deconv(x2)
    let mut rng = Rng::new(0x999);
    let p = DeconvParams::new(2, 1, 1);
    let x1 = Tensor::randn(&[1, 6, 6, 3], &mut rng);
    let x2 = Tensor::randn(&[1, 6, 6, 3], &mut rng);
    let k = Tensor::randn(&[3, 3, 3, 2], &mut rng);
    let a = 2.5f32;
    let lhs = engine::conv2d_transpose(&x1.scale(a).add(&x2), &k, &p);
    let rhs = engine::conv2d_transpose(&x1, &k, &p).scale(a)
        .add(&engine::conv2d_transpose(&x2, &k, &p));
    assert!(lhs.allclose(&rhs, 1e-3), "diff {}", lhs.max_abs_diff(&rhs));
}
