//! Property-based tests over the deconvolution engines (hand-rolled
//! generator loop — the vendor set has no proptest; every case is seeded
//! and reproducible from the printed seed).
//!
//! Invariants:
//!  * HUGE² == baseline on random legal transposed-conv configs
//!  * untangled dilated == naive dilated on random configs
//!  * decomposition partitions the kernel taps exactly
//!  * MAC accounting: huge2 ≤ naive, equality iff stride == 1

use huge2::config::tiny_segnet;
use huge2::deconv::{axis_pattern, baseline, col2im_baseline, dilated,
                    huge2 as engine, parallel, polyphase_len, segregated,
                    DeconvParams, DilatedParams, Engine};
use huge2::gan::Generator;
use huge2::plan::ExecPlan;
use huge2::rng::Rng;
use huge2::seg::{SegLayer, SegNet};
use huge2::tensor::Tensor;
use huge2::workspace::Workspace;

const CASES: usize = 120;

fn rand_dims(rng: &mut Rng) -> (usize, usize, usize, usize) {
    (
        2 + rng.next_below(7),  // h in 2..9
        1 + rng.next_below(6),  // c
        1 + rng.next_below(6),  // n
        1 + rng.next_below(5),  // r in 1..6
    )
}

#[test]
fn transpose_engines_agree_on_random_configs() {
    let mut rng = Rng::new(0xdeadbeef);
    let mut tested = 0;
    while tested < CASES {
        let seed = rng.next_u64();
        let mut r2 = Rng::new(seed);
        let (h, c, n, r) = rand_dims(&mut r2);
        let stride = 1 + r2.next_below(3);
        let pad = r2.next_below(r);
        let out_pad = r2.next_below(stride.max(1));
        let p = DeconvParams::new(stride, pad, out_pad);
        if (h - 1) * stride + r + out_pad <= 2 * pad {
            continue; // empty output
        }
        let x = Tensor::randn(&[1, h, h, c], &mut r2);
        let k = Tensor::randn(&[r, r, c, n], &mut r2);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let got = engine::conv2d_transpose(&x, &k, &p);
        assert!(got.allclose(&want, 1e-3),
                "seed {seed:#x}: h={h} c={c} n={n} r={r} {p:?} \
                 diff={}", got.max_abs_diff(&want));
        let seg = segregated::conv2d_transpose(&x, &k, &p);
        assert!(seg.allclose(&want, 1e-3),
                "segregated seed {seed:#x}: h={h} c={c} n={n} r={r} \
                 {p:?} diff={}", seg.max_abs_diff(&want));
        tested += 1;
    }
}

#[test]
fn dilated_engines_agree_on_random_configs() {
    let mut rng = Rng::new(0xfeedface);
    let mut tested = 0;
    while tested < CASES {
        let seed = rng.next_u64();
        let mut r2 = Rng::new(seed);
        let (mut h, c, n, r) = rand_dims(&mut r2);
        h += 6; // dilated kernels need room
        let d = 1 + r2.next_below(4);
        let stride = 1 + r2.next_below(2);
        let pad = r2.next_below(2 * d);
        let p = DilatedParams::new(d, stride, pad);
        if h + 2 * pad < p.eff_kernel(r) {
            continue;
        }
        let x = Tensor::randn(&[1, h, h, c], &mut r2);
        let k = Tensor::randn(&[r, r, c, n], &mut r2);
        let want = baseline::conv2d_dilated(&x, &k, &p);
        let got = dilated::conv2d_dilated(&x, &k, &p);
        assert!(got.allclose(&want, 1e-3),
                "seed {seed:#x}: h={h} c={c} n={n} r={r} {p:?} \
                 diff={}", got.max_abs_diff(&want));
        tested += 1;
    }
}

#[test]
fn dilated_property_grid_all_engines() {
    // Deterministic grid over kernel size × dilation × stride × padding
    // ("valid" and "same"), covering stride>1 explicitly. All four
    // implementations must agree with the naive baseline, and the three
    // untangled variants (strided, prepacked, multi-threaded) must be
    // bit-identical to each other — that equivalence is what licenses
    // swapping them freely under recorded serving traces.
    let mut rng = Rng::new(0x5e6);
    for r in [1usize, 3] {
        for d in [1usize, 2, 3] {
            for stride in [1usize, 2] {
                let same = d * (r - 1) / 2; // 'same' when stride == 1
                for pad in [0usize, same] {
                    let p = DilatedParams::new(d, stride, pad);
                    let h = p.eff_kernel(r) + 6;
                    let (c, n) = (3, 4);
                    let x = Tensor::randn(&[2, h, h, c], &mut rng);
                    let k = Tensor::randn(&[r, r, c, n], &mut rng);
                    let want = baseline::conv2d_dilated(&x, &k, &p);
                    let got = dilated::conv2d_dilated(&x, &k, &p);
                    assert!(got.allclose(&want, 1e-3),
                            "r={r} d={d} stride={stride} pad={pad} \
                             diff={}", got.max_abs_diff(&want));
                    if stride == 1 && pad == same {
                        assert_eq!(got.shape(), x.shape()[..3].iter()
                            .chain(&[n]).copied().collect::<Vec<_>>()
                            .as_slice(), "'same' keeps spatial dims");
                    }
                    let taps = dilated::pack_taps(&k);
                    let packed = dilated::conv2d_dilated_with(&x, &taps, &p);
                    let mt = parallel::conv2d_dilated_mt(&x, &taps, &p, 3);
                    assert_eq!(packed.checksum(), got.checksum(),
                               "prepacked r={r} d={d} stride={stride} \
                                pad={pad}");
                    assert_eq!(mt.checksum(), got.checksum(),
                               "mt r={r} d={d} stride={stride} pad={pad}");
                }
            }
        }
    }
}

/// Pooled-vs-fresh bit-identity over the transposed-conv engine grid:
/// for every engine variant × shape × thread count, a forward through a
/// **dirty** (NaN-poisoned, cross-shape-reused) workspace must be
/// bit-identical to one through fresh allocations. Any pooled path that
/// reads stale scratch instead of fully overwriting it propagates NaN
/// into the checksum and fails loudly (DESIGN.md §9).
#[test]
fn pooled_transpose_grid_bit_identical_to_fresh() {
    let ws = Workspace::new(); // ONE pool across all shapes: buffers are
                               // reused dirty across engines and sizes
    let mut rng = Rng::new(0xa11c);
    let shapes = [
        (4, 16, 8, 5, DeconvParams::new(2, 2, 1)),
        (8, 8, 4, 4, DeconvParams::new(2, 1, 0)),
        (5, 3, 2, 5, DeconvParams::new(3, 2, 1)),
        (3, 2, 2, 3, DeconvParams::new(2, 0, 0)),
    ];
    for &(h, c, n, r, p) in &shapes {
        let x = Tensor::randn(&[2, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let patterns = engine::decompose(&k, &p);
        let ctx = format!("h={h} c={c} n={n} r={r} {p:?}");

        ws.poison(f32::NAN);
        assert_eq!(
            engine::conv2d_transpose_ws(&x, &patterns, r, r, &p,
                                        &mut ws.handle()).checksum(),
            engine::conv2d_transpose_with(&x, &patterns, r, r, &p)
                .checksum(),
            "huge2 st pooled != fresh: {ctx}");

        ws.poison(f32::NAN);
        assert_eq!(
            baseline::conv2d_transpose_ws(&x, &k, &p, &mut ws.handle())
                .checksum(),
            baseline::conv2d_transpose(&x, &k, &p).checksum(),
            "baseline st pooled != fresh: {ctx}");

        ws.poison(f32::NAN);
        assert_eq!(
            col2im_baseline::conv2d_transpose_ws(&x, &k, &p,
                                                 &mut ws.handle())
                .checksum(),
            col2im_baseline::conv2d_transpose(&x, &k, &p).checksum(),
            "col2im pooled != fresh: {ctx}");

        let pack = segregated::SegPack::from_patterns(&patterns);
        ws.poison(f32::NAN);
        assert_eq!(
            segregated::conv2d_transpose_ws(&x, &patterns, &pack, r, r,
                                            &p, &mut ws.handle())
                .checksum(),
            segregated::conv2d_transpose_with(&x, &patterns, &pack, r, r,
                                              &p).checksum(),
            "segregated st pooled != fresh: {ctx}");

        for threads in [1usize, 2, 4, 7] {
            ws.poison(f32::NAN);
            assert_eq!(
                parallel::huge2_conv2d_transpose_mt_ws(
                    &x, &patterns, r, r, &p, threads, &ws).checksum(),
                parallel::huge2_conv2d_transpose_mt(
                    &x, &patterns, r, r, &p, threads).checksum(),
                "huge2 mt{threads} pooled != fresh: {ctx}");
            ws.poison(f32::NAN);
            assert_eq!(
                parallel::baseline_conv2d_transpose_mt_ws(
                    &x, &k, &p, threads, &ws).checksum(),
                parallel::baseline_conv2d_transpose_mt(
                    &x, &k, &p, threads).checksum(),
                "baseline mt{threads} pooled != fresh: {ctx}");
            ws.poison(f32::NAN);
            assert_eq!(
                segregated::conv2d_transpose_mt_ws(
                    &x, &patterns, &pack, r, r, &p, threads, &ws)
                    .checksum(),
                segregated::conv2d_transpose_mt(
                    &x, &patterns, &pack, r, r, &p, threads).checksum(),
                "segregated mt{threads} pooled != fresh: {ctx}");
        }
    }
    let c = ws.counters();
    assert!(c.pool_hits > 0, "grid must actually exercise buffer reuse");
    assert!(c.pool_misses < c.checkouts,
            "steady pool must serve most checkouts");
}

/// Same discipline over the dilated-conv engine grid (naive, untangled
/// strided, prepacked, multi-threaded × thread counts).
#[test]
fn pooled_dilated_grid_bit_identical_to_fresh() {
    let ws = Workspace::new();
    let mut rng = Rng::new(0xd11a);
    let shapes = [
        (13, 4, 3, 3, DilatedParams::new(2, 1, 2)),
        (13, 3, 2, 3, DilatedParams::new(2, 2, 2)),
        (9, 2, 5, 1, DilatedParams::new(1, 1, 0)),
        (17, 2, 2, 3, DilatedParams::new(3, 2, 3)),
    ];
    for &(h, c, n, r, p) in &shapes {
        let x = Tensor::randn(&[2, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let taps = dilated::pack_taps(&k);
        let ctx = format!("h={h} c={c} n={n} r={r} {p:?}");

        ws.poison(f32::NAN);
        assert_eq!(
            baseline::conv2d_dilated_ws(&x, &k, &p, &mut ws.handle())
                .checksum(),
            baseline::conv2d_dilated(&x, &k, &p).checksum(),
            "baseline dilated pooled != fresh: {ctx}");

        ws.poison(f32::NAN);
        assert_eq!(
            dilated::conv2d_dilated_ws(&x, &taps, &p, &mut ws.handle())
                .checksum(),
            dilated::conv2d_dilated_with(&x, &taps, &p).checksum(),
            "untangled dilated pooled != fresh: {ctx}");

        for threads in [1usize, 2, 3, 7, 64] {
            ws.poison(f32::NAN);
            assert_eq!(
                parallel::conv2d_dilated_mt_ws(&x, &taps, &p, threads,
                                               &ws).checksum(),
                parallel::conv2d_dilated_mt(&x, &taps, &p, threads)
                    .checksum(),
                "dilated mt{threads} pooled != fresh: {ctx}");
        }
    }
    assert!(ws.counters().pool_hits > 0);
}

/// Plan-vs-legacy bit-identity grid (DESIGN.md §10): executing through
/// the compiled [`ExecPlan`] — NaN-poisoned shared pool, forced thread
/// counts — must reproduce a manual layer-by-layer composition of the
/// public per-layer forwards **bit-for-bit**, for both nets ×
/// {Baseline, Huge2, Segregated, Auto} × thread counts. This is what
/// licenses deleting the models' hand-rolled forward cores: the plan
/// executor IS
/// the forward path, and its engine resolution (incl. Auto and the MT
/// variants) never perturbs a checksum.
#[test]
fn plan_vs_legacy_bit_identity_grid() {
    let ws = Workspace::new(); // ONE dirty pool across the whole grid

    // --- generator: proj + relu + deconv stack (relu/tanh) ---
    let gen = Generator::tiny_cgan(5);
    let z = Tensor::randn(&[2, 8], &mut Rng::new(77));
    let legacy_gan = |e: Engine| -> Tensor {
        let (b, zd) = z.dims2();
        let (_, hid) = gen.proj.dims2();
        let mut cur = vec![0.0f32; b * hid];
        huge2::gemm::sgemm(b, hid, zd, z.data(), gen.proj.data(),
                           &mut cur, false);
        let f = &gen.layers[0].cfg;
        let mut t = Tensor::from_vec(&[b, f.h, f.h, f.c_in], cur).relu();
        let n = gen.layers.len();
        for (i, l) in gen.layers.iter().enumerate() {
            let y = l.forward(&t, e);
            t = if i == n - 1 { y.tanh() } else { y.relu() };
        }
        t
    };
    for e in [Engine::Baseline, Engine::Huge2, Engine::Segregated,
              Engine::Auto] {
        let want = legacy_gan(e);
        for threads in [1usize, 2, 4] {
            let plan = ExecPlan::for_generator(&gen, e)
                .with_threads(threads);
            ws.poison(f32::NAN);
            let got = plan.run(&z, &mut ws.handle());
            assert_eq!(got.checksum(), want.checksum(),
                       "gan plan {e:?} t={threads} != legacy");
        }
    }

    // --- segnet: trunk (relu) + summed pyramid (relu) + head ---
    let net = SegNet::new(&tiny_segnet(), 6);
    let mut img_data = Vec::new();
    for s in [30u64, 31] {
        img_data.extend(Tensor::randn(&[1, 9, 9, 2], &mut Rng::new(s))
            .into_vec());
    }
    let x = Tensor::from_vec(&[2, 9, 9, 2], img_data);
    let legacy_seg = |over: Option<Engine>| -> Tensor {
        let pick = |l: &SegLayer| over.unwrap_or(l.cfg.engine);
        let mut t = x.clone();
        for l in &net.trunk {
            t = l.forward(&t, pick(l)).relu();
        }
        let mut acc = net.aspp[0].forward(&t, pick(&net.aspp[0]));
        for l in &net.aspp[1..] {
            acc = acc.add(&l.forward(&t, pick(l)));
        }
        net.head.forward(&acc.relu(), pick(&net.head))
    };
    for over in [None, Some(Engine::Baseline), Some(Engine::Huge2),
                 Some(Engine::Segregated), Some(Engine::Auto)] {
        let want = legacy_seg(over);
        for threads in [1usize, 2, 3] {
            let plan = ExecPlan::for_segnet(&net, over)
                .with_threads(threads);
            ws.poison(f32::NAN);
            let got = plan.run(&x, &mut ws.handle());
            assert_eq!(got.checksum(), want.checksum(),
                       "seg plan {over:?} t={threads} != legacy");
            // the model forward is the same plan path
            ws.poison(f32::NAN);
            let via_model = net.forward_ws(&x, over, &mut ws.handle());
            assert_eq!(via_model.checksum(), want.checksum(),
                       "seg forward {over:?} != legacy");
        }
    }
    let c = ws.counters();
    assert!(c.pool_hits > 0, "grid must exercise dirty slab reuse");
}

/// Degenerate shard geometries (DESIGN.md §14 shard-clamp convention):
/// every MT engine must clamp its thread count to its shard unit —
/// patterns for the transposed engines, output rows for the dilated
/// one — so `threads` far above the available work, 1×1 spatial
/// inputs, and single-pattern (stride-1) decompositions all produce
/// results bit-identical to the single-threaded engine instead of
/// panicking on empty shards.
#[test]
fn mt_engines_survive_degenerate_shard_geometries() {
    let mut rng = Rng::new(0x51a2d);
    let cases = [
        // 1x1 spatial input, 1x1 output (threads >> ho and patterns)
        (1usize, 3usize, 2usize, 3usize, DeconvParams::new(2, 1, 0)),
        // stride 1: single pattern, threads >> patterns.len()
        (4, 2, 3, 3, DeconvParams::new(1, 1, 0)),
        // stride > r: some patterns have zero taps
        (2, 2, 2, 2, DeconvParams::new(3, 0, 0)),
        // tall stride with out_pad, tiny input
        (2, 1, 1, 4, DeconvParams::new(4, 1, 2)),
    ];
    for &(h, c, n, r, p) in &cases {
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let patterns = engine::decompose(&k, &p);
        let pack = segregated::SegPack::from_patterns(&patterns);
        let st = engine::conv2d_transpose_with(&x, &patterns, r, r, &p);
        let seg_st = segregated::conv2d_transpose_with(
            &x, &patterns, &pack, r, r, &p);
        let ctx = format!("h={h} c={c} n={n} r={r} {p:?}");
        assert!(st.allclose(&want, 1e-3), "huge2 st: {ctx}");
        assert!(seg_st.allclose(&want, 1e-3), "segregated st: {ctx}");
        for threads in [1usize, 5, 64] {
            let mt = parallel::huge2_conv2d_transpose_mt(
                &x, &patterns, r, r, &p, threads);
            assert_eq!(mt.checksum(), st.checksum(),
                       "huge2 mt{threads} != st: {ctx}");
            let seg_mt = segregated::conv2d_transpose_mt(
                &x, &patterns, &pack, r, r, &p, threads);
            assert_eq!(seg_mt.checksum(), seg_st.checksum(),
                       "segregated mt{threads} != st: {ctx}");
            let base_mt = parallel::baseline_conv2d_transpose_mt(
                &x, &k, &p, threads);
            assert!(base_mt.allclose(&want, 1e-3),
                    "baseline mt{threads}: {ctx}");
        }
    }
    // dilated: threads far above the row shard unit (ho == 1)
    let x = Tensor::randn(&[1, 3, 3, 2], &mut rng);
    let k = Tensor::randn(&[3, 3, 2, 2], &mut rng);
    let p = DilatedParams::new(1, 1, 0); // ho = wo = 1
    let taps = dilated::pack_taps(&k);
    let st = dilated::conv2d_dilated_with(&x, &taps, &p);
    assert!(st.allclose(&baseline::conv2d_dilated(&x, &k, &p), 1e-3));
    for threads in [1usize, 5, 64] {
        let mt = parallel::conv2d_dilated_mt(&x, &taps, &p, threads);
        assert_eq!(mt.checksum(), st.checksum(), "dilated mt{threads}");
    }
}

#[test]
fn patterns_partition_taps_and_outputs() {
    let mut rng = Rng::new(0xabcdef);
    for _ in 0..400 {
        let r = 1 + rng.next_below(7);
        let stride = 1 + rng.next_below(4);
        let pad = rng.next_below(r);
        // taps across patterns partition the kernel rows exactly
        let taps: usize = (0..stride)
            .map(|phi| axis_pattern(r, stride, pad, phi).taps)
            .sum();
        assert_eq!(taps, r, "r={r} stride={stride} pad={pad}");
        // polyphases partition any output length
        let total = 1 + rng.next_below(64);
        let s: usize = (0..stride)
            .map(|phi| polyphase_len(total, stride, phi))
            .sum();
        assert_eq!(s, total);
    }
}

#[test]
fn mac_counts_never_increase() {
    let mut rng = Rng::new(0x123456);
    for _ in 0..300 {
        let h = 2 + rng.next_below(30);
        let r = 1 + rng.next_below(6);
        let stride = 1 + rng.next_below(4);
        let pad = rng.next_below(r);
        let out_pad = rng.next_below(stride);
        let p = DeconvParams::new(stride, pad, out_pad);
        if (h - 1) * stride + r + out_pad <= 2 * pad {
            continue;
        }
        let (naive, eff) = engine::mac_counts(h, h, 8, 8, r, r, &p);
        assert!(eff <= naive, "h={h} r={r} {p:?}");
        if stride == 1 {
            assert_eq!(eff, naive, "stride 1 has nothing to skip");
        }
    }
}

#[test]
fn batch_equals_per_image_loop() {
    // processing a batch == processing each image separately
    let mut rng = Rng::new(0x777);
    let p = DeconvParams::new(2, 2, 1);
    let b = 3;
    let x = Tensor::randn(&[b, 5, 5, 4], &mut rng);
    let k = Tensor::randn(&[5, 5, 4, 3], &mut rng);
    let all = engine::conv2d_transpose(&x, &k, &p);
    let (_, ho, wo, n) = all.dims4();
    for bi in 0..b {
        let xi = Tensor::from_vec(
            &[1, 5, 5, 4],
            x.data()[bi * 100..(bi + 1) * 100].to_vec(),
        );
        let yi = engine::conv2d_transpose(&xi, &k, &p);
        let want = &all.data()[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        let diff = yi
            .data()
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-5, "batch {bi} diff {diff}");
    }
}

#[test]
fn linearity_of_the_operator() {
    // deconv(a·x1 + x2) == a·deconv(x1) + deconv(x2)
    let mut rng = Rng::new(0x999);
    let p = DeconvParams::new(2, 1, 1);
    let x1 = Tensor::randn(&[1, 6, 6, 3], &mut rng);
    let x2 = Tensor::randn(&[1, 6, 6, 3], &mut rng);
    let k = Tensor::randn(&[3, 3, 3, 2], &mut rng);
    let a = 2.5f32;
    let lhs = engine::conv2d_transpose(&x1.scale(a).add(&x2), &k, &p);
    let rhs = engine::conv2d_transpose(&x1, &k, &p).scale(a)
        .add(&engine::conv2d_transpose(&x2, &k, &p));
    assert!(lhs.allclose(&rhs, 1e-3), "diff {}", lhs.max_abs_diff(&rhs));
}
