//! Property-based tests on coordinator invariants (hand-rolled,
//! seeded — proptest is not in the vendor set).
//!
//! * queue: model-based test against `VecDeque` (FIFO, capacity,
//!   close semantics hold under random op sequences)
//! * batcher: batches partition the request stream, never exceed
//!   max_batch, preserve order
//! * accounting: submitted == completed + rejected + failed after drain
//! * histogram: quantiles within log-bucket error of exact values

use huge2::coordinator::batcher::{ideal_batches, next_batch};
use huge2::coordinator::{BoundedQueue, PushError};
use huge2::metrics::Histogram;
use huge2::rng::Rng;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[test]
fn queue_matches_vecdeque_model() {
    let mut rng = Rng::new(42);
    for case in 0..50 {
        let cap = 1 + rng.next_below(8);
        let q: BoundedQueue<u32> = BoundedQueue::new(cap);
        let mut model: VecDeque<u32> = VecDeque::new();
        let mut closed = false;
        for op in 0..200 {
            match rng.next_below(if closed { 2 } else { 3 }) {
                0 => {
                    // pop
                    let got = q.try_pop();
                    let want = model.pop_front();
                    assert_eq!(got, want, "case {case} op {op}");
                }
                1 => {
                    // len check
                    assert_eq!(q.len(), model.len());
                }
                _ => {
                    // push
                    let v = rng.next_u64() as u32;
                    match q.try_push(v) {
                        Ok(()) => {
                            assert!(model.len() < cap && !closed);
                            model.push_back(v);
                        }
                        Err(PushError::Full(x)) => {
                            assert_eq!(x, v);
                            assert_eq!(model.len(), cap);
                        }
                        Err(PushError::Closed(x)) => {
                            assert_eq!(x, v);
                            assert!(closed);
                        }
                    }
                }
            }
            if op == 150 {
                q.close();
                closed = true;
            }
        }
    }
}

#[test]
fn batches_partition_stream_in_order() {
    let mut rng = Rng::new(7);
    for _ in 0..30 {
        let n = 1 + rng.next_below(64);
        let max_batch = 1 + rng.next_below(10);
        let q = Arc::new(BoundedQueue::new(n));
        for i in 0..n as u32 {
            q.try_push(i).unwrap();
        }
        q.close();
        let mut seen = Vec::new();
        while let Some(batch) =
            next_batch(&q, max_batch, Duration::from_micros(100),
                       |_: &u32| Instant::now(), |_| {})
        {
            assert!(!batch.is_empty() && batch.len() <= max_batch);
            seen.extend(batch);
        }
        assert_eq!(seen, (0..n as u32).collect::<Vec<_>>(),
                   "stream must be partitioned in order");
    }
}

#[test]
fn ideal_batches_invariants() {
    let mut rng = Rng::new(11);
    for _ in 0..100 {
        let n = 1 + rng.next_below(40);
        let max_batch = 1 + rng.next_below(8);
        let timeout = 1 + rng.next_below(100) as u64;
        let mut t = 0u64;
        let arrivals: Vec<u64> = (0..n)
            .map(|_| {
                t += rng.next_below(50) as u64;
                t
            })
            .collect();
        let batches = ideal_batches(&arrivals, max_batch, timeout);
        assert_eq!(batches.iter().sum::<usize>(), n, "partition");
        assert!(batches.iter().all(|&b| b >= 1 && b <= max_batch));
    }
}

#[test]
fn histogram_quantiles_bounded_error() {
    let mut rng = Rng::new(13);
    for _ in 0..10 {
        let h = Histogram::new();
        let mut vals: Vec<u64> = (0..2000)
            .map(|_| 1 + rng.next_u64() % 1_000_000)
            .collect();
        for &v in &vals {
            h.record(Duration::from_micros(v));
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.99] {
            let exact = vals[((vals.len() as f64 * q) as usize)
                .min(vals.len() - 1)] as f64;
            let est = h.quantile_us(q) as f64;
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.10, "q={q}: est {est} vs exact {exact} \
                                 (rel {rel:.3})");
        }
        assert_eq!(h.count(), 2000);
    }
}

#[test]
fn engine_accounting_invariant_under_flood() {
    use huge2::config::EngineConfig;
    use huge2::coordinator::{Engine, Model, Payload};
    use huge2::gan::Generator;

    let gen = Generator::tiny_cgan(3);
    let mut eng = Engine::new(EngineConfig {
        workers: 2,
        queue_depth: 4,
        max_batch: 4,
        batch_timeout_us: 200,
        ..EngineConfig::default()
    });
    eng.register_native(Model::native("m", Arc::new(gen), 0)).unwrap();

    let mut receivers = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..120 {
        match eng.submit("m", Payload::latent(vec![0.0; 8], vec![])) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    let mut completed = 0u64;
    let mut failed = 0u64;
    for rx in receivers {
        match rx.recv() {
            Ok(Ok(_)) => completed += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => panic!("reply channel closed without an outcome"),
        }
    }
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(eng.counters.submitted.load(Relaxed), 120);
    assert_eq!(eng.counters.rejected.load(Relaxed), rejected);
    assert_eq!(eng.counters.completed.load(Relaxed), completed);
    assert_eq!(eng.counters.failed.load(Relaxed), failed);
    // conservation: every submission is accounted for exactly once
    assert_eq!(completed + rejected + failed, 120);
    assert_eq!(eng.counters.in_flight(), 0, "drained engine");
}
