//! Trace format v4 integration tests: cross-codec round-trips
//! (JSONL ↔ binary, bit-exact), corrupt/truncated binary files,
//! v1–v3 compatibility, window-sliced replay identity, and
//! fingerprint bisection of an injected divergence.

use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Model, Payload, Priority};
use huge2::gan::Generator;
use huge2::metrics::{HistogramSnapshot, MetricsSnapshot};
use huge2::replay::{binary, codec, window, ArrivalPayload,
                    CheckpointState, Divergence, EventBody,
                    ReplayOptions, Replayer, Timing, TraceEvent,
                    TraceHeader, TraceSink};
use huge2::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const Z_DIM: usize = 8;

fn tiny_engine(seed: u64, sink: Option<Arc<TraceSink>>) -> Engine {
    let cfg = EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    if let Some(s) = sink {
        e.set_trace_sink(s).unwrap();
    }
    let gen = Generator::tiny_cgan(seed);
    assert_eq!(gen.z_dim, Z_DIM);
    e.register_native(Model::native("tiny", Arc::new(gen), 0)).unwrap();
    e
}

fn header(seed: u64) -> TraceHeader {
    TraceHeader {
        model: "tiny".into(),
        backend: "native".into(),
        seed,
        z_dim: Z_DIM,
        cond_dim: 0,
        task: "generate".into(),
        net: String::new(),
        engine_digest: String::new(),
        fleet: Vec::new(),
    }
}

/// Record a serve run of `n` requests through a sink checkpointing
/// every `every` events (0 = no checkpoints).
fn record_run(seed: u64, n: usize, every: usize) -> Vec<TraceEvent> {
    let sink = Arc::new(TraceSink::with_checkpoints(every));
    let eng = tiny_engine(seed, Some(sink.clone()));
    let mut rng = Rng::new(1234);
    let mut pending = Vec::new();
    for _ in 0..n {
        let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
        pending.push(eng.submit("tiny", Payload::latent(z, vec![]))
            .unwrap());
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    eng.shutdown();
    sink.snapshot()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("huge2_tf_{}_{}", name,
                                      std::process::id()))
}

// ------------------------------------------------- random event streams

const STRING_PALETTE: &[char] = &[
    'a', 'b', 'Z', '"', '\\', '\n', '\t', '{', '}', '[', ']', ':', ',',
    ' ', 'µ', '☃',
];

fn random_string(rng: &mut Rng) -> String {
    let len = rng.next_below(12);
    (0..len)
        .map(|_| STRING_PALETTE[rng.next_below(STRING_PALETTE.len())])
        .collect()
}

/// Raw-bit floats: hits NaNs, infinities, subnormals, -0.0.
fn random_floats(rng: &mut Rng) -> Vec<f32> {
    let len = rng.next_below(6);
    (0..len).map(|_| f32::from_bits(rng.next_u64() as u32)).collect()
}

fn random_ids(rng: &mut Rng) -> Vec<u64> {
    let len = 1 + rng.next_below(8);
    (0..len).map(|_| rng.next_u64()).collect()
}

fn random_metrics(rng: &mut Rng) -> MetricsSnapshot {
    let mut m = MetricsSnapshot::default();
    for _ in 0..rng.next_below(3) {
        m.counters.insert(random_string(rng), rng.next_u64());
    }
    for _ in 0..rng.next_below(3) {
        // cast wraps: exercises negative gauges
        m.gauges.insert(random_string(rng), rng.next_u64() as i64);
    }
    for _ in 0..rng.next_below(2) {
        // strictly ascending sparse buckets (stride 7 > offset range 5)
        let pairs: Vec<(usize, u64)> = (0..1 + rng.next_below(4))
            .map(|i| (i * 7 + rng.next_below(5),
                      1 + rng.next_u64() % 100))
            .collect();
        let h = HistogramSnapshot::from_sparse(
            &pairs, rng.next_u64() >> 16, rng.next_u64() >> 16).unwrap();
        m.histograms.insert(random_string(rng), h);
    }
    m
}

fn random_checkpoint(rng: &mut Rng) -> EventBody {
    EventBody::Checkpoint(Box::new(CheckpointState {
        seq: rng.next_u64() >> 32,
        events: rng.next_u64() >> 32,
        pending: random_ids(rng),
        next_id: rng.next_u64(),
        submitted: rng.next_u64() >> 32,
        completed: rng.next_u64() >> 32,
        rejected: rng.next_u64() >> 32,
        failed: rng.next_u64() >> 32,
        fingerprint: rng.next_u64(),
        chain: rng.next_u64(),
        metrics: random_metrics(rng),
    }))
}

fn random_priority(rng: &mut Rng) -> Priority {
    Priority::from_rank(rng.next_below(3) as u8).unwrap()
}

fn random_event(rng: &mut Rng, t_us: u64) -> TraceEvent {
    let body = match rng.next_below(12) {
        0 => EventBody::RequestArrival {
            id: rng.next_u64(),
            model: random_string(rng),
            payload: ArrivalPayload::Latent {
                z: random_floats(rng),
                cond: random_floats(rng),
            },
            priority: random_priority(rng),
        },
        6 => EventBody::RequestArrival {
            id: rng.next_u64(),
            model: random_string(rng),
            payload: ArrivalPayload::Image {
                shape: (0..4).map(|_| 1 + rng.next_below(64)).collect(),
                seed: rng.next_u64(),
                checksum: rng.next_u64(),
            },
            priority: random_priority(rng),
        },
        1 => EventBody::Enqueue {
            id: rng.next_u64(),
            depth: rng.next_below(1 << 16),
        },
        2 => EventBody::Reject {
            id: rng.next_u64(),
            reason: random_string(rng),
        },
        3 => EventBody::BatchFormed { ids: random_ids(rng) },
        4 => EventBody::BatchExecuted {
            ids: random_ids(rng),
            bucket: 1 + rng.next_below(64),
            exec_us: rng.next_u64() >> 16,
        },
        7 => EventBody::Failed {
            id: rng.next_u64(),
            kind: ["validation", "backpressure", "batch_failed",
                   "shutdown"][rng.next_below(4)].to_string(),
            reason: random_string(rng),
        },
        8 => random_checkpoint(rng),
        9 => EventBody::Shed {
            id: rng.next_u64(),
            class: random_priority(rng),
        },
        10 => EventBody::Evict {
            model: random_string(rng),
            bytes: rng.next_u64() >> 16,
        },
        11 => EventBody::Reload {
            model: random_string(rng),
            bytes: rng.next_u64() >> 16,
            digest: rng.next_u64(),
        },
        _ => EventBody::Response {
            id: rng.next_u64(),
            batch_size: 1 + rng.next_below(64),
            bucket: 1 + rng.next_below(64),
            latency_us: rng.next_u64() >> 16,
            checksum: rng.next_u64(),
        },
    };
    TraceEvent { t_us, body }
}

/// jsonl → binary → jsonl over a seeded random stream (every event
/// kind, NaN-bit floats, checkpoints with metrics) must reproduce the
/// original JSONL file byte-for-byte — the JSONL encoder is canonical,
/// so byte-identity proves both codecs are lossless.
#[test]
fn cross_codec_round_trip_is_byte_identical() {
    let mut rng = Rng::new(4242);
    let mut t = 0u64;
    let events: Vec<TraceEvent> = (0..200)
        .map(|_| {
            t += rng.next_below(100_000) as u64;
            random_event(&mut rng, t)
        })
        .collect();
    let j1 = tmp("cross_a.jsonl");
    let b = tmp("cross_b.bin");
    let j2 = tmp("cross_c.jsonl");
    codec::write_trace(&j1, &header(1), &events).unwrap();
    let (h1, e1) = binary::read_trace_auto(&j1).unwrap();
    binary::write_trace(&b, &h1, &e1).unwrap();
    assert!(binary::sniff_is_binary(&b).unwrap());
    assert!(!binary::sniff_is_binary(&j1).unwrap());
    let (h2, e2) = binary::read_trace_auto(&b).unwrap();
    codec::write_trace(&j2, &h2, &e2).unwrap();
    let t1 = std::fs::read(&j1).unwrap();
    let t2 = std::fs::read(&j2).unwrap();
    let bin_len = std::fs::metadata(&b).unwrap().len();
    std::fs::remove_file(&j1).ok();
    std::fs::remove_file(&b).ok();
    std::fs::remove_file(&j2).ok();
    assert_eq!(t1, t2, "jsonl → bin → jsonl must be byte-identical");
    assert!(bin_len < t1.len() as u64,
            "binary ({bin_len} B) must be smaller than JSONL ({} B)",
            t1.len());
}

/// Corrupt magic, flipped version and mid-event truncation are all
/// load-time errors — file-level twins of the byte-level negatives in
/// `replay/binary.rs`.
#[test]
fn corrupt_and_truncated_binary_files_are_rejected_at_load() {
    let mut events = record_run(5, 6, 0);
    // guarantee the file ends in a raw 8-byte checksum, so a short cut
    // is unambiguously mid-event
    let last_t = events.last().unwrap().t_us;
    events.push(TraceEvent {
        t_us: last_t + 1,
        body: EventBody::Response {
            id: 9999,
            batch_size: 1,
            bucket: 1,
            latency_us: 7,
            checksum: 0xdead_beef_dead_beef,
        },
    });
    let path = tmp("corrupt.bin");
    binary::write_trace(&path, &header(5), &events).unwrap();
    assert!(Replayer::load(&path).is_ok(), "pristine file loads");
    let bytes = std::fs::read(&path).unwrap();

    // corrupt magic: no longer binary, and not valid JSONL either
    let mut bad = bytes.clone();
    bad[0] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    assert!(Replayer::load(&path).is_err());

    // mid-event EOF: cut into the trailing response's checksum
    std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
    let err = Replayer::load(&path).unwrap_err().to_string();
    assert!(err.contains("offset") || err.contains("truncated"),
            "error should locate the cut: {err}");
    std::fs::remove_file(&path).ok();
}

/// A length prefix above `u32::MAX` is rejected with an explicit
/// byte-located decode error *before* the `u64 → usize` cast — on a
/// 32-bit edge target that cast would silently truncate a corrupt
/// length into a wrong-but-plausible one. The crafted event is a
/// latent arrival whose model-string length claims 2³², which no
/// plausibility cap below it should mask.
#[test]
fn oversize_length_prefix_is_rejected_with_byte_offset() {
    let mut bytes = Vec::new();
    binary::encode_header_into(&mut bytes, &header(1));
    bytes.push(1); // TAG_ARRIVAL_LATENT
    bytes.push(0); // Δt (zigzag 0)
    bytes.push(1); // id
    let len_at = bytes.len();
    // varint encoding of u32::MAX + 1 as the model-string length
    let mut v = u32::MAX as u64 + 1;
    while v >= 0x80 {
        bytes.push((v & 0x7f) as u8 | 0x80);
        v >>= 7;
    }
    bytes.push(v as u8);
    let path = tmp("oversize_len.bin");
    std::fs::write(&path, &bytes).unwrap();
    let err = Replayer::load(&path).unwrap_err().to_string();
    std::fs::remove_file(&path).ok();
    assert!(err.contains("exceeds u32::MAX"),
            "error should name the overflow, got: {err}");
    assert!(err.contains(&format!("byte {len_at}")),
            "error should locate the length prefix at byte {len_at}: \
             {err}");
}

/// v1–v4 JSONL traces (older version numbers, no checkpoints, no
/// priority/fleet fields) still load and replay cleanly against the
/// v5 reader — it accepts 1..=5, reading absent priorities as the
/// default class and an absent fleet roster as empty. The old-format
/// file is produced faithfully: version rewritten AND the v5-only
/// fields stripped from every line.
#[test]
fn v1_to_v4_jsonl_traces_still_load_and_replay() {
    let events = record_run(5, 6, 0);
    let path = tmp("compat.jsonl");
    codec::write_trace(&path, &header(5), &events).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    for v in [4u32, 3, 2, 1] {
        let rewritten = text
            .replacen("\"huge2_trace\":5",
                      &format!("\"huge2_trace\":{v}"), 1)
            .replace(",\"priority\":\"interactive\"", "")
            .replace(",\"fleet\":[]", "");
        assert_ne!(rewritten, text, "header version must be rewritable");
        assert!(!rewritten.contains("priority"),
                "v{v} fixture must carry no v5 fields");
        std::fs::write(&path, &rewritten).unwrap();
        let rp = Replayer::load(&path).unwrap();
        assert!(rp
            .events()
            .iter()
            .filter_map(|e| match &e.body {
                EventBody::RequestArrival { priority, .. } =>
                    Some(*priority),
                _ => None,
            })
            .all(|p| p == Priority::default()),
            "v{v}: priority-less arrivals must read as the default \
             class");
        let eng = tiny_engine(5, None);
        let report = rp.run(&eng, Timing::Fast).unwrap();
        eng.shutdown();
        assert!(report.is_clean(), "v{v}: {:?}", report.divergences);
        assert_eq!(report.matched, 6, "v{v}");
    }
    std::fs::remove_file(&path).ok();
}

/// A checkpointed recording saved in the binary format loads by magic,
/// fingerprint-verifies, and replays end-to-end with zero divergence.
#[test]
fn checkpointed_binary_trace_replays_end_to_end() {
    let events = record_run(7, 12, 8);
    assert!(events.iter().any(|e| {
        matches!(e.body, EventBody::Checkpoint(_))
    }), "cadence 8 over 12 requests must checkpoint");
    let path = tmp("ck.bin");
    binary::write_trace(&path, &header(7), &events).unwrap();
    let rp = Replayer::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let eng = tiny_engine(7, None);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, 12);
}

/// Replaying every window individually must (a) verify cleanly, (b)
/// drive fewer arrivals than the full trace for interior windows, and
/// (c) tile the full replay — summed matched outcomes equal the full
/// run's.
#[test]
fn window_replays_compose_to_the_full_replay() {
    let events = record_run(5, 24, 8);
    window::verify_fingerprints(&events).unwrap();
    let rp = Replayer::from_parts(header(5), events);
    let wm = rp.windows();
    assert!(wm.count() >= 3, "expected several windows, got {}",
            wm.count());
    let eng = tiny_engine(5, None);
    let full = rp.run(&eng, Timing::Fast).unwrap();
    assert!(full.is_clean(), "full: {:?}", full.divergences);
    assert_eq!(full.matched, 24);
    let mut matched = 0usize;
    let mut min_requests = usize::MAX;
    for w in 0..wm.count() {
        let r = rp.run_with(&eng, Timing::Fast, &ReplayOptions {
            window: Some(w..w + 1),
            progress: false,
        }).unwrap();
        assert!(r.is_clean(), "window {w}: {:?}", r.divergences);
        assert_eq!(r.extra_responses, 0,
                   "window {w}: boundary-pending ids are not extras");
        matched += r.matched;
        min_requests = min_requests.min(r.requests);
    }
    eng.shutdown();
    assert_eq!(matched, full.matched, "windows tile the trace");
    assert!(min_requests < full.requests,
            "a single window must re-drive fewer arrivals than the \
             full trace ({min_requests} vs {})", full.requests);
}

/// An out-of-range window is an error, not a panic.
#[test]
fn out_of_range_window_is_a_clean_error() {
    let events = record_run(5, 8, 8);
    let rp = Replayer::from_parts(header(5), events);
    let wm = rp.windows();
    let eng = tiny_engine(5, None);
    let err = rp.run_with(&eng, Timing::Fast, &ReplayOptions {
        window: Some(0..wm.count() + 1),
        progress: false,
    }).unwrap_err().to_string();
    eng.shutdown();
    assert!(err.contains("out of range"), "{err}");
}

/// Inject a single-bit checksum tamper, synthesize checkpoints *after*
/// the tamper (so fingerprints are self-consistent — the live-engine
/// divergence case, which only replay can catch), and bisect: the
/// search must land on exactly the tampered window in at most
/// 2 + ⌈log₂ W⌉ window replays.
#[test]
fn bisect_localizes_an_injected_divergence() {
    let mut events = record_run(5, 24, 0);
    let resp_indices: Vec<usize> = events
        .iter()
        .enumerate()
        .filter_map(|(i, e)| {
            matches!(e.body, EventBody::Response { .. }).then_some(i)
        })
        .collect();
    let victim = resp_indices[resp_indices.len() / 2];
    let victim_id = match &mut events[victim].body {
        EventBody::Response { id, checksum, .. } => {
            *checksum ^= 1;
            *id
        }
        _ => unreachable!(),
    };
    let events = window::insert_checkpoints(&events, 8);
    // tamper happened before synthesis: the trace is self-consistent
    window::verify_fingerprints(&events).unwrap();
    let idx = events
        .iter()
        .position(|e| matches!(&e.body,
            EventBody::Response { id, .. } if *id == victim_id))
        .unwrap();
    let rp = Replayer::from_parts(header(5), events);
    let wm = rp.windows();
    assert!(wm.count() >= 4, "want several windows, got {}", wm.count());
    let expected = wm.window_of_event(idx);
    let eng = tiny_engine(5, None);
    let br = rp.bisect(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert_eq!(br.divergent, Some(expected),
               "bisect must land on the tampered window");
    let budget = 2 + (usize::BITS
                      - (wm.count() - 1).leading_zeros()) as usize;
    assert!(br.replays <= budget,
            "{} replays for {} windows (budget {budget})",
            br.replays, wm.count());
    match br.report.first_divergence() {
        Some(Divergence::ChecksumMismatch { event_index, id, .. }) => {
            assert_eq!(*event_index, idx, "absolute trace index");
            assert_eq!(*id, victim_id);
        }
        other => panic!("expected ChecksumMismatch, got {other:?}"),
    }
}

/// Fingerprint verification catches a post-recording tamper at load —
/// before any replay compute is spent.
#[test]
fn tampered_checkpointed_trace_fails_fingerprint_verification_at_load() {
    let mut events = record_run(5, 16, 8);
    let victim = events
        .iter()
        .position(|e| matches!(e.body, EventBody::Response { .. }))
        .unwrap();
    if let EventBody::Response { checksum, .. } = &mut events[victim].body {
        *checksum ^= 1;
    }
    let path = tmp("tampered.bin");
    binary::write_trace(&path, &header(5), &events).unwrap();
    let err = Replayer::load(&path).unwrap_err().to_string();
    std::fs::remove_file(&path).ok();
    assert!(err.contains("fingerprint"), "{err}");
}
