//! Autotuner stack tests (DESIGN.md §15): the tuned-plan artifact and
//! its interaction with the record/replay digest gate.
//!
//! * a trace recorded under the heuristic plan hard-errors when replayed
//!   against an engine serving a *differing* tuned plan — the
//!   engine-selection digest gate treats tuned selections exactly like a
//!   changed `Auto` heuristic — and round-trips divergence-free when the
//!   serving plan matches the recording.
//! * `huge2 tune` determinism: tuning the same net twice under the
//!   pinned reference calibration encodes to identical bytes.
//! * artifact robustness: corrupt/truncated files fail with byte-offset
//!   errors; a version bump decodes to a clean typed fallback, not an
//!   error.

use huge2::config::EngineConfig;
use huge2::coordinator::{Engine, Model, Payload};
use huge2::deconv::Engine as DeconvEngine;
use huge2::gan::Generator;
use huge2::plan::{ExecPlan, PlanOp, PlanTuning, StepSelection};
use huge2::replay::{Replayer, Timing, TraceEvent, TraceHeader, TraceSink};
use huge2::rng::Rng;
use huge2::tune::{tune_plan, Calibration, LoadedTuned, TunedPlan};
use std::sync::Arc;

const Z_DIM: usize = 8;

/// Native engine over `tiny_cgan(seed)`, optionally recording, serving
/// either the heuristic plan or an explicitly provided (tuned) one.
fn engine_with(seed: u64, sink: Option<Arc<TraceSink>>,
               plan: Option<ExecPlan>) -> Engine {
    let cfg = EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    if let Some(s) = sink {
        e.set_trace_sink(s).unwrap();
    }
    let gen = Arc::new(Generator::tiny_cgan(seed));
    assert_eq!(gen.z_dim, Z_DIM);
    let model = match plan {
        Some(p) => Model::native_with_plan("tiny", gen, 0, p),
        None => Model::native("tiny", gen, 0),
    };
    e.register_native(model).unwrap();
    e
}

/// A tuning that provably differs from the heuristic plan: every
/// transpose step flipped to `Segregated x2` (bit-identical outputs,
/// different digest — see plan::with_tuning tests).
fn differing_tuning(plan: &ExecPlan) -> PlanTuning {
    let selections: Vec<StepSelection> = plan
        .steps()
        .iter()
        .enumerate()
        .filter(|(_, st)| matches!(st.op, PlanOp::TransposeConv { .. }))
        .map(|(i, st)| {
            assert_ne!(st.engine, Some(DeconvEngine::Segregated),
                       "heuristic never picks Segregated");
            StepSelection {
                step: i,
                engine: Some(DeconvEngine::Segregated),
                threads: 2,
                tile: None,
            }
        })
        .collect();
    assert!(!selections.is_empty());
    PlanTuning { selections }
}

/// Record `n` requests against `eng`; header carries the engine's own
/// compiled-plan digest (exactly what `serve --record` writes).
fn record_run(eng: Engine, sink: Arc<TraceSink>, n: usize)
              -> (TraceHeader, Vec<TraceEvent>) {
    let digest = eng.plan_digest("tiny").expect("native model has a plan");
    let mut rng = Rng::new(1234);
    let mut pending = Vec::new();
    for _ in 0..n {
        let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
        pending.push(eng.submit("tiny", Payload::latent(z, vec![]))
            .unwrap());
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    eng.shutdown();
    let header = TraceHeader {
        model: "tiny".into(),
        backend: "native".into(),
        seed: 5,
        z_dim: Z_DIM,
        cond_dim: 0,
        task: "generate".into(),
        net: String::new(),
        engine_digest: format!("{:016x}", digest),
        fleet: Vec::new(),
    };
    (header, sink.snapshot())
}

#[test]
fn heuristic_trace_hard_errors_against_a_differing_tuned_plan() {
    // record under the heuristic Auto plan
    let sink = Arc::new(TraceSink::new());
    let eng = engine_with(5, Some(sink.clone()), None);
    let (header, events) = record_run(eng, sink, 8);

    // replay against an engine serving a digest-moving tuned plan:
    // the gate must refuse up front, not report per-request divergences
    let base = Generator::tiny_cgan(5).plan().clone();
    let tuned = base.with_tuning(&differing_tuning(&base));
    assert_ne!(tuned.engine_digest(), base.engine_digest());
    let eng = engine_with(5, None, Some(tuned));
    let err = Replayer::from_parts(header.clone(), events.clone())
        .run(&eng, Timing::Fast)
        .unwrap_err()
        .to_string();
    eng.shutdown();
    assert!(err.contains("digest mismatch"), "{err}");
    assert!(err.contains(&header.engine_digest),
            "error must name the recorded digest: {err}");

    // same trace against the matching heuristic plan: divergence-free
    let eng = engine_with(5, None, None);
    let report = Replayer::from_parts(header, events)
        .run(&eng, Timing::Fast)
        .unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, 8);
}

#[test]
fn tuned_trace_round_trips_under_the_same_tuned_plan() {
    // record *under* the tuned plan — header carries the tuned digest
    let base = Generator::tiny_cgan(5).plan().clone();
    let tuning = differing_tuning(&base);
    let sink = Arc::new(TraceSink::new());
    let eng = engine_with(5, Some(sink.clone()),
                          Some(base.with_tuning(&tuning)));
    let (header, events) = record_run(eng, sink, 8);
    assert_eq!(header.engine_digest,
               format!("{:016x}",
                       base.with_tuning(&tuning).engine_digest()));

    // replay against a freshly compiled engine under the same tuning
    let eng = engine_with(5, None, Some(base.with_tuning(&tuning)));
    let report = Replayer::from_parts(header.clone(), events.clone())
        .run(&eng, Timing::Fast)
        .unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "diverged: {:?}", report.divergences);
    assert_eq!(report.matched, 8);

    // and the heuristic plan refuses the tuned trace symmetrically
    let eng = engine_with(5, None, None);
    let err = Replayer::from_parts(header, events)
        .run(&eng, Timing::Fast)
        .unwrap_err()
        .to_string();
    eng.shutdown();
    assert!(err.contains("digest mismatch"), "{err}");
}

#[test]
fn tuning_twice_under_reference_calibration_is_byte_identical() {
    let cal = Calibration::reference();
    let plan = Generator::tiny_cgan(7).plan().clone();
    let a = tune_plan(&plan, "tiny_cgan", &cal).encode();
    let b = tune_plan(&plan, "tiny_cgan", &cal).encode();
    assert_eq!(a, b, "tune must be deterministic under the pinned \
                      reference calibration");
    // ... and the artifact applies to an independently compiled plan of
    // the same net+seed (what `serve --tuned` does after a fresh start)
    let fresh = Generator::tiny_cgan(7).plan().clone();
    match TunedPlan::decode(&a).unwrap() {
        LoadedTuned::Tuned(t) => {
            let served = t.apply(&fresh).unwrap();
            assert_eq!(served.engine_digest(), t.tuned_digest);
        }
        LoadedTuned::VersionMismatch { found } => {
            panic!("fresh artifact reported version {found}");
        }
    }
}

#[test]
fn corrupt_artifacts_fail_with_byte_offsets() {
    let plan = Generator::tiny_cgan(7).plan().clone();
    let bytes = tune_plan(&plan, "tiny_cgan",
                          &Calibration::reference()).encode();

    // truncation: error names the offset where the file ran out
    let err = TunedPlan::decode(&bytes[..bytes.len() - 3]).unwrap_err();
    assert!(err.contains("at byte"), "{err}");
    assert!(err.contains("truncated"), "{err}");

    // bad magic: rejected before any field parsing
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    let err = TunedPlan::decode(&bad).unwrap_err();
    assert!(err.contains("bad magic"), "{err}");

    // trailing garbage: a valid plan followed by junk is corrupt, not
    // silently accepted
    let mut long = bytes.clone();
    long.push(0);
    let err = TunedPlan::decode(&long).unwrap_err();
    assert!(err.contains("trailing"), "{err}");
    assert!(err.contains("at byte"), "{err}");
}

#[test]
fn version_bump_decodes_to_a_typed_fallback() {
    let plan = Generator::tiny_cgan(7).plan().clone();
    let mut bytes = tune_plan(&plan, "tiny_cgan",
                              &Calibration::reference()).encode();
    // version is the LEB128 varint right after the 8-byte magic; the
    // current version (1) is a single byte there
    bytes[8] = 7;
    match TunedPlan::decode(&bytes).unwrap() {
        LoadedTuned::VersionMismatch { found } => assert_eq!(found, 7),
        LoadedTuned::Tuned(_) => {
            panic!("future version must not parse as v1")
        }
    }
}

#[test]
fn stale_artifact_refuses_a_moved_base_plan() {
    // tune against seed-7 weights, apply to a *different architecture's*
    // plan (dcgan geometry digests differently) — loud failure
    let plan = Generator::tiny_cgan(7).plan().clone();
    let art = tune_plan(&plan, "tiny_cgan", &Calibration::reference());
    let other = Generator::tiny_cgan(7).plan()
        .with_tuning(&differing_tuning(&plan));
    let err = art.apply(&other).unwrap_err();
    assert!(err.contains("stale"), "{err}");
}
