//! Fault-containment integration tests (DESIGN.md §11).
//!
//! The contract under test: **every accepted request terminates in
//! exactly one observable outcome** — `Ok(Response)` or a typed
//! `ServeError` — and the counters satisfy the conservation invariant
//! `submitted == completed + rejected + failed` once the engine is
//! drained. Specifically:
//!
//! * a malformed row in a batch gather fails *only that request*; the
//!   rest of the batch executes bit-identically to a clean batch;
//! * an injected worker panic is caught by supervision, fails its
//!   batch with `BatchFailed`, and leaves the worker pool serving;
//! * queue-full submits return the typed `Backpressure` refusal;
//! * the conservation invariant holds after a concurrent soak mixing
//!   valid requests, validation rejects, backpressure floods and a
//!   worker panic;
//! * failures are trace outcomes (v3 `Failed` events) and replay
//!   verifies failure determinism like it verifies checksums.

use huge2::config::EngineConfig;
use huge2::coordinator::worker::{execute_batch, ObsCtx};
use huge2::coordinator::{Engine, Model, Observability, Payload, Request,
                         ServeError, ServeResult};
use huge2::gan::Generator;
use huge2::metrics::span::{SpanOutcome, STAGE_FORWARD, STAGE_GATHER,
                           STAGE_QUEUE_WAIT};
use huge2::metrics::{FlightRecorder, MetricsRegistry, SpanStamps, Stage};
use huge2::replay::{Divergence, EventBody, Replayer, Timing,
                    TraceHeader, TraceSink};
use huge2::rng::Rng;
use huge2::workspace::Workspace;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const Z_DIM: usize = 8;

fn tiny_model() -> Model {
    Model::native("tiny", Arc::new(Generator::tiny_cgan(5)), 0)
}

fn tiny_engine(workers: usize, queue_depth: usize) -> Engine {
    let cfg = EngineConfig {
        workers,
        queue_depth,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    e.register_native(tiny_model()).unwrap();
    e
}

fn req(id: u64, payload: Payload)
       -> (Request, mpsc::Receiver<ServeResult>) {
    let (tx, rx) = mpsc::channel();
    (Request { id, payload, priority: Default::default(),
               enqueued: Instant::now(),
               stamps: SpanStamps::now(), reply: tx }, rx)
}

fn latent(rng: &mut Rng) -> Payload {
    Payload::latent((0..Z_DIM).map(|_| rng.next_normal()).collect(),
                    vec![])
}

// ------------------------------------------------ gather-row isolation

/// One malformed payload in a native batch gather fails exactly that
/// request with `Validation`; the good rows still execute and their
/// outputs are bit-identical to a clean solo run (batch-composition
/// invariance extends to faulted batches).
#[test]
fn mixed_batch_serves_good_rows_bit_identically() {
    let model = tiny_model();
    let ws = Workspace::new();
    let mut hnd = ws.handle();
    let mut rng = Rng::new(77);
    let goods: Vec<Payload> = (0..3).map(|_| latent(&mut rng)).collect();

    // solo reference checksums, one clean single-request batch each
    let mut solo = Vec::new();
    for (i, p) in goods.iter().enumerate() {
        let (r, rx) = req(100 + i as u64, p.clone());
        let mut batch = vec![r];
        let out = execute_batch(&model, &mut batch, None, &mut hnd,
                                None, |_| {});
        assert_eq!((out.completed, out.failed), (1, 0));
        solo.push(rx.recv().unwrap().unwrap().output.checksum());
    }

    // mixed batch: good, BAD (wrong latent width), good, good
    let (r0, rx0) = req(0, goods[0].clone());
    let (rb, rxb) = req(1, Payload::latent(vec![0.0; Z_DIM - 3], vec![]));
    let (r2, rx2) = req(2, goods[1].clone());
    let (r3, rx3) = req(3, goods[2].clone());
    let mut batch = vec![r0, rb, r2, r3];
    let out = execute_batch(&model, &mut batch, None, &mut hnd, None,
                            |o| {
        assert_eq!(o.completed, 3);
        assert_eq!(o.failed, 1);
    });
    assert!(batch.is_empty(), "every request must be drained");
    assert_eq!(out.bucket, 3, "only the good rows execute");
    assert!(out.error.is_none(), "row fault is not a batch fault");

    let err = rxb.recv().unwrap().unwrap_err();
    assert_eq!(err.kind(), "validation");
    assert!(err.to_string().contains("input elements"), "{err}");
    for (rx, want) in [rx0, rx2, rx3].into_iter().zip(&solo) {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.output.checksum(), *want,
                   "good rows must be bit-identical to a clean batch");
        assert_eq!(resp.batch_size, 4);
    }
}

/// Worker-level trace capture: the malformed row records a v3 `Failed`
/// event (kind `validation`), the good rows record `Response` events —
/// all before any client observes its outcome.
#[test]
fn malformed_row_records_a_failed_event() {
    let model = tiny_model();
    let ws = Workspace::new();
    let mut hnd = ws.handle();
    let mut rng = Rng::new(78);
    let sink = TraceSink::new();
    let (r0, _rx0) = req(10, latent(&mut rng));
    let (rb, _rxb) = req(11, Payload::image(
        huge2::tensor::Tensor::zeros(&[1, 2, 2, 1]), 0));
    let mut batch = vec![r0, rb];
    execute_batch(&model, &mut batch, Some(&sink), &mut hnd, None,
                  |_| {});
    let evs = sink.snapshot();
    assert!(evs.iter().any(|e| matches!(&e.body,
        EventBody::Response { id: 10, .. })));
    assert!(evs.iter().any(|e| matches!(&e.body,
        EventBody::Failed { id: 11, kind, .. } if kind == "validation")));
}

// ----------------------------------------------------- supervision

/// An injected worker panic must not shrink the pool: the batch's
/// requests fail with a typed `BatchFailed`, the panic is counted, and
/// the *same single worker thread* keeps serving afterwards.
#[test]
fn injected_worker_panic_leaves_pool_serving() {
    let e = tiny_engine(1, 16);
    let mut rng = Rng::new(9);
    // healthy round first
    let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
    e.generate("tiny", z, vec![]).unwrap();

    assert!(!e.inject_worker_panic("no-such-model"));
    assert!(e.inject_worker_panic("tiny"));
    let rx = e.submit("tiny", latent(&mut rng)).unwrap();
    let outcome = rx.recv_timeout(Duration::from_secs(30))
        .expect("supervision must deliver an outcome, not hang");
    let err = outcome.unwrap_err();
    assert_eq!(err.kind(), "batch_failed");
    assert!(err.to_string().contains("panicked"), "{err}");
    assert_eq!(e.counters.panics.load(Relaxed), 1);

    // the only worker thread survived the panic and still serves
    for _ in 0..3 {
        let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
        let r = e.generate("tiny", z, vec![]).unwrap();
        assert_eq!(r.output.shape(), &[1, 32, 32, 3]);
    }
    assert_eq!(e.counters.in_flight(), 0);
    e.shutdown();
}

// ----------------------------------------------------- backpressure

/// Queue-full submits return the *typed* `Backpressure` refusal, and
/// every accepted request still completes.
#[test]
fn queue_full_submit_returns_typed_backpressure() {
    let cfg = EngineConfig {
        workers: 1,
        queue_depth: 2,
        max_batch: 1,
        batch_timeout_us: 1,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    e.register_native(tiny_model()).unwrap();
    let mut rng = Rng::new(3);
    let mut receivers = Vec::new();
    let mut backpressured = 0u64;
    for _ in 0..200 {
        match e.submit("tiny", latent(&mut rng)) {
            Ok(rx) => receivers.push(rx),
            Err(err) => {
                assert_eq!(err, ServeError::Backpressure, "{err}");
                backpressured += 1;
            }
        }
    }
    assert!(backpressured > 0, "flood must trigger backpressure");
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(e.counters.rejected.load(Relaxed), backpressured);
    assert_eq!(e.counters.in_flight(), 0);
}

// ------------------------------------------------------- conservation

/// The outcome-conservation invariant under concurrent fault pressure:
/// valid requests, validation rejects, a backpressure flood and an
/// injected panic all running at once — afterwards every submission is
/// accounted for exactly once and no reply channel closed silently.
#[test]
#[ignore = "long concurrent soak; CI release job runs it via -- --ignored"]
fn conservation_invariant_holds_after_concurrent_fault_soak() {
    let e = Arc::new(tiny_engine(2, 8));
    let tally = Arc::new(huge2::metrics::Counters::new()); // client side
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let e = e.clone();
        let tally = tally.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t);
            let mut pending: Vec<mpsc::Receiver<ServeResult>> =
                Vec::new();
            let drain = |pending: &mut Vec<mpsc::Receiver<ServeResult>>| {
                for rx in pending.drain(..) {
                    match rx.recv_timeout(Duration::from_secs(30)) {
                        Ok(Ok(_)) => {
                            tally.completed.fetch_add(1, Relaxed);
                        }
                        Ok(Err(_)) => {
                            tally.failed.fetch_add(1, Relaxed);
                        }
                        Err(_) => panic!("no terminal outcome"),
                    }
                }
            };
            for i in 0..30u64 {
                let payload = if i % 7 == 3 {
                    // deterministic validation reject
                    Payload::latent(vec![0.0; Z_DIM + 1], vec![])
                } else {
                    latent(&mut rng)
                };
                tally.submitted.fetch_add(1, Relaxed);
                match e.submit("tiny", payload) {
                    Ok(rx) => pending.push(rx),
                    Err(_) => {
                        tally.rejected.fetch_add(1, Relaxed);
                    }
                }
                if i == 11 && t == 0 {
                    assert!(e.inject_worker_panic("tiny"));
                }
                // burst without draining to provoke backpressure, then
                // drain to let the soak make progress
                if pending.len() >= 6 {
                    drain(&mut pending);
                }
            }
            drain(&mut pending);
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let c = &e.counters;
    assert_eq!(c.submitted.load(Relaxed), 120);
    assert_eq!(c.submitted.load(Relaxed),
               tally.submitted.load(Relaxed));
    assert_eq!(c.completed.load(Relaxed), tally.completed.load(Relaxed));
    assert_eq!(c.failed.load(Relaxed), tally.failed.load(Relaxed));
    assert_eq!(c.rejected.load(Relaxed), tally.rejected.load(Relaxed));
    assert!(c.rejected.load(Relaxed) >= 4 * (30 / 7),
            "validation rejects must be counted");
    assert_eq!(c.panics.load(Relaxed), 1, "the injected panic was caught");
    assert!(c.failed.load(Relaxed) >= 1,
            "the panicked batch must surface as failed requests");
    // conservation: submitted == completed + rejected + failed
    assert_eq!(c.in_flight(), 0,
               "drained engine must conserve outcomes: submitted={} \
                completed={} rejected={} failed={}",
               c.submitted.load(Relaxed), c.completed.load(Relaxed),
               c.rejected.load(Relaxed), c.failed.load(Relaxed));
    Arc::into_inner(e).expect("soak threads done").shutdown();
}

// -------------------------------------------------- stage-span chains

/// Every terminal outcome carries a complete, monotonically ordered
/// stage chain in the flight recorder (DESIGN.md §12): completed
/// requests pass through all eight stages, submit-side rejects stop at
/// `rejected`, and a panic-failed request ends at `failed` without ever
/// reaching `gather_start` (the injected panic fires first). The panic
/// excerpt names the failing request id.
#[test]
fn terminal_outcomes_carry_monotone_stage_chains() {
    use huge2::metrics::Stage::*;
    let e = tiny_engine(1, 4);
    let mut rng = Rng::new(44);

    let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
    let completed_id = e.generate("tiny", z, vec![]).unwrap().id;
    // ids are sequential per engine, so the next two are deterministic
    let rejected_id = completed_id + 1;
    let failed_id = completed_id + 2;

    let err = e
        .submit("tiny", Payload::latent(vec![0.0; Z_DIM + 2], vec![]))
        .unwrap_err();
    assert_eq!(err.kind(), "validation");

    assert!(e.inject_worker_panic("tiny"));
    let rx = e.submit("tiny", latent(&mut rng)).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_err());

    let obs = e.observability().clone();
    e.shutdown(); // quiesce writers before reading chains

    let chain = |id: u64| -> Vec<Stage> {
        obs.flight.events_for(id).iter().map(|ev| ev.stage).collect()
    };
    assert_eq!(chain(completed_id),
               vec![Submitted, Enqueued, Popped, Batched, GatherStart,
                    ForwardStart, ForwardEnd, Completed]);
    assert_eq!(chain(rejected_id), vec![Submitted, Rejected]);
    assert_eq!(chain(failed_id),
               vec![Submitted, Enqueued, Popped, Batched, Failed]);
    for id in [completed_id, rejected_id, failed_id] {
        let evs = obs.flight.events_for(id);
        assert!(evs.windows(2).all(|w| w[0].t_us <= w[1].t_us),
                "stage chain of {id} must be monotone in time");
        assert!(evs.last().unwrap().stage.is_terminal());
    }
    // the panic-path excerpt correlates the failure by request id
    let excerpt = obs.flight.excerpt(32);
    assert!(excerpt.contains(&format!("req={failed_id} failed")),
            "{excerpt}");
    // stage histograms: the completed request fills all five completed
    // cells; the panic-failed one lands in the failed queue-wait cell
    assert_eq!(obs.stages.merged(STAGE_FORWARD).count(), 1);
    assert_eq!(obs.stages
                   .cell(0, SpanOutcome::Completed, STAGE_QUEUE_WAIT)
                   .count(), 1);
    assert_eq!(obs.stages
                   .cell(0, SpanOutcome::Failed, STAGE_QUEUE_WAIT)
                   .count(), 1);
}

/// Direct `execute_batch` with an observability context: a row that
/// fails gather validation reaches `gather_start` but never
/// `forward_start`, while its good neighbour runs the full chain — all
/// on the worker lane the context declares.
#[test]
fn gather_validation_failure_chain_stops_before_forward() {
    use huge2::metrics::Stage::*;
    let model = tiny_model();
    let ws = Workspace::new();
    let mut hnd = ws.handle();
    let reg = MetricsRegistry::new();
    let obs = Observability::new(&reg, 64, true);
    let octx = ObsCtx { obs: &obs, task: 0, worker: 3 };
    let mut rng = Rng::new(5);
    let (r0, _rx0) = req(20, latent(&mut rng));
    let (rb, rxb) =
        req(21, Payload::latent(vec![0.0; Z_DIM - 1], vec![]));
    let mut batch = vec![r0, rb];
    execute_batch(&model, &mut batch, None, &mut hnd, Some(&octx),
                  |_| {});
    assert_eq!(rxb.recv().unwrap().unwrap_err().kind(), "validation");

    let chain = |id: u64| -> Vec<Stage> {
        obs.flight.events_for(id).iter().map(|ev| ev.stage).collect()
    };
    assert_eq!(chain(21), vec![GatherStart, Failed]);
    assert_eq!(chain(20),
               vec![GatherStart, ForwardStart, ForwardEnd, Completed]);
    assert!(obs.flight.snapshot().iter().all(|ev| ev.worker == 3));
    // both rows pay the same batch-level gather span, in their own
    // outcome cells
    assert_eq!(obs.stages
                   .cell(0, SpanOutcome::Failed, STAGE_GATHER)
                   .count(), 1);
    assert_eq!(obs.stages
                   .cell(0, SpanOutcome::Completed, STAGE_GATHER)
                   .count(), 1);
    assert_eq!(obs.stages
                   .cell(0, SpanOutcome::Completed, STAGE_FORWARD)
                   .count(), 1);
}

/// Concurrent wrap soak over the flight recorder: the overwrite
/// accounting is exact (ticket-counter arithmetic, not a sampled
/// statistic) and a quiescent snapshot returns the whole ring in ticket
/// order. Fast — 20k pushes over a 64-slot ring.
#[test]
fn flight_recorder_counts_overwrites_exactly_under_concurrency() {
    let fr = Arc::new(FlightRecorder::new(64));
    let threads = 4u64;
    let per = 5000u64;
    let mut joins = Vec::new();
    for t in 0..threads {
        let fr = fr.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..per {
                let stage = match i % 3 {
                    0 => Stage::Popped,
                    1 => Stage::Batched,
                    _ => Stage::Completed,
                };
                fr.record(t * per + i, stage, t as u32);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(fr.pushed(), threads * per);
    assert_eq!(fr.overwrites(), threads * per - 64,
               "overwrites must equal pushed - capacity, exactly");
    let evs = fr.snapshot();
    assert_eq!(evs.len(), 64,
               "a quiescent snapshot returns the full ring");
    for w in evs.windows(2) {
        assert!(w[0].ticket < w[1].ticket, "ticket order");
    }
}

// ------------------------------------------------- replay integration

fn gan_header(seed: u64, engine_digest: String) -> TraceHeader {
    TraceHeader {
        model: "tiny".into(),
        backend: "native".into(),
        seed,
        z_dim: Z_DIM,
        cond_dim: 0,
        task: "generate".into(),
        net: String::new(),
        engine_digest,
        fleet: Vec::new(),
    }
}

/// Record a run whose third batch panics: the trace carries v3 `Failed`
/// events. A replay (no injection) answers those requests — which the
/// failure-determinism check must flag as `FailureMismatch`, with the
/// healthy requests still verifying bit-for-bit.
#[test]
fn recorded_panic_failures_are_checked_on_replay() {
    let sink = Arc::new(TraceSink::new());
    let cfg = EngineConfig {
        workers: 1,
        queue_depth: 16,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    e.set_trace_sink(sink.clone()).unwrap();
    e.register_native(tiny_model()).unwrap();
    let mut rng = Rng::new(12);
    for _ in 0..2 {
        let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
        e.generate("tiny", z, vec![]).unwrap();
    }
    e.inject_worker_panic("tiny");
    let rx = e.submit("tiny", latent(&mut rng)).unwrap();
    let victim_err = rx.recv_timeout(Duration::from_secs(30))
        .unwrap().unwrap_err();
    assert_eq!(victim_err.kind(), "batch_failed");
    e.shutdown();

    let events = sink.snapshot();
    let failed_ids: Vec<u64> = events.iter().filter_map(|ev| {
        match &ev.body {
            EventBody::Failed { id, kind, .. } => {
                assert_eq!(kind, "batch_failed");
                Some(*id)
            }
            _ => None,
        }
    }).collect();
    assert_eq!(failed_ids.len(), 1, "the panicked request was recorded");

    let rp = Replayer::from_parts(gan_header(5, String::new()), events);
    let eng = tiny_engine(2, 64);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    // healthy outcomes reproduce; the recorded failure does not (no
    // panic on replay) and is named as a failure-determinism divergence
    assert_eq!(report.divergences.len(), 1, "{:?}", report.divergences);
    match &report.divergences[0] {
        Divergence::FailureMismatch { id, recorded_kind, replayed, .. }
        => {
            assert_eq!(*id, failed_ids[0]);
            assert_eq!(recorded_kind, "batch_failed");
            assert_eq!(replayed, "response");
        }
        other => panic!("expected FailureMismatch, got {other:?}"),
    }
}

/// Satellite regression: replaying a digest-less (pre-plan) trace that
/// diverges by checksum names the likely cause — "re-record or pin the
/// engine" — instead of leaving a bare mismatch; a digest-carrying
/// trace with the same mismatch gets no such hint.
#[test]
fn digest_less_checksum_divergence_carries_re_record_hint() {
    // record with seed-5 weights, digest-less header (pre-plan style)
    let sink = Arc::new(TraceSink::new());
    let cfg = EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg);
    e.set_trace_sink(sink.clone()).unwrap();
    e.register_native(tiny_model()).unwrap();
    let mut rng = Rng::new(21);
    for _ in 0..4 {
        let z: Vec<f32> = (0..Z_DIM).map(|_| rng.next_normal()).collect();
        e.generate("tiny", z, vec![]).unwrap();
    }
    e.shutdown();
    let events = sink.snapshot();

    // clean same-weights replay: no divergence, no hint
    let rp = Replayer::from_parts(gan_header(5, String::new()),
                                  events.clone());
    let eng = tiny_engine(2, 64);
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(report.is_clean(), "{:?}", report.divergences);
    assert!(report.hint.is_none());

    // wrong-weights replay of the digest-less trace: mismatch + hint
    let rp = Replayer::from_parts(gan_header(6, String::new()),
                                  events.clone());
    let mut eng = Engine::new(EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    });
    eng.register_native(Model::native(
        "tiny", Arc::new(Generator::tiny_cgan(6)), 0)).unwrap();
    let report = rp.run(&eng, Timing::Fast).unwrap();
    let digest = eng.plan_digest("tiny").unwrap();
    eng.shutdown();
    assert!(!report.is_clean());
    let hint = report.hint.as_deref().expect("digest-less divergence \
                                              must carry a diagnosis");
    assert!(hint.contains("engine_digest"), "{hint}");
    assert!(hint.to_lowercase().contains("re-record"), "{hint}");

    // same divergence but the trace DOES pin the digest: no hint (the
    // selection gate already passed, so the cause is elsewhere)
    let rp = Replayer::from_parts(
        gan_header(6, format!("{digest:016x}")), events);
    let mut eng = Engine::new(EngineConfig {
        workers: 2,
        queue_depth: 64,
        max_batch: 4,
        batch_timeout_us: 500,
        ..EngineConfig::default()
    });
    eng.register_native(Model::native(
        "tiny", Arc::new(Generator::tiny_cgan(6)), 0)).unwrap();
    let report = rp.run(&eng, Timing::Fast).unwrap();
    eng.shutdown();
    assert!(!report.is_clean());
    assert!(report.hint.is_none(),
            "a digest-verified trace must not blame the digest");
}
