//! im2col / col2im — the DarkNet-baseline substrate.
//!
//! "Most 2D standard and transpose convolution implementations in modern
//! deep learning libraries are based on im2col" (paper §4). The baseline
//! engine materialises the full column matrix — including every inserted
//! zero of the inflated input — which is exactly the waste HUGE² removes.
//!
//! Layout: NHWC activations, so one column row is the flattened
//! `(R, S, C)` receptive field of one output position and the column
//! matrix is `(Ho·Wo, R·S·C)`.

use crate::tensor::Tensor;

/// Column matrix geometry for a standard conv over `x`.
pub fn col_shape(h: usize, w: usize, r: usize, s: usize, stride: usize,
                 pad: usize) -> (usize, usize, usize) {
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - s) / stride + 1;
    (ho, wo, r * s * 0 + r * s) // (ho, wo, taps)
}

/// Expand NHWC input (single batch) into the `(Ho·Wo, R·S·C)` column
/// matrix of a stride-`stride`, pad-`pad` standard convolution.
pub fn im2col(x: &Tensor, r: usize, s: usize, stride: usize, pad: usize)
              -> (Tensor, usize, usize) {
    let (b, h, w, c) = x.dims4();
    assert_eq!(b, 1, "im2col is per-image (batch handled by caller)");
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - s) / stride + 1;
    let mut col = Tensor::zeros(&[ho * wo, r * s * c]);
    im2col_into(x.data(), h, w, c, r, s, stride, pad, col.data_mut());
    (col, ho, wo)
}

/// [`im2col`] over a raw image slice, writing into caller-owned scratch
/// (a workspace slab on the pooled paths). Every element of `dst` is
/// written — padding taps are zero-filled explicitly — so a **dirty**
/// buffer is safe (DESIGN.md §9). Returns `(ho, wo)`.
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(xd: &[f32], h: usize, w: usize, c: usize, r: usize,
                   s: usize, stride: usize, pad: usize, dst: &mut [f32])
                   -> (usize, usize) {
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - s) / stride + 1;
    assert_eq!(xd.len(), h * w * c, "image size");
    assert_eq!(dst.len(), ho * wo * r * s * c, "column matrix size");
    for oy in 0..ho {
        for ox in 0..wo {
            let row = (oy * wo + ox) * r * s * c;
            for m in 0..r {
                let iy = (oy * stride + m) as isize - pad as isize;
                for n in 0..s {
                    let ix = (ox * stride + n) as isize - pad as isize;
                    let d = row + (m * s + n) * c;
                    if iy >= 0 && (iy as usize) < h && ix >= 0
                        && (ix as usize) < w
                    {
                        let src = ((iy as usize) * w + ix as usize) * c;
                        dst[d..d + c].copy_from_slice(&xd[src..src + c]);
                    } else {
                        dst[d..d + c].fill(0.0); // padding (explicit:
                                                 // dst may be dirty)
                    }
                }
            }
        }
    }
    (ho, wo)
}

/// Scatter-accumulate a `(Ho·Wo, R·S·C)` column matrix back into an NHWC
/// image — the adjoint of [`im2col`]. DarkNet implements transposed
/// convolution as `GEMM -> col2im`; we expose it for the baseline
/// gradient path and for property-testing the adjoint identity.
pub fn col2im(col: &Tensor, h: usize, w: usize, c: usize, r: usize,
              s: usize, stride: usize, pad: usize) -> Tensor {
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - s) / stride + 1;
    assert_eq!(col.shape(), &[ho * wo, r * s * c]);
    let mut out = Tensor::zeros(&[1, h, w, c]);
    let od = out.data_mut();
    let cd = col.data();
    for oy in 0..ho {
        for ox in 0..wo {
            let row = (oy * wo + ox) * r * s * c;
            for m in 0..r {
                let iy = (oy * stride + m) as isize - pad as isize;
                for n in 0..s {
                    let ix = (ox * stride + n) as isize - pad as isize;
                    if iy >= 0 && (iy as usize) < h && ix >= 0
                        && (ix as usize) < w
                    {
                        let dst = ((iy as usize) * w + ix as usize) * c;
                        let src = row + (m * s + n) * c;
                        for ci in 0..c {
                            od[dst + ci] += cd[src + ci];
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn identity_kernel_geometry() {
        // 1x1 kernel, stride 1, no pad: col == flattened input
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[1, 3, 4, 5], &mut rng);
        let (col, ho, wo) = im2col(&x, 1, 1, 1, 0);
        assert_eq!((ho, wo), (3, 4));
        assert_eq!(col.data(), x.data());
    }

    #[test]
    fn padding_zeroes_border() {
        let x = Tensor::full(&[1, 2, 2, 1], 1.0);
        let (col, ho, wo) = im2col(&x, 3, 3, 1, 1);
        assert_eq!((ho, wo), (2, 2));
        // top-left output's top-left tap is padding
        assert_eq!(col.at(&[0, 0]), 0.0);
        // its centre tap is the (0,0) input
        assert_eq!(col.at(&[0, 4]), 1.0);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y
        let mut rng = Rng::new(3);
        let (h, w, c, r, s, stride, pad) = (5, 6, 3, 3, 3, 2, 1);
        let x = Tensor::randn(&[1, h, w, c], &mut rng);
        let (col, ho, wo) = im2col(&x, r, s, stride, pad);
        let y = Tensor::randn(&[ho * wo, r * s * c], &mut rng);
        let lhs: f64 = col
            .data()
            .iter()
            .zip(y.data())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let back = col2im(&y, h, w, c, r, s, stride, pad);
        let rhs: f64 = x
            .data()
            .iter()
            .zip(back.data())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }
}
