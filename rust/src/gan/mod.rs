//! Pure-Rust GAN models assembled from Table-1 configs — the CPU-side
//! workload of Fig. 7/8. (The PJRT-compiled JAX models in `artifacts/` are
//! the served path; this module is the native path the CPU benches and the
//! fallback `--engine native` serving mode use.)

use crate::config::{cgan_layers, dcgan_layers, LayerConfig};
use crate::deconv::huge2::{decompose, Pattern};
use crate::deconv::{baseline, huge2};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

// The engine selector is shared with the segmentation stack; it lives in
// `deconv` (the layer both stacks sit on) and is re-exported here so
// `gan::Engine` call sites keep working.
pub use crate::deconv::Engine;

/// The shared forward surface of every natively-servable model (the GAN
/// [`Generator`], the segmentation [`crate::seg::SegNet`]): batch-major
/// NHWC tensors in and out, engine-selectable per call. Cross-engine
/// property tests are written against this trait so one helper covers
/// every model family. (The coordinator's worker still dispatches on the
/// concrete `Backend` variants — input assembly is task-specific — so a
/// new model family extends `Backend` and `Model` too, not just this.)
pub trait Forward {
    /// `x`: `(B, ...)` → output `(B, ...)`; the same input must produce
    /// bit-identical output regardless of which other rows share the
    /// batch (DESIGN.md §3 batch-composition invariance).
    fn forward(&self, x: &Tensor, engine: Engine) -> Tensor;
    /// Shape [`Forward::forward`] returns for batch size `b`.
    fn out_shape(&self, b: usize) -> Vec<usize>;
}

/// One deconv layer with its weights and (for HUGE²) the pre-decomposed
/// patterns — decomposition happens once at model-load time, as a serving
/// engine would do.
pub struct GenLayer {
    pub cfg: LayerConfig,
    pub kernel: Tensor,
    patterns: Vec<Pattern>,
}

impl GenLayer {
    pub fn new(cfg: LayerConfig, kernel: Tensor) -> Self {
        assert_eq!(kernel.shape(),
                   &[cfg.k, cfg.k, cfg.c_in, cfg.c_out]);
        let patterns = decompose(&kernel, &cfg.deconv_params());
        GenLayer { cfg, kernel, patterns }
    }

    pub fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        let p = self.cfg.deconv_params();
        match engine {
            Engine::Baseline => baseline::conv2d_transpose(x, &self.kernel, &p),
            Engine::Huge2 => huge2::conv2d_transpose_with(
                x, &self.patterns, self.cfg.k, self.cfg.k, &p),
        }
    }

    /// Slice-level forward for the pooled generator path: `xd` is the
    /// `(b, h, h, c_in)` activation (dims from `cfg`), `out` the
    /// `(b, h_out, h_out, c_out)` destination; all scratch from `hnd`.
    pub(crate) fn forward_into(&self, xd: &[f32], b: usize, engine: Engine,
                               out: &mut [f32], hnd: &mut WsHandle) {
        let p = self.cfg.deconv_params();
        let (ih, c_in) = (self.cfg.h, self.cfg.c_in);
        match engine {
            Engine::Baseline => baseline::transpose_into(
                xd, b, ih, ih, c_in, &self.kernel, &p, out, hnd),
            Engine::Huge2 => huge2::transpose_into(
                xd, b, ih, ih, c_in, &self.patterns, self.cfg.k,
                self.cfg.k, &p, out, hnd),
        }
    }
}

/// A DCGAN/cGAN-style generator: dense projection + deconv stack.
pub struct Generator {
    pub z_dim: usize,
    /// `(z_dim [+ n_classes], h0·h0·c0)` projection matrix.
    pub proj: Tensor,
    pub layers: Vec<GenLayer>,
}

impl Generator {
    /// Build with seeded DCGAN-style weights (0.02·N(0,1)).
    pub fn new(layer_cfgs: Vec<LayerConfig>, z_dim: usize, cond: usize,
               rng: &mut Rng) -> Self {
        let first = &layer_cfgs[0];
        let proj = Tensor::randn(
            &[z_dim + cond, first.h * first.h * first.c_in], rng)
            .scale(0.02);
        let layers = layer_cfgs
            .into_iter()
            .map(|cfg| {
                let k = Tensor::randn(
                    &[cfg.k, cfg.k, cfg.c_in, cfg.c_out], rng)
                    .scale(0.02);
                GenLayer::new(cfg, k)
            })
            .collect();
        Generator { z_dim, proj, layers }
    }

    /// The paper's DCGAN generator (Table 1, DC1–DC4).
    pub fn dcgan(seed: u64) -> Self {
        Generator::new(dcgan_layers(), 100, 0, &mut Rng::new(seed))
    }

    /// The paper's cGAN generator (Table 1, DC1–DC2; 10-class conditioning).
    pub fn cgan(seed: u64) -> Self {
        Generator::new(cgan_layers(), 100, 10, &mut Rng::new(seed))
    }

    /// Tiny unconditional cGAN-geometry generator (1/8 channels, 8-dim
    /// latent) — the shared fast, bit-reproducible native model for
    /// tests and benches (`32x32x3` output in ~sub-ms per image).
    pub fn tiny_cgan(seed: u64) -> Self {
        let mut cfgs = cgan_layers();
        for l in &mut cfgs {
            l.c_in /= 8;
            if l.c_out > 3 {
                l.c_out /= 8;
            }
        }
        cfgs[1].c_in = cfgs[0].c_out;
        Generator::new(cfgs, 8, 0, &mut Rng::new(seed))
    }

    /// `z`: `(B, z_dim [+cond])` -> image `(B, H, W, c_out)` in [-1, 1].
    pub fn forward(&self, z: &Tensor, engine: Engine) -> Tensor {
        let ws = Workspace::new();
        self.forward_ws(z, engine, &mut ws.handle())
    }

    /// [`Generator::forward`] drawing every intermediate activation and
    /// all engine scratch from a workspace handle — the steady-state
    /// serving path (bit-identical to the fresh-workspace wrapper;
    /// DESIGN.md §9).
    pub fn forward_ws(&self, z: &Tensor, engine: Engine,
                      hnd: &mut WsHandle) -> Tensor {
        let (b, zd) = z.dims2();
        let (pd, _) = self.proj.dims2();
        assert_eq!(zd, pd, "latent dim mismatch");
        let mut out = Tensor::zeros(&self.out_shape(b));
        self.forward_into(z.data(), b, engine, out.data_mut(), hnd);
        out
    }

    /// Slice-level forward: `zd` is the `(b, z_dim [+cond])` latent
    /// matrix, `out` the `(b, H, W, c_out)` destination (fully
    /// overwritten). Intermediate activations ping-pong between pooled
    /// slabs instead of allocating per layer.
    pub fn forward_into(&self, zd: &[f32], b: usize, engine: Engine,
                        out: &mut [f32], hnd: &mut WsHandle) {
        let (pd, hid) = self.proj.dims2();
        assert_eq!(zd.len(), b * pd, "latent dim mismatch");
        let last = &self.layers[self.layers.len() - 1].cfg;
        assert_eq!(out.len(), b * last.h_out() * last.h_out() * last.c_out,
                   "output size");
        // dense projection (sgemm overwrites the full slice — dirty ok)
        let mut cur = hnd.checkout(b * hid);
        crate::gemm::sgemm_with(hnd, b, hid, pd, zd, self.proj.data(),
                                &mut cur, false);
        crate::tensor::relu_inplace(&mut cur);
        let n = self.layers.len();
        for (i, layer) in self.layers.iter().enumerate() {
            if i == n - 1 {
                layer.forward_into(&cur, b, engine, out, hnd);
                crate::tensor::tanh_inplace(out);
            } else {
                let cfg = &layer.cfg;
                let mut nxt = hnd.checkout(
                    b * cfg.h_out() * cfg.h_out() * cfg.c_out);
                layer.forward_into(&cur, b, engine, &mut nxt, hnd);
                crate::tensor::relu_inplace(&mut nxt);
                hnd.checkin(cur);
                cur = nxt;
            }
        }
        hnd.checkin(cur);
    }

    /// Output image shape for batch `b`.
    pub fn out_shape(&self, b: usize) -> Vec<usize> {
        let last = &self.layers[self.layers.len() - 1].cfg;
        vec![b, last.h_out(), last.h_out(), last.c_out]
    }
}

impl Forward for Generator {
    fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        Generator::forward(self, x, engine)
    }

    fn out_shape(&self, b: usize) -> Vec<usize> {
        Generator::out_shape(self, b)
    }
}

/// Strided-conv discriminator (the training-side workload of §3.2.3).
pub struct Discriminator {
    pub kernels: Vec<Tensor>, // each (5,5,C,N), stride 2, pad 2
    pub head: Tensor,         // (4·4·c_last, 1)
}

impl Discriminator {
    pub fn new(chans: &[usize], rng: &mut Rng) -> Self {
        let kernels = chans
            .windows(2)
            .map(|w| Tensor::randn(&[5, 5, w[0], w[1]], rng).scale(0.02))
            .collect();
        let head = Tensor::randn(&[4 * 4 * chans[chans.len() - 1], 1], rng)
            .scale(0.02);
        Discriminator { kernels, head }
    }

    /// `img`: `(B, 32, 32, C0)` -> logits `(B, 1)`; also returns the
    /// per-layer activations (needed by the backward bench).
    pub fn forward(&self, img: &Tensor) -> (Tensor, Vec<Tensor>) {
        let mut acts = vec![img.clone()];
        let mut x = img.clone();
        for k in &self.kernels {
            x = baseline::conv2d(&x, k, 2, 2).leaky_relu(0.2);
            acts.push(x.clone());
        }
        let (b, h, w, c) = x.dims4();
        let flat = x.reshape(&[b, h * w * c]);
        let mut logits = vec![0.0f32; b];
        crate::gemm::sgemm(b, 1, h * w * c, flat.data(), self.head.data(),
                           &mut logits, false);
        (Tensor::from_vec(&[b, 1], logits), acts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::table1;

    fn tiny_gen() -> Generator {
        // Table-1 geometry at 1/32 channel scale for fast tests
        let cfgs: Vec<LayerConfig> = table1()
            .into_iter()
            .filter(|l| l.gan == "DCGAN")
            .collect();
        let mut shrunk = Vec::new();
        let mut c_in = 32;
        for l in cfgs {
            let c_out = if l.c_out == 3 { 3 } else { l.c_out / 32 };
            shrunk.push(LayerConfig { c_in, c_out, ..l });
            c_in = c_out;
        }
        Generator::new(shrunk, 16, 0, &mut Rng::new(9))
    }

    #[test]
    fn engines_agree_end_to_end() {
        let g = tiny_gen();
        let mut rng = Rng::new(10);
        let z = Tensor::randn(&[2, 16], &mut rng);
        let a = g.forward(&z, Engine::Huge2);
        let b = g.forward(&z, Engine::Baseline);
        assert_eq!(a.shape(), g.out_shape(2).as_slice());
        assert_eq!(a.shape(), &[2, 64, 64, 3]);
        assert!(a.allclose(&b, 1e-4), "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn output_in_tanh_range() {
        let g = tiny_gen();
        let mut rng = Rng::new(11);
        let z = Tensor::randn(&[1, 16], &mut rng);
        let img = g.forward(&z, Engine::Huge2);
        assert!(img.data().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn deterministic_weights() {
        let a = Generator::dcgan(3);
        let b = Generator::dcgan(3);
        assert_eq!(a.proj.checksum(), b.proj.checksum());
        assert_eq!(a.layers[0].kernel.checksum(),
                   b.layers[0].kernel.checksum());
    }

    #[test]
    fn discriminator_pipeline() {
        let mut rng = Rng::new(12);
        let d = Discriminator::new(&[3, 8, 16, 32], &mut rng);
        let img = Tensor::randn(&[2, 32, 32, 3], &mut rng);
        let (logits, acts) = d.forward(&img);
        assert_eq!(logits.shape(), &[2, 1]);
        assert_eq!(acts.len(), 4);
        assert_eq!(acts[3].shape(), &[2, 4, 4, 32]);
    }
}
