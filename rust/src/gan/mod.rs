//! Pure-Rust GAN models assembled from Table-1 configs — the CPU-side
//! workload of Fig. 7/8. (The PJRT-compiled JAX models in `artifacts/` are
//! the served path; this module is the native path the CPU benches and the
//! fallback `--engine native` serving mode use.)
//!
//! Since the plan refactor (DESIGN.md §10) the forward internals live in
//! **one** place — [`crate::plan::ExecPlan`] — compiled once at model
//! load. `Generator::forward*` are thin wrappers: calls matching the
//! stored plan's engine run it directly; other engines compile a
//! transient plan (cheap — prepacked state is `Arc`-shared, never
//! re-packed).

use std::sync::Arc;

use crate::config::{cgan_layers, dcgan_layers, LayerConfig};
use crate::deconv::huge2::{decompose, Pattern};
use crate::deconv::baseline;
use crate::plan::{resolve_transpose, run_transpose_op, ExecPlan};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

// The engine selector is shared with the segmentation stack; it lives in
// `deconv` (the layer both stacks sit on) and is re-exported here so
// `gan::Engine` call sites keep working.
pub use crate::deconv::Engine;

/// The shared forward surface of every natively-servable model (the GAN
/// [`Generator`], the segmentation [`crate::seg::SegNet`]): batch-major
/// NHWC tensors in and out, engine-selectable per call. Cross-engine
/// property tests are written against this trait so one helper covers
/// every model family. (The coordinator's worker executes the models'
/// compiled [`ExecPlan`]s uniformly — input assembly is the only
/// task-specific step left, so a new model family extends `Backend` and
/// `Model` too, not just this.)
pub trait Forward {
    /// `x`: `(B, ...)` → output `(B, ...)`; the same input must produce
    /// bit-identical output regardless of which other rows share the
    /// batch (DESIGN.md §3 batch-composition invariance).
    fn forward(&self, x: &Tensor, engine: Engine) -> Tensor;
    /// Shape [`Forward::forward`] returns for batch size `b`.
    fn out_shape(&self, b: usize) -> Vec<usize>;
}

/// One deconv layer with its weights and (for HUGE²) the pre-decomposed
/// patterns — decomposition happens once at model-load time, as a serving
/// engine would do. The prepacked state is `Arc`-shared with every
/// compiled [`ExecPlan`] that references this layer.
pub struct GenLayer {
    pub cfg: LayerConfig,
    pub kernel: Arc<Tensor>,
    pub(crate) patterns: Arc<Vec<Pattern>>,
}

impl GenLayer {
    pub fn new(cfg: LayerConfig, kernel: Tensor) -> Self {
        assert_eq!(kernel.shape(),
                   &[cfg.k, cfg.k, cfg.c_in, cfg.c_out]);
        let patterns = Arc::new(decompose(&kernel, &cfg.deconv_params()));
        GenLayer { cfg, kernel: Arc::new(kernel), patterns }
    }

    /// Forward one layer with an explicit engine choice (`Auto` resolves
    /// through the plan heuristic). Accepts any batch/spatial geometry
    /// compatible with the kernel, like the raw engines do.
    pub fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        let ws = Workspace::new();
        let hnd = &mut ws.handle();
        let p = self.cfg.deconv_params();
        let (b, h, w, c) = x.dims4();
        let (eng, threads) =
            resolve_transpose(engine, h, w, c, self.cfg.c_out, self.cfg.k,
                              &p, 1);
        let ho = p.out_size(h, self.cfg.k);
        let wo = p.out_size(w, self.cfg.k);
        let mut out = Tensor::zeros(&[b, ho, wo, self.cfg.c_out]);
        // legacy per-call path: no precompiled fused panels — a
        // Segregated resolution packs transiently inside the dispatch
        run_transpose_op(x.data(), b, h, w, c, &self.kernel,
                         &self.patterns, self.cfg.k, &p, eng, threads,
                         None, out.data_mut(), hnd);
        out
    }
}

/// A DCGAN/cGAN-style generator: dense projection + deconv stack,
/// compiled to an [`ExecPlan`] at load time.
pub struct Generator {
    pub z_dim: usize,
    /// `(z_dim [+ n_classes], h0·h0·c0)` projection matrix.
    pub proj: Arc<Tensor>,
    pub layers: Vec<GenLayer>,
    /// The serving plan, compiled with [`Engine::Auto`] (load-time
    /// engine selection); explicit-engine forwards compile transients.
    plan: ExecPlan,
}

impl Generator {
    /// Build with seeded DCGAN-style weights (0.02·N(0,1)).
    pub fn new(layer_cfgs: Vec<LayerConfig>, z_dim: usize, cond: usize,
               rng: &mut Rng) -> Self {
        let first = &layer_cfgs[0];
        let proj = Arc::new(Tensor::randn(
            &[z_dim + cond, first.h * first.h * first.c_in], rng)
            .scale(0.02));
        let layers: Vec<GenLayer> = layer_cfgs
            .into_iter()
            .map(|cfg| {
                let k = Tensor::randn(
                    &[cfg.k, cfg.k, cfg.c_in, cfg.c_out], rng)
                    .scale(0.02);
                GenLayer::new(cfg, k)
            })
            .collect();
        let plan = ExecPlan::compile_gan(&proj, &layers, Engine::Auto);
        Generator { z_dim, proj, layers, plan }
    }

    /// The paper's DCGAN generator (Table 1, DC1–DC4).
    pub fn dcgan(seed: u64) -> Self {
        Generator::new(dcgan_layers(), 100, 0, &mut Rng::new(seed))
    }

    /// The paper's cGAN generator (Table 1, DC1–DC2; 10-class conditioning).
    pub fn cgan(seed: u64) -> Self {
        Generator::new(cgan_layers(), 100, 10, &mut Rng::new(seed))
    }

    /// Tiny unconditional cGAN-geometry generator (1/8 channels, 8-dim
    /// latent) — the shared fast, bit-reproducible native model for
    /// tests and benches (`32x32x3` output in ~sub-ms per image).
    pub fn tiny_cgan(seed: u64) -> Self {
        let mut cfgs = cgan_layers();
        for l in &mut cfgs {
            l.c_in /= 8;
            if l.c_out > 3 {
                l.c_out /= 8;
            }
        }
        cfgs[1].c_in = cfgs[0].c_out;
        Generator::new(cfgs, 8, 0, &mut Rng::new(seed))
    }

    /// The load-time-compiled execution plan (serving path; engine
    /// selection already resolved, all prepacking shared).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// `z`: `(B, z_dim [+cond])` -> image `(B, H, W, c_out)` in [-1, 1].
    pub fn forward(&self, z: &Tensor, engine: Engine) -> Tensor {
        let ws = Workspace::new();
        self.forward_ws(z, engine, &mut ws.handle())
    }

    /// [`Generator::forward`] drawing every intermediate activation and
    /// all engine scratch from a workspace handle — the steady-state
    /// serving path (bit-identical to the fresh-workspace wrapper;
    /// DESIGN.md §9).
    pub fn forward_ws(&self, z: &Tensor, engine: Engine,
                      hnd: &mut WsHandle) -> Tensor {
        let (b, zd) = z.dims2();
        let (pd, _) = self.proj.dims2();
        assert_eq!(zd, pd, "latent dim mismatch");
        let mut out = Tensor::zeros(&self.out_shape(b));
        self.forward_into(z.data(), b, engine, out.data_mut(), hnd);
        out
    }

    /// Slice-level forward: `zd` is the `(b, z_dim [+cond])` latent
    /// matrix, `out` the `(b, H, W, c_out)` destination (fully
    /// overwritten). Thin wrapper over [`ExecPlan::run_into`] — the one
    /// place the forward internals live. Calls whose engine the stored
    /// plan already resolves to (the common Huge2 case: every GAN layer
    /// is stride-2) run it directly — no per-call compile, so the
    /// steady state stays allocation-free; only a genuinely different
    /// selection compiles a transient plan.
    pub fn forward_into(&self, zd: &[f32], b: usize, engine: Engine,
                        out: &mut [f32], hnd: &mut WsHandle) {
        if Some(engine) == self.plan.requested()
            || self.plan.resolves_to(engine)
        {
            self.plan.run_into(zd, b, out, hnd);
        } else {
            ExecPlan::compile_gan(&self.proj, &self.layers, engine)
                .run_into(zd, b, out, hnd);
        }
    }

    /// Output image shape for batch `b`.
    pub fn out_shape(&self, b: usize) -> Vec<usize> {
        self.plan.out_shape(b)
    }
}

impl Forward for Generator {
    fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        Generator::forward(self, x, engine)
    }

    fn out_shape(&self, b: usize) -> Vec<usize> {
        Generator::out_shape(self, b)
    }
}

/// Strided-conv discriminator (the training-side workload of §3.2.3).
pub struct Discriminator {
    pub kernels: Vec<Tensor>, // each (5,5,C,N), stride 2, pad 2
    pub head: Tensor,         // (4·4·c_last, 1)
}

impl Discriminator {
    pub fn new(chans: &[usize], rng: &mut Rng) -> Self {
        let kernels = chans
            .windows(2)
            .map(|w| Tensor::randn(&[5, 5, w[0], w[1]], rng).scale(0.02))
            .collect();
        let head = Tensor::randn(&[4 * 4 * chans[chans.len() - 1], 1], rng)
            .scale(0.02);
        Discriminator { kernels, head }
    }

    /// `img`: `(B, 32, 32, C0)` -> logits `(B, 1)`; also returns the
    /// per-layer activations (needed by the backward bench).
    pub fn forward(&self, img: &Tensor) -> (Tensor, Vec<Tensor>) {
        let mut acts = vec![img.clone()];
        let mut x = img.clone();
        for k in &self.kernels {
            x = baseline::conv2d(&x, k, 2, 2).leaky_relu(0.2);
            acts.push(x.clone());
        }
        let (b, h, w, c) = x.dims4();
        let flat = x.reshape(&[b, h * w * c]);
        let mut logits = vec![0.0f32; b];
        crate::gemm::sgemm(b, 1, h * w * c, flat.data(), self.head.data(),
                           &mut logits, false);
        (Tensor::from_vec(&[b, 1], logits), acts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::table1;

    fn tiny_gen() -> Generator {
        // Table-1 geometry at 1/32 channel scale for fast tests
        let cfgs: Vec<LayerConfig> = table1()
            .into_iter()
            .filter(|l| l.gan == "DCGAN")
            .collect();
        let mut shrunk = Vec::new();
        let mut c_in = 32;
        for l in cfgs {
            let c_out = if l.c_out == 3 { 3 } else { l.c_out / 32 };
            shrunk.push(LayerConfig { c_in, c_out, ..l });
            c_in = c_out;
        }
        Generator::new(shrunk, 16, 0, &mut Rng::new(9))
    }

    #[test]
    fn engines_agree_end_to_end() {
        let g = tiny_gen();
        let mut rng = Rng::new(10);
        let z = Tensor::randn(&[2, 16], &mut rng);
        let a = g.forward(&z, Engine::Huge2);
        let b = g.forward(&z, Engine::Baseline);
        assert_eq!(a.shape(), g.out_shape(2).as_slice());
        assert_eq!(a.shape(), &[2, 64, 64, 3]);
        assert!(a.allclose(&b, 1e-4), "diff {}", a.max_abs_diff(&b));
        // Auto resolves per layer but stays within engine tolerance,
        // and the stored plan reproduces it bit-exactly
        let c = g.forward(&z, Engine::Auto);
        assert!(c.allclose(&a, 1e-4));
        let ws = Workspace::new();
        let d = g.plan().run(&z, &mut ws.handle());
        assert_eq!(c.checksum(), d.checksum());
    }

    #[test]
    fn output_in_tanh_range() {
        let g = tiny_gen();
        let mut rng = Rng::new(11);
        let z = Tensor::randn(&[1, 16], &mut rng);
        let img = g.forward(&z, Engine::Huge2);
        assert!(img.data().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn deterministic_weights() {
        let a = Generator::dcgan(3);
        let b = Generator::dcgan(3);
        assert_eq!(a.proj.checksum(), b.proj.checksum());
        assert_eq!(a.layers[0].kernel.checksum(),
                   b.layers[0].kernel.checksum());
        assert_eq!(a.plan().engine_digest(), b.plan().engine_digest());
    }

    #[test]
    fn discriminator_pipeline() {
        let mut rng = Rng::new(12);
        let d = Discriminator::new(&[3, 8, 16, 32], &mut rng);
        let img = Tensor::randn(&[2, 32, 32, 3], &mut rng);
        let (logits, acts) = d.forward(&img);
        assert_eq!(logits.shape(), &[2, 1]);
        assert_eq!(acts.len(), 4);
        assert_eq!(acts[3].shape(), &[2, 4, 4, 32]);
    }
}
