//! Multi-core engines — the paper's testbed is a 4-core Cortex-A57, and
//! §3.1's "non-overlapped sparse regions … do not cause any race
//! conditions" is precisely a parallelism claim: HUGE²'s `stride²`
//! patterns write disjoint output polyphases, so they parallelise with
//! no synchronisation at all. The baseline parallelises only inside its
//! single big GEMM (its output rows overlap the col matrix, and the
//! inflation/im2col phases are bandwidth-bound).

use crate::gemm::{sgemm_parallel_with, sgemm_prepacked_with};
use crate::im2col::im2col_into;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsBuf};

use super::dilated::{self, DilatedTaps};
use super::huge2::Pattern;
use super::{pad_spatial_into, polyphase_len, DeconvParams, DilatedParams};

/// Multi-threaded naive baseline: inflate + im2col single-threaded
/// (bandwidth-bound), GEMM sharded over `threads`.
pub fn baseline_conv2d_transpose_mt(x: &Tensor, k: &Tensor,
                                    p: &DeconvParams, threads: usize)
                                    -> Tensor {
    let ws = Workspace::new();
    baseline_conv2d_transpose_mt_ws(x, k, p, threads, &ws)
}

/// [`baseline_conv2d_transpose_mt`] over a shared workspace: the
/// inflation and column buffers come from the caller's pool, and each
/// GEMM shard thread draws its packing panels through its own handle.
pub fn baseline_conv2d_transpose_mt_ws(x: &Tensor, k: &Tensor,
                                       p: &DeconvParams, threads: usize,
                                       ws: &Workspace) -> Tensor {
    let mut hnd = ws.handle();
    let (b, h, w, c) = x.dims4();
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc, "channel mismatch");
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let st = p.stride;
    let (lo_h, hi_h) = p.inflate_pad(r);
    let (lo_w, hi_w) = p.inflate_pad(s);
    let ih = (h - 1) * st + 1 + lo_h + hi_h;
    let iw = (w - 1) * st + 1 + lo_w + hi_w;
    let mut inflated = hnd.checkout(b * ih * iw * c);
    super::baseline::inflate_into(x.data(), b, h, w, c, r, s, p,
                                  &mut inflated);
    let mut col = hnd.checkout(ho * wo * r * s * c);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    for bi in 0..b {
        let img = &inflated[bi * ih * iw * c..(bi + 1) * ih * iw * c];
        im2col_into(img, ih, iw, c, r, s, 1, 0, &mut col);
        let dst = &mut out.data_mut()[bi * ho * wo * n
            ..(bi + 1) * ho * wo * n];
        sgemm_parallel_with(ws, ho * wo, n, r * s * c, &col, k.data(),
                            dst, false, threads);
    }
    hnd.checkin(inflated);
    hnd.checkin(col);
    out
}

/// Multi-threaded HUGE²: one thread per pattern (up to `threads`),
/// zero synchronisation — each pattern owns a disjoint output polyphase.
pub fn huge2_conv2d_transpose_mt(x: &Tensor, patterns: &[Pattern],
                                 r: usize, s: usize, p: &DeconvParams,
                                 threads: usize) -> Tensor {
    let ws = Workspace::new();
    huge2_conv2d_transpose_mt_ws(x, patterns, r, s, p, threads, &ws)
}

/// [`huge2_conv2d_transpose_mt`] over a shared workspace: each pattern
/// thread draws its sub-output, A-assembly buffer and GEMM panels
/// through its own per-thread handle; sub-outputs travel back to the
/// main thread for the scatter and are checked in there.
pub fn huge2_conv2d_transpose_mt_ws(x: &Tensor, patterns: &[Pattern],
                                    r: usize, s: usize, p: &DeconvParams,
                                    threads: usize, ws: &Workspace)
                                    -> Tensor {
    let (b, h, w, c) = x.dims4();
    let n = patterns[0].sub.shape()[3];
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    transpose_mt_into(x.data(), b, h, w, c, patterns, r, s, p, threads,
                      out.data_mut(), ws);
    out
}

/// Slice-level core of the multi-threaded untangled transposed conv
/// (the plan executor's MT path). `out` is fully overwritten (zeroed,
/// then polyphase-scattered), so a dirty pooled slab is safe —
/// bit-identical to [`super::huge2::transpose_into`] for every thread
/// count (each pattern's tap loop and scatter are the same code path).
#[allow(clippy::too_many_arguments)]
pub(crate) fn transpose_mt_into(xd: &[f32], b: usize, h: usize, w: usize,
                                c: usize, patterns: &[Pattern], r: usize,
                                s: usize, p: &DeconvParams, threads: usize,
                                out: &mut [f32], ws: &Workspace) {
    let mut hnd = ws.handle();
    let n = patterns[0].sub.shape()[3];
    let st = p.stride;
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    out.fill(0.0);

    // shared padded input (same algebra as the single-threaded engine)
    let (pad_lo_y, pad_hi_y, pad_lo_x, pad_hi_x) =
        super::huge2::pad_geometry(patterns, h, w, ho, wo, st);
    let mut xp = hnd.checkout(b * (h + pad_lo_y + pad_hi_y)
        * (w + pad_lo_x + pad_hi_x) * c);
    let (hp, wp) = pad_spatial_into(xd, b, h, w, c, pad_lo_y,
                                    pad_hi_y, pad_lo_x, pad_hi_x,
                                    &mut xp);

    // Patterns are the shard unit — clamp like the dilated engine
    // clamps to output rows, so `threads > patterns.len()` never spawns
    // idle workers and the chunking algebra below sees a sane count.
    let threads = threads.max(1).min(patterns.len().max(1));

    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        // Compute every pattern's polyphase concurrently...
        let mut results: Vec<(usize, WsBuf, usize, usize)> =
            std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for (pi, chunk) in patterns.chunks(
                    patterns.len().div_ceil(threads)).enumerate()
                {
                    handles.push(sc.spawn(move || {
                        let mut h = ws.handle();
                        let mut local = Vec::new();
                        for (ci, pt) in chunk.iter().enumerate() {
                            let qy = polyphase_len(ho, st, pt.phi_y);
                            let qx = polyphase_len(wo, st, pt.phi_x);
                            if qy == 0 || qx == 0 || pt.ay.taps == 0
                                || pt.ax.taps == 0
                            {
                                continue;
                            }
                            let mut sub = h.checkout_zeroed(qy * qx * n);
                            let mut a_buf = h.checkout(qy * qx * c);
                            for t_y in 0..pt.ay.taps {
                                for t_x in 0..pt.ax.taps {
                                    let pb = &pt.packed[t_y * pt.ax.taps
                                        + t_x];
                                    let ix0 = (t_x as isize + pt.ax.delta
                                        + pad_lo_x as isize) as usize;
                                    for q_y in 0..qy {
                                        let iy = (q_y as isize
                                            + t_y as isize + pt.ay.delta
                                            + pad_lo_y as isize) as usize;
                                        let a0 = (iy * wp + ix0) * c;
                                        a_buf[q_y * qx * c
                                            ..(q_y + 1) * qx * c]
                                            .copy_from_slice(
                                                &img[a0..a0 + qx * c]);
                                    }
                                    sgemm_prepacked_with(
                                        &mut h, qy * qx,
                                        &a_buf[..qy * qx * c],
                                        c, pb, &mut sub, true);
                                }
                            }
                            h.checkin(a_buf);
                            let idx = pi * patterns.len()
                                .div_ceil(threads) + ci;
                            local.push((idx, sub, qy, qx));
                        }
                        local
                    }));
                }
                handles.into_iter()
                    .flat_map(|h| h.join().unwrap())
                    .collect()
            });
        // ...then scatter serially (cheap, disjoint anyway).
        results.sort_by_key(|(i, ..)| *i);
        for (idx, sub, qy, qx) in results {
            let pt = &patterns[idx];
            for q_y in 0..qy {
                let oy = pt.phi_y + q_y * st;
                for q_x in 0..qx {
                    let ox = pt.phi_x + q_x * st;
                    let src = (q_y * qx + q_x) * n;
                    let dst = ((bi * ho + oy) * wo + ox) * n;
                    out[dst..dst + n].copy_from_slice(&sub[src..src + n]);
                }
            }
            hnd.checkin(sub);
        }
    }
    hnd.checkin(xp);
}

/// Multi-threaded HUGE² dilated convolution: output *rows* are sharded
/// over `threads` (dilated outputs are dense, so rows — not polyphases —
/// are the natural disjoint partition). Every row runs the same
/// [`dilated::accumulate_row`] as the single-threaded engine, so results
/// are **bit-identical for every thread count** by construction — the
/// replay subsystem's fast mode depends on exactly this (DESIGN.md
/// §3/§8).
pub fn conv2d_dilated_mt(x: &Tensor, taps: &DilatedTaps, p: &DilatedParams,
                         threads: usize) -> Tensor {
    let ws = Workspace::new();
    conv2d_dilated_mt_ws(x, taps, p, threads, &ws)
}

/// [`conv2d_dilated_mt`] over a shared workspace: the padded input comes
/// from the caller's pool, and each row-shard thread draws its GEMM
/// panels through its own per-thread handle.
pub fn conv2d_dilated_mt_ws(x: &Tensor, taps: &DilatedTaps,
                            p: &DilatedParams, threads: usize,
                            ws: &Workspace) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let ho = p.out_size(h, taps.r);
    let wo = p.out_size(w, taps.s);
    let mut out = Tensor::zeros(&[b, ho, wo, taps.n]);
    dilated_mt_into(x.data(), b, h, w, c, taps, p, threads,
                    out.data_mut(), ws);
    out
}

/// Slice-level core of the multi-threaded untangled dilated conv (the
/// seg stack's pooled layer path). `out` is fully overwritten.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dilated_mt_into(xd: &[f32], b: usize, h: usize, w: usize,
                              c: usize, taps: &DilatedTaps,
                              p: &DilatedParams, threads: usize,
                              out: &mut [f32], ws: &Workspace) {
    let (r, s, n) = (taps.r, taps.s, taps.n);
    assert_eq!(c, taps.c);
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    out.fill(0.0);
    let mut hnd = ws.handle();
    let mut xp = hnd.checkout(b * (h + 2 * p.pad) * (w + 2 * p.pad) * c);
    let (hp, wp) = pad_spatial_into(xd, b, h, w, c, p.pad, p.pad, p.pad,
                                    p.pad, &mut xp);
    let threads = threads.max(1).min(ho.max(1));
    let rows_per = ho.div_ceil(threads);

    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        let od = &mut out[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        std::thread::scope(|sc| {
            let mut rest = od;
            let mut oy0 = 0;
            while oy0 < ho {
                let rows = rows_per.min(ho - oy0);
                let (band, tail) = rest.split_at_mut(rows * wo * n);
                rest = tail;
                let y0 = oy0;
                sc.spawn(move || {
                    let mut th = ws.handle();
                    for (ri, dst) in band.chunks_mut(wo * n).enumerate() {
                        dilated::accumulate_row(dst, img, taps, p, y0 + ri,
                                                wp, wo, &mut th);
                    }
                });
                oy0 += rows;
            }
        });
    }
    hnd.checkin(xp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::{baseline, dilated, huge2};
    use crate::rng::Rng;

    #[test]
    fn mt_engines_match_single_thread() {
        let mut rng = Rng::new(21);
        let p = DeconvParams::new(2, 2, 1);
        let x = Tensor::randn(&[1, 8, 8, 16], &mut rng);
        let k = Tensor::randn(&[5, 5, 16, 8], &mut rng);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let patterns = huge2::decompose(&k, &p);
        for threads in [1, 2, 4, 7] {
            let a = baseline_conv2d_transpose_mt(&x, &k, &p, threads);
            let b = huge2_conv2d_transpose_mt(&x, &patterns, 5, 5, &p,
                                              threads);
            assert!(a.allclose(&want, 1e-4), "baseline mt{threads}");
            assert!(b.allclose(&want, 1e-4), "huge2 mt{threads}: {}",
                    b.max_abs_diff(&want));
        }
    }

    #[test]
    fn mt_stride3() {
        let mut rng = Rng::new(22);
        let p = DeconvParams::new(3, 2, 1);
        let x = Tensor::randn(&[2, 5, 5, 4], &mut rng);
        let k = Tensor::randn(&[5, 5, 4, 3], &mut rng);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let patterns = huge2::decompose(&k, &p);
        let got = huge2_conv2d_transpose_mt(&x, &patterns, 5, 5, &p, 3);
        assert!(got.allclose(&want, 1e-4));
    }

    #[test]
    fn mt_dilated_bit_identical_for_every_thread_count() {
        let mut rng = Rng::new(23);
        for p in [DilatedParams::new(2, 1, 2), DilatedParams::new(3, 2, 3),
                  DilatedParams::new(1, 1, 1)] {
            let x = Tensor::randn(&[2, 13, 13, 5], &mut rng);
            let k = Tensor::randn(&[3, 3, 5, 4], &mut rng);
            let taps = dilated::pack_taps(&k);
            let want = dilated::conv2d_dilated_with(&x, &taps, &p);
            assert!(want.allclose(&baseline::conv2d_dilated(&x, &k, &p),
                                  1e-4));
            for threads in [1, 2, 3, 7, 64] {
                let got = conv2d_dilated_mt(&x, &taps, &p, threads);
                assert_eq!(got.checksum(), want.checksum(),
                           "threads={threads} {p:?} must be bit-identical");
            }
        }
    }
}
