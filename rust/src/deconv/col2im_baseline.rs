//! Second baseline: the **output-side (col2im) formulation** — what
//! DarkNet's `forward_deconvolutional_layer` literally does:
//!
//! ```text
//! col(R·S·N, H·W) = Kᵀ(R·S·N, C) · X(C, H·W)      (one GEMM per image)
//! O += col2im(col)                                  (overlapped scatter)
//! ```
//!
//! Unlike the zero-insertion baseline it performs **no** wasted zero-MACs
//! (its GEMM is over the real input only) — its costs are the col-matrix
//! materialisation and, crucially, the *overlapped accumulation scatter*
//! the paper's §2.2 "Reverse Looping and Overlapping" discussion targets:
//! chained read-modify-writes to the same output locations, which
//! serialise on parallel hardware and defeat write-coalescing.
//!
//! Having both baselines makes the ablation exact:
//! * zero-insertion baseline → measures the *zero-skipping* win,
//! * col2im baseline        → measures the *scatter/locality* win.

use crate::gemm::sgemm_with;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::DeconvParams;

/// DarkNet-style transposed convolution: GEMM then col2im scatter-add.
///
/// `x`: NHWC `(B,H,W,C)`; `k`: HWIO `(R,S,C,N)`; output `(B,Ho,Wo,N)`.
/// Numerically identical to the other two engines.
pub fn conv2d_transpose(x: &Tensor, k: &Tensor, p: &DeconvParams) -> Tensor {
    let ws = Workspace::new();
    conv2d_transpose_ws(x, k, p, &mut ws.handle())
}

/// [`conv2d_transpose`] drawing `Kᵀ`, the col matrix and the `Xᵀ` buffer
/// from a workspace handle (all three are fully overwritten before use,
/// so dirty slabs are safe; bit-identical — DESIGN.md §9).
pub fn conv2d_transpose_ws(x: &Tensor, k: &Tensor, p: &DeconvParams,
                           hnd: &mut WsHandle) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc);
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let (lo_h, _) = p.inflate_pad(r);
    let (lo_w, _) = p.inflate_pad(s);
    let st = p.stride;

    // Kᵀ: (R·S·N, C) — reorganised once (model-load cost, same treatment
    // as HUGE²'s decomposition). Every element written → dirty-safe.
    let mut kt = hnd.checkout(r * s * n * c);
    for m in 0..r {
        for nn in 0..s {
            for ci in 0..c {
                for j in 0..n {
                    kt[((m * s + nn) * n + j) * c + ci] =
                        k.data()[((m * s + nn) * c + ci) * n + j];
                }
            }
        }
    }

    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    let mut col = hnd.checkout(r * s * n * h * w);
    // Xᵀ buffer: (C, H·W) per image.
    let mut xt = hnd.checkout(c * h * w);
    for bi in 0..b {
        let img = &x.data()[bi * h * w * c..(bi + 1) * h * w * c];
        for pix in 0..h * w {
            for ci in 0..c {
                xt[ci * h * w + pix] = img[pix * c + ci];
            }
        }
        // col(R·S·N, H·W) = Kᵀ · X
        sgemm_with(hnd, r * s * n, h * w, c, &kt, &xt, &mut col, false);
        // col2im: overlapped scatter-add into the output
        let od = &mut out.data_mut()[bi * ho * wo * n
            ..(bi + 1) * ho * wo * n];
        for m in 0..r {
            for nn in 0..s {
                for j in 0..n {
                    let crow = &col[((m * s + nn) * n + j) * h * w..]
                        [..h * w];
                    for iy in 0..h {
                        // input row iy sits at inflated position
                        // lo + iy·st; tap m reads it into output row
                        // y = (lo + iy·st) − m
                        let oy = iy as isize * st as isize + lo_h as isize
                            - m as isize;
                        if oy < 0 || oy as usize >= ho {
                            continue;
                        }
                        for ix in 0..w {
                            let ox = ix as isize * st as isize
                                + lo_w as isize - nn as isize;
                            if ox < 0 || ox as usize >= wo {
                                continue;
                            }
                            od[((oy as usize) * wo + ox as usize) * n + j]
                                += crow[iy * w + ix];
                        }
                    }
                }
            }
        }
    }
    hnd.checkin(kt);
    hnd.checkin(col);
    hnd.checkin(xt);
    out
}

/// Cost accounting for the ablation: (MACs, scatter-adds).
pub fn costs(h: usize, w: usize, c: usize, n: usize, r: usize, s: usize)
             -> (u64, u64) {
    ((r * s * n * h * w * c) as u64, (r * s * n * h * w) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::{baseline, huge2};
    use crate::rng::Rng;

    fn check(h: usize, c: usize, n: usize, r: usize, p: DeconvParams,
             seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let got = conv2d_transpose(&x, &k, &p);
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-3),
                "h={h} c={c} n={n} r={r} {p:?} diff={}",
                got.max_abs_diff(&want));
    }

    #[test]
    fn matches_other_engines_dcgan() {
        check(4, 16, 8, 5, DeconvParams::new(2, 2, 1), 31);
        check(8, 8, 4, 5, DeconvParams::new(2, 2, 1), 32);
    }

    #[test]
    fn matches_other_engines_cgan_and_strides() {
        check(8, 8, 4, 4, DeconvParams::new(2, 1, 0), 33);
        check(5, 3, 2, 5, DeconvParams::new(3, 2, 1), 34);
        check(3, 2, 2, 3, DeconvParams::new(2, 0, 0), 35);
    }

    #[test]
    fn batch() {
        let mut rng = Rng::new(36);
        let p = DeconvParams::new(2, 2, 1);
        let x = Tensor::randn(&[3, 4, 4, 6], &mut rng);
        let k = Tensor::randn(&[5, 5, 6, 4], &mut rng);
        let a = conv2d_transpose(&x, &k, &p);
        let b = huge2::conv2d_transpose(&x, &k, &p);
        assert!(a.allclose(&b, 1e-3));
    }

    #[test]
    fn no_zero_macs_by_construction() {
        // the col2im baseline's GEMM MAC count equals HUGE2's effective
        // count (both skip zeros) — its cost is the scatter, not the MACs
        let (macs, scatters) = costs(16, 16, 256, 128, 5, 5);
        let p = DeconvParams::new(2, 2, 1);
        let (_, eff) = huge2::mac_counts(16, 16, 256, 128, 5, 5, &p);
        // col2im does r·s·n·h·w·c; huge2 does ho·wo/4·r·s·c·n ≈ same
        assert!((macs as f64 / eff as f64 - 1.0).abs() < 0.35,
                "macs {macs} vs eff {eff}");
        assert!(scatters > 0);
    }
}
