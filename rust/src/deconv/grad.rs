//! GAN-training gradients (paper §3.2.3 and Fig. 6 right).
//!
//! * **Discriminator weight gradient** — the derivative maps act as a
//!   stride-dilated kernel convolving the input:
//!   `dK[m,n,c,j] = Σ_{oh,ow} X[m+oh·st-pad, n+ow·st-pad, c]·dY[oh,ow,j]`.
//!   Untangled, each of the `R·S` taps is a `(C,N) += Xᵀ·dY` GEMM
//!   ([`crate::gemm::sgemm_at`]). The naive variant materialises the
//!   zero-dilated derivative kernel first (what the baseline engine does).
//! * **Generator input gradient** — a transposed convolution of `dY` with
//!   the flipped kernel, so it reuses the Fig.-7 engines directly; both
//!   variants exposed for the Fig.-8-right bench.

use crate::gemm::sgemm_at;
use crate::tensor::Tensor;

use super::{baseline, huge2, DeconvParams};

/// Untangled (HUGE²) discriminator weight gradient.
///
/// `x`: `(B,H,W,C)` forward input; `dy`: `(B,Oh,Ow,N)` derivative maps of
/// a forward conv with kernel `(r,s,C,N)`, stride `st`, pad `pad`.
/// Returns `dk`: `(r,s,C,N)`.
pub fn weight_grad_huge2(x: &Tensor, dy: &Tensor, r: usize, s: usize,
                         stride: usize, pad: usize) -> Tensor {
    let (b, _h, _w, c) = x.dims4();
    let (b2, oh, ow, n) = dy.dims4();
    assert_eq!(b, b2);
    let xp = x.pad_spatial(pad, pad, pad, pad);
    let (_, hp, wp, _) = xp.dims4();
    let mut dk = Tensor::zeros(&[r, s, c, n]);

    for bi in 0..b {
        let img = &xp.data()[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        let dyb = &dy.data()[bi * oh * ow * n..(bi + 1) * oh * ow * n];
        for m in 0..r {
            for nn in 0..s {
                let dst = &mut dk.data_mut()[(m * s + nn) * c * n
                    ..(m * s + nn + 1) * c * n];
                // Accumulate over output rows: each row is a
                // (C,N) += Xᵀ(C,Ow)·dY(Ow,N) rank-Ow update.
                for oy in 0..oh {
                    let iy = m + oy * stride;
                    let ix0 = nn;
                    let a0 = (iy * wp + ix0) * c;
                    let lda = stride * c;
                    let a_len = (ow - 1) * lda + c;
                    let a = &img[a0..a0 + a_len];
                    let brow = &dyb[oy * ow * n..(oy + 1) * ow * n];
                    sgemm_at(ow, n, c, a, lda, brow, dst, true);
                }
            }
        }
    }
    dk
}

/// Naive discriminator weight gradient: materialise the stride-dilated
/// derivative maps as kernels (zeros included), im2col the input over the
/// *full dilated extent*, and run one dense GEMM — the DarkNet-style
/// baseline cost model of Fig. 8 right (step 3 of Fig. 6). It uses the
/// same GEMM core as HUGE², so the measured ratio isolates the wasted
/// zero-MACs + materialisation traffic, not GEMM quality.
pub fn weight_grad_baseline(x: &Tensor, dy: &Tensor, r: usize, s: usize,
                            stride: usize, pad: usize) -> Tensor {
    use crate::gemm::sgemm;
    let (b, _h, _w, c) = x.dims4();
    let (_, oh, ow, n) = dy.dims4();
    // Dilate dy into an ((oh-1)*st+1) square kernel per (b, j).
    let er = (oh - 1) * stride + 1;
    let es = (ow - 1) * stride + 1;
    let mut dk = Tensor::zeros(&[r, s, c, n]);
    let xp = x.pad_spatial(pad, pad, pad, pad);
    let (_, hp, wp, _) = xp.dims4();
    let mut dker = vec![0.0f32; er * es * n];
    // col matrix: one row per (m, nn, ci) over the full dilated window
    let mut col = vec![0.0f32; r * s * c * er * es];
    for bi in 0..b {
        // dilated derivative kernel, materialised with its zeros
        dker.fill(0.0);
        for oy in 0..oh {
            for ox in 0..ow {
                for j in 0..n {
                    dker[((oy * stride) * es + ox * stride) * n + j] =
                        dy.at(&[bi, oy, ox, j]);
                }
            }
        }
        // im2col over the dilated extent (zeros and all)
        let img = &xp.data()[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        col.fill(0.0);
        for m in 0..r {
            for nn in 0..s {
                for ci in 0..c {
                    let row = ((m * s + nn) * c + ci) * er * es;
                    for u in 0..er {
                        let iy = m + u;
                        if iy >= hp {
                            break;
                        }
                        for v in 0..es {
                            let ix = nn + v;
                            if ix >= wp {
                                break;
                            }
                            col[row + u * es + v] =
                                img[(iy * wp + ix) * c + ci];
                        }
                    }
                }
            }
        }
        // one dense GEMM: (r·s·c, er·es) @ (er·es, n) — every zero of the
        // dilated derivative kernel is multiplied; exactly the naive waste
        sgemm(r * s * c, n, er * es, &col, &dker, dk.data_mut(), true);
    }

    dk
}

/// Generator input gradient via the HUGE² transposed-conv engine.
pub fn input_grad_huge2(dy: &Tensor, k: &Tensor, p: &DeconvParams) -> Tensor {
    huge2::conv2d_transpose(dy, &flip_swap(k), p)
}

/// Generator input gradient via the naive engine.
pub fn input_grad_baseline(dy: &Tensor, k: &Tensor, p: &DeconvParams)
                           -> Tensor {
    baseline::conv2d_transpose(dy, &flip_swap(k), p)
}

/// Spatially flip `(R,S,C,N)` and swap the channel axes -> `(R,S,N,C)`.
fn flip_swap(k: &Tensor) -> Tensor {
    let (r, s, c, n) = k.dims4();
    let mut out = Tensor::zeros(&[r, s, n, c]);
    for m in 0..r {
        for nn in 0..s {
            for ci in 0..c {
                for ni in 0..n {
                    let v = k.at(&[r - 1 - m, s - 1 - nn, ci, ni]);
                    out.set(&[m, nn, ni, ci], v);
                }
            }
        }
    }
    out
}

/// MAC counts for the weight gradient: naive (dilated derivative kernel,
/// zeros included) vs untangled.
pub fn weight_grad_macs(_h: usize, _w: usize, c: usize, n: usize, r: usize,
                        s: usize, oh: usize, ow: usize, stride: usize)
                        -> (u64, u64) {
    let er = (oh - 1) * stride + 1;
    let es = (ow - 1) * stride + 1;
    let naive = (r * s * c * n * er * es) as u64;
    let eff = (r * s * c * n * oh * ow) as u64;

    (naive, eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::baseline as base;
    use crate::rng::Rng;

    /// Finite-difference check of the weight gradient.
    #[test]
    fn weight_grad_matches_finite_difference() {
        let mut rng = Rng::new(11);
        let (h, c, n, r, st, pad) = (6, 2, 2, 3, 2, 1);
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let mut k = Tensor::randn(&[r, r, c, n], &mut rng);
        let y = base::conv2d(&x, &k, st, pad);
        let dy = Tensor::full(y.shape(), 1.0);
        let g = weight_grad_huge2(&x, &dy, r, r, st, pad);
        // check a few entries by central differences
        let eps = 1e-3;
        for &idx in &[0usize, 3, 7, k.len() - 1] {
            let orig = k.data()[idx];
            k.data_mut()[idx] = orig + eps;
            let yp: f32 = base::conv2d(&x, &k, st, pad).data().iter().sum();
            k.data_mut()[idx] = orig - eps;
            let ym: f32 = base::conv2d(&x, &k, st, pad).data().iter().sum();
            k.data_mut()[idx] = orig;
            let fd = (yp - ym) / (2.0 * eps);
            let an = g.data()[idx];
            assert!((fd - an).abs() < 2e-2, "idx {idx}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn huge2_matches_baseline_weight_grad() {
        let mut rng = Rng::new(12);
        let (h, c, n, r, st, pad) = (8, 3, 4, 5, 2, 2);
        let x = Tensor::randn(&[2, h, h, c], &mut rng);
        let oh = (h + 2 * pad - r) / st + 1;
        let dy = Tensor::randn(&[2, oh, oh, n], &mut rng);
        let a = weight_grad_huge2(&x, &dy, r, r, st, pad);
        let b = weight_grad_baseline(&x, &dy, r, r, st, pad);
        assert!(a.allclose(&b, 1e-3), "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn input_grad_engines_agree() {
        let mut rng = Rng::new(13);
        let p = DeconvParams::new(2, 2, 1);
        let k = Tensor::randn(&[5, 5, 3, 4], &mut rng);
        let dy = Tensor::randn(&[1, 4, 4, 4], &mut rng);
        let a = input_grad_huge2(&dy, &k, &p);
        let b = input_grad_baseline(&dy, &k, &p);
        assert_eq!(a.shape(), &[1, 8, 8, 3]);
        assert!(a.allclose(&b, 1e-4));
    }

    #[test]
    fn input_grad_is_conv_adjoint() {
        // <conv(x), dy> == <x, input_grad(dy)>
        let mut rng = Rng::new(14);
        let (st, pad) = (2, 2);
        let x = Tensor::randn(&[1, 8, 8, 2], &mut rng);
        let k = Tensor::randn(&[5, 5, 2, 3], &mut rng);
        let y = base::conv2d(&x, &k, st, pad);
        let dy = Tensor::randn(y.shape(), &mut rng);
        let gx = input_grad_huge2(&dy, &k, &DeconvParams::new(st, pad, 1));
        let lhs: f64 = y.data().iter().zip(dy.data())
            .map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.data().iter().zip(gx.data())
            .map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0),
                "{lhs} vs {rhs}");
    }

    #[test]
    fn weight_grad_mac_ratio() {
        // DCGAN D1-like: 32->16, 5x5, stride 2: naive dilates 16x16 dy to
        // 31x31 -> ~3.75x more MACs
        let (naive, eff) = weight_grad_macs(32, 32, 3, 64, 5, 5, 16, 16, 2);
        let ratio = naive as f64 / eff as f64;
        assert!(ratio > 3.0 && ratio < 4.0, "{ratio}");
    }
}
