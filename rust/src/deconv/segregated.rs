//! Kernel-segregated transposed convolution (Tida et al., arXiv
//! 2209.03704; unified form in 2502.20493) — the third engine beside the
//! naive baseline and HUGE².
//!
//! It shares HUGE²'s first move: segregate (decompose) the `R×S` kernel
//! into `stride²` parity patterns so no inserted zero is ever touched,
//! each pattern producing one disjoint output polyphase. It differs in
//! the second move. HUGE² *untangles* a pattern into `taps_y·taps_x`
//! separate 1×1-conv GEMMs that run directly on strided views of the
//! input (no im2col at all, but `R·S` small GEMMs per image). The
//! segregated formulation instead keeps each pattern **fused**: a tiny
//! per-pattern im2col gathers the pattern's full receptive field into a
//! `(Qy·Qx, taps_y·taps_x·C)` column matrix, and ONE GEMM against the
//! pattern's dense sub-kernel — flattened to `(taps_y·taps_x·C, N)`,
//! exactly the layout [`Pattern::sub`] already stores — produces the
//! whole polyphase. `stride²` GEMMs per image instead of `R·S`, at the
//! cost of a col copy the size of the pattern's receptive field.
//!
//! The col gather is cheap by construction: tap-adjacent x positions are
//! adjacent in the padded image, so each `(q_y, q_x, t_y)` triple copies
//! `taps_x·C` **contiguous** floats. Accumulation order inside a fused
//! GEMM differs from HUGE²'s tap-by-tap order, so the two engines agree
//! to GEMM tolerance (`allclose`), not bitwise — but within this engine,
//! single- vs multi-threaded and pooled vs fresh runs are bit-identical
//! (same per-pattern code path; MT shards whole patterns).
//!
//! Packing ([`SegPack`]) happens once at model load, parallel to the
//! HUGE² pattern list, so plans can offer both engines over one shared
//! decomposition.

use crate::gemm::{sgemm_prepacked_with, PackedB};
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::huge2::{decompose, pad_geometry, Pattern};
use super::{pad_spatial_into, polyphase_len, DeconvParams};

/// Per-pattern fused `(taps_y·taps_x·C, N)` weight panels in GEMM
/// micro-kernel layout, parallel to the [`Pattern`] list they were built
/// from. Packed once at model load; inference never packs B.
#[derive(Debug, Clone)]
pub struct SegPack {
    packed: Vec<PackedB>,
}

impl SegPack {
    /// Fuse each pattern's dense sub-kernel into one packed B panel.
    /// `Pattern::sub` is `(taps_y, taps_x, C, N)` row-major, which
    /// flattened **is** the `(taps_y·taps_x·C, N)` GEMM operand — no
    /// reshuffle, just packing.
    pub fn from_patterns(patterns: &[Pattern]) -> Self {
        let packed = patterns
            .iter()
            .map(|pt| {
                let sh = pt.sub.shape();
                let (ty, tx, c, n) = (sh[0], sh[1], sh[2], sh[3]);
                PackedB::pack(ty * tx * c, n, pt.sub.data())
            })
            .collect();
        SegPack { packed }
    }

    /// Bytes held by the fused panels (plan "prepacked bytes" column).
    pub fn bytes(&self) -> usize {
        self.packed.iter().map(|p| p.bytes()).sum()
    }
}

/// Kernel-segregated transposed convolution.
///
/// `x`: NHWC `(B,H,W,C)`; `k`: HWIO `(R,S,C,N)`; output `(B,Ho,Wo,N)`.
/// Agrees with [`super::baseline::conv2d_transpose`] to GEMM tolerance.
pub fn conv2d_transpose(x: &Tensor, k: &Tensor, p: &DeconvParams)
                        -> Tensor {
    let patterns = decompose(k, p);
    let pack = SegPack::from_patterns(&patterns);
    conv2d_transpose_with(x, &patterns, &pack, k.shape()[0], k.shape()[1],
                          p)
}

/// Same, with the decomposition and fused packing done once at model
/// load.
pub fn conv2d_transpose_with(x: &Tensor, patterns: &[Pattern],
                             pack: &SegPack, r: usize, s: usize,
                             p: &DeconvParams) -> Tensor {
    let ws = Workspace::new();
    conv2d_transpose_ws(x, patterns, pack, r, s, p, &mut ws.handle())
}

/// [`conv2d_transpose_with`] drawing the padded input, per-pattern col
/// matrix, sub-output and GEMM panels from a workspace handle
/// (bit-identical; DESIGN.md §9).
pub fn conv2d_transpose_ws(x: &Tensor, patterns: &[Pattern],
                           pack: &SegPack, r: usize, s: usize,
                           p: &DeconvParams, hnd: &mut WsHandle)
                           -> Tensor {
    let (b, h, w, c) = x.dims4();
    let n = patterns[0].sub.shape()[3];
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    transpose_into(x.data(), b, h, w, c, patterns, pack, r, s, p,
                   out.data_mut(), hnd);
    out
}

/// Multi-threaded segregated transpose: whole patterns are sharded over
/// `threads` (disjoint polyphases — no synchronisation), exactly like
/// the MT HUGE² engine. Bit-identical to the single-threaded engine for
/// every thread count.
pub fn conv2d_transpose_mt(x: &Tensor, patterns: &[Pattern],
                           pack: &SegPack, r: usize, s: usize,
                           p: &DeconvParams, threads: usize) -> Tensor {
    let ws = Workspace::new();
    conv2d_transpose_mt_ws(x, patterns, pack, r, s, p, threads, &ws)
}

/// [`conv2d_transpose_mt`] over a shared workspace: each pattern thread
/// draws its col matrix, sub-output and GEMM panels through its own
/// per-thread handle.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_transpose_mt_ws(x: &Tensor, patterns: &[Pattern],
                              pack: &SegPack, r: usize, s: usize,
                              p: &DeconvParams, threads: usize,
                              ws: &Workspace) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let n = patterns[0].sub.shape()[3];
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    transpose_mt_into(x.data(), b, h, w, c, patterns, pack, r, s, p,
                      threads, out.data_mut(), ws);
    out
}

/// Gather one pattern's receptive field into its `(qy·qx, taps_y·
/// taps_x·C)` column matrix. Each `(q_y, q_x, t_y)` copies `taps_x·C`
/// contiguous floats — tap-adjacent x positions are adjacent in the
/// padded image. Fully overwrites `col[..qy·qx·kk]`, so dirty pooled
/// buffers are safe.
#[allow(clippy::too_many_arguments)]
fn assemble_col(col: &mut [f32], img: &[f32], wp: usize, c: usize,
                pt: &Pattern, qy: usize, qx: usize, pad_lo_y: usize,
                pad_lo_x: usize) {
    let row_tx = pt.ax.taps * c;
    let kk = pt.ay.taps * row_tx;
    let ix0 = (pt.ax.delta + pad_lo_x as isize) as usize;
    for q_y in 0..qy {
        for t_y in 0..pt.ay.taps {
            let iy = (q_y as isize + t_y as isize + pt.ay.delta
                + pad_lo_y as isize) as usize;
            let src0 = (iy * wp + ix0) * c;
            for q_x in 0..qx {
                let dst = (q_y * qx + q_x) * kk + t_y * row_tx;
                let src = src0 + q_x * c;
                col[dst..dst + row_tx]
                    .copy_from_slice(&img[src..src + row_tx]);
            }
        }
    }
}

/// Slice-level core of the segregated transposed conv: `out` (length
/// `b·ho·wo·n`) is fully overwritten (zeroed, then polyphase-scattered);
/// all scratch comes from `hnd`. One fused GEMM per pattern.
#[allow(clippy::too_many_arguments)]
pub(crate) fn transpose_into(xd: &[f32], b: usize, h: usize, w: usize,
                             c: usize, patterns: &[Pattern],
                             pack: &SegPack, r: usize, s: usize,
                             p: &DeconvParams, out: &mut [f32],
                             hnd: &mut WsHandle) {
    let n = patterns[0].sub.shape()[3];
    let st = p.stride;
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    assert_eq!(pack.packed.len(), patterns.len(), "pack/pattern mismatch");
    out.fill(0.0);

    let (pad_lo_y, pad_hi_y, pad_lo_x, pad_hi_x) =
        pad_geometry(patterns, h, w, ho, wo, st);
    let mut xp = hnd.checkout(b * (h + pad_lo_y + pad_hi_y)
        * (w + pad_lo_x + pad_hi_x) * c);
    let (hp, wp) = pad_spatial_into(xd, b, h, w, c, pad_lo_y, pad_hi_y,
                                    pad_lo_x, pad_hi_x, &mut xp);

    let max_qy = (0..st).map(|phi| polyphase_len(ho, st, phi)).max().unwrap();
    let max_qx = (0..st).map(|phi| polyphase_len(wo, st, phi)).max().unwrap();
    let col_cap = patterns
        .iter()
        .map(|pt| {
            polyphase_len(ho, st, pt.phi_y) * polyphase_len(wo, st, pt.phi_x)
                * pt.ay.taps * pt.ax.taps * c
        })
        .max()
        .unwrap_or(0);
    let mut sub_out = hnd.checkout(max_qy * max_qx * n);
    let mut col = hnd.checkout(col_cap.max(1));

    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        for (pt, pb) in patterns.iter().zip(&pack.packed) {
            let qy = polyphase_len(ho, st, pt.phi_y);
            let qx = polyphase_len(wo, st, pt.phi_x);
            if qy == 0 || qx == 0 || pt.ay.taps == 0 || pt.ax.taps == 0 {
                continue;
            }
            let kk = pt.ay.taps * pt.ax.taps * c;
            assemble_col(&mut col, img, wp, c, pt, qy, qx, pad_lo_y,
                         pad_lo_x);
            let sub = &mut sub_out[..qy * qx * n];
            // accumulate=false: the fused GEMM is the whole pattern.
            sgemm_prepacked_with(hnd, qy * qx, &col[..qy * qx * kk], kk,
                                 pb, sub, false);
            for q_y in 0..qy {
                let oy = pt.phi_y + q_y * st;
                for q_x in 0..qx {
                    let ox = pt.phi_x + q_x * st;
                    let src = (q_y * qx + q_x) * n;
                    let dst = ((bi * ho + oy) * wo + ox) * n;
                    out[dst..dst + n].copy_from_slice(&sub[src..src + n]);
                }
            }
        }
    }
    hnd.checkin(xp);
    hnd.checkin(sub_out);
    hnd.checkin(col);
}

/// Slice-level core of the multi-threaded segregated transpose (the
/// plan executor's MT path). `out` is fully overwritten; bit-identical
/// to [`transpose_into`] for every thread count (each pattern's col
/// assembly and fused GEMM are the same code path).
#[allow(clippy::too_many_arguments)]
pub(crate) fn transpose_mt_into(xd: &[f32], b: usize, h: usize, w: usize,
                                c: usize, patterns: &[Pattern],
                                pack: &SegPack, r: usize, s: usize,
                                p: &DeconvParams, threads: usize,
                                out: &mut [f32], ws: &Workspace) {
    let mut hnd = ws.handle();
    let n = patterns[0].sub.shape()[3];
    let st = p.stride;
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    assert_eq!(pack.packed.len(), patterns.len(), "pack/pattern mismatch");
    out.fill(0.0);

    let (pad_lo_y, pad_hi_y, pad_lo_x, pad_hi_x) =
        pad_geometry(patterns, h, w, ho, wo, st);
    let mut xp = hnd.checkout(b * (h + pad_lo_y + pad_hi_y)
        * (w + pad_lo_x + pad_hi_x) * c);
    let (hp, wp) = pad_spatial_into(xd, b, h, w, c, pad_lo_y, pad_hi_y,
                                    pad_lo_x, pad_hi_x, &mut xp);

    // patterns are the shard unit: more threads than patterns would
    // only spawn idle workers (DESIGN.md §14 shard-clamp convention).
    let threads = threads.max(1).min(patterns.len().max(1));
    let chunk = patterns.len().div_ceil(threads);

    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        let mut results: Vec<(usize, crate::workspace::WsBuf, usize,
                              usize)> =
            std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for (pi, (pchunk, bchunk)) in patterns
                    .chunks(chunk)
                    .zip(pack.packed.chunks(chunk))
                    .enumerate()
                {
                    handles.push(sc.spawn(move || {
                        let mut th = ws.handle();
                        let mut local = Vec::new();
                        for (ci, (pt, pb)) in
                            pchunk.iter().zip(bchunk).enumerate()
                        {
                            let qy = polyphase_len(ho, st, pt.phi_y);
                            let qx = polyphase_len(wo, st, pt.phi_x);
                            if qy == 0 || qx == 0 || pt.ay.taps == 0
                                || pt.ax.taps == 0
                            {
                                continue;
                            }
                            let kk = pt.ay.taps * pt.ax.taps * c;
                            let mut col = th.checkout(qy * qx * kk);
                            assemble_col(&mut col, img, wp, c, pt, qy,
                                         qx, pad_lo_y, pad_lo_x);
                            let mut sub = th.checkout(qy * qx * n);
                            sgemm_prepacked_with(&mut th, qy * qx,
                                                 &col[..qy * qx * kk],
                                                 kk, pb, &mut sub,
                                                 false);
                            th.checkin(col);
                            local.push((pi * chunk + ci, sub, qy, qx));
                        }
                        local
                    }));
                }
                handles.into_iter()
                    .flat_map(|h| h.join().unwrap())
                    .collect()
            });
        results.sort_by_key(|(i, ..)| *i);
        for (idx, sub, qy, qx) in results {
            let pt = &patterns[idx];
            for q_y in 0..qy {
                let oy = pt.phi_y + q_y * st;
                for q_x in 0..qx {
                    let ox = pt.phi_x + q_x * st;
                    let src = (q_y * qx + q_x) * n;
                    let dst = ((bi * ho + oy) * wo + ox) * n;
                    out[dst..dst + n].copy_from_slice(&sub[src..src + n]);
                }
            }
            hnd.checkin(sub);
        }
    }
    hnd.checkin(xp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::baseline;
    use crate::rng::Rng;

    fn roundtrip(h: usize, c: usize, n: usize, r: usize, p: DeconvParams,
                 seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let got = conv2d_transpose(&x, &k, &p);
        assert_eq!(got.shape(), want.shape());
        let d = got.max_abs_diff(&want);
        assert!(d < 1e-4 * (c as f32).sqrt(),
                "diff {d} h={h} c={c} n={n} r={r} {p:?}");
    }

    #[test]
    fn dcgan_config() {
        roundtrip(4, 16, 8, 5, DeconvParams::new(2, 2, 1), 1);
        roundtrip(8, 8, 4, 5, DeconvParams::new(2, 2, 1), 2);
    }

    #[test]
    fn cgan_config() {
        roundtrip(8, 8, 4, 4, DeconvParams::new(2, 1, 0), 3);
    }

    #[test]
    fn stride3_4_stride1_and_no_padding() {
        roundtrip(5, 3, 2, 5, DeconvParams::new(3, 2, 1), 4);
        roundtrip(4, 2, 3, 5, DeconvParams::new(4, 1, 2), 5);
        roundtrip(6, 3, 2, 3, DeconvParams::new(1, 1, 0), 6);
        roundtrip(3, 2, 2, 3, DeconvParams::new(2, 0, 0), 7);
    }

    #[test]
    fn mt_bit_identical_to_st_for_every_thread_count() {
        let mut rng = Rng::new(31);
        let p = DeconvParams::new(2, 2, 1);
        let x = Tensor::randn(&[2, 6, 6, 8], &mut rng);
        let k = Tensor::randn(&[5, 5, 8, 4], &mut rng);
        let patterns = decompose(&k, &p);
        let pack = SegPack::from_patterns(&patterns);
        let want = conv2d_transpose_with(&x, &patterns, &pack, 5, 5, &p);
        for threads in [1, 2, 4, 7, 64] {
            let got = conv2d_transpose_mt(&x, &patterns, &pack, 5, 5, &p,
                                          threads);
            assert_eq!(got.checksum(), want.checksum(),
                       "threads={threads} must be bit-identical");
        }
    }

    #[test]
    fn pack_accounts_bytes() {
        let mut rng = Rng::new(32);
        let k = Tensor::randn(&[5, 5, 3, 2], &mut rng);
        let patterns = decompose(&k, &DeconvParams::new(2, 2, 1));
        let pack = SegPack::from_patterns(&patterns);
        assert_eq!(pack.packed.len(), 4);
        assert!(pack.bytes() > 0);
    }
}
