//! The HUGE² engine: kernel decomposition (§3.1) + untangling (§3.2) +
//! polyphase scatter (Fig. 4).
//!
//! For stride `s`, the `R×S` transposed kernel splits into `s·s` patterns
//! by row/col parity; pattern `(φy, φx)` produces exactly the output
//! polyphase `O[φy::s, φx::s]` from *real* input elements only. Each
//! pattern is then untangled into its `taps_y · taps_x` kernel taps, and
//! every tap is one `(Q_x, C) @ (C, N)` GEMM running **directly on a view
//! of the input row** (`sgemm_strided`; no im2col copy, no inflation).
//!
//! Memory behaviour this buys (the paper's §4.2 claims):
//! * input rows are streamed contiguously along C (coalesced);
//! * the `(C, N)` tap weights are contiguous in HWIO layout (the paper's
//!   preferred `C×N` innermost order);
//! * polyphase outputs are disjoint — no read-modify-write races, and the
//!   scatter writes each cache line exactly once per pattern.

use crate::gemm::{sgemm_prepacked_with, PackedB};
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::{axis_pattern, pad_spatial_into, polyphase_len, AxisPattern,
            DeconvParams};

/// One decomposed pattern of a 2-D kernel: the dense sub-kernel plus the
/// axis algebra needed to address its receptive field.
#[derive(Debug, Clone)]
pub struct Pattern {
    pub phi_y: usize,
    pub phi_x: usize,
    pub ay: AxisPattern,
    pub ax: AxisPattern,
    /// `(taps_y, taps_x, C, N)` dense sub-kernel (zeros removed).
    pub sub: Tensor,
    /// Per-tap `(C, N)` weight panels in GEMM micro-kernel layout —
    /// packed once here (model load) so the per-inference tap GEMMs skip
    /// all B packing (§Perf iteration 1).
    pub(crate) packed: Vec<PackedB>,
}

/// Decompose `k` (HWIO `(R,S,C,N)`) into the `stride²` patterns.
pub fn decompose(k: &Tensor, p: &DeconvParams) -> Vec<Pattern> {
    let (r, s, c, n) = k.dims4();
    let st = p.stride;
    let mut out = Vec::with_capacity(st * st);
    for phi_y in 0..st {
        let ay = axis_pattern(r, st, p.pad, phi_y);
        for phi_x in 0..st {
            let ax = axis_pattern(s, st, p.pad, phi_x);
            let mut sub = Tensor::zeros(&[ay.taps, ax.taps, c, n]);
            let mut packed = Vec::with_capacity(ay.taps * ax.taps);
            for ty in 0..ay.taps {
                let src_r = ay.a0 + ty * st;
                for tx in 0..ax.taps {
                    let src_s = ax.a0 + tx * st;
                    let src = ((src_r * s) + src_s) * c * n;
                    let dst = ((ty * ax.taps) + tx) * c * n;
                    sub.data_mut()[dst..dst + c * n]
                        .copy_from_slice(&k.data()[src..src + c * n]);
                    packed.push(PackedB::pack(
                        c, n, &k.data()[src..src + c * n]));
                }
            }
            out.push(Pattern { phi_y, phi_x, ay, ax, sub, packed });
        }
    }
    out
}

/// HUGE² transposed convolution.
///
/// `x`: NHWC `(B,H,W,C)`; `k`: HWIO `(R,S,C,N)`; output `(B,Ho,Wo,N)`.
/// Numerically identical to [`super::baseline::conv2d_transpose`].
pub fn conv2d_transpose(x: &Tensor, k: &Tensor, p: &DeconvParams) -> Tensor {
    let patterns = decompose(k, p);
    conv2d_transpose_with(x, &patterns, k.shape()[0], k.shape()[1], p)
}

/// Same, with a pre-decomposed kernel (serving engines decompose once at
/// model-load time and reuse across requests).
pub fn conv2d_transpose_with(x: &Tensor, patterns: &[Pattern], r: usize,
                             s: usize, p: &DeconvParams) -> Tensor {
    let ws = Workspace::new();
    conv2d_transpose_ws(x, patterns, r, s, p, &mut ws.handle())
}

/// [`conv2d_transpose_with`] drawing the padded input, per-pattern
/// sub-output, tap A-assembly buffer and GEMM panels from a workspace
/// handle (bit-identical; DESIGN.md §9).
pub fn conv2d_transpose_ws(x: &Tensor, patterns: &[Pattern], r: usize,
                           s: usize, p: &DeconvParams, hnd: &mut WsHandle)
                           -> Tensor {
    let (b, h, w, c) = x.dims4();
    let n = patterns[0].sub.shape()[3];
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    transpose_into(x.data(), b, h, w, c, patterns, r, s, p,
                   out.data_mut(), hnd);
    out
}

/// Padded-input geometry shared by the single- and multi-threaded
/// untangled transpose engines (and the plan's workspace accounting):
/// `(pad_lo_y, pad_hi_y, pad_lo_x, pad_hi_x)` — a border generous
/// enough to cover every pattern's receptive-field reach.
pub(crate) fn pad_geometry(patterns: &[Pattern], h: usize, w: usize,
                           ho: usize, wo: usize, st: usize)
                           -> (usize, usize, usize, usize) {
    let max_dy = patterns.iter().map(|pt| pt.ay.taps as isize - 1
        + pt.ay.delta).max().unwrap_or(0);
    let max_dx = patterns.iter().map(|pt| pt.ax.taps as isize - 1
        + pt.ax.delta).max().unwrap_or(0);
    let min_dy = patterns.iter().map(|pt| pt.ay.delta).min().unwrap_or(0);
    let min_dx = patterns.iter().map(|pt| pt.ax.delta).min().unwrap_or(0);
    let max_qy = (0..st).map(|phi| polyphase_len(ho, st, phi)).max().unwrap();
    let max_qx = (0..st).map(|phi| polyphase_len(wo, st, phi)).max().unwrap();
    let pad_lo_y = (-min_dy).max(0) as usize;
    let pad_lo_x = (-min_dx).max(0) as usize;
    let pad_hi_y = ((max_qy as isize - 1 + max_dy) - (h as isize - 1)).max(0)
        as usize;
    let pad_hi_x = ((max_qx as isize - 1 + max_dx) - (w as isize - 1)).max(0)
        as usize;
    (pad_lo_y, pad_hi_y, pad_lo_x, pad_hi_x)
}

/// Slice-level core of the untangled transposed conv: `out` (length
/// `b·ho·wo·n`) is fully overwritten (zeroed, then polyphase-scattered);
/// all scratch comes from `hnd`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn transpose_into(xd: &[f32], b: usize, h: usize, w: usize,
                             c: usize, patterns: &[Pattern], r: usize,
                             s: usize, p: &DeconvParams, out: &mut [f32],
                             hnd: &mut WsHandle) {
    let n = patterns[0].sub.shape()[3];
    let st = p.stride;
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    // Unconditional: `out` may be a dirty pooled slab (gan ping-pong),
    // and empty-pattern polyphases are never scattered over. Callers
    // passing a fresh Tensor::zeros pay ~nothing extra: large zeroed
    // allocations come from calloc, so this is the only real memset.
    out.fill(0.0);

    // Shared padded input: generous border covers every pattern's reach.
    let (pad_lo_y, pad_hi_y, pad_lo_x, pad_hi_x) =
        pad_geometry(patterns, h, w, ho, wo, st);
    let mut xp = hnd.checkout(b * (h + pad_lo_y + pad_hi_y)
        * (w + pad_lo_x + pad_hi_x) * c);
    let (hp, wp) = pad_spatial_into(xd, b, h, w, c, pad_lo_y, pad_hi_y,
                                    pad_lo_x, pad_hi_x, &mut xp);

    // Per-pattern sub-output buffer + tap A-assembly buffer, both reused
    // (and pooled: dirty is fine — `sub` is zero-filled per pattern, the
    // A buffer's used prefix is fully overwritten per tap).
    let max_qy = (0..st).map(|phi| polyphase_len(ho, st, phi)).max().unwrap();
    let max_qx = (0..st).map(|phi| polyphase_len(wo, st, phi)).max().unwrap();
    let mut sub_out = hnd.checkout(max_qy * max_qx * n);
    let mut a_buf = hnd.checkout(max_qy * max_qx * c);

    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        for pt in patterns {
            let qy = polyphase_len(ho, st, pt.phi_y);
            let qx = polyphase_len(wo, st, pt.phi_x);
            if qy == 0 || qx == 0 || pt.ay.taps == 0 || pt.ax.taps == 0 {
                continue;
            }
            let sub = &mut sub_out[..qy * qx * n];
            sub.fill(0.0);
            // Untangled taps: ONE prepacked GEMM per tap. The tap's
            // receptive field is assembled into a contiguous
            // (qy·qx, C) A (qy row copies — a tiny "im2col" over real
            // elements only), so the GEMM runs at full M and the
            // pre-packed (C, N) panel is reused across the whole output
            // (§Perf iterations 1+2).
            for t_y in 0..pt.ay.taps {
                for t_x in 0..pt.ax.taps {
                    let pb = &pt.packed[t_y * pt.ax.taps + t_x];
                    let ix0 = (t_x as isize + pt.ax.delta
                        + pad_lo_x as isize) as usize;
                    for q_y in 0..qy {
                        let iy = (q_y as isize + t_y as isize + pt.ay.delta
                            + pad_lo_y as isize) as usize;
                        let a0 = (iy * wp + ix0) * c;
                        a_buf[q_y * qx * c..(q_y + 1) * qx * c]
                            .copy_from_slice(&img[a0..a0 + qx * c]);
                    }
                    sgemm_prepacked_with(hnd, qy * qx,
                                         &a_buf[..qy * qx * c], c, pb,
                                         sub, true);
                }
            }
            // Polyphase scatter (disjoint writes; paper Fig. 4).
            for q_y in 0..qy {
                let oy = pt.phi_y + q_y * st;
                for q_x in 0..qx {
                    let ox = pt.phi_x + q_x * st;
                    let src = (q_y * qx + q_x) * n;
                    let dst = ((bi * ho + oy) * wo + ox) * n;
                    out[dst..dst + n].copy_from_slice(&sub[src..src + n]);
                }
            }
        }
    }
    hnd.checkin(xp);
    hnd.checkin(sub_out);
    hnd.checkin(a_buf);
}

/// Effective-MAC accounting for one layer (feeds the GPU roofline and the
/// Fig. 8 analytics; mirrors python `decomposed.flop_count`).
pub fn mac_counts(h: usize, w: usize, c: usize, n: usize, r: usize,
                  s: usize, p: &DeconvParams) -> (u64, u64) {
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let naive = (ho * wo * r * s * c * n) as u64;
    let mut eff: u64 = 0;
    for phi_y in 0..p.stride {
        let ay = axis_pattern(r, p.stride, p.pad, phi_y);
        let qy = polyphase_len(ho, p.stride, phi_y);
        for phi_x in 0..p.stride {
            let ax = axis_pattern(s, p.stride, p.pad, phi_x);
            let qx = polyphase_len(wo, p.stride, phi_x);
            eff += (qy * qx * ay.taps * ax.taps * c * n) as u64;
        }
    }
    (naive, eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::baseline;
    use crate::rng::Rng;

    fn roundtrip(h: usize, c: usize, n: usize, r: usize, p: DeconvParams,
                 seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        let got = conv2d_transpose(&x, &k, &p);
        assert_eq!(got.shape(), want.shape());
        let d = got.max_abs_diff(&want);
        assert!(d < 1e-4 * (c as f32).sqrt(),
                "diff {d} h={h} c={c} n={n} r={r} {p:?}");
    }

    #[test]
    fn dcgan_config() {
        roundtrip(4, 16, 8, 5, DeconvParams::new(2, 2, 1), 1);
        roundtrip(8, 8, 4, 5, DeconvParams::new(2, 2, 1), 2);
    }

    #[test]
    fn cgan_config() {
        roundtrip(8, 8, 4, 4, DeconvParams::new(2, 1, 0), 3);
    }

    #[test]
    fn stride3_and_4() {
        roundtrip(5, 3, 2, 5, DeconvParams::new(3, 2, 1), 4);
        roundtrip(4, 2, 3, 5, DeconvParams::new(4, 1, 2), 5);
    }

    #[test]
    fn no_padding() {
        roundtrip(3, 2, 2, 3, DeconvParams::new(2, 0, 0), 6);
    }

    #[test]
    fn batch_consistency() {
        let mut rng = Rng::new(7);
        let p = DeconvParams::new(2, 2, 1);
        let x = Tensor::randn(&[3, 4, 4, 6], &mut rng);
        let k = Tensor::randn(&[5, 5, 6, 4], &mut rng);
        let got = conv2d_transpose(&x, &k, &p);
        let want = baseline::conv2d_transpose(&x, &k, &p);
        assert!(got.allclose(&want, 1e-4));
    }

    #[test]
    fn decompose_partitions_weights() {
        let mut rng = Rng::new(8);
        let k = Tensor::randn(&[5, 5, 3, 2], &mut rng);
        let pats = decompose(&k, &DeconvParams::new(2, 2, 1));
        assert_eq!(pats.len(), 4);
        let total_taps: usize = pats.iter()
            .map(|p| p.ay.taps * p.ax.taps).sum();
        assert_eq!(total_taps, 25);
        // sum of all sub-kernel elements == sum of original kernel
        let sk: f32 = pats.iter()
            .map(|p| p.sub.data().iter().sum::<f32>()).sum();
        let k0: f32 = k.data().iter().sum();
        assert!((sk - k0).abs() < 1e-4);
    }

    #[test]
    fn mac_ratio_stride2() {
        let p = DeconvParams::new(2, 2, 1);
        let (naive, eff) = mac_counts(16, 16, 256, 128, 5, 5, &p);
        let ratio = naive as f64 / eff as f64;
        assert!(ratio > 3.0 && ratio < 4.5, "ratio {ratio}");
    }
}
