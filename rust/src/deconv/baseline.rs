//! The DarkNet-style naive baseline (the algorithm Fig. 7/8 compare
//! against): zero-inflate the input, then run a dense standard convolution
//! via im2col + GEMM.
//!
//! Every inserted zero is materialised, copied into the column matrix and
//! multiplied — at stride 2 roughly 3/4 of the inflated tensor is zeros,
//! so ~75 % of MACs and column-matrix traffic is waste. This is faithful
//! to DarkNet's `forward_deconvolutional_layer` cost model (GEMM over the
//! full inflated geometry; DarkNet phrases it as GEMM+col2im, which touches
//! the same bytes in the adjoint order).

use crate::gemm::sgemm;
use crate::im2col::im2col;
use crate::tensor::Tensor;

use super::{DeconvParams, DilatedParams};

/// Materialise the zero-inflated, asymmetrically padded input tensor
/// (`Î` in the paper): zeros between every pair of rows/cols plus the
/// `(r-1-pad, r-1-pad+out_pad)` border.
pub fn inflate(x: &Tensor, r: usize, s: usize, p: &DeconvParams) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let st = p.stride;
    let ih = (h - 1) * st + 1;
    let iw = (w - 1) * st + 1;
    let (lo_h, hi_h) = p.inflate_pad(r);
    let (lo_w, hi_w) = p.inflate_pad(s);
    let mut out = Tensor::zeros(&[b, ih + lo_h + hi_h, iw + lo_w + hi_w, c]);
    let wo = iw + lo_w + hi_w;
    let xd = x.data();
    let od = out.data_mut();
    for bi in 0..b {
        for hi in 0..h {
            for wi in 0..w {
                let src = ((bi * h + hi) * w + wi) * c;
                let dst = ((bi * (ih + lo_h + hi_h) + lo_h + hi * st) * wo
                    + lo_w + wi * st) * c;
                od[dst..dst + c].copy_from_slice(&xd[src..src + c]);
            }
        }
    }
    out
}

/// Naive transposed convolution: inflate → im2col → GEMM.
///
/// `x`: NHWC `(B,H,W,C)`; `k`: HWIO `(R,S,C,N)`; output `(B,Ho,Wo,N)`.
pub fn conv2d_transpose(x: &Tensor, k: &Tensor, p: &DeconvParams) -> Tensor {
    let (b, h, w, _c) = x.dims4();
    let (r, s, kc, n) = k.dims4();
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let inflated = inflate(x, r, s, p);
    let (_, ih, iw, _) = inflated.dims4();
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    let kmat = k.data(); // (R*S*C, N) row-major — exactly HWIO flattened
    for bi in 0..b {
        let img = Tensor::from_vec(
            &[1, ih, iw, inflated.shape()[3]],
            inflated.data()[bi * ih * iw * kc..(bi + 1) * ih * iw * kc]
                .to_vec(),
        );
        let (col, oh2, ow2) = im2col(&img, r, s, 1, 0);
        debug_assert_eq!((oh2, ow2), (ho, wo));
        let dst = &mut out.data_mut()[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        sgemm(ho * wo, n, r * s * kc, col.data(), kmat, dst, false);
    }
    out
}

/// Naive standard convolution (im2col + GEMM) — used by the discriminator
/// forward and as the substrate of the naive dilated path.
pub fn conv2d(x: &Tensor, k: &Tensor, stride: usize, pad: usize) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc, "channel mismatch");
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - s) / stride + 1;
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    for bi in 0..b {
        let img = Tensor::from_vec(
            &[1, h, w, c],
            x.data()[bi * h * w * c..(bi + 1) * h * w * c].to_vec(),
        );
        let (col, _, _) = im2col(&img, r, s, stride, pad);
        let dst = &mut out.data_mut()[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        sgemm(ho * wo, n, r * s * c, col.data(), k.data(), dst, false);
    }
    out
}

/// Naive dilated convolution: materialise the zero-dilated kernel, then a
/// dense standard convolution over it (paper Alg. 2 as implemented by
/// engines without atrous support).
pub fn conv2d_dilated(x: &Tensor, k: &Tensor, p: &DilatedParams) -> Tensor {
    let (r, s, c, n) = k.dims4();
    let d = p.dilation;
    let er = (r - 1) * d + 1;
    let es = (s - 1) * d + 1;
    let mut dk = Tensor::zeros(&[er, es, c, n]);
    for m in 0..r {
        for nn in 0..s {
            for ci in 0..c {
                for ni in 0..n {
                    let v = k.at(&[m, nn, ci, ni]);
                    dk.set(&[m * d, nn * d, ci, ni], v);
                }
            }
        }
    }
    conv2d(x, &dk, p.stride, p.pad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn inflate_geometry_dcgan() {
        let x = Tensor::full(&[1, 4, 4, 2], 1.0);
        let p = DeconvParams::new(2, 2, 1);
        let inf = inflate(&x, 5, 5, &p);
        // core 7 + pads (2,3) = 12
        assert_eq!(inf.shape(), &[1, 12, 12, 2]);
        let nz = inf.data().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 16 * 2); // only the 16 real elements survive
        assert_eq!(inf.at(&[0, 2, 2, 0]), 1.0); // first real elem at (lo, lo)
    }

    #[test]
    fn identity_kernel_upsamples() {
        // 1x1 kernel * stride 2: output is the zero-inflated input
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[1, 3, 3, 1], &mut rng);
        let k = Tensor::full(&[1, 1, 1, 1], 1.0);
        let p = DeconvParams::new(2, 0, 1);
        let y = conv2d_transpose(&x, &k, &p);
        assert_eq!(y.shape(), &[1, 6, 6, 1]);
        assert_eq!(y.at(&[0, 0, 0, 0]), x.at(&[0, 0, 0, 0]));
        assert_eq!(y.at(&[0, 2, 4, 0]), x.at(&[0, 1, 2, 0]));
        assert_eq!(y.at(&[0, 1, 1, 0]), 0.0);
    }

    #[test]
    fn conv2d_known_values() {
        // all-ones 2x2 input, all-ones 2x2 kernel, valid: single output 4
        let x = Tensor::full(&[1, 2, 2, 1], 1.0);
        let k = Tensor::full(&[2, 2, 1, 1], 1.0);
        let y = conv2d(&x, &k, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.at(&[0, 0, 0, 0]), 4.0);
    }

    #[test]
    fn dilated_equals_bigger_dense_kernel() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[1, 7, 7, 2], &mut rng);
        let k = Tensor::randn(&[3, 3, 2, 2], &mut rng);
        let p = DilatedParams::new(2, 1, 0);
        let y = conv2d_dilated(&x, &k, &p);
        assert_eq!(y.shape(), &[1, 3, 3, 2]);
    }
}
