//! The DarkNet-style naive baseline (the algorithm Fig. 7/8 compare
//! against): zero-inflate the input, then run a dense standard convolution
//! via im2col + GEMM.
//!
//! Every inserted zero is materialised, copied into the column matrix and
//! multiplied — at stride 2 roughly 3/4 of the inflated tensor is zeros,
//! so ~75 % of MACs and column-matrix traffic is waste. This is faithful
//! to DarkNet's `forward_deconvolutional_layer` cost model (GEMM over the
//! full inflated geometry; DarkNet phrases it as GEMM+col2im, which touches
//! the same bytes in the adjoint order).

use crate::gemm::sgemm_with;
use crate::im2col::im2col_into;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::{DeconvParams, DilatedParams};

/// Materialise the zero-inflated, asymmetrically padded input tensor
/// (`Î` in the paper): zeros between every pair of rows/cols plus the
/// `(r-1-pad, r-1-pad+out_pad)` border.
pub fn inflate(x: &Tensor, r: usize, s: usize, p: &DeconvParams) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let st = p.stride;
    let ih = (h - 1) * st + 1;
    let iw = (w - 1) * st + 1;
    let (lo_h, hi_h) = p.inflate_pad(r);
    let (lo_w, hi_w) = p.inflate_pad(s);
    let mut out = Tensor::zeros(&[b, ih + lo_h + hi_h, iw + lo_w + hi_w, c]);
    inflate_into(x.data(), b, h, w, c, r, s, p, out.data_mut());
    out
}

/// [`inflate`] over raw slices into caller-owned scratch. Fully
/// overwrites `dst` (the inserted zeros are written explicitly), so a
/// dirty workspace slab is safe. Returns the padded `(ih, iw)`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn inflate_into(xd: &[f32], b: usize, h: usize, w: usize,
                           c: usize, r: usize, s: usize, p: &DeconvParams,
                           dst: &mut [f32]) -> (usize, usize) {
    let st = p.stride;
    let (lo_h, hi_h) = p.inflate_pad(r);
    let (lo_w, hi_w) = p.inflate_pad(s);
    let ih = (h - 1) * st + 1 + lo_h + hi_h;
    let iw = (w - 1) * st + 1 + lo_w + hi_w;
    assert_eq!(dst.len(), b * ih * iw * c, "inflated size");
    dst.fill(0.0);
    for bi in 0..b {
        for hi in 0..h {
            for wi in 0..w {
                let src = ((bi * h + hi) * w + wi) * c;
                let d = ((bi * ih + lo_h + hi * st) * iw + lo_w + wi * st)
                    * c;
                dst[d..d + c].copy_from_slice(&xd[src..src + c]);
            }
        }
    }
    (ih, iw)
}

/// Naive transposed convolution: inflate → im2col → GEMM.
///
/// `x`: NHWC `(B,H,W,C)`; `k`: HWIO `(R,S,C,N)`; output `(B,Ho,Wo,N)`.
pub fn conv2d_transpose(x: &Tensor, k: &Tensor, p: &DeconvParams) -> Tensor {
    let ws = Workspace::new();
    conv2d_transpose_ws(x, k, p, &mut ws.handle())
}

/// [`conv2d_transpose`] drawing the inflated tensor and column matrix
/// from a workspace handle (bit-identical; DESIGN.md §9).
pub fn conv2d_transpose_ws(x: &Tensor, k: &Tensor, p: &DeconvParams,
                           h: &mut WsHandle) -> Tensor {
    let (b, ih, iw, c) = x.dims4();
    let (r, s, _kc, n) = k.dims4();
    let ho = p.out_size(ih, r);
    let wo = p.out_size(iw, s);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    transpose_into(x.data(), b, ih, iw, c, k, p, out.data_mut(), h);
    out
}

/// Slice-level core of the naive transposed conv: `out` (length
/// `b·ho·wo·n`) is fully overwritten; all scratch comes from `hnd`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn transpose_into(xd: &[f32], b: usize, h: usize, w: usize,
                             c: usize, k: &Tensor, p: &DeconvParams,
                             out: &mut [f32], hnd: &mut WsHandle) {
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc, "channel mismatch");
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    let st = p.stride;
    let (lo_h, hi_h) = p.inflate_pad(r);
    let (lo_w, hi_w) = p.inflate_pad(s);
    let ih = (h - 1) * st + 1 + lo_h + hi_h;
    let iw = (w - 1) * st + 1 + lo_w + hi_w;
    let mut inflated = hnd.checkout(b * ih * iw * c);
    inflate_into(xd, b, h, w, c, r, s, p, &mut inflated);
    let mut col = hnd.checkout(ho * wo * r * s * c);
    let kmat = k.data(); // (R*S*C, N) row-major — exactly HWIO flattened
    for bi in 0..b {
        let img = &inflated[bi * ih * iw * c..(bi + 1) * ih * iw * c];
        let dims = im2col_into(img, ih, iw, c, r, s, 1, 0, &mut col);
        debug_assert_eq!(dims, (ho, wo));
        let dst = &mut out[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        sgemm_with(hnd, ho * wo, n, r * s * c, &col, kmat, dst, false);
    }
    hnd.checkin(inflated);
    hnd.checkin(col);
}

/// Naive standard convolution (im2col + GEMM) — used by the discriminator
/// forward and as the substrate of the naive dilated path.
pub fn conv2d(x: &Tensor, k: &Tensor, stride: usize, pad: usize) -> Tensor {
    let ws = Workspace::new();
    conv2d_ws(x, k, stride, pad, &mut ws.handle())
}

/// [`conv2d`] drawing its column matrix from a workspace handle.
pub fn conv2d_ws(x: &Tensor, k: &Tensor, stride: usize, pad: usize,
                 h: &mut WsHandle) -> Tensor {
    let (b, ih, iw, c) = x.dims4();
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc, "channel mismatch");
    let ho = (ih + 2 * pad - r) / stride + 1;
    let wo = (iw + 2 * pad - s) / stride + 1;
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    conv2d_into(x.data(), b, ih, iw, c, k.data(), r, s, n, stride, pad,
                out.data_mut(), h);
    out
}

/// Slice-level core of the standard conv: the kernel arrives as its HWIO
/// flattening `(R·S·C, N)` so the dilated path can hand over a
/// workspace-built dilated kernel without a `Tensor` detour.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv2d_into(xd: &[f32], b: usize, h: usize, w: usize,
                          c: usize, kmat: &[f32], r: usize, s: usize,
                          n: usize, stride: usize, pad: usize,
                          out: &mut [f32], hnd: &mut WsHandle) {
    assert_eq!(kmat.len(), r * s * c * n, "kernel size");
    let ho = (h + 2 * pad - r) / stride + 1;
    let wo = (w + 2 * pad - s) / stride + 1;
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    let mut col = hnd.checkout(ho * wo * r * s * c);
    for bi in 0..b {
        let img = &xd[bi * h * w * c..(bi + 1) * h * w * c];
        im2col_into(img, h, w, c, r, s, stride, pad, &mut col);
        let dst = &mut out[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        sgemm_with(hnd, ho * wo, n, r * s * c, &col, kmat, dst, false);
    }
    hnd.checkin(col);
}

/// Naive dilated convolution: materialise the zero-dilated kernel, then a
/// dense standard convolution over it (paper Alg. 2 as implemented by
/// engines without atrous support).
pub fn conv2d_dilated(x: &Tensor, k: &Tensor, p: &DilatedParams) -> Tensor {
    let ws = Workspace::new();
    conv2d_dilated_ws(x, k, p, &mut ws.handle())
}

/// [`conv2d_dilated`] drawing the dilated kernel and column matrix from
/// a workspace handle.
pub fn conv2d_dilated_ws(x: &Tensor, k: &Tensor, p: &DilatedParams,
                         h: &mut WsHandle) -> Tensor {
    let (b, ih, iw, c) = x.dims4();
    let (r, s, _, n) = k.dims4();
    let ho = p.out_size(ih, r);
    let wo = p.out_size(iw, s);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);
    conv2d_dilated_into(x.data(), b, ih, iw, c, k, p, out.data_mut(), h);
    out
}

/// Slice-level core of the naive dilated conv.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv2d_dilated_into(xd: &[f32], b: usize, h: usize,
                                  w: usize, c: usize, k: &Tensor,
                                  p: &DilatedParams, out: &mut [f32],
                                  hnd: &mut WsHandle) {
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc, "channel mismatch");
    let d = p.dilation;
    let er = (r - 1) * d + 1;
    let es = (s - 1) * d + 1;
    let mut dk = hnd.checkout_zeroed(er * es * c * n);
    let kd = k.data();
    for m in 0..r {
        for nn in 0..s {
            let src = (m * s + nn) * c * n;
            let dst = (m * d * es + nn * d) * c * n;
            dk[dst..dst + c * n].copy_from_slice(&kd[src..src + c * n]);
        }
    }
    conv2d_into(xd, b, h, w, c, &dk, er, es, n, p.stride, p.pad, out, hnd);
    hnd.checkin(dk);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn inflate_geometry_dcgan() {
        let x = Tensor::full(&[1, 4, 4, 2], 1.0);
        let p = DeconvParams::new(2, 2, 1);
        let inf = inflate(&x, 5, 5, &p);
        // core 7 + pads (2,3) = 12
        assert_eq!(inf.shape(), &[1, 12, 12, 2]);
        let nz = inf.data().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 16 * 2); // only the 16 real elements survive
        assert_eq!(inf.at(&[0, 2, 2, 0]), 1.0); // first real elem at (lo, lo)
    }

    #[test]
    fn identity_kernel_upsamples() {
        // 1x1 kernel * stride 2: output is the zero-inflated input
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[1, 3, 3, 1], &mut rng);
        let k = Tensor::full(&[1, 1, 1, 1], 1.0);
        let p = DeconvParams::new(2, 0, 1);
        let y = conv2d_transpose(&x, &k, &p);
        assert_eq!(y.shape(), &[1, 6, 6, 1]);
        assert_eq!(y.at(&[0, 0, 0, 0]), x.at(&[0, 0, 0, 0]));
        assert_eq!(y.at(&[0, 2, 4, 0]), x.at(&[0, 1, 2, 0]));
        assert_eq!(y.at(&[0, 1, 1, 0]), 0.0);
    }

    #[test]
    fn conv2d_known_values() {
        // all-ones 2x2 input, all-ones 2x2 kernel, valid: single output 4
        let x = Tensor::full(&[1, 2, 2, 1], 1.0);
        let k = Tensor::full(&[2, 2, 1, 1], 1.0);
        let y = conv2d(&x, &k, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.at(&[0, 0, 0, 0]), 4.0);
    }

    #[test]
    fn dilated_equals_bigger_dense_kernel() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[1, 7, 7, 2], &mut rng);
        let k = Tensor::randn(&[3, 3, 2, 2], &mut rng);
        let p = DilatedParams::new(2, 1, 0);
        let y = conv2d_dilated(&x, &k, &p);
        assert_eq!(y.shape(), &[1, 3, 3, 2]);
    }
}
