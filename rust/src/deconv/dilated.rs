//! HUGE² dilated (atrous) convolution — untangling without kernel
//! inflation (paper §3.2.2).
//!
//! Each of the `R·S` real taps reads a stride-strided view of the input
//! and contributes one `(Wo, C) @ (C, N)` GEMM per output row; the view's
//! element stride is `stride·C`, which [`crate::gemm::sgemm_strided`]
//! absorbs during packing — still zero copies.

use crate::gemm::{sgemm_prepacked_with, sgemm_strided_with, PackedB};
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::{pad_spatial_into, DilatedParams};

/// A dilated kernel's `R·S` taps, each pre-packed into GEMM micro-kernel
/// layout — the dilated-path analogue of [`super::huge2::decompose`]:
/// packing happens once at model-load time, so every inference's tap
/// GEMMs skip all B packing (`seg::SegLayer` holds one of these per
/// layer, exactly as `gan::GenLayer` holds its `Pattern`s).
#[derive(Debug, Clone)]
pub struct DilatedTaps {
    pub r: usize,
    pub s: usize,
    pub c: usize,
    pub n: usize,
    /// `(C, N)` panels in `(t_r·S + t_c)` order.
    pub(crate) packed: Vec<PackedB>,
}

impl DilatedTaps {
    /// Bytes held by the packed tap panels (plan prepack accounting).
    pub fn packed_bytes(&self) -> usize {
        self.packed.iter().map(|p| p.bytes()).sum()
    }
}

/// Pack every tap of `k` (HWIO `(R,S,C,N)`) for [`conv2d_dilated_with`].
pub fn pack_taps(k: &Tensor) -> DilatedTaps {
    let (r, s, c, n) = k.dims4();
    let packed = (0..r * s)
        .map(|t| PackedB::pack(c, n, &k.data()[t * c * n..(t + 1) * c * n]))
        .collect();
    DilatedTaps { r, s, c, n, packed }
}

/// HUGE² dilated convolution. `x`: NHWC; `k`: HWIO `(R,S,C,N)`.
/// Numerically identical to [`super::baseline::conv2d_dilated`].
pub fn conv2d_dilated(x: &Tensor, k: &Tensor, p: &DilatedParams) -> Tensor {
    let ws = Workspace::new();
    let hnd = &mut ws.handle();
    let (b, h, w, c) = x.dims4();
    let (r, s, kc, n) = k.dims4();
    assert_eq!(c, kc);
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let mut xp = hnd.checkout(b * (h + 2 * p.pad) * (w + 2 * p.pad) * c);
    let (hp, wp) = pad_spatial_into(x.data(), b, h, w, c, p.pad, p.pad,
                                    p.pad, p.pad, &mut xp);
    let mut out = Tensor::zeros(&[b, ho, wo, n]);

    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        let od = &mut out.data_mut()[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        // Tap loops outer so the (C, N) tap weights stay cache-resident
        // across all output rows (same reuse order as the transposed path).
        for t_r in 0..r {
            for t_c in 0..s {
                let wslice = &k.data()[(t_r * s + t_c) * c * n
                    ..(t_r * s + t_c + 1) * c * n];
                let ix0 = t_c * p.dilation;
                for oy in 0..ho {
                    let dst = &mut od[oy * wo * n..(oy + 1) * wo * n];
                    let iy = oy * p.stride + t_r * p.dilation;
                    let a0 = (iy * wp + ix0) * c;
                    // A: (wo, C) view, element row stride = stride·C
                    let lda = p.stride * c;
                    let a_len = (wo - 1) * lda + c;
                    let a = &img[a0..a0 + a_len];
                    sgemm_strided_with(hnd, wo, n, c, a, lda, wslice, dst,
                                       true);
                }
            }
        }
    }
    out
}

/// Accumulate every tap's contribution into one output row (`dst` is
/// row `oy`, length `wo·n`; `img` is one padded image of width `wp`).
/// Taps run in `(t_r, t_c)` ascending order — this one function defines
/// the per-row accumulation order for **both** the single-threaded and
/// the multi-threaded untangled engines, so their bit-identity
/// (DESIGN.md §8) holds by construction, not by duplication discipline.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accumulate_row(dst: &mut [f32], img: &[f32],
                             taps: &DilatedTaps, p: &DilatedParams,
                             oy: usize, wp: usize, wo: usize,
                             hnd: &mut WsHandle) {
    let (s, c) = (taps.s, taps.c);
    for t_r in 0..taps.r {
        for t_c in 0..s {
            let pb = &taps.packed[t_r * s + t_c];
            let ix0 = t_c * p.dilation;
            let iy = oy * p.stride + t_r * p.dilation;
            let a0 = (iy * wp + ix0) * c;
            let lda = p.stride * c;
            let a_len = (wo - 1) * lda + c;
            sgemm_prepacked_with(hnd, wo, &img[a0..a0 + a_len], lda, pb,
                                 dst, true);
        }
    }
}

/// [`conv2d_dilated`] with pre-packed tap panels (model-load-time
/// decomposition). Bit-identical to the unpacked engine: the per-row
/// tap accumulation order and the blocked GEMM are the same, so serving
/// engines can switch to this without perturbing replay checksums.
pub fn conv2d_dilated_with(x: &Tensor, taps: &DilatedTaps,
                           p: &DilatedParams) -> Tensor {
    let ws = Workspace::new();
    conv2d_dilated_ws(x, taps, p, &mut ws.handle())
}

/// [`conv2d_dilated_with`] drawing padded input and GEMM scratch from a
/// workspace handle (bit-identical; DESIGN.md §9).
pub fn conv2d_dilated_ws(x: &Tensor, taps: &DilatedTaps, p: &DilatedParams,
                         hnd: &mut WsHandle) -> Tensor {
    let (b, h, w, c) = x.dims4();
    let ho = p.out_size(h, taps.r);
    let wo = p.out_size(w, taps.s);
    let mut out = Tensor::zeros(&[b, ho, wo, taps.n]);
    dilated_into(x.data(), b, h, w, c, taps, p, out.data_mut(), hnd);
    out
}

/// Slice-level core of the untangled dilated conv: `out` (length
/// `b·ho·wo·n`) is fully overwritten (zeroed, then tap-accumulated); all
/// scratch comes from `hnd`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dilated_into(xd: &[f32], b: usize, h: usize, w: usize,
                           c: usize, taps: &DilatedTaps, p: &DilatedParams,
                           out: &mut [f32], hnd: &mut WsHandle) {
    let (r, s, n) = (taps.r, taps.s, taps.n);
    assert_eq!(c, taps.c);
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    assert_eq!(out.len(), b * ho * wo * n, "output size");
    // Unconditional: `out` may be a dirty pooled slab, and the tap
    // GEMMs accumulate (+=). Fresh Tensor::zeros callers pay ~nothing
    // extra (calloc), so this is the only real memset.
    out.fill(0.0);
    let mut xp = hnd.checkout(b * (h + 2 * p.pad) * (w + 2 * p.pad) * c);
    let (hp, wp) = pad_spatial_into(xd, b, h, w, c, p.pad, p.pad, p.pad,
                                    p.pad, &mut xp);
    for bi in 0..b {
        let img = &xp[bi * hp * wp * c..(bi + 1) * hp * wp * c];
        let od = &mut out[bi * ho * wo * n..(bi + 1) * ho * wo * n];
        for oy in 0..ho {
            accumulate_row(&mut od[oy * wo * n..(oy + 1) * wo * n], img,
                           taps, p, oy, wp, wo, hnd);
        }
    }
    hnd.checkin(xp);
}

/// MAC counts: naive (dense over the dilated kernel extent) vs untangled.
pub fn mac_counts(h: usize, w: usize, c: usize, n: usize, r: usize,
                  s: usize, p: &DilatedParams) -> (u64, u64) {
    let ho = p.out_size(h, r);
    let wo = p.out_size(w, s);
    let er = p.eff_kernel(r);
    let es = p.eff_kernel(s);
    let naive = (ho * wo * er * es * c * n) as u64;
    let eff = (ho * wo * r * s * c * n) as u64;
    (naive, eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::baseline;
    use crate::rng::Rng;

    fn roundtrip(h: usize, c: usize, n: usize, r: usize, p: DilatedParams,
                 seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[1, h, h, c], &mut rng);
        let k = Tensor::randn(&[r, r, c, n], &mut rng);
        let want = baseline::conv2d_dilated(&x, &k, &p);
        let got = conv2d_dilated(&x, &k, &p);
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-4),
                "h={h} c={c} n={n} r={r} {p:?} diff={}",
                got.max_abs_diff(&want));
    }

    #[test]
    fn same_padding() {
        roundtrip(13, 4, 3, 3, DilatedParams::new(2, 1, 2), 1);
        roundtrip(13, 4, 3, 3, DilatedParams::new(4, 1, 4), 2);
    }

    #[test]
    fn valid_padding() {
        roundtrip(9, 2, 2, 3, DilatedParams::new(2, 1, 0), 3);
    }

    #[test]
    fn strided() {
        roundtrip(13, 3, 2, 3, DilatedParams::new(2, 2, 2), 4);
        roundtrip(17, 2, 2, 3, DilatedParams::new(3, 2, 3), 5);
    }

    #[test]
    fn dilation_one_is_standard_conv() {
        let mut rng = Rng::new(6);
        let x = Tensor::randn(&[1, 8, 8, 3], &mut rng);
        let k = Tensor::randn(&[3, 3, 3, 2], &mut rng);
        let p = DilatedParams::new(1, 1, 1);
        let got = conv2d_dilated(&x, &k, &p);
        let want = baseline::conv2d(&x, &k, 1, 1);
        assert!(got.allclose(&want, 1e-4));
    }

    #[test]
    fn depthwise_outer_product_case() {
        // paper 3.2.3: C=1 dilated conv is an outer product of vectors
        roundtrip(7, 1, 1, 3, DilatedParams::new(2, 1, 0), 7);
    }

    #[test]
    fn batch() {
        let mut rng = Rng::new(8);
        let p = DilatedParams::new(2, 1, 2);
        let x = Tensor::randn(&[2, 9, 9, 3], &mut rng);
        let k = Tensor::randn(&[3, 3, 3, 4], &mut rng);
        let got = conv2d_dilated(&x, &k, &p);
        let want = baseline::conv2d_dilated(&x, &k, &p);
        assert!(got.allclose(&want, 1e-4));
    }

    #[test]
    fn prepacked_taps_are_bit_identical() {
        let mut rng = Rng::new(9);
        for (h, c, n, r, p) in [
            (13, 4, 3, 3, DilatedParams::new(2, 1, 2)),
            (13, 3, 2, 3, DilatedParams::new(2, 2, 2)),
            (9, 2, 5, 1, DilatedParams::new(1, 1, 0)),
        ] {
            let x = Tensor::randn(&[2, h, h, c], &mut rng);
            let k = Tensor::randn(&[r, r, c, n], &mut rng);
            let want = conv2d_dilated(&x, &k, &p);
            let taps = pack_taps(&k);
            let got = conv2d_dilated_with(&x, &taps, &p);
            assert_eq!(got.checksum(), want.checksum(),
                       "prepacked path must not perturb replay checksums");
        }
    }

    #[test]
    fn mac_ratio_is_dilation_squared() {
        let p = DilatedParams::new(2, 1, 2);
        let (naive, eff) = mac_counts(16, 16, 8, 8, 3, 3, &p);
        // (5*5)/(3*3) ≈ 2.78
        assert!((naive as f64 / eff as f64 - 25.0 / 9.0).abs() < 1e-9);
    }
}
