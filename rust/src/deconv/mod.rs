//! Deconvolution engines — the paper's core contribution and its baseline.
//!
//! * [`baseline`] — the naive DarkNet-style algorithm: materialise the
//!   zero-inflated input, im2col, one big GEMM. ~75 % of its MACs multiply
//!   zeros at stride 2.
//! * [`huge2`] — the paper's engine: kernel decomposition (§3.1) into
//!   stride-parity patterns + untangling (§3.2) into 1×1-conv GEMMs +
//!   polyphase scatter, never touching an inserted zero.
//! * [`segregated`] — kernel-segregated transposed convolution (Tida et
//!   al., arXiv 2209.03704 / 2502.20493): the same parity decomposition
//!   as HUGE², but each pattern stays **fused** — one per-pattern im2col
//!   + one GEMM per pattern instead of one GEMM per tap.
//! * [`dilated`] — both variants of dilated (atrous) convolution (§2.1.2).
//! * [`grad`] — GAN-training gradients (§3.2.3): weight gradient as a
//!   dilated convolution, input gradient as a transposed convolution.
//!
//! All engines share [`crate::gemm`], so measured ratios isolate the
//! algorithm (DESIGN.md §2).

pub mod baseline;
pub mod col2im_baseline;
pub mod dilated;
pub mod grad;
pub mod huge2;
pub mod parallel;
pub mod segregated;

/// Which deconvolution engine a forward pass uses. Shared by every
/// consumer of the two kernel families — the GAN generator stack
/// ([`crate::gan`], transposed convs) and the segmentation stack
/// ([`crate::seg`], dilated convs) — so multi-task models can make the
/// baseline-vs-HUGE² choice per layer with one vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// DarkNet-style zero-insertion (transposed) / zero-dilated-kernel
    /// (dilated) + im2col + GEMM.
    Baseline,
    /// Kernel decomposition + untangling (the paper).
    Huge2,
    /// Kernel-segregated fused form ([`segregated`]): parity
    /// decomposition like HUGE², then one per-pattern im2col + GEMM
    /// instead of per-tap GEMMs. Explicit-only: the `Auto` heuristic
    /// never selects it, so existing plan digests (and the traces that
    /// embed them) stay valid. Dilated convs have no inserted zeros to
    /// segregate, so on the dilated path it resolves to the HUGE²
    /// untangled engine.
    Segregated,
    /// Resolve per layer at plan-compile time from the shape/thread
    /// heuristic in [`crate::plan`] (Baseline vs HUGE² vs the
    /// multi-threaded HUGE² engines). Never reaches an engine kernel:
    /// [`crate::plan::resolve_transpose`]/[`crate::plan::resolve_dilated`]
    /// turn it into one of the concrete variants.
    Auto,
}

impl Engine {
    /// Stable lowercase name (plan tables, digests, `--engine` flag).
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Baseline => "baseline",
            Engine::Huge2 => "huge2",
            Engine::Segregated => "segregated",
            Engine::Auto => "auto",
        }
    }
}

/// Geometry of one transposed-convolution layer (mirrors the python
/// `DeconvLayer` / `ref.py` conventions exactly).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeconvParams {
    pub stride: usize,
    pub pad: usize,
    pub out_pad: usize,
}

impl DeconvParams {
    pub const fn new(stride: usize, pad: usize, out_pad: usize) -> Self {
        DeconvParams { stride, pad, out_pad }
    }

    /// Output spatial size: `(h-1)·stride - 2·pad + r + out_pad`.
    pub fn out_size(&self, h: usize, r: usize) -> usize {
        (h - 1) * self.stride + r + self.out_pad - 2 * self.pad
    }

    /// Low/high zero-padding of the inflated tensor along one axis.
    pub fn inflate_pad(&self, r: usize) -> (usize, usize) {
        let lo = r - 1 - self.pad;
        (lo, lo + self.out_pad)
    }
}

/// Geometry of a dilated convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DilatedParams {
    pub dilation: usize,
    pub stride: usize,
    pub pad: usize,
}

impl DilatedParams {
    pub const fn new(dilation: usize, stride: usize, pad: usize) -> Self {
        DilatedParams { dilation, stride, pad }
    }

    /// Effective (dilated) kernel extent.
    pub fn eff_kernel(&self, r: usize) -> usize {
        (r - 1) * self.dilation + 1
    }

    pub fn out_size(&self, h: usize, r: usize) -> usize {
        (h + 2 * self.pad - self.eff_kernel(r)) / self.stride + 1
    }
}

/// One §3.1 pattern along a single axis.
///
/// For output phase `phi` (`y ≡ phi mod stride`), the taps used are
/// `a0, a0+stride, …` and tap `t` reads input index `q + t + delta`
/// where `q = (y - phi)/stride`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxisPattern {
    /// First kernel tap of this pattern.
    pub a0: usize,
    /// Number of taps (`ceil((r - a0)/stride)`).
    pub taps: usize,
    /// Input offset of tap 0 (can be negative: reads the padded border).
    pub delta: isize,
}

/// Decomposition algebra for one axis (see python `pattern_params`).
pub fn axis_pattern(r: usize, stride: usize, pad: usize, phi: usize)
                    -> AxisPattern {
    let lo = r - 1 - pad; // low inflate-pad
    let a0 = (lo + stride - phi % stride) % stride;
    let taps = if a0 >= r { 0 } else { (r - a0).div_ceil(stride) };
    let delta = (phi as isize + a0 as isize - lo as isize) / stride as isize;
    debug_assert_eq!((phi as isize + a0 as isize - lo as isize)
                         .rem_euclid(stride as isize), 0);
    AxisPattern { a0, taps, delta }
}

/// Zero-pad the spatial dims of a raw NHWC slice into caller-owned
/// scratch (the pooled engines' padded-input buffer). Fully overwrites
/// `dst` (borders zeroed explicitly), so dirty workspace slabs are safe.
/// Returns `(hp, wp)`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pad_spatial_into(xd: &[f32], b: usize, h: usize, w: usize,
                               c: usize, lo_h: usize, hi_h: usize,
                               lo_w: usize, hi_w: usize, dst: &mut [f32])
                               -> (usize, usize) {
    let hp = h + lo_h + hi_h;
    let wp = w + lo_w + hi_w;
    assert_eq!(xd.len(), b * h * w * c, "input size");
    assert_eq!(dst.len(), b * hp * wp * c, "padded size");
    dst.fill(0.0);
    for bi in 0..b {
        for hi in 0..h {
            let src = ((bi * h + hi) * w) * c;
            let d = ((bi * hp + hi + lo_h) * wp + lo_w) * c;
            dst[d..d + w * c].copy_from_slice(&xd[src..src + w * c]);
        }
    }
    (hp, wp)
}

/// Number of output positions `y < total` with `y ≡ phi (mod stride)`.
pub fn polyphase_len(total: usize, stride: usize, phi: usize) -> usize {
    if phi >= total {
        0
    } else {
        (total - phi).div_ceil(stride)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcgan_geometry() {
        let p = DeconvParams::new(2, 2, 1);
        assert_eq!(p.out_size(4, 5), 8);
        assert_eq!(p.out_size(32, 5), 64);
        assert_eq!(p.inflate_pad(5), (2, 3));
    }

    #[test]
    fn cgan_geometry() {
        let p = DeconvParams::new(2, 1, 0);
        assert_eq!(p.out_size(8, 4), 16);
        assert_eq!(p.inflate_pad(4), (2, 2));
    }

    #[test]
    fn patterns_partition_kernel() {
        // sum of per-pattern taps == r for every (r, stride, pad)
        for r in 1..=7 {
            for stride in 1..=4 {
                for pad in 0..r {
                    let total: usize = (0..stride)
                        .map(|phi| axis_pattern(r, stride, pad, phi).taps)
                        .sum();
                    assert_eq!(total, r, "r={r} stride={stride} pad={pad}");
                }
            }
        }
    }

    #[test]
    fn dcgan_patterns_match_paper() {
        // 5x5 kernel, stride 2, pad 2 -> patterns with 3 and 2 taps
        let p0 = axis_pattern(5, 2, 2, 0);
        let p1 = axis_pattern(5, 2, 2, 1);
        assert_eq!((p0.a0, p0.taps), (0, 3));
        assert_eq!((p1.a0, p1.taps), (1, 2));
    }

    #[test]
    fn polyphase_lengths_sum_to_total() {
        for total in 1..40 {
            for stride in 1..5 {
                let s: usize = (0..stride)
                    .map(|phi| polyphase_len(total, stride, phi))
                    .sum();
                assert_eq!(s, total);
            }
        }
    }

    #[test]
    fn dilated_geometry() {
        let p = DilatedParams::new(2, 1, 2);
        assert_eq!(p.eff_kernel(3), 5);
        assert_eq!(p.out_size(13, 3), 13); // 'same' when pad == dilation
    }
}
