//! Compact binary trace codec (format v4/v5) — the JSONL format's
//! exact twin, auto-detected on read by magic (DESIGN.md §13).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    8 bytes  "HG2TRACE"
//! version  varint   == TRACE_VERSION (5); v4 still decodes
//! header   model, backend (str) · seed, z_dim, cond_dim (varint) ·
//!          task, net, engine_digest (str) ·
//!          fleet (varint count + (str, str) pairs — v5 only)
//! events*  tag (1 byte) · Δt_us (zigzag varint vs previous event) ·
//!          per-kind fields
//! ```
//!
//! v5 (fleet serving, DESIGN.md §16) adds priority-tagged arrival
//! variants (tags 10/11 — one trailing class byte; default-class
//! arrivals still write the v4 tags 1/2, so a single-model
//! default-priority recording is byte-identical to what a v4 writer
//! produced), shed/evict/reload events (tags 12–14), and the header's
//! fleet roster. A v4 reader never sees the new tags unless the
//! recording actually used fleet features.
//!
//! Field encodings: `varint` is LEB128; `str` is varint length +
//! UTF-8 bytes; lists are varint count + items; **f32s are raw
//! IEEE-754 bit patterns** (4 bytes — bit-exact by construction, NaN
//! payloads included); u64 checksums/fingerprints are raw 8 bytes
//! (high-entropy values gain nothing from varint). Timestamps are
//! delta-encoded against the previous event — monotone in recorded
//! traces, so almost always 1–2 bytes — with zigzag so hand-built
//! non-monotone streams still encode.
//!
//! The result is ~4–6× smaller than the same events in JSONL (the
//! recording-overhead phase of `benches/serving.rs` measures it, CI
//! enforces ≥4× on a soak). There is no compression pass: every byte
//! is directly seekable/parseable, and a truncated or bit-flipped file
//! fails decode with a byte offset instead of silently skipping.
//!
//! Encoding appends to a caller-owned scratch buffer
//! ([`encode_event_into`]) so a steady-state recording sink performs
//! zero allocations once the scratch has warmed up.

use anyhow::{anyhow, Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::coordinator::Priority;
use crate::metrics::{HistogramSnapshot, MetricsSnapshot};

use super::codec::{self, TRACE_VERSION};
use super::event::{ArrivalPayload, CheckpointState, EventBody,
                   TraceEvent, TraceHeader};

/// First 8 bytes of every binary trace. 'H' ≠ '{', so JSONL and binary
/// traces are distinguishable from their first byte alone.
pub const MAGIC: [u8; 8] = *b"HG2TRACE";

const TAG_ARRIVAL_LATENT: u8 = 1;
const TAG_ARRIVAL_IMAGE: u8 = 2;
const TAG_ENQUEUE: u8 = 3;
const TAG_REJECT: u8 = 4;
const TAG_BATCH_FORMED: u8 = 5;
const TAG_BATCH_EXECUTED: u8 = 6;
const TAG_RESPONSE: u8 = 7;
const TAG_FAILED: u8 = 8;
const TAG_CHECKPOINT: u8 = 9;
// v5 (fleet serving): arrivals with a non-default priority class carry
// one extra trailing byte (the class rank); default-class arrivals
// keep the v4 tags above for byte-stable output.
const TAG_ARRIVAL_LATENT_PRI: u8 = 10;
const TAG_ARRIVAL_IMAGE_PRI: u8 = 11;
const TAG_SHED: u8 = 12;
const TAG_EVICT: u8 = 13;
const TAG_RELOAD: u8 = 14;

/// Oldest binary version this build still reads (the binary format was
/// born at v4).
const MIN_BINARY_VERSION: u64 = 4;

/// Decode-side sanity caps: a corrupt length prefix must produce a
/// clean error, not a multi-gigabyte allocation.
const MAX_STR: u64 = 1 << 20;
const MAX_LIST: u64 = 1 << 24;

// ----------------------------------------------------------------- encode

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_varint(buf, vs.len() as u64);
    for v in vs {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn put_u64_list(buf: &mut Vec<u8>, vs: &[u64]) {
    put_varint(buf, vs.len() as u64);
    for &v in vs {
        put_varint(buf, v);
    }
}

fn put_metrics(buf: &mut Vec<u8>, m: &MetricsSnapshot) {
    put_varint(buf, m.counters.len() as u64);
    for (k, &v) in &m.counters {
        put_str(buf, k);
        put_varint(buf, v);
    }
    put_varint(buf, m.gauges.len() as u64);
    for (k, &v) in &m.gauges {
        put_str(buf, k);
        put_varint(buf, zigzag(v));
    }
    put_varint(buf, m.histograms.len() as u64);
    for (k, h) in &m.histograms {
        put_str(buf, k);
        let (pairs, sum_us, max_us) = h.to_sparse();
        put_varint(buf, sum_us);
        put_varint(buf, max_us);
        put_varint(buf, pairs.len() as u64);
        for (idx, n) in pairs {
            put_varint(buf, idx as u64);
            put_varint(buf, n);
        }
    }
}

/// Append the magic + version + header to `buf`.
pub fn encode_header_into(buf: &mut Vec<u8>, h: &TraceHeader) {
    buf.extend_from_slice(&MAGIC);
    put_varint(buf, TRACE_VERSION as u64);
    put_str(buf, &h.model);
    put_str(buf, &h.backend);
    put_varint(buf, h.seed);
    put_varint(buf, h.z_dim as u64);
    put_varint(buf, h.cond_dim as u64);
    put_str(buf, &h.task);
    put_str(buf, &h.net);
    put_str(buf, &h.engine_digest);
    // v5: fleet roster — (name, digest) pairs; empty for single-model
    put_varint(buf, h.fleet.len() as u64);
    for (name, digest) in &h.fleet {
        put_str(buf, name);
        put_str(buf, digest);
    }
}

/// Append one event to `buf`. `prev_t_us` is the previous event's
/// timestamp (0 for the first) — timestamps are delta-encoded. Appends
/// only; callers that reuse one scratch buffer allocate nothing in
/// steady state.
pub fn encode_event_into(buf: &mut Vec<u8>, prev_t_us: u64,
                         e: &TraceEvent) {
    match &e.body {
        EventBody::RequestArrival {
            id,
            model,
            payload: ArrivalPayload::Latent { z, cond },
            priority,
        } => {
            // default class keeps the v4 tag — byte-stable old traces
            let tagged = *priority != Priority::default();
            buf.push(if tagged { TAG_ARRIVAL_LATENT_PRI }
                     else { TAG_ARRIVAL_LATENT });
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            put_str(buf, model);
            put_f32s(buf, z);
            put_f32s(buf, cond);
            if tagged {
                buf.push(priority.rank());
            }
        }
        EventBody::RequestArrival {
            id,
            model,
            payload: ArrivalPayload::Image { shape, seed, checksum },
            priority,
        } => {
            let tagged = *priority != Priority::default();
            buf.push(if tagged { TAG_ARRIVAL_IMAGE_PRI }
                     else { TAG_ARRIVAL_IMAGE });
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            put_str(buf, model);
            put_varint(buf, shape.len() as u64);
            for &d in shape {
                put_varint(buf, d as u64);
            }
            put_varint(buf, *seed);
            buf.extend_from_slice(&checksum.to_le_bytes());
            if tagged {
                buf.push(priority.rank());
            }
        }
        EventBody::Enqueue { id, depth } => {
            buf.push(TAG_ENQUEUE);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            put_varint(buf, *depth as u64);
        }
        EventBody::Reject { id, reason } => {
            buf.push(TAG_REJECT);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            put_str(buf, reason);
        }
        EventBody::BatchFormed { ids } => {
            buf.push(TAG_BATCH_FORMED);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_u64_list(buf, ids);
        }
        EventBody::BatchExecuted { ids, bucket, exec_us } => {
            buf.push(TAG_BATCH_EXECUTED);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_u64_list(buf, ids);
            put_varint(buf, *bucket as u64);
            put_varint(buf, *exec_us);
        }
        EventBody::Response { id, batch_size, bucket, latency_us,
                              checksum } => {
            buf.push(TAG_RESPONSE);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            put_varint(buf, *batch_size as u64);
            put_varint(buf, *bucket as u64);
            put_varint(buf, *latency_us);
            buf.extend_from_slice(&checksum.to_le_bytes());
        }
        EventBody::Failed { id, kind, reason } => {
            buf.push(TAG_FAILED);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            put_str(buf, kind);
            put_str(buf, reason);
        }
        EventBody::Shed { id, class } => {
            buf.push(TAG_SHED);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, *id);
            buf.push(class.rank());
        }
        EventBody::Evict { model, bytes } => {
            buf.push(TAG_EVICT);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_str(buf, model);
            put_varint(buf, *bytes);
        }
        EventBody::Reload { model, bytes, digest } => {
            buf.push(TAG_RELOAD);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_str(buf, model);
            put_varint(buf, *bytes);
            buf.extend_from_slice(&digest.to_le_bytes());
        }
        EventBody::Checkpoint(c) => {
            buf.push(TAG_CHECKPOINT);
            put_varint(buf, zigzag(e.t_us as i64 - prev_t_us as i64));
            put_varint(buf, c.seq);
            put_varint(buf, c.events);
            put_u64_list(buf, &c.pending);
            put_varint(buf, c.next_id);
            put_varint(buf, c.submitted);
            put_varint(buf, c.completed);
            put_varint(buf, c.rejected);
            put_varint(buf, c.failed);
            buf.extend_from_slice(&c.fingerprint.to_le_bytes());
            buf.extend_from_slice(&c.chain.to_le_bytes());
            put_metrics(buf, &c.metrics);
        }
    }
}

/// Streaming binary-trace writer: one reused scratch buffer, flushed to
/// the inner writer per event — the zero-steady-state-allocation sink
/// the recording path and the serving bench use.
pub struct BinaryWriter<W: Write> {
    w: W,
    prev_t_us: u64,
    scratch: Vec<u8>,
}

impl<W: Write> BinaryWriter<W> {
    /// Write magic + version + header, ready for events.
    pub fn new(w: W, header: &TraceHeader) -> Result<Self> {
        let mut bw =
            BinaryWriter { w, prev_t_us: 0, scratch: Vec::new() };
        encode_header_into(&mut bw.scratch, header);
        bw.flush_scratch()?;
        Ok(bw)
    }

    fn flush_scratch(&mut self) -> Result<()> {
        self.w.write_all(&self.scratch)?;
        self.scratch.clear();
        Ok(())
    }

    pub fn event(&mut self, e: &TraceEvent) -> Result<()> {
        encode_event_into(&mut self.scratch, self.prev_t_us, e);
        self.prev_t_us = e.t_us;
        self.flush_scratch()
    }

    /// Current capacity of the reused scratch buffer — stable once
    /// warmed up (asserted by the serving bench's recording-overhead
    /// phase).
    pub fn scratch_capacity(&self) -> usize {
        self.scratch.capacity()
    }

    pub fn finish(mut self) -> Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Write a complete trace in the binary format.
pub fn write_trace(path: &Path, header: &TraceHeader,
                   events: &[TraceEvent]) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating trace {}", path.display()))?;
    let mut w = BinaryWriter::new(BufWriter::new(file), header)?;
    for e in events {
        w.event(e)?;
    }
    w.finish()?;
    Ok(())
}

// ----------------------------------------------------------------- decode

/// Cursor over the raw bytes; every error names the byte offset, so a
/// truncated or bit-flipped trace is rejected with a location instead
/// of silently skipped.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.pos)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.bytes.len() {
            return Err(format!(
                "unexpected end of file at byte {} (wanted {n} more \
                 byte(s) — truncated trace?)",
                self.bytes.len()
            ));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn byte(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn varint(&mut self) -> Result<u64, String> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.byte()?;
            if shift == 63 && b > 1 {
                return Err(self.err("varint overflows u64"));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(self.err("varint too long"));
            }
        }
    }

    fn len(&mut self, cap: u64, what: &str) -> Result<usize, String> {
        let at = self.pos;
        let n = self.varint()?;
        // Reject lengths above u32::MAX before the `usize` cast: on a
        // 32-bit target (the ARM edge builds) the cast would silently
        // truncate, turning a corrupt length into a wrong-but-plausible
        // one. Checked first so the error names the real failure even
        // if a cap is ever raised past 32 bits.
        if n > u32::MAX as u64 {
            return Err(format!(
                "{what} length {n} at byte {at} exceeds u32::MAX \
                 (corrupt length prefix?)"
            ));
        }
        if n > cap {
            return Err(self.err(&format!(
                "implausible {what} length {n} (cap {cap})"
            )));
        }
        Ok(n as usize)
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.len(MAX_STR, "string")?;
        let at = self.pos;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| format!("invalid UTF-8 string at byte {at}"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.len(MAX_LIST, "f32 list")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(4)?;
            out.push(f32::from_bits(u32::from_le_bytes(
                b.try_into().unwrap(),
            )));
        }
        Ok(out)
    }

    fn u64_list(&mut self) -> Result<Vec<u64>, String> {
        let n = self.len(MAX_LIST, "u64 list")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.varint()?);
        }
        Ok(out)
    }

    fn raw_u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn priority(&mut self) -> Result<Priority, String> {
        let at = self.pos;
        let rank = self.byte()?;
        Priority::from_rank(rank).ok_or_else(|| {
            format!("unknown priority class rank {rank} at byte {at}")
        })
    }

    fn t_us(&mut self, prev: u64) -> Result<u64, String> {
        let at = self.pos;
        let delta = unzigzag(self.varint()?);
        (prev as i64)
            .checked_add(delta)
            .filter(|&t| t >= 0)
            .map(|t| t as u64)
            .ok_or_else(|| {
                format!("timestamp delta underflows at byte {at}")
            })
    }

    fn metrics(&mut self) -> Result<MetricsSnapshot, String> {
        let mut out = MetricsSnapshot::default();
        for _ in 0..self.len(MAX_LIST, "metrics counter")? {
            let k = self.str()?;
            let v = self.varint()?;
            out.counters.insert(k, v);
        }
        for _ in 0..self.len(MAX_LIST, "metrics gauge")? {
            let k = self.str()?;
            let v = unzigzag(self.varint()?);
            out.gauges.insert(k, v);
        }
        for _ in 0..self.len(MAX_LIST, "metrics histogram")? {
            let k = self.str()?;
            let sum_us = self.varint()?;
            let max_us = self.varint()?;
            let npairs = self.len(MAX_LIST, "sparse bucket")?;
            let mut pairs = Vec::with_capacity(npairs);
            for _ in 0..npairs {
                let idx = self.varint()? as usize;
                let n = self.varint()?;
                pairs.push((idx, n));
            }
            let h = HistogramSnapshot::from_sparse(&pairs, sum_us,
                                                   max_us)
                .map_err(|e| format!("histogram {k:?}: {e}"))?;
            out.histograms.insert(k, h);
        }
        Ok(out)
    }

    fn event(&mut self, prev_t_us: u64) -> Result<TraceEvent, String> {
        let at = self.pos;
        let tag = self.byte()?;
        let t_us = self.t_us(prev_t_us)?;
        let body = match tag {
            TAG_ARRIVAL_LATENT | TAG_ARRIVAL_LATENT_PRI => {
                let id = self.varint()?;
                let model = self.str()?;
                let z = self.f32s()?;
                let cond = self.f32s()?;
                let priority = if tag == TAG_ARRIVAL_LATENT_PRI {
                    self.priority()?
                } else {
                    Priority::default()
                };
                EventBody::RequestArrival {
                    id,
                    model,
                    payload: ArrivalPayload::Latent { z, cond },
                    priority,
                }
            }
            TAG_ARRIVAL_IMAGE | TAG_ARRIVAL_IMAGE_PRI => {
                let id = self.varint()?;
                let model = self.str()?;
                let ndims = self.len(16, "shape")?;
                let mut shape = Vec::with_capacity(ndims);
                for _ in 0..ndims {
                    shape.push(self.varint()? as usize);
                }
                let seed = self.varint()?;
                let checksum = self.raw_u64()?;
                let priority = if tag == TAG_ARRIVAL_IMAGE_PRI {
                    self.priority()?
                } else {
                    Priority::default()
                };
                EventBody::RequestArrival {
                    id,
                    model,
                    payload: ArrivalPayload::Image {
                        shape,
                        seed,
                        checksum,
                    },
                    priority,
                }
            }
            TAG_ENQUEUE => EventBody::Enqueue {
                id: self.varint()?,
                depth: self.varint()? as usize,
            },
            TAG_REJECT => EventBody::Reject {
                id: self.varint()?,
                reason: self.str()?,
            },
            TAG_BATCH_FORMED => EventBody::BatchFormed {
                ids: self.u64_list()?,
            },
            TAG_BATCH_EXECUTED => EventBody::BatchExecuted {
                ids: self.u64_list()?,
                bucket: self.varint()? as usize,
                exec_us: self.varint()?,
            },
            TAG_RESPONSE => EventBody::Response {
                id: self.varint()?,
                batch_size: self.varint()? as usize,
                bucket: self.varint()? as usize,
                latency_us: self.varint()?,
                checksum: self.raw_u64()?,
            },
            TAG_FAILED => EventBody::Failed {
                id: self.varint()?,
                kind: self.str()?,
                reason: self.str()?,
            },
            TAG_SHED => EventBody::Shed {
                id: self.varint()?,
                class: self.priority()?,
            },
            TAG_EVICT => EventBody::Evict {
                model: self.str()?,
                bytes: self.varint()?,
            },
            TAG_RELOAD => EventBody::Reload {
                model: self.str()?,
                bytes: self.varint()?,
                digest: self.raw_u64()?,
            },
            TAG_CHECKPOINT => {
                EventBody::Checkpoint(Box::new(CheckpointState {
                    seq: self.varint()?,
                    events: self.varint()?,
                    pending: self.u64_list()?,
                    next_id: self.varint()?,
                    submitted: self.varint()?,
                    completed: self.varint()?,
                    rejected: self.varint()?,
                    failed: self.varint()?,
                    fingerprint: self.raw_u64()?,
                    chain: self.raw_u64()?,
                    metrics: self.metrics()?,
                }))
            }
            other => {
                return Err(format!(
                    "unknown event tag {other} at byte {at}"
                ));
            }
        };
        Ok(TraceEvent { t_us, body })
    }
}

/// Decode a complete binary trace from raw bytes.
pub fn decode_trace(bytes: &[u8])
                    -> Result<(TraceHeader, Vec<TraceEvent>), String> {
    let mut r = Reader { bytes, pos: 0 };
    let magic = r.take(MAGIC.len())?;
    if magic != MAGIC {
        return Err("not a huge2 binary trace (bad magic)".into());
    }
    let version = r.varint()?;
    // The binary format was born at v4 — v4 and v5 both decode (a v4
    // header simply has no fleet roster); newer versions are rejected
    // like JSONL does.
    if !(MIN_BINARY_VERSION..=TRACE_VERSION as u64).contains(&version) {
        return Err(format!(
            "unsupported binary trace version {version} (this build \
             reads {MIN_BINARY_VERSION}..={TRACE_VERSION})"
        ));
    }
    let mut header = TraceHeader {
        model: r.str()?,
        backend: r.str()?,
        seed: r.varint()?,
        z_dim: r.varint()? as usize,
        cond_dim: r.varint()? as usize,
        task: r.str()?,
        net: r.str()?,
        engine_digest: r.str()?,
        fleet: Vec::new(),
    };
    if version >= 5 {
        for _ in 0..r.len(MAX_LIST, "fleet roster")? {
            let name = r.str()?;
            let digest = r.str()?;
            header.fleet.push((name, digest));
        }
    }
    let mut events = Vec::new();
    let mut prev_t_us = 0u64;
    while r.pos < r.bytes.len() {
        let e = r.event(prev_t_us)?;
        prev_t_us = e.t_us;
        events.push(e);
    }
    Ok((header, events))
}

/// Read a complete binary trace file.
pub fn read_trace(path: &Path)
                  -> Result<(TraceHeader, Vec<TraceEvent>)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("opening trace {}", path.display()))?;
    decode_trace(&bytes)
        .map_err(|e| anyhow!("{}: {e}", path.display()))
}

/// Does `path` start with the binary-trace magic? (Extension is
/// irrelevant on the read side — only the first bytes decide.)
pub fn sniff_is_binary(path: &Path) -> Result<bool> {
    use std::io::Read;
    let mut file = std::fs::File::open(path)
        .with_context(|| format!("opening trace {}", path.display()))?;
    let mut head = [0u8; 8];
    let mut n = 0;
    while n < head.len() {
        let read = file.read(&mut head[n..])
            .with_context(|| format!("reading {}", path.display()))?;
        if read == 0 {
            break;
        }
        n += read;
    }
    Ok(n == head.len() && head == MAGIC)
}

/// Load a trace in either format: binary when the magic matches, JSONL
/// otherwise. This is the read path every consumer (`replay`, `trace
/// info/convert/fingerprints/bisect`) goes through — the file
/// extension never matters on read.
pub fn read_trace_auto(path: &Path)
                       -> Result<(TraceHeader, Vec<TraceEvent>)> {
    if sniff_is_binary(path)? {
        read_trace(path)
    } else {
        codec::read_trace(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> TraceHeader {
        TraceHeader {
            model: "dcgan".into(),
            backend: "native".into(),
            seed: 7,
            z_dim: 100,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: "00ff00ff00ff00ff".into(),
            fleet: vec![("seg".into(), "123456789abcdef0".into())],
        }
    }

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                t_us: 10,
                body: EventBody::RequestArrival {
                    id: 0,
                    model: "dcgan µ\"\\".into(),
                    payload: ArrivalPayload::Latent {
                        z: vec![1.5, -0.0, f32::NAN,
                                f32::MIN_POSITIVE],
                        cond: vec![],
                    },
                    priority: Priority::default(),
                },
            },
            TraceEvent {
                t_us: 11,
                body: EventBody::Enqueue { id: 0, depth: 1 },
            },
            TraceEvent {
                t_us: 12,
                body: EventBody::RequestArrival {
                    id: 1,
                    model: "seg".into(),
                    payload: ArrivalPayload::Image {
                        shape: vec![1, 33, 33, 3],
                        seed: 0xfeed_beef,
                        checksum: u64::MAX,
                    },
                    // non-default: exercises TAG_ARRIVAL_IMAGE_PRI
                    priority: Priority::Batch,
                },
            },
            TraceEvent {
                t_us: 12,
                body: EventBody::Reject { id: 2, reason: "full".into() },
            },
            TraceEvent {
                t_us: 13,
                body: EventBody::RequestArrival {
                    id: 3,
                    model: "dcgan".into(),
                    payload: ArrivalPayload::Latent {
                        z: vec![0.25],
                        cond: vec![1.0],
                    },
                    // non-default: exercises TAG_ARRIVAL_LATENT_PRI
                    priority: Priority::Background,
                },
            },
            TraceEvent {
                t_us: 14,
                body: EventBody::Shed {
                    id: 3,
                    class: Priority::Background,
                },
            },
            TraceEvent {
                t_us: 15,
                body: EventBody::Evict {
                    model: "seg".into(),
                    bytes: 1 << 20,
                },
            },
            TraceEvent {
                t_us: 16,
                body: EventBody::Reload {
                    model: "seg".into(),
                    bytes: 1 << 20,
                    digest: 0xdead_beef_dead_beef,
                },
            },
            TraceEvent {
                t_us: 40,
                body: EventBody::BatchFormed { ids: vec![0, 1] },
            },
            TraceEvent {
                t_us: 90,
                body: EventBody::BatchExecuted {
                    ids: vec![0, 1],
                    bucket: 2,
                    exec_us: 50,
                },
            },
            TraceEvent {
                t_us: 95,
                body: EventBody::Response {
                    id: 0,
                    batch_size: 2,
                    bucket: 2,
                    latency_us: 85,
                    checksum: 0x9f86_d081_884c_7d65,
                },
            },
            TraceEvent {
                t_us: 96,
                body: EventBody::Failed {
                    id: 1,
                    kind: "batch_failed".into(),
                    reason: "boom\n".into(),
                },
            },
            TraceEvent {
                t_us: 97,
                body: EventBody::Checkpoint(Box::new(CheckpointState {
                    seq: 1,
                    events: 8,
                    pending: vec![],
                    next_id: 3,
                    submitted: 3,
                    completed: 1,
                    rejected: 1,
                    failed: 1,
                    fingerprint: 0x0123_4567_89ab_cdef,
                    chain: u64::MAX,
                    metrics: MetricsSnapshot::default(),
                })),
            },
        ]
    }

    fn encode(h: &TraceHeader, evs: &[TraceEvent]) -> Vec<u8> {
        let mut buf = Vec::new();
        encode_header_into(&mut buf, h);
        let mut prev = 0;
        for e in evs {
            encode_event_into(&mut buf, prev, e);
            prev = e.t_us;
        }
        buf
    }

    #[test]
    fn varint_round_trips() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader { bytes: &buf, pos: 0 };
            assert_eq!(r.varint().unwrap(), v);
            assert_eq!(r.pos, buf.len());
        }
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn every_event_kind_round_trips() {
        let h = header();
        let evs = sample_events();
        let bytes = encode(&h, &evs);
        let (h2, evs2) = decode_trace(&bytes).unwrap();
        assert_eq!(h2, h);
        // NaN != NaN under PartialEq: compare via re-encoding, which is
        // bit-pattern-faithful (same trick as the JSONL codec tests).
        assert_eq!(encode(&h2, &evs2), bytes);
        assert_eq!(evs2.len(), evs.len());
    }

    #[test]
    fn truncation_anywhere_is_rejected() {
        let bytes = encode(&header(), &sample_events());
        // every strict prefix must fail (clean EOF only at event
        // boundaries — and the header alone IS a valid empty trace, so
        // skip exact boundary positions)
        let boundaries: Vec<usize> = {
            let mut ends = Vec::new();
            let mut buf = Vec::new();
            encode_header_into(&mut buf, &header());
            ends.push(buf.len());
            let mut prev = 0;
            for e in sample_events() {
                encode_event_into(&mut buf, prev, &e);
                prev = e.t_us;
                ends.push(buf.len());
            }
            ends
        };
        for cut in 0..bytes.len() {
            if boundaries.contains(&cut) {
                assert!(decode_trace(&bytes[..cut]).is_ok(),
                        "cut at boundary {cut} must decode");
            } else {
                assert!(decode_trace(&bytes[..cut]).is_err(),
                        "mid-event cut at byte {cut} must be rejected");
            }
        }
    }

    #[test]
    fn corrupt_magic_and_version_are_rejected() {
        let mut bytes = encode(&header(), &[]);
        bytes[0] ^= 0xff;
        let err = decode_trace(&bytes).unwrap_err();
        assert!(err.contains("magic"), "{err}");
        let mut bytes = encode(&header(), &[]);
        bytes[8] = 99; // version varint
        let err = decode_trace(&bytes).unwrap_err();
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn v4_binary_stream_still_decodes() {
        // hand-build a v4 stream: version byte 4, and no fleet-count
        // varint at the end of the header (v4 headers predate fleets)
        let mut h = header();
        h.fleet.clear();
        let arrival = TraceEvent {
            t_us: 5,
            body: EventBody::RequestArrival {
                id: 0,
                model: "dcgan".into(),
                payload: ArrivalPayload::Latent {
                    z: vec![0.5],
                    cond: vec![],
                },
                priority: Priority::default(),
            },
        };
        let mut v5 = Vec::new();
        encode_header_into(&mut v5, &h);
        let mut v4 = v5.clone();
        v4[8] = 4; // version varint (single byte)
        let trailing = v4.pop(); // fleet count 0 — absent in v4
        assert_eq!(trailing, Some(0));
        encode_event_into(&mut v4, 0, &arrival);
        let (h2, evs) = decode_trace(&v4).unwrap();
        assert_eq!(h2, h, "v4 header decodes with an empty fleet");
        assert!(matches!(
            &evs[0].body,
            EventBody::RequestArrival {
                priority: Priority::Interactive, ..
            }
        ));
        // and a default-priority arrival encodes to the same bytes a
        // v4 writer produced (tag 1, no priority byte): the event
        // stream is byte-stable, only the header grew
        let mut event_only = Vec::new();
        encode_event_into(&mut event_only, 0, &arrival);
        assert_eq!(event_only[0], TAG_ARRIVAL_LATENT);
        assert!(v4.ends_with(&event_only));
    }

    #[test]
    fn unknown_tag_and_bogus_length_are_rejected() {
        let mut bytes = encode(&header(), &[]);
        bytes.push(0xfe); // no such tag
        bytes.push(0x00);
        let err = decode_trace(&bytes).unwrap_err();
        assert!(err.contains("unknown event tag 254"), "{err}");
        // an arrival whose z-length claims 2^30 floats: clean error,
        // no allocation
        let mut bytes = encode(&header(), &[]);
        bytes.push(TAG_ARRIVAL_LATENT);
        bytes.push(0); // Δt
        bytes.push(0); // id
        bytes.push(1); // model len 1
        bytes.push(b'm');
        put_varint(&mut bytes, 1 << 30); // z count
        let err = decode_trace(&bytes).unwrap_err();
        assert!(err.contains("implausible"), "{err}");
    }

    #[test]
    fn file_round_trip_and_sniffing() {
        let dir = std::env::temp_dir();
        let bin = dir.join(format!("huge2_bin_codec_{}.bin",
                                   std::process::id()));
        let jsonl = dir.join(format!("huge2_bin_codec_{}.jsonl",
                                     std::process::id()));
        let evs: Vec<TraceEvent> = sample_events()
            .into_iter()
            .filter(|e| {
                // keep the comparison PartialEq-friendly here: drop the
                // NaN-bearing arrival (bit-exactness is covered above)
                !matches!(&e.body,
                          EventBody::RequestArrival {
                              payload: ArrivalPayload::Latent { z, .. },
                              ..
                          } if z.iter().any(|v| v.is_nan()))
            })
            .collect();
        write_trace(&bin, &header(), &evs).unwrap();
        codec::write_trace(&jsonl, &header(), &evs).unwrap();
        assert!(sniff_is_binary(&bin).unwrap());
        assert!(!sniff_is_binary(&jsonl).unwrap());
        // auto-detection reads both, extension notwithstanding
        let (hb, eb) = read_trace_auto(&bin).unwrap();
        let (hj, ej) = read_trace_auto(&jsonl).unwrap();
        assert_eq!(hb, hj);
        assert_eq!(eb, ej);
        assert_eq!(eb, evs);
        // binary is materially smaller even on this tiny mixed sample
        let bin_len = std::fs::metadata(&bin).unwrap().len();
        let jsonl_len = std::fs::metadata(&jsonl).unwrap().len();
        assert!(bin_len * 2 < jsonl_len,
                "binary {bin_len} B vs jsonl {jsonl_len} B");
        std::fs::remove_file(&bin).ok();
        std::fs::remove_file(&jsonl).ok();
    }

    #[test]
    fn writer_scratch_stops_growing() {
        let mut w =
            BinaryWriter::new(Vec::new(), &header()).unwrap();
        let evs = sample_events();
        for e in &evs {
            w.event(e).unwrap();
        }
        let warmed = w.scratch_capacity();
        for _ in 0..100 {
            for e in &evs {
                w.event(e).unwrap();
            }
        }
        assert_eq!(w.scratch_capacity(), warmed,
                   "steady-state encoding must not reallocate");
        let bytes = w.finish().unwrap();
        // repeated event blocks rewind t_us — zigzag deltas encode the
        // non-monotone stream and decode reproduces it exactly
        let (_, evs2) = decode_trace(&bytes).unwrap();
        assert_eq!(evs2.len(), evs.len() * 101);
        assert_eq!(evs2[evs.len()].t_us, evs[0].t_us);
    }
}
