//! Divergence detection and reporting — the wasm-rr contract: replay
//! either reproduces every recorded *outcome* or fails loudly, naming
//! the **first** trace event whose outcome the replay could not
//! reproduce. Outcomes cover both sides of the serving contract:
//! `Response` events verify by output checksum, `Failed` events (trace
//! v3) verify by `ServeError::kind()` — failure determinism is checked
//! the same way output determinism is.

use std::collections::HashMap;
use std::fmt;
use std::time::Duration;

use super::event::{EventBody, TraceEvent};

/// What a replay run produced for one request id — the replay-side
/// value diffed against recorded `Response`/`Failed` events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplayedOutcome {
    /// A response whose output hashed to this checksum.
    Response(u64),
    /// A typed failure with this `ServeError::kind()` tag (delivered
    /// through the reply channel, or refused at submit).
    Failed(String),
}

/// One reproducibility violation, anchored to the recorded trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Divergence {
    /// The replayed output for `id` hashed differently than recorded.
    ChecksumMismatch {
        /// 0-based index of the recorded `Response` event in the trace.
        event_index: usize,
        id: u64,
        recorded: u64,
        replayed: u64,
    },
    /// The recording answered `id` but the replay produced no outcome
    /// at all (worker thread died without replying — an engine bug by
    /// the supervision contract).
    MissingResponse { event_index: usize, id: u64 },
    /// The recording answered `id` with a response, but the replay
    /// failed it with this `ServeError::kind()`.
    ResponseBecameFailure {
        event_index: usize,
        id: u64,
        kind: String,
    },
    /// The recording failed `id` (a v3 `Failed` event) but the replay
    /// did not reproduce that failure: `replayed` is the differing
    /// failure kind, `"response"` when the replay answered it, or
    /// `"none"` when the replay produced no outcome.
    FailureMismatch {
        event_index: usize,
        id: u64,
        recorded_kind: String,
        replayed: String,
    },
}

impl Divergence {
    /// Trace index of the first event the replay failed to reproduce.
    pub fn event_index(&self) -> usize {
        match self {
            Divergence::ChecksumMismatch { event_index, .. }
            | Divergence::MissingResponse { event_index, .. }
            | Divergence::ResponseBecameFailure { event_index, .. }
            | Divergence::FailureMismatch { event_index, .. } => {
                *event_index
            }
        }
    }
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Divergence::ChecksumMismatch {
                event_index,
                id,
                recorded,
                replayed,
            } => write!(
                f,
                "event #{event_index} (response id={id}): checksum \
                 mismatch — recorded {recorded:#018x}, replayed \
                 {replayed:#018x}"
            ),
            Divergence::MissingResponse { event_index, id } => write!(
                f,
                "event #{event_index} (response id={id}): recorded a \
                 response but replay produced none"
            ),
            Divergence::ResponseBecameFailure { event_index, id,
                                                kind } => write!(
                f,
                "event #{event_index} (response id={id}): recorded a \
                 response but replay failed it ({kind})"
            ),
            Divergence::FailureMismatch {
                event_index,
                id,
                recorded_kind,
                replayed,
            } => write!(
                f,
                "event #{event_index} (failed id={id}): recorded a \
                 {recorded_kind:?} failure but replay produced \
                 {replayed:?}"
            ),
        }
    }
}

/// Outcome of one replay run.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Arrivals re-driven through the engine.
    pub requests: usize,
    /// Replayed outcomes that had a recorded counterpart to verify
    /// (`Response` and `Failed` events both count).
    pub compared: usize,
    /// Of those, how many matched (checksum bit-for-bit, or failure
    /// kind).
    pub matched: usize,
    /// Replay outcomes with no recorded counterpart — e.g. the
    /// recording rejected the request at submit but fast replay
    /// admitted and answered it. A replay-side typed refusal of a
    /// request the recording *also* rejected is agreement and is not
    /// counted. Informational — scheduling is allowed to differ,
    /// outcomes are not.
    pub extra_responses: usize,
    /// All violations, ordered by recorded event index.
    pub divergences: Vec<Divergence>,
    /// A diagnosis for the divergences when the replayer can infer one
    /// (e.g. checksum mismatches replaying a digest-less pre-plan trace
    /// under `Engine::Auto` — "re-record or pin the engine"). Printed
    /// by the CLI alongside the first divergence.
    pub hint: Option<String>,
    /// Replay wall-clock.
    pub wall: Duration,
}

impl ReplayReport {
    pub fn is_clean(&self) -> bool {
        self.divergences.is_empty()
    }

    /// The first mismatching event (trace order), if any.
    pub fn first_divergence(&self) -> Option<&Divergence> {
        self.divergences.first()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests replayed, {}/{} outcomes verified, {} \
             divergence(s), {} extra response(s), {:.2}s wall",
            self.requests,
            self.matched,
            self.compared,
            self.divergences.len(),
            self.extra_responses,
            self.wall.as_secs_f64()
        )
    }
}

/// Compare replayed outcomes against every recorded `Response` and
/// `Failed` event, in trace order. `replayed` maps request id → the
/// outcome the replay produced for it.
pub fn diff_responses(events: &[TraceEvent],
                      replayed: &HashMap<u64, ReplayedOutcome>)
                      -> (Vec<Divergence>, usize, usize) {
    diff_responses_at(events, replayed, 0)
}

/// [`diff_responses`] over a window slice: `base_index` is the slice's
/// offset into the full trace, so divergence `event_index` values stay
/// absolute trace positions whichever window was replayed.
pub fn diff_responses_at(events: &[TraceEvent],
                         replayed: &HashMap<u64, ReplayedOutcome>,
                         base_index: usize)
                         -> (Vec<Divergence>, usize, usize) {
    let mut divergences = Vec::new();
    let mut compared = 0;
    let mut matched = 0;
    for (idx, ev) in events.iter().enumerate() {
        let idx = base_index + idx;
        match &ev.body {
            EventBody::Response { id, checksum, .. } => {
                match replayed.get(id) {
                    None => divergences.push(Divergence::MissingResponse {
                        event_index: idx,
                        id: *id,
                    }),
                    Some(ReplayedOutcome::Response(got)) => {
                        compared += 1;
                        if got == checksum {
                            matched += 1;
                        } else {
                            divergences.push(
                                Divergence::ChecksumMismatch {
                                    event_index: idx,
                                    id: *id,
                                    recorded: *checksum,
                                    replayed: *got,
                                });
                        }
                    }
                    Some(ReplayedOutcome::Failed(kind)) => {
                        compared += 1;
                        divergences.push(
                            Divergence::ResponseBecameFailure {
                                event_index: idx,
                                id: *id,
                                kind: kind.clone(),
                            });
                    }
                }
            }
            EventBody::Failed { id, kind, .. } => {
                let got = match replayed.get(id) {
                    None => "none".to_string(),
                    Some(ReplayedOutcome::Response(_)) => {
                        compared += 1;
                        "response".to_string()
                    }
                    Some(ReplayedOutcome::Failed(k)) => {
                        compared += 1;
                        k.clone()
                    }
                };
                if &got == kind {
                    matched += 1;
                } else {
                    divergences.push(Divergence::FailureMismatch {
                        event_index: idx,
                        id: *id,
                        recorded_kind: kind.clone(),
                        replayed: got,
                    });
                }
            }
            _ => {}
        }
    }
    (divergences, compared, matched)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(t_us: u64, id: u64, checksum: u64) -> TraceEvent {
        TraceEvent {
            t_us,
            body: EventBody::Response {
                id,
                batch_size: 1,
                bucket: 1,
                latency_us: 1,
                checksum,
            },
        }
    }

    fn failed(t_us: u64, id: u64, kind: &str) -> TraceEvent {
        TraceEvent {
            t_us,
            body: EventBody::Failed {
                id,
                kind: kind.into(),
                reason: "r".into(),
            },
        }
    }

    fn ok(checksum: u64) -> ReplayedOutcome {
        ReplayedOutcome::Response(checksum)
    }

    #[test]
    fn clean_when_all_match() {
        let events = vec![resp(0, 0, 10), resp(1, 1, 11)];
        let replayed: HashMap<u64, ReplayedOutcome> =
            [(0, ok(10)), (1, ok(11))].into_iter().collect();
        let (d, compared, matched) = diff_responses(&events, &replayed);
        assert!(d.is_empty());
        assert_eq!((compared, matched), (2, 2));
    }

    #[test]
    fn mismatch_names_first_event() {
        let events = vec![
            TraceEvent {
                t_us: 0,
                body: EventBody::Enqueue { id: 0, depth: 1 },
            },
            resp(1, 0, 10),
            resp(2, 1, 11),
        ];
        let replayed: HashMap<u64, ReplayedOutcome> =
            [(0, ok(10)), (1, ok(99))].into_iter().collect();
        let (d, compared, matched) = diff_responses(&events, &replayed);
        assert_eq!((compared, matched), (2, 1));
        assert_eq!(
            d,
            vec![Divergence::ChecksumMismatch {
                event_index: 2,
                id: 1,
                recorded: 11,
                replayed: 99,
            }]
        );
        assert_eq!(d[0].event_index(), 2);
        let msg = d[0].to_string();
        assert!(msg.contains("event #2"), "{msg}");
        assert!(msg.contains("id=1"), "{msg}");
    }

    #[test]
    fn missing_response_is_a_divergence() {
        let events = vec![resp(0, 3, 10)];
        let replayed = HashMap::new();
        let (d, compared, _) = diff_responses(&events, &replayed);
        assert_eq!(compared, 0);
        assert_eq!(
            d,
            vec![Divergence::MissingResponse { event_index: 0, id: 3 }]
        );
    }

    #[test]
    fn recorded_failure_matches_by_kind() {
        let events = vec![failed(0, 7, "validation")];
        let replayed: HashMap<u64, ReplayedOutcome> =
            [(7, ReplayedOutcome::Failed("validation".into()))]
                .into_iter()
                .collect();
        let (d, compared, matched) = diff_responses(&events, &replayed);
        assert!(d.is_empty(), "{d:?}");
        assert_eq!((compared, matched), (1, 1));
    }

    #[test]
    fn failure_mismatches_name_what_replay_did() {
        // recorded failure vs replay response / different kind / nothing
        let events = vec![
            failed(0, 1, "batch_failed"),
            failed(1, 2, "batch_failed"),
            failed(2, 3, "batch_failed"),
            resp(3, 4, 10),
        ];
        let replayed: HashMap<u64, ReplayedOutcome> = [
            (1, ok(5)),
            (2, ReplayedOutcome::Failed("validation".into())),
            (4, ReplayedOutcome::Failed("batch_failed".into())),
        ]
        .into_iter()
        .collect();
        let (d, compared, matched) = diff_responses(&events, &replayed);
        assert_eq!(matched, 0);
        assert_eq!(compared, 3); // id 3 produced nothing: not compared
        assert_eq!(d.len(), 4);
        assert_eq!(
            d[0],
            Divergence::FailureMismatch {
                event_index: 0,
                id: 1,
                recorded_kind: "batch_failed".into(),
                replayed: "response".into(),
            }
        );
        assert_eq!(
            d[1],
            Divergence::FailureMismatch {
                event_index: 1,
                id: 2,
                recorded_kind: "batch_failed".into(),
                replayed: "validation".into(),
            }
        );
        assert_eq!(
            d[2],
            Divergence::FailureMismatch {
                event_index: 2,
                id: 3,
                recorded_kind: "batch_failed".into(),
                replayed: "none".into(),
            }
        );
        assert_eq!(
            d[3],
            Divergence::ResponseBecameFailure {
                event_index: 3,
                id: 4,
                kind: "batch_failed".into(),
            }
        );
        for div in &d {
            assert!(!div.to_string().is_empty());
        }
    }

    #[test]
    fn window_diff_reports_absolute_indices() {
        let events = vec![resp(0, 0, 10), resp(1, 1, 11)];
        let replayed: HashMap<u64, ReplayedOutcome> =
            [(1, ok(99))].into_iter().collect();
        // diff only the second event, as window replay does, offset 1
        let (d, compared, matched) =
            diff_responses_at(&events[1..], &replayed, 1);
        assert_eq!((compared, matched), (1, 0));
        assert_eq!(
            d,
            vec![Divergence::ChecksumMismatch {
                event_index: 1,
                id: 1,
                recorded: 11,
                replayed: 99,
            }]
        );
    }

    #[test]
    fn divergences_come_out_in_trace_order() {
        let events = vec![resp(0, 2, 1), resp(1, 0, 1), resp(2, 1, 1)];
        let replayed: HashMap<u64, ReplayedOutcome> =
            [(2, ok(9)), (0, ok(9)), (1, ok(9))].into_iter().collect();
        let (d, _, _) = diff_responses(&events, &replayed);
        let idxs: Vec<usize> =
            d.iter().map(|x| x.event_index()).collect();
        assert_eq!(idxs, vec![0, 1, 2]);
    }
}
