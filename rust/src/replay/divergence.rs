//! Divergence detection and reporting — the wasm-rr contract: replay
//! either reproduces every recorded output checksum or fails loudly,
//! naming the **first** trace event whose outcome the replay could not
//! reproduce.

use std::collections::HashMap;
use std::fmt;
use std::time::Duration;

use super::event::{EventBody, TraceEvent};

/// One reproducibility violation, anchored to the recorded trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Divergence {
    /// The replayed output for `id` hashed differently than recorded.
    ChecksumMismatch {
        /// 0-based index of the recorded `Response` event in the trace.
        event_index: usize,
        id: u64,
        recorded: u64,
        replayed: u64,
    },
    /// The recording answered `id` but the replay produced no response
    /// (rejected at submit, or the batch failed).
    MissingResponse { event_index: usize, id: u64 },
}

impl Divergence {
    /// Trace index of the first event the replay failed to reproduce.
    pub fn event_index(&self) -> usize {
        match self {
            Divergence::ChecksumMismatch { event_index, .. }
            | Divergence::MissingResponse { event_index, .. } => {
                *event_index
            }
        }
    }
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Divergence::ChecksumMismatch {
                event_index,
                id,
                recorded,
                replayed,
            } => write!(
                f,
                "event #{event_index} (response id={id}): checksum \
                 mismatch — recorded {recorded:#018x}, replayed \
                 {replayed:#018x}"
            ),
            Divergence::MissingResponse { event_index, id } => write!(
                f,
                "event #{event_index} (response id={id}): recorded a \
                 response but replay produced none"
            ),
        }
    }
}

/// Outcome of one replay run.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Arrivals re-driven through the engine.
    pub requests: usize,
    /// Replayed responses that had a recorded counterpart to verify.
    pub compared: usize,
    /// Of those, how many matched bit-for-bit.
    pub matched: usize,
    /// Replay responses with no recorded counterpart (the recording
    /// rejected the request; fast replay may admit it). Informational —
    /// scheduling is allowed to differ, outputs are not.
    pub extra_responses: usize,
    /// All violations, ordered by recorded event index.
    pub divergences: Vec<Divergence>,
    /// Replay wall-clock.
    pub wall: Duration,
}

impl ReplayReport {
    pub fn is_clean(&self) -> bool {
        self.divergences.is_empty()
    }

    /// The first mismatching event (trace order), if any.
    pub fn first_divergence(&self) -> Option<&Divergence> {
        self.divergences.first()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests replayed, {}/{} checksums verified, {} \
             divergence(s), {} extra response(s), {:.2}s wall",
            self.requests,
            self.matched,
            self.compared,
            self.divergences.len(),
            self.extra_responses,
            self.wall.as_secs_f64()
        )
    }
}

/// Compare replayed output checksums against every recorded `Response`
/// event, in trace order. `replayed` maps request id → output checksum.
pub fn diff_responses(events: &[TraceEvent],
                      replayed: &HashMap<u64, u64>)
                      -> (Vec<Divergence>, usize, usize) {
    let mut divergences = Vec::new();
    let mut compared = 0;
    let mut matched = 0;
    for (idx, ev) in events.iter().enumerate() {
        if let EventBody::Response { id, checksum, .. } = &ev.body {
            match replayed.get(id) {
                None => divergences.push(Divergence::MissingResponse {
                    event_index: idx,
                    id: *id,
                }),
                Some(got) => {
                    compared += 1;
                    if got == checksum {
                        matched += 1;
                    } else {
                        divergences.push(Divergence::ChecksumMismatch {
                            event_index: idx,
                            id: *id,
                            recorded: *checksum,
                            replayed: *got,
                        });
                    }
                }
            }
        }
    }
    (divergences, compared, matched)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(t_us: u64, id: u64, checksum: u64) -> TraceEvent {
        TraceEvent {
            t_us,
            body: EventBody::Response {
                id,
                batch_size: 1,
                bucket: 1,
                latency_us: 1,
                checksum,
            },
        }
    }

    #[test]
    fn clean_when_all_match() {
        let events = vec![resp(0, 0, 10), resp(1, 1, 11)];
        let replayed: HashMap<u64, u64> =
            [(0, 10), (1, 11)].into_iter().collect();
        let (d, compared, matched) = diff_responses(&events, &replayed);
        assert!(d.is_empty());
        assert_eq!((compared, matched), (2, 2));
    }

    #[test]
    fn mismatch_names_first_event() {
        let events = vec![
            TraceEvent {
                t_us: 0,
                body: EventBody::Enqueue { id: 0, depth: 1 },
            },
            resp(1, 0, 10),
            resp(2, 1, 11),
        ];
        let replayed: HashMap<u64, u64> =
            [(0, 10), (1, 99)].into_iter().collect();
        let (d, compared, matched) = diff_responses(&events, &replayed);
        assert_eq!((compared, matched), (2, 1));
        assert_eq!(
            d,
            vec![Divergence::ChecksumMismatch {
                event_index: 2,
                id: 1,
                recorded: 11,
                replayed: 99,
            }]
        );
        assert_eq!(d[0].event_index(), 2);
        let msg = d[0].to_string();
        assert!(msg.contains("event #2"), "{msg}");
        assert!(msg.contains("id=1"), "{msg}");
    }

    #[test]
    fn missing_response_is_a_divergence() {
        let events = vec![resp(0, 3, 10)];
        let replayed = HashMap::new();
        let (d, compared, _) = diff_responses(&events, &replayed);
        assert_eq!(compared, 0);
        assert_eq!(
            d,
            vec![Divergence::MissingResponse { event_index: 0, id: 3 }]
        );
    }

    #[test]
    fn divergences_come_out_in_trace_order() {
        let events = vec![resp(0, 2, 1), resp(1, 0, 1), resp(2, 1, 1)];
        let replayed: HashMap<u64, u64> =
            [(2, 9), (0, 9), (1, 9)].into_iter().collect();
        let (d, _, _) = diff_responses(&events, &replayed);
        let idxs: Vec<usize> =
            d.iter().map(|x| x.event_index()).collect();
        assert_eq!(idxs, vec![0, 1, 2]);
    }
}
