//! The replay side: re-drive a recorded workload through a live engine
//! and verify every recorded output checksum.
//!
//! Two timing modes (the casettek/raster window-replay split):
//!
//! * **faithful** — sleep until each request's recorded arrival offset,
//!   reproducing the original open-loop pressure (batch sizes and
//!   latencies come out statistically comparable — useful for perf
//!   bisection);
//! * **fast** — submit as fast as the queue admits (batches form
//!   differently, wall-clock shrinks — useful for CI regression checks,
//!   valid because per-request outputs are batch-composition-invariant,
//!   DESIGN.md §7).
//!
//! In both modes the verification contract is identical: every recorded
//! `Response` checksum must be reproduced bit-for-bit, else the run
//! reports a [`Divergence`](super::divergence::Divergence) naming the
//! first mismatching event.

use anyhow::{anyhow, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::ops::Range;
use std::path::Path;
use std::str::FromStr;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::{Engine, Payload, ServeError, ServeResult};
use crate::rng::Rng;
use crate::tensor::Tensor;

use super::binary;
use super::divergence::{diff_responses_at, Divergence, ReplayReport,
                        ReplayedOutcome};
use super::event::{ArrivalPayload, EventBody, TraceEvent, TraceHeader};
use super::window::{self, WindowMap};

/// How the replayer paces recorded arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Timing {
    /// Sleep to each recorded arrival offset.
    Faithful,
    /// Submit as fast as possible.
    Fast,
}

impl Timing {
    pub fn as_str(&self) -> &'static str {
        match self {
            Timing::Faithful => "faithful",
            Timing::Fast => "fast",
        }
    }
}

impl FromStr for Timing {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "faithful" => Ok(Timing::Faithful),
            "fast" => Ok(Timing::Fast),
            other => Err(anyhow!(
                "--timing expects 'faithful' or 'fast', got {other:?}"
            )),
        }
    }
}

/// Knobs for [`Replayer::run_with`].
#[derive(Debug, Clone, Default)]
pub struct ReplayOptions {
    /// Replay only this checkpoint-window range (0-based, end
    /// exclusive — the `--window A..B` flag). `None` replays the whole
    /// trace.
    pub window: Option<Range<usize>>,
    /// Print a periodic progress line (to stderr) at each checkpoint
    /// boundary crossed while re-driving.
    pub progress: bool,
}

/// Result of [`Replayer::bisect`]: which window the first divergence
/// lives in, and how many window replays it took to find it.
#[derive(Debug)]
pub struct BisectReport {
    /// Total checkpoint windows in the trace.
    pub windows: usize,
    /// Window replays performed (1 + ~log2(windows) when divergent).
    pub replays: usize,
    /// 0-based index of the first divergent window, `None` when the
    /// full replay came back clean.
    pub divergent: Option<usize>,
    /// The report of the last probe: the full-trace replay when clean,
    /// the single divergent window's replay otherwise (its divergences
    /// carry absolute trace event indices).
    pub report: ReplayReport,
}

/// A loaded trace, ready to re-drive.
pub struct Replayer {
    header: TraceHeader,
    events: Vec<TraceEvent>,
}

impl Replayer {
    /// Load and fully validate a trace file in either format (binary
    /// by magic, JSONL otherwise — the extension never matters). A
    /// tampered line, truncated byte, or checkpoint that disagrees
    /// with the events it summarizes (fingerprint verification,
    /// DESIGN.md §13) is an error here, before any compute is spent.
    pub fn load(path: &Path) -> Result<Self> {
        let (header, events) = binary::read_trace_auto(path)?;
        window::verify_fingerprints(&events)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Ok(Replayer { header, events })
    }

    /// Build from in-memory parts (tests, benches).
    pub fn from_parts(header: TraceHeader, events: Vec<TraceEvent>)
                      -> Self {
        Replayer { header, events }
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Number of recorded arrivals (requests a replay will re-drive).
    pub fn arrival_count(&self) -> usize {
        self.events
            .iter()
            .filter(|e| {
                matches!(e.body, EventBody::RequestArrival { .. })
            })
            .count()
    }

    /// Re-drive the recorded workload through `engine` (the trace's model
    /// must already be registered) and verify every recorded outcome:
    /// `Response` events by output checksum, `Failed` events (trace v3)
    /// by `ServeError` kind — failure determinism is part of the
    /// contract (DESIGN.md §11).
    ///
    /// Admission may legitimately differ from the recording (fast mode
    /// floods the queue the recording paced): a request the recording
    /// *rejected* but the replay answers is counted as an extra response,
    /// not a divergence. A request the recording *answered* must be
    /// answered identically, and a request the recording *failed* must
    /// fail with the same kind — anything else diverges.
    ///
    /// Backpressure on replay is NOT a divergence: when `submit` rejects
    /// while our own requests are still in flight, the replayer drains
    /// the oldest in-flight response and retries, so a fast replay of a
    /// trace larger than the queue depth completes instead of
    /// mis-reporting deterministic requests as missing. A reject with
    /// nothing in flight (validation failure, shutdown) records the
    /// typed failure as this request's replay outcome — which is
    /// exactly what makes a deterministically-failing request verify
    /// against its recorded `Failed` event.
    pub fn run(&self, engine: &Engine, timing: Timing)
               -> Result<ReplayReport> {
        self.run_with(engine, timing, &ReplayOptions::default())
    }

    /// [`Replayer::run`] with options: window-sliced replay and/or
    /// progress reporting.
    ///
    /// **Window replay** (DESIGN.md §13): `window: Some(a..b)` replays
    /// only checkpoint windows `a..b`. State at the window boundary is
    /// reconstructed from checkpoint `a`'s pending set — those
    /// requests' arrival events are fetched from the earlier part of
    /// the trace and re-driven first, then the range's own arrivals —
    /// and only outcomes *recorded inside the range* are verified.
    /// This is sound because per-request outputs are
    /// batch-composition-invariant (§7) and models rebuild from the
    /// header seed: a window replay verifies exactly the same
    /// checksums for those events as a full replay would.
    pub fn run_with(&self, engine: &Engine, timing: Timing,
                    opts: &ReplayOptions) -> Result<ReplayReport> {
        // Engine-selection digest gate (DESIGN.md §10): a trace recorded
        // against a compiled plan names the plan's per-layer engine
        // choices; the replaying engine must have compiled the *same*
        // ones (`Engine::Auto` heuristics may change between builds, a
        // tampered header must not silently "replay"). A mismatch makes
        // every output checksum incomparable, so it is a hard error —
        // like a failed image reconstruction — not a per-request
        // divergence. Traces without the field (v1, pre-plan v2, PJRT)
        // skip the gate.
        if !self.header.engine_digest.is_empty() {
            let want = u64::from_str_radix(&self.header.engine_digest, 16)
                .map_err(|_| anyhow!(
                    "trace header engine_digest {:?} is not a u64 hex",
                    self.header.engine_digest))?;
            if let Some(got) = engine.plan_digest(&self.header.model) {
                if got != want {
                    return Err(anyhow!(
                        "engine-selection digest mismatch for model \
                         {:?}: trace recorded {want:016x}, this engine \
                         compiled {got:016x} — the plan's per-layer \
                         engine choices differ, so recorded checksums \
                         are not comparable",
                        self.header.model));
                }
            }
        }
        // Fleet roster gate (trace v5, DESIGN.md §16): every additional
        // resident model in the recording must reproduce its recorded
        // digest too — an LRU-evicted-and-reloaded plan re-verifies
        // against the same pinned digest, so one gate per model at
        // replay start covers every reload the replay will do.
        for (name, digest_hex) in &self.header.fleet {
            if digest_hex.is_empty() {
                continue;
            }
            let want = u64::from_str_radix(digest_hex, 16)
                .map_err(|_| anyhow!(
                    "trace header fleet digest {digest_hex:?} for model \
                     {name:?} is not a u64 hex"))?;
            if let Some(got) = engine.plan_digest(name) {
                if got != want {
                    return Err(anyhow!(
                        "engine-selection digest mismatch for fleet \
                         model {name:?}: trace recorded {want:016x}, \
                         this engine compiled {got:016x}"));
                }
            }
        }
        // Resolve the event range to drive/verify, and — for a window
        // replay — the indices of *earlier* arrivals whose outcome was
        // still pending at the window-opening checkpoint. Those must be
        // re-driven first: their responses may land inside the range.
        let wm = WindowMap::of(&self.events);
        let (range, preload) = match &opts.window {
            None => (0..self.events.len(), Vec::new()),
            Some(w) => {
                if w.start >= w.end || w.end > wm.count() {
                    return Err(anyhow!(
                        "--window {}..{} is out of range: trace has {} \
                         window(s)",
                        w.start, w.end, wm.count()));
                }
                let range = wm.span_events(w);
                let carried: HashSet<u64> = wm
                    .opening_checkpoint(&self.events, w.start)
                    .map(|c| c.pending.iter().copied().collect())
                    .unwrap_or_default();
                let preload: Vec<usize> = self.events[..range.start]
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| matches!(&e.body,
                        EventBody::RequestArrival { id, .. }
                            if carried.contains(id)))
                    .map(|(i, _)| i)
                    .collect();
                (range, preload)
            }
        };
        let total_windows = opts.window.as_ref()
            .map(|w| w.len())
            .unwrap_or_else(|| wm.count());
        let t0 = Instant::now();
        // Faithful offsets are rebased to the first driven arrival:
        // recorded t_us counts from sink creation, which includes the
        // recording run's model-load time — dead idle that pacing must
        // not replay. (For a window replay this also skips the whole
        // pre-window span in one jump.)
        let base_us = preload
            .first()
            .copied()
            .or_else(|| {
                self.events[range.clone()]
                    .iter()
                    .position(|e| matches!(
                        e.body, EventBody::RequestArrival { .. }))
                    .map(|p| range.start + p)
            })
            .map(|i| self.events[i].t_us)
            .unwrap_or(0);
        let mut pending: VecDeque<(u64, mpsc::Receiver<ServeResult>)> =
            VecDeque::new();
        let mut replayed: HashMap<u64, ReplayedOutcome> = HashMap::new();
        let mut requests = 0usize;
        // One terminal outcome per reply channel: checksum or typed kind.
        fn outcome_of(res: ServeResult) -> ReplayedOutcome {
            match res {
                Ok(resp) => {
                    ReplayedOutcome::Response(resp.output.checksum())
                }
                Err(e) => ReplayedOutcome::Failed(e.kind().to_string()),
            }
        }
        let mut windows_closed = 0usize;
        let mut events_seen = 0usize;
        for ev_idx in preload.iter().copied().chain(range.clone()) {
            let ev = &self.events[ev_idx];
            events_seen += 1;
            if let EventBody::Checkpoint(_) = &ev.body {
                // checkpoints only occur in the in-range part (preload
                // holds arrival indices only), each closing one window
                windows_closed += 1;
                if opts.progress {
                    let secs = t0.elapsed().as_secs_f64().max(1e-9);
                    eprintln!(
                        "replay: window {windows_closed}/{total_windows} \
                         verified · {requests} arrivals driven · \
                         {:.0} ev/s",
                        events_seen as f64 / secs);
                }
            }
            let EventBody::RequestArrival { id, model, payload,
                                            priority } = &ev.body
            else {
                continue;
            };
            requests += 1;
            // Rebuild the recorded input. Latents are stored bit-exactly;
            // image payloads are stored as (shape, seed, checksum) — the
            // tensor is regenerated from the canonical synthesis and the
            // checksum proves it matches what the recording served
            // (trace v2, DESIGN.md §8). A mismatch means the trace (or
            // this build's synthesis) is broken, so the whole replay is
            // invalid — a hard error, not a per-request divergence.
            let payload = match payload {
                ArrivalPayload::Latent { z, cond } => {
                    Payload::latent(z.clone(), cond.clone())
                }
                ArrivalPayload::Image { shape, seed, checksum } => {
                    // the shape comes from an untrusted file: bound it
                    // before allocating (a tampered line must produce a
                    // clean error, not an OOM abort)
                    const MAX_IMAGE_ELEMS: usize = 1 << 24; // 64 MiB f32
                    let elems: usize =
                        shape.iter().try_fold(1usize, |a, &d| {
                            a.checked_mul(d)
                        }).unwrap_or(usize::MAX);
                    if shape.len() != 4 || elems > MAX_IMAGE_ELEMS {
                        return Err(anyhow!(
                            "event #{ev_idx} (arrival id={id}): \
                             implausible image shape {shape:?} in trace"));
                    }
                    let t = Tensor::randn(shape, &mut Rng::new(*seed));
                    if t.checksum() != *checksum {
                        return Err(anyhow!(
                            "event #{ev_idx} (arrival id={id}): image \
                             payload reconstruction checksum mismatch — \
                             recorded {checksum:#018x}, rebuilt {:#018x}",
                            t.checksum()));
                    }
                    Payload::image(t, *seed)
                }
            };
            if timing == Timing::Faithful {
                let at =
                    Duration::from_micros(ev.t_us.saturating_sub(base_us));
                let elapsed = t0.elapsed();
                if at > elapsed {
                    std::thread::sleep(at - elapsed);
                }
            }
            // Re-drive with the recorded priority class: admission and
            // batch ordering see the same classes the recording did.
            loop {
                match engine.submit_with(model, payload.clone(),
                                         *priority) {
                    Ok(rx) => {
                        pending.push_back((*id, rx));
                        break;
                    }
                    Err(ServeError::Backpressure)
                        if !pending.is_empty() =>
                    {
                        // transient backpressure from our own in-flight
                        // requests: drain the oldest, then retry
                        let (pid, rx) = pending.pop_front().unwrap();
                        if let Ok(res) = rx.recv() {
                            replayed.insert(pid, outcome_of(res));
                        }
                    }
                    // Deterministic reject (validation/shutdown) — or
                    // backpressure with nothing of ours in flight, which
                    // cannot clear by waiting. The typed kind is this
                    // request's replay outcome: it verifies a recorded
                    // `Failed` of the same kind, and diverges
                    // (ResponseBecameFailure) iff the recording
                    // answered this id.
                    Err(e) => {
                        replayed.insert(
                            *id,
                            ReplayedOutcome::Failed(e.kind().to_string()));
                        break;
                    }
                }
            }
        }

        for (id, rx) in pending {
            if let Ok(res) = rx.recv() {
                replayed.insert(id, outcome_of(res));
            }
        }

        // Verification is scoped to the replayed slice: only outcomes
        // the recording placed inside `range` are compared (divergence
        // indices come back absolute via the slice base).
        let slice = &self.events[range.clone()];
        let (divergences, compared, matched) =
            diff_responses_at(slice, &replayed, range.start);
        let recorded_ids: HashSet<u64> = slice
            .iter()
            .filter_map(|e| match &e.body {
                EventBody::Response { id, .. }
                | EventBody::Failed { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        // A recorded shed is an admission refusal like a reject: load
        // on replay may legitimately admit what the recording shed (and
        // vice versa for typed refusals), so both feed the same
        // agreement set below.
        let rejected_ids: HashSet<u64> = slice
            .iter()
            .filter_map(|e| match &e.body {
                EventBody::Reject { id, .. }
                | EventBody::Shed { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        // Requests still pending at the range's *end* boundary resolved
        // after the window in the recording — the replay answered them,
        // the slice has no terminal event for them. That's the window
        // semantics working as designed, not an extra.
        let end_pending: HashSet<u64> = match &opts.window {
            Some(w) if w.end < wm.count() => wm
                .opening_checkpoint(&self.events, w.end)
                .map(|c| c.pending.iter().copied().collect())
                .unwrap_or_default(),
            _ => HashSet::new(),
        };
        // "Extra" = a replay outcome the recording has no terminal
        // event for. A typed refusal on replay of a request the
        // recording *also* rejected is agreement, not an extra — don't
        // report a bit-perfect faithful replay of a reject-heavy trace
        // as N extras. (A replay *response* for a recorded reject still
        // counts: fast mode legitimately admits what the recording
        // shed, and that is worth surfacing.)
        let extra_responses = replayed
            .iter()
            .filter(|(id, out)| {
                !recorded_ids.contains(id)
                    && !end_pending.contains(id)
                    && !(rejected_ids.contains(id)
                         && matches!(out, ReplayedOutcome::Failed(_)))
            })
            .count();
        // Diagnose the classic digest-less divergence (DESIGN.md §10):
        // a pre-plan trace carries no engine_digest, so the hard gate
        // above never ran — if this engine compiled a plan and the
        // checksums mismatch, the likeliest cause is `Engine::Auto`
        // resolving different per-layer engines than the recording's
        // build, not corrupted data. Say so instead of leaving a bare
        // checksum mismatch.
        let hint = (divergences.iter().any(|d| {
            matches!(d, Divergence::ChecksumMismatch { .. })
        }) && self.header.engine_digest.is_empty()
            && engine.plan_digest(&self.header.model).is_some())
        .then(|| {
            "trace has no engine_digest header field (recorded by a \
             pre-plan build), so the engine-selection gate could not \
             run: this engine's compiled plan — Engine::Auto by \
             default — may resolve different per-layer engines than \
             the recording executed. Re-record the trace with this \
             build, or pin the recording's engine selection \
             (DESIGN.md §10)"
                .to_string()
        });
        Ok(ReplayReport {
            requests,
            compared,
            matched,
            extra_responses,
            divergences,
            hint,
            wall: t0.elapsed(),
        })
    }

    /// The trace's checkpoint-window structure.
    pub fn windows(&self) -> WindowMap {
        WindowMap::of(&self.events)
    }

    /// Localize the first divergent checkpoint window in O(log W)
    /// window replays (DESIGN.md §13).
    ///
    /// One full replay establishes whether the trace diverges at all;
    /// if it does, a dirty-interval search follows: the invariant is
    /// "every window before `lo` is clean, and `lo..hi` contains a
    /// divergent window" — probe the left half, shrink toward whichever
    /// side the first dirty window must be on. This is NOT a plain
    /// binary search on a monotone predicate (later windows can be
    /// clean again after a divergent one); the invariant form finds the
    /// *first* dirty window regardless. Window probes are sound
    /// independently of each other because each re-drives the pending
    /// set carried into its range (see [`Replayer::run_with`]).
    pub fn bisect(&self, engine: &Engine, timing: Timing)
                  -> Result<BisectReport> {
        let total = WindowMap::of(&self.events).count();
        let mut replays = 0usize;
        replays += 1;
        let full = self.probe(engine, timing, 0..total)?;
        if full.is_clean() {
            return Ok(BisectReport {
                windows: total,
                replays,
                divergent: None,
                report: full,
            });
        }
        let (mut lo, mut hi) = (0usize, total);
        let mut narrowed: Option<(Range<usize>, ReplayReport)> = None;
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            replays += 1;
            let left = self.probe(engine, timing, lo..mid)?;
            if left.is_clean() {
                // every window in lo..mid is clean — the first dirty
                // one is in mid..hi
                lo = mid;
            } else {
                hi = mid;
                narrowed = Some((lo..mid, left));
            }
        }
        // Confirm on the single window unless the last dirty probe
        // already was exactly that range.
        let report = match narrowed {
            Some((r, rep)) if r == (lo..lo + 1) => rep,
            _ => {
                replays += 1;
                self.probe(engine, timing, lo..lo + 1)?
            }
        };
        if report.is_clean() {
            // The divergence did not reproduce in isolation (should not
            // happen for deterministic traces) — report the full-trace
            // evidence rather than claiming a clean bisect.
            return Ok(BisectReport {
                windows: total,
                replays,
                divergent: None,
                report: full,
            });
        }
        Ok(BisectReport {
            windows: total,
            replays,
            divergent: Some(lo),
            report,
        })
    }

    /// One bisection probe: a windowed, progress-less replay.
    fn probe(&self, engine: &Engine, timing: Timing, w: Range<usize>)
             -> Result<ReplayReport> {
        self.run_with(engine, timing, &ReplayOptions {
            window: Some(w),
            progress: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_parses() {
        assert_eq!("fast".parse::<Timing>().unwrap(), Timing::Fast);
        assert_eq!("faithful".parse::<Timing>().unwrap(),
                   Timing::Faithful);
        assert!("slow".parse::<Timing>().is_err());
        assert_eq!(Timing::Fast.as_str(), "fast");
    }

    #[test]
    fn arrival_count_counts_only_arrivals() {
        let header = TraceHeader {
            model: "m".into(),
            backend: "native".into(),
            seed: 0,
            z_dim: 1,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        };
        let events = vec![
            TraceEvent {
                t_us: 0,
                body: EventBody::RequestArrival {
                    id: 0,
                    model: "m".into(),
                    payload: ArrivalPayload::Latent {
                        z: vec![0.0],
                        cond: vec![],
                    },
                    priority: Default::default(),
                },
            },
            TraceEvent {
                t_us: 1,
                body: EventBody::Enqueue { id: 0, depth: 1 },
            },
        ];
        let rp = Replayer::from_parts(header, events);
        assert_eq!(rp.arrival_count(), 1);
    }
}
