//! Dependency-free JSONL codec for trace files (in the spirit of
//! `config/toml_mini.rs`: we parse exactly the subset we emit, with
//! useful errors, and nothing else).
//!
//! Wire format: line 1 is the header object, every further line is one
//! event object. Floats round-trip **bit-exactly** as 8-hex-digit IEEE-754
//! bit patterns (`"3f800000"`), because a replay that perturbs a latent in
//! the 7th decimal is not a replay. Checksums are 16-hex-digit strings —
//! JSON numbers are f64 and cannot carry a u64 faithfully through other
//! tools.
//!
//! ```text
//! {"huge2_trace":3,"model":"dcgan","backend":"native","seed":7,"z_dim":100,"cond_dim":0,"task":"generate","net":"","engine_digest":""}
//! {"t_us":812,"ev":"arrival","id":0,"model":"dcgan","z":["bf1c6a00","3e99f3c2"],"cond":[]}
//! {"t_us":815,"ev":"enqueue","id":0,"depth":1}
//! {"t_us":2201,"ev":"batch_formed","ids":[0,1]}
//! {"t_us":9610,"ev":"batch_executed","ids":[0,1],"bucket":2,"exec_us":7409}
//! {"t_us":9612,"ev":"response","id":0,"batch_size":2,"bucket":2,"latency_us":8800,"checksum":"9f86d081884c7d65"}
//! {"t_us":9613,"ev":"failed","id":1,"kind":"batch_failed","reason":"worker panicked: boom"}
//! ```
//!
//! **Versioning** (DESIGN.md §8/§11/§13/§16): writes always stamp
//! [`TRACE_VERSION`] (5). Reads accept v1..=v5; a v1 header decodes with
//! `task="generate"`, `net=""` — v1 GAN traces replay unchanged, because
//! latent arrival events are encoded identically in all versions. New
//! in v2: `task`/`net` header fields, and image-payload arrivals
//! (`"shape":[1,33,33,3],"input_seed":9,"input_checksum":"…"` in place of
//! `z`/`cond` — payload checksums replace raw capture for image inputs).
//! New in v3: `failed` events
//! (`{"t_us":…,"ev":"failed","id":…,"kind":"batch_failed","reason":"…"}`)
//! — a request that was accepted but terminated in a typed `ServeError`;
//! header fields are unchanged from v2, so v2 traces (which simply
//! contain no `failed` events) decode as-is. New in v4: `checkpoint`
//! events (window boundaries carrying pending ids, folded counters,
//! fingerprints, and an embedded metrics snapshot — DESIGN.md §13), and
//! a binary twin of this whole format ([`super::binary`], auto-detected
//! by magic). v1–v3 traces simply contain no checkpoints and decode
//! as-is. New in v5 (fleet serving, DESIGN.md §16): a `"priority"`
//! field on arrivals (absent decodes as the default class,
//! `interactive`), `shed`/`evict`/`reload` events, and a `"fleet"`
//! header list naming additional resident models with their engine
//! digests. v1–v4 traces carry none of these and decode as-is.

use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::coordinator::Priority;
use crate::metrics::{HistogramSnapshot, MetricsSnapshot};

use super::event::{ArrivalPayload, CheckpointState, EventBody,
                   TraceEvent, TraceHeader};

/// Current trace-format version (the header's `huge2_trace` value, and
/// the binary codec's version field).
pub const TRACE_VERSION: u32 = 5;

// ------------------------------------------------------------------ encode

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

fn f32_hex(v: f32) -> String {
    format!("{:08x}", v.to_bits())
}

fn f32s_json(vs: &[f32]) -> String {
    let items: Vec<String> =
        vs.iter().map(|&v| format!("\"{}\"", f32_hex(v))).collect();
    format!("[{}]", items.join(","))
}

/// Bare-number JSON list (`[1,2,3]`) — ids, shapes.
fn nums_json<T: std::fmt::Display>(vs: &[T]) -> String {
    let items: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", items.join(","))
}

/// Serialize the header to its (single) JSONL line, stamped with
/// [`TRACE_VERSION`].
pub fn encode_header(h: &TraceHeader) -> String {
    // fleet roster as a flat alternating [name, digest, …] list (the
    // codec's value model has no nested objects)
    let fleet: Vec<String> = h
        .fleet
        .iter()
        .flat_map(|(name, digest)| {
            [format!("\"{}\"", esc(name)),
             format!("\"{}\"", esc(digest))]
        })
        .collect();
    format!(
        "{{\"huge2_trace\":{TRACE_VERSION},\"model\":\"{}\",\
         \"backend\":\"{}\",\"seed\":{},\"z_dim\":{},\"cond_dim\":{},\
         \"task\":\"{}\",\"net\":\"{}\",\"engine_digest\":\"{}\",\
         \"fleet\":[{}]}}",
        esc(&h.model),
        esc(&h.backend),
        h.seed,
        h.z_dim,
        h.cond_dim,
        esc(&h.task),
        esc(&h.net),
        esc(&h.engine_digest),
        fleet.join(",")
    )
}

/// Serialize one event to its JSONL line.
pub fn encode_event(e: &TraceEvent) -> String {
    let t = e.t_us;
    match &e.body {
        EventBody::RequestArrival {
            id,
            model,
            payload: ArrivalPayload::Latent { z, cond },
            priority,
        } => format!(
            "{{\"t_us\":{t},\"ev\":\"arrival\",\"id\":{id},\
             \"model\":\"{}\",\"z\":{},\"cond\":{},\
             \"priority\":\"{}\"}}",
            esc(model),
            f32s_json(z),
            f32s_json(cond),
            priority.as_str()
        ),
        EventBody::RequestArrival {
            id,
            model,
            payload: ArrivalPayload::Image { shape, seed, checksum },
            priority,
        } => format!(
            "{{\"t_us\":{t},\"ev\":\"arrival\",\"id\":{id},\
             \"model\":\"{}\",\"shape\":{},\"input_seed\":{seed},\
             \"input_checksum\":\"{checksum:016x}\",\
             \"priority\":\"{}\"}}",
            esc(model),
            nums_json(shape),
            priority.as_str()
        ),
        EventBody::Enqueue { id, depth } => format!(
            "{{\"t_us\":{t},\"ev\":\"enqueue\",\"id\":{id},\
             \"depth\":{depth}}}"
        ),
        EventBody::Reject { id, reason } => format!(
            "{{\"t_us\":{t},\"ev\":\"reject\",\"id\":{id},\
             \"reason\":\"{}\"}}",
            esc(reason)
        ),
        EventBody::BatchFormed { ids } => format!(
            "{{\"t_us\":{t},\"ev\":\"batch_formed\",\"ids\":{}}}",
            nums_json(ids)
        ),
        EventBody::BatchExecuted { ids, bucket, exec_us } => format!(
            "{{\"t_us\":{t},\"ev\":\"batch_executed\",\"ids\":{},\
             \"bucket\":{bucket},\"exec_us\":{exec_us}}}",
            nums_json(ids)
        ),
        EventBody::Response { id, batch_size, bucket, latency_us,
                              checksum } => format!(
            "{{\"t_us\":{t},\"ev\":\"response\",\"id\":{id},\
             \"batch_size\":{batch_size},\"bucket\":{bucket},\
             \"latency_us\":{latency_us},\"checksum\":\"{checksum:016x}\"}}"
        ),
        EventBody::Failed { id, kind, reason } => format!(
            "{{\"t_us\":{t},\"ev\":\"failed\",\"id\":{id},\
             \"kind\":\"{}\",\"reason\":\"{}\"}}",
            esc(kind),
            esc(reason)
        ),
        EventBody::Shed { id, class } => format!(
            "{{\"t_us\":{t},\"ev\":\"shed\",\"id\":{id},\
             \"class\":\"{}\"}}",
            class.as_str()
        ),
        EventBody::Evict { model, bytes } => format!(
            "{{\"t_us\":{t},\"ev\":\"evict\",\"model\":\"{}\",\
             \"bytes\":{bytes}}}",
            esc(model)
        ),
        EventBody::Reload { model, bytes, digest } => format!(
            "{{\"t_us\":{t},\"ev\":\"reload\",\"model\":\"{}\",\
             \"bytes\":{bytes},\"digest\":\"{digest:016x}\"}}",
            esc(model)
        ),
        EventBody::Checkpoint(c) => format!(
            "{{\"t_us\":{t},\"ev\":\"checkpoint\",\"seq\":{},\
             \"events\":{},\"pending\":{},\"next_id\":{},\
             \"submitted\":{},\"completed\":{},\"rejected\":{},\
             \"failed\":{},\"fingerprint\":\"{:016x}\",\
             \"chain\":\"{:016x}\",{}}}",
            c.seq,
            c.events,
            nums_json(&c.pending),
            c.next_id,
            c.submitted,
            c.completed,
            c.rejected,
            c.failed,
            c.fingerprint,
            c.chain,
            metrics_json(&c.metrics)
        ),
    }
}

/// The checkpoint's embedded metrics snapshot, flattened into the
/// codec's value model (numbers, strings, nested lists — no nested
/// objects): counters as an alternating `[name, value, …]` list,
/// gauges likewise but with the i64 as a decimal *string* (JSON-number
/// fields here are u64-only, and gauges may be negative), histograms
/// as `[name, sum_us, max_us, [idx, count, …]]` entries in the sparse
/// form of [`HistogramSnapshot::to_sparse`].
fn metrics_json(m: &MetricsSnapshot) -> String {
    let counters: Vec<String> = m
        .counters
        .iter()
        .map(|(k, v)| format!("\"{}\",{v}", esc(k)))
        .collect();
    let gauges: Vec<String> = m
        .gauges
        .iter()
        .map(|(k, v)| format!("\"{}\",\"{v}\"", esc(k)))
        .collect();
    let hists: Vec<String> = m
        .histograms
        .iter()
        .map(|(k, h)| {
            let (pairs, sum_us, max_us) = h.to_sparse();
            let flat: Vec<String> = pairs
                .iter()
                .flat_map(|&(i, n)| [i.to_string(), n.to_string()])
                .collect();
            format!("[\"{}\",{sum_us},{max_us},[{}]]", esc(k),
                    flat.join(","))
        })
        .collect();
    format!(
        "\"m_counters\":[{}],\"m_gauges\":[{}],\"m_hists\":[{}]",
        counters.join(","),
        gauges.join(","),
        hists.join(",")
    )
}

// ------------------------------------------------------------------ decode

/// Parsed JSON value (the subset the trace format uses).
#[derive(Debug, Clone, PartialEq)]
enum Val {
    Num(u64),
    Str(String),
    List(Vec<Val>),
}

struct Parser {
    chars: Vec<char>,
    i: usize,
}

impl Parser {
    fn new(s: &str) -> Self {
        Parser { chars: s.chars().collect(), i: 0 }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, want: char) -> Result<(), String> {
        self.skip_ws();
        match self.bump() {
            Some(c) if c == want => Ok(()),
            Some(c) => Err(format!("expected {want:?}, got {c:?} \
                                    at char {}", self.i)),
            None => Err(format!("expected {want:?}, got end of line")),
        }
    }

    /// Parse a string; the opening quote must be the next token.
    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('r') => out.push('\r'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| c.to_digit(16))
                                .ok_or("bad \\u escape")?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or("bad \\u code point")?,
                        );
                    }
                    other => {
                        return Err(format!("bad escape {other:?}"));
                    }
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<u64, String> {
        let start = self.i;
        while matches!(self.peek(), Some('0'..='9')) {
            self.i += 1;
        }
        if self.i == start {
            return Err(format!("expected a number at char {}", self.i));
        }
        let s: String = self.chars[start..self.i].iter().collect();
        s.parse::<u64>().map_err(|_| format!("number {s:?} out of range"))
    }

    fn value(&mut self) -> Result<Val, String> {
        self.skip_ws();
        match self.peek() {
            Some('"') => Ok(Val::Str(self.string()?)),
            Some('0'..='9') => Ok(Val::Num(self.number()?)),
            Some('[') => {
                self.bump();
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(']') {
                    self.bump();
                    return Ok(Val::List(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.bump() {
                        Some(',') => continue,
                        Some(']') => return Ok(Val::List(items)),
                        other => {
                            return Err(format!(
                                "expected ',' or ']' in list, got {other:?}"
                            ));
                        }
                    }
                }
            }
            other => Err(format!("unexpected value start {other:?}")),
        }
    }

    /// Parse a flat `{"k":v,...}` object; nothing may trail it.
    fn object(&mut self) -> Result<Vec<(String, Val)>, String> {
        self.expect('{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.bump();
        } else {
            loop {
                self.skip_ws();
                let key = self.string()?;
                self.expect(':')?;
                let val = self.value()?;
                fields.push((key, val));
                self.skip_ws();
                match self.bump() {
                    Some(',') => continue,
                    Some('}') => break,
                    other => {
                        return Err(format!(
                            "expected ',' or '}}' after field, got {other:?}"
                        ));
                    }
                }
            }
        }
        self.skip_ws();
        if let Some(c) = self.peek() {
            return Err(format!("trailing {c:?} after object"));
        }
        Ok(fields)
    }
}

fn get<'a>(m: &'a [(String, Val)], k: &str) -> Result<&'a Val, String> {
    m.iter()
        .find(|(key, _)| key == k)
        .map(|(_, v)| v)
        .ok_or_else(|| format!("missing field {k:?}"))
}

fn num(m: &[(String, Val)], k: &str) -> Result<u64, String> {
    match get(m, k)? {
        Val::Num(n) => Ok(*n),
        other => Err(format!("field {k:?}: expected number, got {other:?}")),
    }
}

fn string(m: &[(String, Val)], k: &str) -> Result<String, String> {
    match get(m, k)? {
        Val::Str(s) => Ok(s.clone()),
        other => Err(format!("field {k:?}: expected string, got {other:?}")),
    }
}

/// A string field that later builds added to an existing version:
/// absence decodes as empty, presence must still be a string.
fn string_opt(m: &[(String, Val)], k: &str) -> Result<String, String> {
    if get(m, k).is_err() {
        return Ok(String::new());
    }
    string(m, k)
}

fn u64_list(m: &[(String, Val)], k: &str) -> Result<Vec<u64>, String> {
    match get(m, k)? {
        Val::List(items) => items
            .iter()
            .map(|v| match v {
                Val::Num(n) => Ok(*n),
                other => Err(format!(
                    "field {k:?}: expected number item, got {other:?}"
                )),
            })
            .collect(),
        other => Err(format!("field {k:?}: expected list, got {other:?}")),
    }
}

fn hex_u32(s: &str) -> Result<u32, String> {
    if s.is_empty() || s.len() > 8 {
        return Err(format!("bad f32 bit pattern {s:?}"));
    }
    u32::from_str_radix(s, 16)
        .map_err(|_| format!("bad f32 bit pattern {s:?}"))
}

fn f32_list(m: &[(String, Val)], k: &str) -> Result<Vec<f32>, String> {
    match get(m, k)? {
        Val::List(items) => items
            .iter()
            .map(|v| match v {
                Val::Str(s) => Ok(f32::from_bits(hex_u32(s)?)),
                other => Err(format!(
                    "field {k:?}: expected hex-string item, got {other:?}"
                )),
            })
            .collect(),
        other => Err(format!("field {k:?}: expected list, got {other:?}")),
    }
}

fn hex64(m: &[(String, Val)], k: &str) -> Result<u64, String> {
    let s = string(m, k)?;
    if s.is_empty() || s.len() > 16 {
        return Err(format!("field {k:?}: bad u64 hex {s:?}"));
    }
    u64::from_str_radix(&s, 16)
        .map_err(|_| format!("field {k:?}: bad u64 hex {s:?}"))
}

/// Inverse of [`metrics_json`].
fn metrics_from(m: &[(String, Val)]) -> Result<MetricsSnapshot, String> {
    let mut out = MetricsSnapshot::default();
    let Val::List(items) = get(m, "m_counters")? else {
        return Err("field \"m_counters\": expected list".into());
    };
    for pair in items.chunks(2) {
        match pair {
            [Val::Str(k), Val::Num(v)] => {
                out.counters.insert(k.clone(), *v);
            }
            other => {
                return Err(format!(
                    "m_counters: expected [name, value] pairs, got \
                     {other:?}"
                ));
            }
        }
    }
    let Val::List(items) = get(m, "m_gauges")? else {
        return Err("field \"m_gauges\": expected list".into());
    };
    for pair in items.chunks(2) {
        match pair {
            [Val::Str(k), Val::Str(v)] => {
                let v = v.parse::<i64>().map_err(|_| {
                    format!("m_gauges: bad i64 {v:?} for {k:?}")
                })?;
                out.gauges.insert(k.clone(), v);
            }
            other => {
                return Err(format!(
                    "m_gauges: expected [name, \"value\"] pairs, got \
                     {other:?}"
                ));
            }
        }
    }
    let Val::List(items) = get(m, "m_hists")? else {
        return Err("field \"m_hists\": expected list".into());
    };
    for item in items {
        let Val::List(entry) = item else {
            return Err(format!("m_hists: expected list entry, got \
                                {item:?}"));
        };
        let [Val::Str(k), Val::Num(sum_us), Val::Num(max_us),
             Val::List(flat)] = entry.as_slice()
        else {
            return Err(format!(
                "m_hists: expected [name, sum_us, max_us, buckets], \
                 got {entry:?}"
            ));
        };
        if flat.len() % 2 != 0 {
            return Err(format!(
                "m_hists {k:?}: odd sparse-bucket list length {}",
                flat.len()
            ));
        }
        let mut pairs = Vec::with_capacity(flat.len() / 2);
        for pair in flat.chunks(2) {
            match pair {
                [Val::Num(i), Val::Num(n)] => {
                    pairs.push((*i as usize, *n));
                }
                other => {
                    return Err(format!(
                        "m_hists {k:?}: expected numeric [idx, count] \
                         pairs, got {other:?}"
                    ));
                }
            }
        }
        let h = HistogramSnapshot::from_sparse(&pairs, *sum_us, *max_us)
            .map_err(|e| format!("m_hists {k:?}: {e}"))?;
        out.histograms.insert(k.clone(), h);
    }
    Ok(out)
}

/// Parse the header line. Accepts format versions `1..=TRACE_VERSION`;
/// v1 headers decode with `task="generate"`, `net=""`.
pub fn decode_header(line: &str) -> Result<TraceHeader, String> {
    let m = Parser::new(line).object()?;
    // compare in u64: a corrupt header like 2^32+2 must not truncate
    // into a "valid" version
    let version = num(&m, "huge2_trace")?;
    if version == 0 || version > TRACE_VERSION as u64 {
        return Err(format!(
            "unsupported trace version {version} (this build reads \
             1..={TRACE_VERSION})"
        ));
    }
    let (task, net, engine_digest) = if version >= 2 {
        // engine_digest is a v2-compatible *extra* field: traces written
        // before it existed decode with it empty
        (string(&m, "task")?, string(&m, "net")?,
         string_opt(&m, "engine_digest")?)
    } else {
        ("generate".to_string(), String::new(), String::new())
    };
    // fleet roster (v5): flat [name, digest, …] list; absent (v1–v4,
    // and single-model v5 writers' empty list) decodes empty
    let fleet = match get(&m, "fleet") {
        Err(_) => Vec::new(),
        Ok(Val::List(items)) => {
            if items.len() % 2 != 0 {
                return Err(format!(
                    "field \"fleet\": odd [name, digest] list length {}",
                    items.len()
                ));
            }
            items
                .chunks(2)
                .map(|pair| match pair {
                    [Val::Str(name), Val::Str(digest)] => {
                        Ok((name.clone(), digest.clone()))
                    }
                    other => Err(format!(
                        "field \"fleet\": expected string [name, \
                         digest] pairs, got {other:?}"
                    )),
                })
                .collect::<Result<Vec<_>, String>>()?
        }
        Ok(other) => {
            return Err(format!(
                "field \"fleet\": expected list, got {other:?}"
            ));
        }
    };
    Ok(TraceHeader {
        model: string(&m, "model")?,
        backend: string(&m, "backend")?,
        seed: num(&m, "seed")?,
        z_dim: num(&m, "z_dim")? as usize,
        cond_dim: num(&m, "cond_dim")? as usize,
        task,
        net,
        engine_digest,
        fleet,
    })
}

/// The arrival's priority class (v5 field): absent decodes as the
/// default class, so v1–v4 arrivals come back `Interactive`.
fn priority_opt(m: &[(String, Val)]) -> Result<Priority, String> {
    let s = string_opt(m, "priority")?;
    if s.is_empty() {
        return Ok(Priority::default());
    }
    s.parse::<Priority>()
        .map_err(|e| format!("field \"priority\": {e}"))
}

/// Parse one event line.
pub fn decode_event(line: &str) -> Result<TraceEvent, String> {
    let m = Parser::new(line).object()?;
    let t_us = num(&m, "t_us")?;
    let kind = string(&m, "ev")?;
    let body = match kind.as_str() {
        "arrival" => {
            // latent arrivals carry "z"/"cond" (v1 == v2); image
            // arrivals (v2) carry "shape"/"input_seed"/"input_checksum"
            let payload = if get(&m, "z").is_ok() {
                ArrivalPayload::Latent {
                    z: f32_list(&m, "z")?,
                    cond: f32_list(&m, "cond")?,
                }
            } else {
                ArrivalPayload::Image {
                    shape: u64_list(&m, "shape")?
                        .into_iter()
                        .map(|v| v as usize)
                        .collect(),
                    seed: num(&m, "input_seed")?,
                    checksum: hex64(&m, "input_checksum")?,
                }
            };
            EventBody::RequestArrival {
                id: num(&m, "id")?,
                model: string(&m, "model")?,
                payload,
                priority: priority_opt(&m)?,
            }
        }
        "enqueue" => EventBody::Enqueue {
            id: num(&m, "id")?,
            depth: num(&m, "depth")? as usize,
        },
        "reject" => EventBody::Reject {
            id: num(&m, "id")?,
            reason: string(&m, "reason")?,
        },
        "batch_formed" => EventBody::BatchFormed {
            ids: u64_list(&m, "ids")?,
        },
        "batch_executed" => EventBody::BatchExecuted {
            ids: u64_list(&m, "ids")?,
            bucket: num(&m, "bucket")? as usize,
            exec_us: num(&m, "exec_us")?,
        },
        "response" => EventBody::Response {
            id: num(&m, "id")?,
            batch_size: num(&m, "batch_size")? as usize,
            bucket: num(&m, "bucket")? as usize,
            latency_us: num(&m, "latency_us")?,
            checksum: hex64(&m, "checksum")?,
        },
        "failed" => EventBody::Failed {
            id: num(&m, "id")?,
            kind: string(&m, "kind")?,
            reason: string(&m, "reason")?,
        },
        "shed" => EventBody::Shed {
            id: num(&m, "id")?,
            class: string(&m, "class")?
                .parse::<Priority>()
                .map_err(|e| format!("field \"class\": {e}"))?,
        },
        "evict" => EventBody::Evict {
            model: string(&m, "model")?,
            bytes: num(&m, "bytes")?,
        },
        "reload" => EventBody::Reload {
            model: string(&m, "model")?,
            bytes: num(&m, "bytes")?,
            digest: hex64(&m, "digest")?,
        },
        "checkpoint" => EventBody::Checkpoint(Box::new(CheckpointState {
            seq: num(&m, "seq")?,
            events: num(&m, "events")?,
            pending: u64_list(&m, "pending")?,
            next_id: num(&m, "next_id")?,
            submitted: num(&m, "submitted")?,
            completed: num(&m, "completed")?,
            rejected: num(&m, "rejected")?,
            failed: num(&m, "failed")?,
            fingerprint: hex64(&m, "fingerprint")?,
            chain: hex64(&m, "chain")?,
            metrics: metrics_from(&m)?,
        })),
        other => return Err(format!("unknown event kind {other:?}")),
    };
    Ok(TraceEvent { t_us, body })
}

// ---------------------------------------------------------------- file I/O

/// Write a complete trace (header + events) as JSONL.
pub fn write_trace(path: &Path, header: &TraceHeader,
                   events: &[TraceEvent]) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating trace {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "{}", encode_header(header))?;
    for e in events {
        writeln!(w, "{}", encode_event(e))?;
    }
    w.flush()?;
    Ok(())
}

/// Read a complete trace. Errors name the offending line — a tampered
/// or truncated trace is rejected, never silently skipped.
pub fn read_trace(path: &Path) -> Result<(TraceHeader, Vec<TraceEvent>)> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening trace {}", path.display()))?;
    let reader = BufReader::new(file);
    let mut header: Option<TraceHeader> = None;
    let mut events = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line
            .with_context(|| format!("reading {}", path.display()))?;
        if line.trim().is_empty() {
            continue;
        }
        if header.is_none() {
            header = Some(decode_header(&line).map_err(|e| {
                anyhow!("{}:{}: {e}", path.display(), lineno + 1)
            })?);
        } else {
            events.push(decode_event(&line).map_err(|e| {
                anyhow!("{}:{}: {e}", path.display(), lineno + 1)
            })?);
        }
    }
    let header = header
        .ok_or_else(|| anyhow!("{}: empty trace", path.display()))?;
    Ok((header, events))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> TraceHeader {
        TraceHeader {
            model: "dcgan".into(),
            backend: "native".into(),
            seed: 7,
            z_dim: 100,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        }
    }

    #[test]
    fn header_round_trip() {
        let h = header();
        assert_eq!(decode_header(&encode_header(&h)).unwrap(), h);
        let seg = TraceHeader {
            task: "segment".into(),
            net: "segnet".into(),
            z_dim: 0,
            engine_digest: "00ff00ff00ff00ff".into(),
            ..header()
        };
        assert_eq!(decode_header(&encode_header(&seg)).unwrap(), seg);
        // fleet roster (v5) round-trips
        let fleet = TraceHeader {
            fleet: vec![("seg".into(), "00ff00ff00ff00ff".into()),
                        ("tiny".into(), "0123456789abcdef".into())],
            ..header()
        };
        assert_eq!(decode_header(&encode_header(&fleet)).unwrap(), fleet);
    }

    #[test]
    fn v2_header_without_digest_decodes_empty() {
        // a v2 trace written before the engine_digest field existed
        let line = "{\"huge2_trace\":2,\"model\":\"seg\",\
                    \"backend\":\"native\",\"seed\":5,\"z_dim\":0,\
                    \"cond_dim\":0,\"task\":\"segment\",\
                    \"net\":\"tiny_segnet\"}";
        let h = decode_header(line).unwrap();
        assert_eq!(h.engine_digest, "");
        assert_eq!(h.net, "tiny_segnet");
    }

    #[test]
    fn v1_header_decodes_with_generate_defaults() {
        let line = "{\"huge2_trace\":1,\"model\":\"dcgan\",\
                    \"backend\":\"native\",\"seed\":7,\"z_dim\":100,\
                    \"cond_dim\":0}";
        let h = decode_header(line).unwrap();
        assert_eq!(h, header());
        assert_eq!(h.task, "generate");
        assert_eq!(h.net, "");
        // future versions are rejected, past versions are not
        assert!(decode_header("{\"huge2_trace\":6}").is_err());
        assert!(decode_header("{\"huge2_trace\":0}").is_err());
    }

    #[test]
    fn v4_arrival_without_priority_decodes_interactive() {
        // a v4 line: no "priority" field at all
        let line = "{\"t_us\":1,\"ev\":\"arrival\",\"id\":0,\
                    \"model\":\"m\",\"z\":[\"3f800000\"],\"cond\":[]}";
        match decode_event(line).unwrap().body {
            EventBody::RequestArrival { priority, .. } => {
                assert_eq!(priority, Priority::Interactive);
            }
            other => panic!("expected arrival, got {other:?}"),
        }
        // an explicit class round-trips; a bogus one is rejected
        let e = TraceEvent {
            t_us: 2,
            body: EventBody::RequestArrival {
                id: 1,
                model: "m".into(),
                payload: ArrivalPayload::Latent { z: vec![1.0],
                                                  cond: vec![] },
                priority: Priority::Background,
            },
        };
        let enc = encode_event(&e);
        assert!(enc.contains("\"priority\":\"background\""), "{enc}");
        assert_eq!(decode_event(&enc).unwrap(), e);
        let bad = enc.replace("background", "bogus");
        assert!(decode_event(&bad).is_err());
    }

    #[test]
    fn image_arrival_round_trips() {
        let e = TraceEvent {
            t_us: 4,
            body: EventBody::RequestArrival {
                id: 9,
                model: "segnet".into(),
                payload: ArrivalPayload::Image {
                    shape: vec![1, 33, 33, 3],
                    seed: 0xfeed_beef,
                    checksum: u64::MAX,
                },
                priority: Priority::default(),
            },
        };
        let line = encode_event(&e);
        assert!(line.contains("\"input_seed\""), "{line}");
        assert_eq!(decode_event(&line).unwrap(), e);
        // tampered input checksum hex is rejected at decode
        let bad = line.replace("\"input_checksum\":\"ffff",
                               "\"input_checksum\":\"zzzz");
        assert!(decode_event(&bad).is_err());
    }

    #[test]
    fn every_event_kind_round_trips() {
        let evs = vec![
            TraceEvent {
                t_us: 0,
                body: EventBody::RequestArrival {
                    id: 0,
                    model: "m\"with\\quotes\nand newline".into(),
                    payload: ArrivalPayload::Latent {
                        z: vec![1.5, -0.0, f32::NAN, f32::MIN_POSITIVE],
                        cond: vec![],
                    },
                    priority: Priority::default(),
                },
            },
            TraceEvent {
                t_us: 0,
                body: EventBody::RequestArrival {
                    id: 7,
                    model: "segnet".into(),
                    payload: ArrivalPayload::Image {
                        shape: vec![1, 9, 9, 2],
                        seed: 3,
                        checksum: 0xabcd,
                    },
                    priority: Priority::Batch,
                },
            },
            TraceEvent {
                t_us: 1,
                body: EventBody::Enqueue { id: 0, depth: 3 },
            },
            TraceEvent {
                t_us: 2,
                body: EventBody::Reject {
                    id: 1,
                    reason: "queue full for \"m\"".into(),
                },
            },
            TraceEvent {
                t_us: 3,
                body: EventBody::BatchFormed { ids: vec![0, 2, 5] },
            },
            TraceEvent {
                t_us: 4,
                body: EventBody::BatchExecuted {
                    ids: vec![0, 2],
                    bucket: 4,
                    exec_us: 1234,
                },
            },
            TraceEvent {
                t_us: 5,
                body: EventBody::Response {
                    id: 0,
                    batch_size: 2,
                    bucket: 4,
                    latency_us: 999,
                    checksum: u64::MAX,
                },
            },
            TraceEvent {
                t_us: 6,
                body: EventBody::Failed {
                    id: 3,
                    kind: "batch_failed".into(),
                    reason: "worker panicked: \"boom\"\n".into(),
                },
            },
            TraceEvent {
                t_us: 7,
                body: EventBody::Shed {
                    id: 4,
                    class: Priority::Batch,
                },
            },
            TraceEvent {
                t_us: 8,
                body: EventBody::Evict {
                    model: "seg".into(),
                    bytes: 1 << 20,
                },
            },
            TraceEvent {
                t_us: 9,
                body: EventBody::Reload {
                    model: "seg".into(),
                    bytes: 1 << 20,
                    digest: u64::MAX,
                },
            },
        ];
        for e in &evs {
            let line = encode_event(e);
            let back = decode_event(&line).unwrap();
            // NaN != NaN under PartialEq: compare via re-encoding, which
            // is bit-pattern-faithful.
            assert_eq!(encode_event(&back), line, "line {line}");
        }
    }

    #[test]
    fn checkpoint_round_trips_with_metrics() {
        let mut metrics = MetricsSnapshot::default();
        metrics.counters.insert(
            "huge2_requests_total{model=\"tiny\"}".into(), 42);
        metrics.gauges.insert("huge2_queue_depth".into(), -3);
        let hist = crate::metrics::Histogram::new();
        hist.record_us(7);
        hist.record_us(70_000);
        metrics
            .histograms
            .insert("huge2_latency_us".into(), hist.snapshot());
        let e = TraceEvent {
            t_us: 99,
            body: EventBody::Checkpoint(Box::new(CheckpointState {
                seq: 2,
                events: 512,
                pending: vec![17, 19],
                next_id: 20,
                submitted: 20,
                completed: 17,
                rejected: 1,
                failed: 0,
                fingerprint: u64::MAX,
                chain: 0x0123_4567_89ab_cdef,
                metrics,
            })),
        };
        let line = encode_event(&e);
        assert_eq!(decode_event(&line).unwrap(), e);
        // quantiles survive the sparse histogram round trip
        let EventBody::Checkpoint(back) =
            decode_event(&line).unwrap().body
        else {
            unreachable!()
        };
        let h = &back.metrics.histograms["huge2_latency_us"];
        assert_eq!(h.count(), 2);
        assert!(h.quantile_us(0.99) >= 65536);
        // a corrupt fingerprint field is rejected
        let bad = line.replace("\"fingerprint\":\"ffff",
                               "\"fingerprint\":\"zzzz");
        assert!(decode_event(&bad).is_err());
        // an empty-metrics checkpoint round-trips too
        let e2 = TraceEvent {
            t_us: 1,
            body: EventBody::Checkpoint(Box::new(CheckpointState {
                seq: 1,
                events: 0,
                pending: vec![],
                next_id: 0,
                submitted: 0,
                completed: 0,
                rejected: 0,
                failed: 0,
                fingerprint: super::super::fingerprint::FNV_OFFSET,
                chain: 1,
                metrics: MetricsSnapshot::default(),
            })),
        };
        assert_eq!(decode_event(&encode_event(&e2)).unwrap(), e2);
    }

    #[test]
    fn f32_bit_exactness() {
        for v in [0.0f32, -0.0, 1.0, -1.0, f32::INFINITY, f32::EPSILON,
                  1.0e-38, 1.234_567_9] {
            let e = TraceEvent {
                t_us: 0,
                body: EventBody::RequestArrival {
                    id: 0,
                    model: "m".into(),
                    payload: ArrivalPayload::Latent {
                        z: vec![v],
                        cond: vec![],
                    },
                    priority: Priority::default(),
                },
            };
            match decode_event(&encode_event(&e)).unwrap().body {
                EventBody::RequestArrival {
                    payload: ArrivalPayload::Latent { z, .. },
                    ..
                } => {
                    assert_eq!(z[0].to_bits(), v.to_bits());
                }
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(decode_event("").is_err());
        assert!(decode_event("{").is_err());
        assert!(decode_event("{}").is_err());
        assert!(decode_event("{\"t_us\":1}").is_err());
        assert!(decode_event("{\"t_us\":1,\"ev\":\"nope\"}").is_err());
        assert!(decode_event(
            "{\"t_us\":1,\"ev\":\"enqueue\",\"id\":0,\"depth\":1}x"
        )
        .is_err());
        // tampered checksum (non-hex)
        assert!(decode_event(
            "{\"t_us\":1,\"ev\":\"response\",\"id\":0,\"batch_size\":1,\
             \"bucket\":1,\"latency_us\":1,\"checksum\":\"zzzz\"}"
        )
        .is_err());
        // tampered latent bits
        assert!(decode_event(
            "{\"t_us\":1,\"ev\":\"arrival\",\"id\":0,\"model\":\"m\",\
             \"z\":[\"nothex\"],\"cond\":[]}"
        )
        .is_err());
        assert!(decode_header("{\"huge2_trace\":99}").is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "huge2_codec_test_{}.jsonl",
            std::process::id()
        ));
        let evs = vec![
            TraceEvent {
                t_us: 10,
                body: EventBody::Enqueue { id: 0, depth: 1 },
            },
            TraceEvent {
                t_us: 20,
                body: EventBody::Response {
                    id: 0,
                    batch_size: 1,
                    bucket: 1,
                    latency_us: 5,
                    checksum: 0xdead_beef,
                },
            },
        ];
        write_trace(&path, &header(), &evs).unwrap();
        let (h, back) = read_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(h, header());
        assert_eq!(back, evs);
    }
}
