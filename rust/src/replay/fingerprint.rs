//! Rolling FNV-1a fingerprints over the *deterministic* content of a
//! trace window (DESIGN.md §13).
//!
//! A fingerprint covers exactly what replay pins: arrival payloads
//! (latent bits, image shape/seed/checksum) and recorded outcomes
//! (response checksums, failure kinds, reject ids). Scheduling telemetry
//! — enqueue depths, batch composition, execution times, timestamps — is
//! deliberately **excluded**: a valid replay is allowed to batch
//! differently (DESIGN.md §7), so hashing scheduling detail would make
//! every fingerprint unreproducible by construction. What remains is a
//! per-window tamper-evidence seal: flip one latent bit or one recorded
//! checksum and the window's fingerprint (verified incrementally at
//! load) breaks, naming the window.
//!
//! The hash is FNV-1a 64 — the same primitive the engine-selection and
//! plan digests use — over a canonical byte encoding (tag byte, then
//! little-endian fixed-width fields). Checkpoint events are boundaries,
//! not content, and are never hashed.

use super::event::{ArrivalPayload, EventBody};
use crate::coordinator::Priority;

pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64 hasher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Fnv(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fold one event into a window fingerprint. Events that carry no
/// deterministic content (scheduling telemetry, checkpoints) are
/// no-ops, so the fingerprint of a window is invariant under the
/// scheduling jitter a legitimate re-recording would show.
pub fn fold_event(h: &mut Fnv, body: &EventBody) {
    match body {
        EventBody::RequestArrival {
            id,
            model,
            payload: ArrivalPayload::Latent { z, cond },
            priority,
        } => {
            h.write(&[0x01]);
            h.write_u64(*id);
            h.write(model.as_bytes());
            h.write_u64(z.len() as u64);
            for v in z {
                h.write(&v.to_bits().to_le_bytes());
            }
            h.write_u64(cond.len() as u64);
            for v in cond {
                h.write(&v.to_bits().to_le_bytes());
            }
            fold_priority(h, *priority);
        }
        EventBody::RequestArrival {
            id,
            model,
            payload: ArrivalPayload::Image { shape, seed, checksum },
            priority,
        } => {
            h.write(&[0x02]);
            h.write_u64(*id);
            h.write(model.as_bytes());
            h.write_u64(shape.len() as u64);
            for d in shape {
                h.write_u64(*d as u64);
            }
            h.write_u64(*seed);
            h.write_u64(*checksum);
            fold_priority(h, *priority);
        }
        // A reject is an admission outcome: hash the id but not the
        // reason text (human telemetry, may carry run-specific detail).
        EventBody::Reject { id, .. } => {
            h.write(&[0x03]);
            h.write_u64(*id);
        }
        EventBody::Response { id, checksum, .. } => {
            h.write(&[0x07]);
            h.write_u64(*id);
            h.write_u64(*checksum);
        }
        EventBody::Failed { id, kind, .. } => {
            h.write(&[0x08]);
            h.write_u64(*id);
            h.write(kind.as_bytes());
        }
        // A shed is an admission outcome (trace v5), folded like a
        // reject: the id and the shed class are deterministic content.
        // Safe for back-compat — v1–v4 streams contain no sheds.
        EventBody::Shed { id, class } => {
            h.write(&[0x09]);
            h.write_u64(*id);
            h.write(&[class.rank()]);
        }
        // Eviction/reload are load-dependent residency decisions
        // (scheduling telemetry, like batch composition): a legitimate
        // re-recording may evict differently, so they are not hashed.
        EventBody::Enqueue { .. }
        | EventBody::BatchFormed { .. }
        | EventBody::BatchExecuted { .. }
        | EventBody::Evict { .. }
        | EventBody::Reload { .. }
        | EventBody::Checkpoint(_) => {}
    }
}

/// Priority is folded only when it differs from the default class:
/// every v1–v4 arrival (which decodes as `Interactive`) re-folds to the
/// exact fingerprint its recording computed, while a v5 trace with
/// explicit lower classes pins them tamper-evidently.
fn fold_priority(h: &mut Fnv, priority: Priority) {
    if priority != Priority::default() {
        h.write(&[0xf0, priority.rank()]);
    }
}

/// Chain a finished window fingerprint onto the running chain value, so
/// checkpoint `k`'s chain commits to every window before it. Window 0
/// chains onto [`FNV_OFFSET`].
pub fn chain(prev: u64, window_fp: u64) -> u64 {
    let mut h = Fnv(prev);
    h.write_u64(window_fp);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrival(id: u64, z: Vec<f32>) -> EventBody {
        EventBody::RequestArrival {
            id,
            model: "m".into(),
            payload: ArrivalPayload::Latent { z, cond: vec![] },
            priority: Priority::default(),
        }
    }

    #[test]
    fn scheduling_events_do_not_perturb_fingerprints() {
        let mut a = Fnv::new();
        fold_event(&mut a, &arrival(0, vec![1.0]));
        let mut b = Fnv::new();
        fold_event(&mut b, &EventBody::Enqueue { id: 0, depth: 3 });
        fold_event(&mut b, &arrival(0, vec![1.0]));
        fold_event(&mut b, &EventBody::BatchFormed { ids: vec![0] });
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn payload_bits_do_perturb_fingerprints() {
        let mut a = Fnv::new();
        fold_event(&mut a, &arrival(0, vec![1.0]));
        let mut b = Fnv::new();
        fold_event(&mut b, &arrival(0, vec![1.0 + f32::EPSILON]));
        assert_ne!(a.finish(), b.finish());
        // NaN payloads hash by bit pattern, not by float compare
        let mut c = Fnv::new();
        fold_event(&mut c, &arrival(0, vec![f32::NAN]));
        let mut d = Fnv::new();
        fold_event(&mut d, &arrival(0, vec![f32::NAN]));
        assert_eq!(c.finish(), d.finish());
    }

    #[test]
    fn outcome_checksums_perturb_fingerprints() {
        let resp = |latency_us, checksum| EventBody::Response {
            id: 4,
            batch_size: 1,
            bucket: 1,
            latency_us,
            checksum,
        };
        let mut a = Fnv::new();
        fold_event(&mut a, &resp(9, 10));
        let mut b = Fnv::new();
        fold_event(&mut b, &resp(9, 11));
        assert_ne!(a.finish(), b.finish());
        // latency is scheduling telemetry: not hashed
        let mut c = Fnv::new();
        fold_event(&mut c, &resp(99_999, 10));
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn default_priority_folds_like_a_v4_arrival() {
        // the v1–v4 back-compat contract: an Interactive (default)
        // arrival hashes exactly as arrivals did before priorities
        let mut a = Fnv::new();
        fold_event(&mut a, &arrival(0, vec![1.0]));
        let mut manual = Fnv::new();
        manual.write(&[0x01]);
        manual.write_u64(0);
        manual.write("m".as_bytes());
        manual.write_u64(1);
        manual.write(&1.0f32.to_bits().to_le_bytes());
        manual.write_u64(0);
        assert_eq!(a.finish(), manual.finish());
        // a non-default class perturbs the fingerprint
        let mut b = Fnv::new();
        fold_event(&mut b, &EventBody::RequestArrival {
            id: 0,
            model: "m".into(),
            payload: ArrivalPayload::Latent { z: vec![1.0],
                                              cond: vec![] },
            priority: Priority::Background,
        });
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn shed_is_folded_but_residency_events_are_not() {
        let mut a = Fnv::new();
        fold_event(&mut a, &EventBody::Shed {
            id: 3, class: Priority::Batch });
        let mut b = Fnv::new();
        fold_event(&mut b, &EventBody::Shed {
            id: 3, class: Priority::Background });
        assert_ne!(a.finish(), b.finish(), "class is pinned");
        let mut c = Fnv::new();
        fold_event(&mut c, &EventBody::Evict {
            model: "m".into(), bytes: 1024 });
        fold_event(&mut c, &EventBody::Reload {
            model: "m".into(), bytes: 1024, digest: 7 });
        assert_eq!(c.finish(), Fnv::new().finish(),
                   "residency churn is scheduling telemetry");
    }

    #[test]
    fn chain_is_order_sensitive() {
        let a = chain(chain(FNV_OFFSET, 1), 2);
        let b = chain(chain(FNV_OFFSET, 2), 1);
        assert_ne!(a, b);
    }
}
