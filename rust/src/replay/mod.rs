//! Deterministic record/replay for the serving engine.
//!
//! A `huge2 serve --record out.jsonl` run captures every
//! non-deterministic workload input (arrival offsets, request ids,
//! latents) plus a checksum of every output into a JSONL trace;
//! `huge2 replay out.jsonl` re-drives the identical workload through a
//! freshly built engine and verifies each per-request output checksum
//! bit-for-bit. The contract is wasm-rr's: *all non-deterministic inputs
//! return recorded values; divergence is an error* — reported with the
//! first mismatching trace event.
//!
//! Layout:
//!
//! * [`event`] — the structured trace-event model + trace header.
//! * [`codec`] — dependency-free JSONL encode/decode (bit-exact floats).
//! * [`recorder`] — the `Arc<TraceSink>` hook the coordinator feeds, and
//!   the `Recorder` that saves a session.
//! * [`replayer`] — re-drives a trace, `--timing faithful|fast`.
//! * [`divergence`] — checksum comparison + first-mismatch reporting.
//!
//! Recording is **multi-task** (trace format v2): latent payloads are
//! captured bit-exactly; image payloads (segmentation requests) are
//! captured as (shape, synthesis seed, checksum) — raw pixels never hit
//! the trace — and replay regenerates + verifies them before submitting.
//! v1 traces still load (they decode as `task="generate"`).
//!
//! Failures are first-class outcomes (trace format v3, DESIGN.md §11):
//! a request answered with a typed `ServeError` records a `Failed`
//! event carrying the error's stable kind, and replay verifies
//! **failure determinism** — a recorded failure must fail again with
//! the same kind — exactly as it verifies response checksums. v2
//! traces (no `Failed` events) load unchanged.
//!
//! The canonical library-level quickstart (Recorder → set_trace_sink →
//! serve → save, then Replayer::load → run → is_clean) lives in the
//! [crate docs](crate); `examples/record_replay.rs` is the runnable
//! version, and DESIGN.md §7/§8 specify the semantics.

pub mod codec;
pub mod divergence;
pub mod event;
pub mod recorder;
pub mod replayer;

pub use codec::TRACE_VERSION;
pub use divergence::{Divergence, ReplayReport, ReplayedOutcome};
pub use event::{ArrivalPayload, EventBody, TraceEvent, TraceHeader};
pub use recorder::{Recorder, TraceSink};
pub use replayer::{Replayer, Timing};
