//! Deterministic record/replay for the serving engine.
//!
//! A `huge2 serve --record out.jsonl` run captures every
//! non-deterministic workload input (arrival offsets, request ids,
//! latents) plus a checksum of every output into a JSONL trace;
//! `huge2 replay out.jsonl` re-drives the identical workload through a
//! freshly built engine and verifies each per-request output checksum
//! bit-for-bit. The contract is wasm-rr's: *all non-deterministic inputs
//! return recorded values; divergence is an error* — reported with the
//! first mismatching trace event.
//!
//! Layout:
//!
//! * [`event`] — the structured trace-event model + trace header.
//! * [`codec`] — dependency-free JSONL encode/decode (bit-exact floats).
//! * [`binary`] — the compact binary twin format (`HG2TRACE` magic,
//!   varint fields, raw f32 bits) + magic-sniffing auto-detection.
//! * [`recorder`] — the `Arc<TraceSink>` hook the coordinator feeds, and
//!   the `Recorder` that saves a session.
//! * [`replayer`] — re-drives a trace (full, or a checkpoint-window
//!   slice), `--timing faithful|fast`, plus fingerprint bisection.
//! * [`divergence`] — checksum comparison + first-mismatch reporting.
//! * [`fingerprint`] — FNV-1a folding of deterministic event content.
//! * [`window`] — checkpoint building/verification and the
//!   window-boundary map over a trace.
//!
//! Recording is **multi-task** (trace format v2): latent payloads are
//! captured bit-exactly; image payloads (segmentation requests) are
//! captured as (shape, synthesis seed, checksum) — raw pixels never hit
//! the trace — and replay regenerates + verifies them before submitting.
//! v1 traces still load (they decode as `task="generate"`).
//!
//! Failures are first-class outcomes (trace format v3, DESIGN.md §11):
//! a request answered with a typed `ServeError` records a `Failed`
//! event carrying the error's stable kind, and replay verifies
//! **failure determinism** — a recorded failure must fail again with
//! the same kind — exactly as it verifies response checksums. v2
//! traces (no `Failed` events) load unchanged.
//!
//! Trace-scale tooling (trace format v4, DESIGN.md §13): a recording
//! sink built with a checkpoint cadence appends periodic `Checkpoint`
//! events — a verifiable fold of the preceding stream (pending ids,
//! counters, per-window FNV fingerprint + chain) plus a metrics
//! snapshot backfilled by the engine's checkpoint pump. Checkpoints
//! enable `huge2 replay --window A..B` (reconstruct state at a window
//! boundary, replay just that slice) and `huge2 trace bisect`
//! (localize the first divergent window in O(log W) window replays).
//! Traces can be saved in either of two on-disk formats — JSONL or the
//! compact binary format — converted losslessly between them with
//! `huge2 trace convert`, and are always read back by sniffing the
//! magic bytes, never the file extension. v1–v3 JSONL traces load and
//! replay unchanged (checkpoints can be synthesized offline for
//! bisection via [`window::insert_checkpoints`]).
//!
//! The canonical library-level quickstart (Recorder → set_trace_sink →
//! serve → save, then Replayer::load → run → is_clean) lives in the
//! [crate docs](crate); `examples/record_replay.rs` is the runnable
//! version, and DESIGN.md §7/§8 specify the semantics.

pub mod binary;
pub mod codec;
pub mod divergence;
pub mod event;
pub mod fingerprint;
pub mod recorder;
pub mod replayer;
pub mod window;

pub use codec::TRACE_VERSION;
pub use divergence::{Divergence, ReplayReport, ReplayedOutcome};
pub use event::{ArrivalPayload, CheckpointState, EventBody, TraceEvent,
                TraceHeader};
pub use recorder::{Recorder, TraceSink};
pub use replayer::{BisectReport, ReplayOptions, Replayer, Timing};
pub use window::{WindowMap, DEFAULT_CHECKPOINT_EVERY};
