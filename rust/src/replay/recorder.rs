//! The recording side: a cheap, thread-safe event sink the coordinator
//! feeds, plus the `Recorder` that owns the header and saves JSONL.
//!
//! Cost model: the engine holds an `Option<Arc<TraceSink>>` — a run
//! without `--record` pays one pointer null-check per hook site and
//! nothing else. A recording run pays one short mutex section per event
//! (the lock also serialises timestamping, which is what makes `t_us`
//! monotone non-decreasing in file order).

use anyhow::Result;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::codec;
use super::event::{EventBody, TraceEvent, TraceHeader};

/// Append-only, timestamping event sink shared by the engine's threads.
#[derive(Debug)]
pub struct TraceSink {
    t0: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink {
    pub fn new() -> Self {
        TraceSink { t0: Instant::now(), events: Mutex::new(Vec::new()) }
    }

    /// Append `body`, stamped with the µs offset since sink creation.
    /// Stamping happens *inside* the lock so event order and timestamp
    /// order never disagree.
    pub fn record(&self, body: EventBody) {
        let mut g = self.events.lock().unwrap();
        let t_us = self.t0.elapsed().as_micros() as u64;
        g.push(TraceEvent { t_us, body });
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the events recorded so far.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }
}

/// A recording session: the header describing the serving setup plus the
/// shared sink. Saving is explicit — callers decide when the run is over
/// (after `Engine::shutdown`, so worker-side events are all in).
pub struct Recorder {
    header: TraceHeader,
    sink: Arc<TraceSink>,
}

impl Recorder {
    /// Start a fresh recording.
    pub fn new(header: TraceHeader) -> Self {
        Recorder { header, sink: Arc::new(TraceSink::new()) }
    }

    /// Wrap an existing sink (when the sink had to be installed on the
    /// engine before the header's fields — z_dim etc. — were known).
    pub fn from_parts(header: TraceHeader, sink: Arc<TraceSink>) -> Self {
        Recorder { header, sink }
    }

    /// The sink to install via `Engine::set_trace_sink`.
    pub fn sink(&self) -> Arc<TraceSink> {
        self.sink.clone()
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    /// Write header + all events recorded so far; returns the event count.
    pub fn save(&self, path: &Path) -> Result<usize> {
        let events = self.sink.snapshot();
        codec::write_trace(path, &self.header, &events)?;
        Ok(events.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timestamps_monotone_under_contention() {
        let sink = Arc::new(TraceSink::new());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let sink = sink.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..200 {
                    sink.record(EventBody::Enqueue {
                        id: t * 1000 + i,
                        depth: 0,
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let evs = sink.snapshot();
        assert_eq!(evs.len(), 800);
        for w in evs.windows(2) {
            assert!(w[0].t_us <= w[1].t_us,
                    "timestamps must be monotone in file order");
        }
    }

    #[test]
    fn save_round_trips_through_codec() {
        let rec = Recorder::new(TraceHeader {
            model: "tiny".into(),
            backend: "native".into(),
            seed: 5,
            z_dim: 8,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
        });
        let sink = rec.sink();
        sink.record(EventBody::Enqueue { id: 0, depth: 1 });
        sink.record(EventBody::Response {
            id: 0,
            batch_size: 1,
            bucket: 1,
            latency_us: 42,
            checksum: 0xfeed,
        });
        let path = std::env::temp_dir().join(format!(
            "huge2_recorder_test_{}.jsonl",
            std::process::id()
        ));
        let n = rec.save(&path).unwrap();
        assert_eq!(n, 2);
        let (h, evs) = codec::read_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(&h, rec.header());
        assert_eq!(evs, sink.snapshot());
    }
}
