//! The recording side: a cheap, thread-safe event sink the coordinator
//! feeds, plus the `Recorder` that owns the header and saves the trace.
//!
//! Cost model: the engine holds an `Option<Arc<TraceSink>>` — a run
//! without `--record` pays one pointer null-check per hook site and
//! nothing else. A recording run pays one short mutex section per event
//! (the lock also serialises timestamping, which is what makes `t_us`
//! monotone non-decreasing in file order).
//!
//! Checkpointing (trace v4, DESIGN.md §13): a sink built with
//! [`TraceSink::with_checkpoints`] folds every event it records into a
//! [`CheckpointBuilder`] and appends a `Checkpoint` event each time the
//! cadence is reached — under the same lock, so the checkpoint sits at
//! its exact stream position and its state is exactly the fold of the
//! prefix. The checkpoint's *metrics* snapshot is deliberately NOT
//! taken under that lock (the registry's gauge closures read queue
//! depths, and `record` is called from inside a queue lock — snapshot
//! here and the lock order would cycle). Instead checkpoints are
//! appended with empty metrics and remembered; the engine's checkpoint
//! pump thread periodically calls [`TraceSink::backfill_metrics`] with
//! a registry snapshot taken lock-independently, filling them in a
//! beat later. Deterministic state is exact; telemetry is
//! near-boundary — the right trade, since replay never consumes the
//! metrics.

use anyhow::Result;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::MetricsSnapshot;

use super::binary;
use super::codec;
use super::event::{EventBody, TraceEvent, TraceHeader};
use super::window::CheckpointBuilder;

#[derive(Debug)]
struct SinkInner {
    events: Vec<TraceEvent>,
    /// Present when checkpointing is on.
    builder: Option<CheckpointBuilder>,
    /// Indices of checkpoint events still carrying empty metrics.
    unfilled: Vec<usize>,
}

/// Append-only, timestamping event sink shared by the engine's threads.
#[derive(Debug)]
pub struct TraceSink {
    t0: Instant,
    inner: Mutex<SinkInner>,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink {
    /// A plain sink: no checkpoints (the pre-v4 behavior, and the
    /// right default for unit tests that count exact event kinds).
    pub fn new() -> Self {
        Self::with_checkpoints(0)
    }

    /// A sink that appends a `Checkpoint` event every `every` recorded
    /// events (0 disables).
    pub fn with_checkpoints(every: usize) -> Self {
        TraceSink {
            t0: Instant::now(),
            inner: Mutex::new(SinkInner {
                events: Vec::new(),
                builder: (every > 0)
                    .then(|| CheckpointBuilder::new(every)),
                unfilled: Vec::new(),
            }),
        }
    }

    /// Checkpoint cadence (0 when checkpointing is off).
    pub fn checkpoint_every(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .builder
            .as_ref()
            .map(|b| b.cadence())
            .unwrap_or(0)
    }

    /// Append `body`, stamped with the µs offset since sink creation.
    /// Stamping happens *inside* the lock so event order and timestamp
    /// order never disagree — and so does checkpoint emission, so a
    /// checkpoint's state is exactly the fold of the events before it.
    pub fn record(&self, body: EventBody) {
        let mut g = self.inner.lock().unwrap();
        let t_us = self.t0.elapsed().as_micros() as u64;
        let ckpt = g.builder.as_mut().and_then(|b| b.observe(&body));
        g.events.push(TraceEvent { t_us, body });
        if let Some(c) = ckpt {
            let idx = g.events.len();
            g.unfilled.push(idx);
            g.events.push(TraceEvent {
                t_us,
                body: EventBody::Checkpoint(c),
            });
        }
    }

    /// Are there checkpoints still waiting for a metrics snapshot?
    pub fn wants_metrics(&self) -> bool {
        !self.inner.lock().unwrap().unfilled.is_empty()
    }

    /// Fill every metrics-less checkpoint with `snap`. Called by the
    /// engine's checkpoint pump (never from inside `record` — see the
    /// module docs for the lock-order reasoning).
    pub fn backfill_metrics(&self, snap: &MetricsSnapshot) {
        let mut g = self.inner.lock().unwrap();
        let unfilled = std::mem::take(&mut g.unfilled);
        for idx in unfilled {
            if let EventBody::Checkpoint(c) = &mut g.events[idx].body {
                c.metrics = snap.clone();
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the events recorded so far.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.inner.lock().unwrap().events.clone()
    }
}

/// A recording session: the header describing the serving setup plus the
/// shared sink. Saving is explicit — callers decide when the run is over
/// (after `Engine::shutdown`, so worker-side events are all in).
pub struct Recorder {
    header: TraceHeader,
    sink: Arc<TraceSink>,
}

impl Recorder {
    /// Start a fresh recording.
    pub fn new(header: TraceHeader) -> Self {
        Recorder { header, sink: Arc::new(TraceSink::new()) }
    }

    /// Wrap an existing sink (when the sink had to be installed on the
    /// engine before the header's fields — z_dim etc. — were known).
    pub fn from_parts(header: TraceHeader, sink: Arc<TraceSink>) -> Self {
        Recorder { header, sink }
    }

    /// The sink to install via `Engine::set_trace_sink`.
    pub fn sink(&self) -> Arc<TraceSink> {
        self.sink.clone()
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    /// Write header + all events recorded so far; returns the event
    /// count. The write codec is picked by extension — `.bin` writes
    /// the binary format, anything else JSONL (DESIGN.md §13). Readers
    /// never look at the extension: they sniff the magic.
    pub fn save(&self, path: &Path) -> Result<usize> {
        let events = self.sink.snapshot();
        if path.extension().is_some_and(|e| e == "bin") {
            binary::write_trace(path, &self.header, &events)?;
        } else {
            codec::write_trace(path, &self.header, &events)?;
        }
        Ok(events.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::window;

    #[test]
    fn timestamps_monotone_under_contention() {
        let sink = Arc::new(TraceSink::new());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let sink = sink.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..200 {
                    sink.record(EventBody::Enqueue {
                        id: t * 1000 + i,
                        depth: 0,
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let evs = sink.snapshot();
        assert_eq!(evs.len(), 800);
        for w in evs.windows(2) {
            assert!(w[0].t_us <= w[1].t_us,
                    "timestamps must be monotone in file order");
        }
    }

    #[test]
    fn checkpointing_sink_emits_verifiable_checkpoints() {
        let sink = TraceSink::with_checkpoints(5);
        assert_eq!(sink.checkpoint_every(), 5);
        for i in 0..12u64 {
            sink.record(EventBody::Enqueue { id: i, depth: 0 });
        }
        let evs = sink.snapshot();
        // 12 events + 2 checkpoints (after the 5th and 10th)
        assert_eq!(evs.len(), 14);
        assert!(matches!(evs[5].body, EventBody::Checkpoint(_)));
        assert!(matches!(evs[11].body, EventBody::Checkpoint(_)));
        window::verify_fingerprints(&evs).unwrap();
        // unfilled metrics are backfilled in place
        assert!(sink.wants_metrics());
        let mut snap = MetricsSnapshot::default();
        snap.counters.insert("c".into(), 9);
        sink.backfill_metrics(&snap);
        assert!(!sink.wants_metrics());
        let evs = sink.snapshot();
        let EventBody::Checkpoint(c) = &evs[5].body else {
            unreachable!()
        };
        assert_eq!(c.metrics.counters["c"], 9);
        // still verifiable: metrics are outside the fingerprint
        window::verify_fingerprints(&evs).unwrap();
    }

    #[test]
    fn save_round_trips_through_codec() {
        let rec = Recorder::new(TraceHeader {
            model: "tiny".into(),
            backend: "native".into(),
            seed: 5,
            z_dim: 8,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        });
        let sink = rec.sink();
        sink.record(EventBody::Enqueue { id: 0, depth: 1 });
        sink.record(EventBody::Response {
            id: 0,
            batch_size: 1,
            bucket: 1,
            latency_us: 42,
            checksum: 0xfeed,
        });
        let path = std::env::temp_dir().join(format!(
            "huge2_recorder_test_{}.jsonl",
            std::process::id()
        ));
        let n = rec.save(&path).unwrap();
        assert_eq!(n, 2);
        let (h, evs) = codec::read_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(&h, rec.header());
        assert_eq!(evs, sink.snapshot());
    }

    #[test]
    fn save_picks_codec_by_extension() {
        let rec = Recorder::new(TraceHeader {
            model: "tiny".into(),
            backend: "native".into(),
            seed: 5,
            z_dim: 8,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest: String::new(),
            fleet: Vec::new(),
        });
        rec.sink().record(EventBody::Enqueue { id: 0, depth: 1 });
        let dir = std::env::temp_dir();
        let bin = dir.join(format!("huge2_rec_ext_{}.bin",
                                   std::process::id()));
        let jsonl = dir.join(format!("huge2_rec_ext_{}.trace",
                                     std::process::id()));
        rec.save(&bin).unwrap();
        rec.save(&jsonl).unwrap();
        assert!(binary::sniff_is_binary(&bin).unwrap());
        assert!(!binary::sniff_is_binary(&jsonl).unwrap());
        // both load through auto-detection, identically
        let (hb, eb) = binary::read_trace_auto(&bin).unwrap();
        let (hj, ej) = binary::read_trace_auto(&jsonl).unwrap();
        std::fs::remove_file(&bin).ok();
        std::fs::remove_file(&jsonl).ok();
        assert_eq!(hb, hj);
        assert_eq!(eb, ej);
    }
}
