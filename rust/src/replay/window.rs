//! Checkpoint windows: the trace-slicing layer (DESIGN.md §13).
//!
//! A v4 trace is punctuated by [`EventBody::Checkpoint`] events every
//! `checkpoint_every` events. Checkpoint `k` (1-based `seq`) closes
//! **window** `k-1` (0-based); the tail after the last checkpoint is
//! the final window, so a trace with `C` checkpoints has `C + 1`
//! windows. Every checkpoint field except the metrics snapshot is a
//! pure fold over the preceding events ([`CheckpointBuilder`]), which
//! is what makes checkpoints *verifiable*: [`verify_fingerprints`]
//! re-folds the stream and errors on the first checkpoint whose
//! pending set, counters, fingerprint, or chain disagrees with the
//! events it claims to summarize — run at load, so a tampered trace
//! is rejected before any compute is spent, naming the window.
//!
//! [`WindowMap`] turns checkpoint positions into event ranges for
//! `huge2 replay --window A..B`, and [`insert_checkpoints`] synthesizes
//! a consistent checkpoint stream offline — how `trace bisect` windows
//! a v1–v3 trace that never had checkpoints.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use crate::metrics::MetricsSnapshot;

use super::event::{CheckpointState, EventBody, TraceEvent};
use super::fingerprint::{self, Fnv, FNV_OFFSET};

/// Default checkpoint cadence (events between checkpoints) for
/// recording and offline synthesis.
pub const DEFAULT_CHECKPOINT_EVERY: usize = 256;

/// Incremental fold of the event stream into checkpoint state. The
/// recording sink drives one live (every `every` events); offline
/// tools drive one over a finished stream.
#[derive(Debug)]
pub struct CheckpointBuilder {
    every: usize,
    since: usize,
    seq: u64,
    events_seen: u64,
    pending: BTreeSet<u64>,
    next_id: u64,
    submitted: u64,
    completed: u64,
    rejected: u64,
    failed: u64,
    window_fp: Fnv,
    chain: u64,
}

impl CheckpointBuilder {
    /// `every` == 0 disables cadence (observe never yields; use
    /// [`CheckpointBuilder::force`]).
    pub fn new(every: usize) -> Self {
        CheckpointBuilder {
            every,
            since: 0,
            seq: 0,
            events_seen: 0,
            pending: BTreeSet::new(),
            next_id: 0,
            submitted: 0,
            completed: 0,
            rejected: 0,
            failed: 0,
            window_fp: Fnv::new(),
            chain: FNV_OFFSET,
        }
    }

    pub fn cadence(&self) -> usize {
        self.every
    }

    /// Fold one (non-checkpoint) event; yields the checkpoint that
    /// should be appended *after* it when the cadence is reached. The
    /// returned state carries empty metrics — telemetry is the
    /// caller's to fill (the engine's pump, for live recording).
    pub fn observe(&mut self, body: &EventBody)
                   -> Option<Box<CheckpointState>> {
        debug_assert!(
            !matches!(body, EventBody::Checkpoint(_)),
            "checkpoints are boundaries, not foldable content"
        );
        match body {
            EventBody::RequestArrival { id, .. } => {
                self.submitted += 1;
                self.pending.insert(*id);
                self.next_id = self.next_id.max(id + 1);
            }
            EventBody::Reject { id, .. } => {
                self.rejected += 1;
                self.pending.remove(id);
                self.next_id = self.next_id.max(id + 1);
            }
            EventBody::Response { id, .. } => {
                self.completed += 1;
                self.pending.remove(id);
                self.next_id = self.next_id.max(id + 1);
            }
            EventBody::Failed { id, .. } => {
                self.failed += 1;
                self.pending.remove(id);
                self.next_id = self.next_id.max(id + 1);
            }
            // A shed (trace v5) is an admission-refusal terminal: it
            // counts as rejected, exactly like a Reject event.
            EventBody::Shed { id, .. } => {
                self.rejected += 1;
                self.pending.remove(id);
                self.next_id = self.next_id.max(id + 1);
            }
            EventBody::Enqueue { .. }
            | EventBody::BatchFormed { .. }
            | EventBody::BatchExecuted { .. }
            | EventBody::Evict { .. }
            | EventBody::Reload { .. }
            | EventBody::Checkpoint(_) => {}
        }
        fingerprint::fold_event(&mut self.window_fp, body);
        self.events_seen += 1;
        self.since += 1;
        if self.every > 0 && self.since >= self.every {
            Some(self.force())
        } else {
            None
        }
    }

    /// Close the current window now, regardless of cadence.
    pub fn force(&mut self) -> Box<CheckpointState> {
        self.seq += 1;
        self.since = 0;
        let fp = self.window_fp.finish();
        self.chain = fingerprint::chain(self.chain, fp);
        self.window_fp = Fnv::new();
        Box::new(CheckpointState {
            seq: self.seq,
            events: self.events_seen,
            pending: self.pending.iter().copied().collect(),
            next_id: self.next_id,
            submitted: self.submitted,
            completed: self.completed,
            rejected: self.rejected,
            failed: self.failed,
            fingerprint: fp,
            chain: self.chain,
            metrics: MetricsSnapshot::default(),
        })
    }
}

/// Synthesize a consistent checkpoint stream over a finished trace:
/// the input events (which must not already contain checkpoints) with
/// a verifiable checkpoint inserted every `every` events. Metrics are
/// empty — offline synthesis has no registry to snapshot. This is how
/// checkpoint-less v1–v3 traces get windowed for bisection, and how
/// tests build traces with surgically placed divergences.
pub fn insert_checkpoints(events: &[TraceEvent], every: usize)
                          -> Vec<TraceEvent> {
    assert!(every > 0, "cadence must be positive");
    let mut b = CheckpointBuilder::new(every);
    let mut out = Vec::with_capacity(events.len() + events.len() / every);
    for e in events {
        debug_assert!(!matches!(e.body, EventBody::Checkpoint(_)),
                      "insert_checkpoints input already has checkpoints");
        let ckpt = b.observe(&e.body);
        let t_us = e.t_us;
        out.push(e.clone());
        if let Some(c) = ckpt {
            out.push(TraceEvent { t_us, body: EventBody::Checkpoint(c) });
        }
    }
    out
}

/// Checkpoint pruning (`huge2 trace compact`): keep every `keep_every`-th
/// checkpoint and drop the rest, shrinking long recordings whose
/// checkpoint cadence was tighter than the operator needs for windowed
/// replay. Because fingerprints are *per-window*, a kept checkpoint's
/// state cannot simply be copied — dropping its predecessors merges
/// windows, changing the window fingerprint and the chain. So the
/// stream is re-folded from scratch ([`CheckpointBuilder`]) and a fresh,
/// consistent checkpoint is forced at each kept position; the kept
/// checkpoint's metrics snapshot (telemetry, outside the fingerprint)
/// is carried over. The result is re-verified before it is returned —
/// a compacted trace that would not pass [`verify_fingerprints`] is a
/// bug, not an output.
pub fn compact_checkpoints(events: &[TraceEvent], keep_every: usize)
                           -> Result<Vec<TraceEvent>, String> {
    if keep_every == 0 {
        return Err("keep_every must be positive".into());
    }
    let mut b = CheckpointBuilder::new(0);
    let mut out = Vec::with_capacity(events.len());
    let mut seen = 0u64; // original checkpoint ordinal
    for e in events {
        let EventBody::Checkpoint(rec) = &e.body else {
            b.observe(&e.body);
            out.push(e.clone());
            continue;
        };
        seen += 1;
        if seen % keep_every as u64 != 0 {
            continue; // pruned
        }
        let mut c = b.force();
        c.metrics = rec.metrics.clone();
        out.push(TraceEvent { t_us: e.t_us,
                              body: EventBody::Checkpoint(c) });
    }
    verify_fingerprints(&out)
        .map_err(|e| format!("compaction produced an inconsistent \
                              trace (bug): {e}"))?;
    Ok(out)
}

/// Re-fold the whole stream and verify every checkpoint against the
/// events it summarizes: pending set, counters, id allocator, window
/// fingerprint, and chain. Errors name the first bad checkpoint (and
/// thus its window). Metrics are telemetry and not verified. A trace
/// without checkpoints passes vacuously.
pub fn verify_fingerprints(events: &[TraceEvent]) -> Result<(), String> {
    let mut b = CheckpointBuilder::new(0);
    for (idx, e) in events.iter().enumerate() {
        let EventBody::Checkpoint(rec) = &e.body else {
            b.observe(&e.body);
            continue;
        };
        let got = b.force();
        if got.fingerprint != rec.fingerprint {
            return Err(format!(
                "checkpoint #{} (event #{idx}): window {} fingerprint \
                 mismatch — recorded {:016x}, recomputed {:016x} (the \
                 window's payloads or outcomes were altered)",
                rec.seq,
                rec.seq.saturating_sub(1),
                rec.fingerprint,
                got.fingerprint
            ));
        }
        if got.chain != rec.chain {
            return Err(format!(
                "checkpoint #{} (event #{idx}): fingerprint chain \
                 mismatch — recorded {:016x}, recomputed {:016x}",
                rec.seq, rec.chain, got.chain
            ));
        }
        if (got.seq, &got.pending, got.next_id) !=
           (rec.seq, &rec.pending, rec.next_id)
            || (got.events, got.submitted, got.completed) !=
               (rec.events, rec.submitted, rec.completed)
            || (got.rejected, got.failed) != (rec.rejected, rec.failed)
        {
            return Err(format!(
                "checkpoint #{} (event #{idx}): state disagrees with \
                 the events it summarizes (recorded pending={:?} \
                 submitted={} completed={} rejected={} failed={}, \
                 recomputed pending={:?} submitted={} completed={} \
                 rejected={} failed={})",
                rec.seq, rec.pending, rec.submitted, rec.completed,
                rec.rejected, rec.failed, got.pending, got.submitted,
                got.completed, got.rejected, got.failed
            ));
        }
    }
    Ok(())
}

/// Event-range view of a trace's checkpoint windows.
pub struct WindowMap {
    /// Event index of each checkpoint event, ascending.
    boundaries: Vec<usize>,
    total_events: usize,
}

impl WindowMap {
    pub fn of(events: &[TraceEvent]) -> Self {
        let boundaries = events
            .iter()
            .enumerate()
            .filter(|(_, e)| {
                matches!(e.body, EventBody::Checkpoint(_))
            })
            .map(|(i, _)| i)
            .collect();
        WindowMap { boundaries, total_events: events.len() }
    }

    /// Number of windows (`checkpoints + 1`; a checkpoint-less trace
    /// is one window).
    pub fn count(&self) -> usize {
        self.boundaries.len() + 1
    }

    pub fn checkpoint_count(&self) -> usize {
        self.boundaries.len()
    }

    /// Event range of window `w` (0-based). Window `w` ends just past
    /// the checkpoint event that closes it, so the closing checkpoint
    /// belongs to its window; the last window runs to the end of the
    /// trace.
    pub fn window_events(&self, w: usize)
                         -> std::ops::Range<usize> {
        let start = if w == 0 {
            0
        } else {
            self.boundaries[w - 1] + 1
        };
        let end = self
            .boundaries
            .get(w)
            .map(|&b| b + 1)
            .unwrap_or(self.total_events);
        start..end
    }

    /// Event range covering windows `ws.start..ws.end`.
    pub fn span_events(&self, ws: &std::ops::Range<usize>)
                       -> std::ops::Range<usize> {
        self.window_events(ws.start).start
            ..self.window_events(ws.end - 1).end
    }

    /// The checkpoint that *opens* window `w` — i.e. the one closing
    /// window `w-1` — with the pending set a window replay must
    /// re-drive. `None` for window 0 (the trace start is the state).
    pub fn opening_checkpoint<'a>(&self, events: &'a [TraceEvent],
                                  w: usize)
                                  -> Option<&'a CheckpointState> {
        let idx = *self.boundaries.get(w.checked_sub(1)?)?;
        match &events[idx].body {
            EventBody::Checkpoint(c) => Some(c),
            _ => unreachable!("boundary indexes a checkpoint"),
        }
    }

    /// Which window event index `idx` falls in.
    pub fn window_of_event(&self, idx: usize) -> usize {
        self.boundaries.partition_point(|&b| b < idx)
    }
}

/// Flight-recorder-style excerpt of the last `limit` events of an
/// event range — what the CLI prints under a divergence so the
/// operator sees the window's tail without opening the trace.
pub fn excerpt(events: &[TraceEvent], range: std::ops::Range<usize>,
               limit: usize) -> String {
    let slice = &events[range.clone()];
    let skip = slice.len().saturating_sub(limit);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "window excerpt: events #{}..#{} ({} event(s)), last {}:",
        range.start,
        range.end,
        slice.len(),
        slice.len() - skip
    );
    for (off, e) in slice.iter().enumerate().skip(skip) {
        let idx = range.start + off;
        let _ = write!(out, "  #{idx} +{}µs {}", e.t_us, e.body.kind());
        match &e.body {
            EventBody::RequestArrival { id, model, .. } => {
                let _ = writeln!(out, " id={id} model={model}");
            }
            EventBody::Enqueue { id, depth } => {
                let _ = writeln!(out, " id={id} depth={depth}");
            }
            EventBody::Reject { id, reason } => {
                let _ = writeln!(out, " id={id} reason={reason:?}");
            }
            EventBody::BatchFormed { ids }
            | EventBody::BatchExecuted { ids, .. } => {
                let _ = writeln!(out, " n={}", ids.len());
            }
            EventBody::Response { id, checksum, .. } => {
                let _ = writeln!(out, " id={id} checksum={checksum:016x}");
            }
            EventBody::Failed { id, kind, .. } => {
                let _ = writeln!(out, " id={id} kind={kind}");
            }
            EventBody::Shed { id, class } => {
                let _ = writeln!(out, " id={id} class={}",
                                 class.as_str());
            }
            EventBody::Evict { model, bytes } => {
                let _ = writeln!(out, " model={model} bytes={bytes}");
            }
            EventBody::Reload { model, bytes, digest } => {
                let _ = writeln!(
                    out, " model={model} bytes={bytes} \
                          digest={digest:016x}");
            }
            EventBody::Checkpoint(c) => {
                let _ = writeln!(
                    out,
                    " seq={} pending={} fp={:016x}",
                    c.seq,
                    c.pending.len(),
                    c.fingerprint
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::event::ArrivalPayload;

    fn arrival(t_us: u64, id: u64) -> TraceEvent {
        TraceEvent {
            t_us,
            body: EventBody::RequestArrival {
                id,
                model: "m".into(),
                payload: ArrivalPayload::Latent {
                    z: vec![id as f32],
                    cond: vec![],
                },
                priority: Default::default(),
            },
        }
    }

    fn response(t_us: u64, id: u64) -> TraceEvent {
        TraceEvent {
            t_us,
            body: EventBody::Response {
                id,
                batch_size: 1,
                bucket: 1,
                latency_us: 1,
                checksum: 0x1000 + id,
            },
        }
    }

    fn stream(n: u64) -> Vec<TraceEvent> {
        // arrival(i), response(i), arrival(i+1), response(i+1), …
        (0..n)
            .flat_map(|i| [arrival(2 * i, i), response(2 * i + 1, i)])
            .collect()
    }

    #[test]
    fn inserted_checkpoints_verify_and_window() {
        let evs = insert_checkpoints(&stream(8), 4);
        // 16 events / 4 = 4 checkpoints
        let wm = WindowMap::of(&evs);
        assert_eq!(wm.checkpoint_count(), 4);
        assert_eq!(wm.count(), 5);
        verify_fingerprints(&evs).unwrap();
        // ranges tile the trace exactly
        let mut covered = 0;
        for w in 0..wm.count() {
            let r = wm.window_events(w);
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, evs.len());
        assert_eq!(wm.span_events(&(0..wm.count())), 0..evs.len());
        // each event maps back into its window
        for w in 0..wm.count() {
            for i in wm.window_events(w) {
                assert_eq!(wm.window_of_event(i), w,
                           "event {i} in window {w}");
            }
        }
    }

    #[test]
    fn checkpoint_state_folds_pending_and_counters() {
        // arrival 0, arrival 1, response 0 → checkpoint: pending {1}
        let evs = vec![arrival(0, 0), arrival(1, 1), response(2, 0)];
        let evs = insert_checkpoints(&evs, 3);
        let EventBody::Checkpoint(c) = &evs[3].body else {
            panic!("expected checkpoint at index 3, got {evs:?}");
        };
        assert_eq!(c.seq, 1);
        assert_eq!(c.events, 3);
        assert_eq!(c.pending, vec![1]);
        assert_eq!(c.next_id, 2);
        assert_eq!((c.submitted, c.completed, c.rejected, c.failed),
                   (2, 1, 0, 0));
        // conservation: submitted - terminals == pending
        assert_eq!(c.submitted - c.completed - c.rejected - c.failed,
                   c.pending.len() as u64);
    }

    #[test]
    fn tampering_breaks_exactly_its_window() {
        let mut evs = insert_checkpoints(&stream(8), 4);
        verify_fingerprints(&evs).unwrap();
        // flip a checksum inside window 2 (events 10..15)
        let wm = WindowMap::of(&evs);
        let r = wm.window_events(2);
        let victim = evs[r.clone()]
            .iter()
            .position(|e| matches!(e.body, EventBody::Response { .. }))
            .map(|off| r.start + off)
            .unwrap();
        if let EventBody::Response { checksum, .. } =
            &mut evs[victim].body
        {
            *checksum ^= 1;
        }
        let err = verify_fingerprints(&evs).unwrap_err();
        assert!(err.contains("window 2"), "{err}");
        assert!(err.contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn scheduling_jitter_does_not_break_fingerprints() {
        // extra enqueue/batch events change nothing the seal covers
        let base = stream(4);
        let mut noisy = Vec::new();
        for (i, e) in base.iter().enumerate() {
            noisy.push(e.clone());
            noisy.push(TraceEvent {
                t_us: e.t_us,
                body: EventBody::Enqueue { id: i as u64, depth: i },
            });
        }
        let a = insert_checkpoints(&base, base.len());
        let b = insert_checkpoints(&noisy, noisy.len());
        let (EventBody::Checkpoint(ca), EventBody::Checkpoint(cb)) =
            (&a.last().unwrap().body, &b.last().unwrap().body)
        else {
            panic!("last event must be the checkpoint");
        };
        assert_eq!(ca.fingerprint, cb.fingerprint);
        assert_ne!(ca.events, cb.events);
    }

    #[test]
    fn compaction_keeps_every_kth_checkpoint_and_reverifies() {
        let evs = insert_checkpoints(&stream(16), 4); // 8 checkpoints
        let compact = compact_checkpoints(&evs, 2).unwrap();
        let wm = WindowMap::of(&compact);
        assert_eq!(wm.checkpoint_count(), 4, "8 / keep-every-2");
        verify_fingerprints(&compact).unwrap();
        // non-checkpoint events survive untouched, in order
        let strip = |evs: &[TraceEvent]| -> Vec<TraceEvent> {
            evs.iter()
                .filter(|e| {
                    !matches!(e.body, EventBody::Checkpoint(_))
                })
                .cloned()
                .collect()
        };
        assert_eq!(strip(&compact), strip(&evs));
        // kept checkpoints are renumbered 1..=4 with cumulative state
        let ckpts: Vec<_> = compact
            .iter()
            .filter_map(|e| match &e.body {
                EventBody::Checkpoint(c) => Some(c),
                _ => None,
            })
            .collect();
        assert_eq!(ckpts.iter().map(|c| c.seq).collect::<Vec<_>>(),
                   vec![1, 2, 3, 4]);
        assert_eq!(ckpts.last().unwrap().completed, 16);
        // a merged window's fingerprint differs from either original
        // (it seals 2× the events), but the final chain still commits
        // to the same deterministic content
        assert!(compact_checkpoints(&evs, 0).is_err());
        // keep-every-1 is the identity on a consistent trace
        assert_eq!(compact_checkpoints(&evs, 1).unwrap(), evs);
    }

    #[test]
    fn excerpt_names_events_and_truncates() {
        let evs = insert_checkpoints(&stream(8), 4);
        let text = excerpt(&evs, 0..evs.len(), 3);
        assert!(text.contains("last 3"), "{text}");
        assert!(text.lines().count() == 4, "{text}");
        let full = excerpt(&evs, 0..5, 100);
        assert!(full.contains("#0"), "{full}");
        assert!(full.contains("arrival id=0"), "{full}");
        assert!(full.contains("checksum="), "{full}");
        assert!(full.contains("seq=1"), "{full}");
    }
}
