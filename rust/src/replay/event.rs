//! The trace-event model: every externally-visible state transition of
//! the serving engine, stamped with a monotonic timestamp.
//!
//! The deterministic-replay contract (borrowed from wasm-rr): a recording
//! captures **all non-deterministic inputs** of a serve run — arrival
//! times, request ids, latent vectors — plus a checksum of every output,
//! so a replay can re-drive the exact workload and *prove* the engine
//! produced byte-identical images. Scheduling detail (batch composition,
//! queue depths, latencies) is recorded as telemetry but deliberately NOT
//! pinned: the engine is free to batch differently under `--timing fast`,
//! because per-request outputs are batch-composition-invariant (each GEMM
//! row accumulates independently — see DESIGN.md §7).

/// One timestamped trace record.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Microseconds since the recording sink was created. Monotone
    /// non-decreasing in file order (stamped under the sink's lock).
    pub t_us: u64,
    pub body: EventBody,
}

/// The task input a recorded arrival carried (mirrors
/// `coordinator::Payload`, in trace form).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalPayload {
    /// Latent + conditioning, captured bit-exactly (IEEE-754 bit
    /// patterns in the codec).
    Latent { z: Vec<f32>, cond: Vec<f32> },
    /// Image input, captured as **(shape, synthesis seed, checksum)**
    /// instead of raw pixels (trace format v2): replay regenerates
    /// `Tensor::randn(shape, Rng::new(seed))` and verifies the checksum
    /// before submitting, so the trace stays kilobytes while the input
    /// is still pinned bit-exactly.
    Image { shape: Vec<usize>, seed: u64, checksum: u64 },
}

/// What happened.
#[derive(Debug, Clone, PartialEq)]
pub enum EventBody {
    /// A request reached `Engine::submit` — the workload's
    /// non-deterministic input. `priority` is the admission class
    /// (trace format v5; v1–v4 arrivals decode as the default,
    /// `Interactive`, and the fingerprint folds priority only when it
    /// differs from that default, so old traces re-fold identically).
    RequestArrival {
        id: u64,
        model: String,
        payload: ArrivalPayload,
        priority: crate::coordinator::Priority,
    },
    /// Admission succeeded; `depth` is the queue depth just after the push.
    Enqueue { id: u64, depth: usize },
    /// Admission failed (validation, backpressure, or shutdown).
    Reject { id: u64, reason: String },
    /// The dynamic batcher closed a batch (ids in queue order).
    BatchFormed { ids: Vec<u64> },
    /// A batch finished executing on its backend.
    BatchExecuted {
        ids: Vec<u64>,
        /// Compiled bucket the batch ran in (== len(ids) on native).
        bucket: usize,
        exec_us: u64,
    },
    /// A response was sent to a client. `checksum` pins the output bytes
    /// ([`crate::tensor::Tensor::checksum`]); replay verifies it.
    Response {
        id: u64,
        batch_size: usize,
        bucket: usize,
        latency_us: u64,
        checksum: u64,
    },
    /// A typed failure was sent to a client (trace format v3): the
    /// request was *accepted* but terminated in a
    /// `ServeError` instead of a response — a malformed row isolated at
    /// gather, a failed batch, a caught worker panic. `kind` is the
    /// stable `ServeError::kind()` tag; replay verifies failure
    /// determinism by kind, the same way it verifies response
    /// checksums. `reason` is human telemetry and deliberately not
    /// compared (it may carry run-specific detail).
    Failed { id: u64, kind: String, reason: String },
    /// The admission controller shed a request under load (trace format
    /// v5, DESIGN.md §16): either refused at submit (queue full, class
    /// below `Interactive`) or displaced from the queue by a
    /// higher-class arrival. A terminal outcome — folded into the
    /// window fingerprint like `Reject`, and counted in `rejected` by
    /// the checkpoint fold.
    Shed { id: u64, class: crate::coordinator::Priority },
    /// LRU weight residency evicted a model's prepacked plan to fit the
    /// resident-budget (trace format v5). Telemetry, NOT folded:
    /// eviction is a load-dependent scheduling decision — a replay may
    /// evict differently and its outputs still verify, because a
    /// reloaded plan must reproduce its pinned engine digest.
    Evict { model: String, bytes: u64 },
    /// A previously evicted model's plan was rebuilt on demand (trace
    /// format v5). `digest` is the rebuilt plan's engine-selection
    /// digest — recorded so a trace reader can audit that every reload
    /// reproduced the registration-time digest. Telemetry, NOT folded
    /// (same reasoning as [`EventBody::Evict`]).
    Reload { model: String, bytes: u64, digest: u64 },
    /// A periodic state snapshot (trace format v4): closes a replay
    /// *window* and records everything needed to reconstruct engine
    /// state at that boundary — in-flight request ids, outcome
    /// counters, the id allocator, the closing window's content
    /// fingerprint, and a metrics-registry snapshot. Emitted by the
    /// sink every `checkpoint_every` events; `huge2 replay --window`
    /// and `huge2 trace bisect` slice the trace at these boundaries.
    Checkpoint(Box<CheckpointState>),
}

/// The state a [`EventBody::Checkpoint`] carries (DESIGN.md §13).
///
/// Every field except `metrics` is a pure fold over the event stream
/// preceding the checkpoint, so a reader can *verify* a checkpoint
/// against the events it summarizes — and
/// [`window::verify_fingerprints`](super::window::verify_fingerprints)
/// does, incrementally, at load. The engine's only live counter/RNG-like
/// state is the request-id allocator (`next_id`): model weights rebuild
/// deterministically from the header seed and the workload RNG is
/// externalized by bit-exact payload capture, so nothing else needs
/// snapshotting to resume a window.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointState {
    /// 1-based checkpoint ordinal; this checkpoint closes window
    /// `seq - 1` (0-based).
    pub seq: u64,
    /// Non-checkpoint events preceding this checkpoint in the stream.
    pub events: u64,
    /// Request ids submitted but not yet terminal (no response, typed
    /// failure, or reject recorded) at this boundary, ascending. A
    /// window replay starting here re-drives exactly these arrivals
    /// before the window's own.
    pub pending: Vec<u64>,
    /// One past the highest request id seen — the id allocator's state.
    pub next_id: u64,
    /// Outcome counters folded from the stream (the conservation
    /// invariant holds: `submitted - completed - rejected - failed ==
    /// pending.len()`).
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    /// FNV-1a fingerprint of the closing window's deterministic content
    /// ([`fingerprint`](super::fingerprint)).
    pub fingerprint: u64,
    /// Fingerprint chain over all windows so far — commits to the whole
    /// prefix, so a verified checkpoint transitively verifies every
    /// earlier window.
    pub chain: u64,
    /// Point-in-time [`MetricsRegistry`](crate::metrics::MetricsRegistry)
    /// snapshot (PR-6 observability surface). Telemetry, not replay
    /// state: it is *not* covered by the fingerprint and may be empty
    /// for checkpoints synthesized offline.
    pub metrics: crate::metrics::MetricsSnapshot,
}

impl EventBody {
    /// Wire tag of the event kind (the codec's `"ev"` field).
    pub fn kind(&self) -> &'static str {
        match self {
            EventBody::RequestArrival { .. } => "arrival",
            EventBody::Enqueue { .. } => "enqueue",
            EventBody::Reject { .. } => "reject",
            EventBody::BatchFormed { .. } => "batch_formed",
            EventBody::BatchExecuted { .. } => "batch_executed",
            EventBody::Response { .. } => "response",
            EventBody::Failed { .. } => "failed",
            EventBody::Shed { .. } => "shed",
            EventBody::Evict { .. } => "evict",
            EventBody::Reload { .. } => "reload",
            EventBody::Checkpoint(_) => "checkpoint",
        }
    }

    /// The request id this event concerns, if it concerns exactly one.
    pub fn request_id(&self) -> Option<u64> {
        match self {
            EventBody::RequestArrival { id, .. }
            | EventBody::Enqueue { id, .. }
            | EventBody::Reject { id, .. }
            | EventBody::Response { id, .. }
            | EventBody::Failed { id, .. }
            | EventBody::Shed { id, .. } => Some(*id),
            EventBody::BatchFormed { .. }
            | EventBody::BatchExecuted { .. }
            | EventBody::Evict { .. }
            | EventBody::Reload { .. }
            | EventBody::Checkpoint(_) => None,
        }
    }
}

/// Trace-file header: everything a replayer needs to rebuild the serving
/// setup the recording ran against. The wire format version is not a
/// field here — the codec stamps [`TRACE_VERSION`] on write and rejects
/// anything newer on read (older versions decode with documented
/// defaults), so an unsupported version is unrepresentable in memory.
///
/// [`TRACE_VERSION`]: crate::replay::codec::TRACE_VERSION
#[derive(Debug, Clone, PartialEq)]
pub struct TraceHeader {
    /// Registered model name requests were submitted under.
    pub model: String,
    /// `"native"` (pure-Rust generator) or `"pjrt"` (AOT artifacts).
    pub backend: String,
    /// Weight seed; the native backend rebuilds the exact model from
    /// it, the PJRT backend re-binds identically seeded weights.
    pub seed: u64,
    pub z_dim: usize,
    pub cond_dim: usize,
    /// `"generate"` or `"segment"` (v2 field; v1 traces decode as
    /// `"generate"`).
    pub task: String,
    /// Segmentation-net config name (`config::segnet_by_name`) for
    /// `task == "segment"`; empty otherwise (v2 field; v1 decodes empty).
    pub net: String,
    /// 16-hex engine-selection digest of the serving model's compiled
    /// plan ([`crate::plan::ExecPlan::engine_digest`]); empty for PJRT
    /// backends and traces recorded before plans existed. A
    /// v2-compatible *extra* field: older readers ignore unknown header
    /// fields, and this build decodes its absence as empty. Replay
    /// re-checks it so `Engine::Auto` replays the exact recorded
    /// selections even if the heuristic changed (DESIGN.md §10).
    pub engine_digest: String,
    /// Fleet roster (trace format v5): `(model name, 16-hex engine
    /// digest)` for every *additional* model registered beside the
    /// primary one, ascending by name. Empty for single-model traces
    /// and all v1–v4 recordings. Replay registers the full roster and
    /// re-checks each digest, so a fleet recording replays against the
    /// exact same engine selections model-by-model.
    pub fleet: Vec<(String, String)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_distinct() {
        let evs = [
            EventBody::RequestArrival {
                id: 0,
                model: "m".into(),
                payload: ArrivalPayload::Latent { z: vec![], cond: vec![] },
                priority: Default::default(),
            },
            EventBody::Enqueue { id: 0, depth: 1 },
            EventBody::Reject { id: 0, reason: "r".into() },
            EventBody::BatchFormed { ids: vec![0] },
            EventBody::BatchExecuted { ids: vec![0], bucket: 1, exec_us: 2 },
            EventBody::Response {
                id: 0,
                batch_size: 1,
                bucket: 1,
                latency_us: 3,
                checksum: 4,
            },
            EventBody::Failed {
                id: 0,
                kind: "batch_failed".into(),
                reason: "r".into(),
            },
            EventBody::Shed {
                id: 0,
                class: crate::coordinator::Priority::Background,
            },
            EventBody::Evict { model: "m".into(), bytes: 64 },
            EventBody::Reload { model: "m".into(), bytes: 64, digest: 9 },
            EventBody::Checkpoint(Box::new(CheckpointState {
                seq: 1,
                events: 7,
                pending: vec![0],
                next_id: 1,
                submitted: 1,
                completed: 0,
                rejected: 0,
                failed: 0,
                fingerprint: 0xfeed,
                chain: 0xbeef,
                metrics: Default::default(),
            })),
        ];
        let mut kinds: Vec<&str> = evs.iter().map(|e| e.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), evs.len());
    }

    #[test]
    fn request_id_only_for_per_request_events() {
        assert_eq!(EventBody::Enqueue { id: 7, depth: 0 }.request_id(),
                   Some(7));
        assert_eq!(EventBody::BatchFormed { ids: vec![7] }.request_id(),
                   None);
    }
}
