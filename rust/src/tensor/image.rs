//! Image output substrate: write generated `(1, H, W, 3)` tensors in
//! [-1, 1] as binary PPM (P6) — no image crates in the vendor set, and an
//! edge generation engine must be able to emit its product.

use super::Tensor;
use std::io::Write;
use std::path::Path;

/// Map [-1, 1] to [0, 255] with clamping.
#[inline]
fn to_u8(v: f32) -> u8 {
    (((v.clamp(-1.0, 1.0) + 1.0) * 0.5) * 255.0).round() as u8
}

/// Write an NHWC `(1, H, W, 3)` tensor as a binary PPM file.
pub fn write_ppm(img: &Tensor, path: &Path) -> std::io::Result<()> {
    let (b, h, w, c) = img.dims4();
    assert_eq!((b, c), (1, 3), "write_ppm wants (1, H, W, 3)");
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P6\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = img.data().iter().map(|&v| to_u8(v)).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Tile a batch `(B, H, W, 3)` into one `(1, rows·H, cols·W, 3)` montage.
pub fn montage(imgs: &Tensor, cols: usize) -> Tensor {
    let (b, h, w, c) = imgs.dims4();
    assert_eq!(c, 3);
    let cols = cols.max(1).min(b);
    let rows = b.div_ceil(cols);
    let mut out = Tensor::zeros(&[1, rows * h, cols * w, c]);
    for bi in 0..b {
        let (ry, cx) = (bi / cols, bi % cols);
        for y in 0..h {
            let src = ((bi * h + y) * w) * c;
            let dst = (((ry * h + y) * cols * w) + cx * w) * c;
            out.data_mut()[dst..dst + w * c]
                .copy_from_slice(&imgs.data()[src..src + w * c]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn ppm_round_trip_header_and_size() {
        let mut rng = Rng::new(1);
        let img = Tensor::randn(&[1, 8, 6, 3], &mut rng).tanh();
        let path = std::env::temp_dir().join("huge2_test.ppm");
        write_ppm(&img, &path).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P6\n6 8\n255\n"));
        assert_eq!(data.len(), b"P6\n6 8\n255\n".len() + 8 * 6 * 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn value_mapping() {
        assert_eq!(to_u8(-1.0), 0);
        assert_eq!(to_u8(1.0), 255);
        assert_eq!(to_u8(0.0), 128);
        assert_eq!(to_u8(-5.0), 0); // clamped
    }

    #[test]
    fn montage_tiles() {
        let mut imgs = Tensor::zeros(&[4, 2, 2, 3]);
        // mark each image's (0,0,0) with its index
        for bi in 0..4 {
            let off = bi * 2 * 2 * 3;
            imgs.data_mut()[off] = bi as f32;
        }
        let m = montage(&imgs, 2);
        assert_eq!(m.shape(), &[1, 4, 4, 3]);
        assert_eq!(m.at(&[0, 0, 0, 0]), 0.0);
        assert_eq!(m.at(&[0, 0, 2, 0]), 1.0);
        assert_eq!(m.at(&[0, 2, 0, 0]), 2.0);
        assert_eq!(m.at(&[0, 2, 2, 0]), 3.0);
    }
}
