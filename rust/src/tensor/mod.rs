//! Dense f32 tensor substrate.
//!
//! Activations are **NHWC** (`[batch, height, width, channels]`) and
//! kernels **HWIO** (`[r, s, c_in, c_out]`) throughout the crate — the
//! same canonical convention as the python oracle (`ref.py`), so numeric
//! cross-checks between layers are byte-comparable.
//!
//! The paper's untangling step prefers layouts where C (inputs) and N
//! (kernels) are innermost/contiguous ("C×N×R×S kernels, C×H×W inputs",
//! §4.2); NHWC/HWIO give exactly that contiguity on the dimensions the
//! untangled GEMMs stream over.

pub mod image;

use crate::rng::Rng;
use std::fmt;

/// A dense, row-major f32 tensor with dynamic rank.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ---------------------------------------------------------------- ctors

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Standard-normal entries scaled like the python init (0.02·N(0,1)
    /// is applied by callers that want DCGAN-style weights).
    pub fn randn(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.next_normal()).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    // ------------------------------------------------------------ accessors

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Flat offset of a multi-index (row-major).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < d, "index {ix} out of bound {d} at dim {i}");
            off = off * d + ix;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    // ----------------------------------------------------------- transforms

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// NHWC -> NCHW copy (for the DarkNet-layout baseline experiments).
    pub fn nhwc_to_nchw(&self) -> Tensor {
        let (b, h, w, c) = self.dims4();
        let mut out = Tensor::zeros(&[b, c, h, w]);
        for bi in 0..b {
            for hi in 0..h {
                for wi in 0..w {
                    for ci in 0..c {
                        let v = self.data[((bi * h + hi) * w + wi) * c + ci];
                        out.data[((bi * c + ci) * h + hi) * w + wi] = v;
                    }
                }
            }
        }
        out
    }

    /// NCHW -> NHWC copy.
    pub fn nchw_to_nhwc(&self) -> Tensor {
        let (b, c, h, w) = self.dims4();
        let mut out = Tensor::zeros(&[b, h, w, c]);
        for bi in 0..b {
            for ci in 0..c {
                for hi in 0..h {
                    for wi in 0..w {
                        let v = self.data[((bi * c + ci) * h + hi) * w + wi];
                        out.data[((bi * h + hi) * w + wi) * c + ci] = v;
                    }
                }
            }
        }
        out
    }

    /// Zero-pad spatial dims of an NHWC tensor:
    /// `(lo_h, hi_h, lo_w, hi_w)`.
    pub fn pad_spatial(&self, lo_h: usize, hi_h: usize, lo_w: usize,
                       hi_w: usize) -> Tensor {
        let (b, h, w, c) = self.dims4();
        let mut out = Tensor::zeros(&[b, h + lo_h + hi_h, w + lo_w + hi_w, c]);
        let wo = w + lo_w + hi_w;
        for bi in 0..b {
            for hi in 0..h {
                let src = ((bi * h + hi) * w) * c;
                let dst = ((bi * (h + lo_h + hi_h) + hi + lo_h) * wo + lo_w) * c;
                out.data[dst..dst + w * c]
                    .copy_from_slice(&self.data[src..src + w * c]);
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    pub fn tanh(&self) -> Tensor {
        self.map(f32::tanh)
    }

    pub fn leaky_relu(&self, a: f32) -> Tensor {
        self.map(|x| if x >= 0.0 { x } else { a * x })
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    // ----------------------------------------------------------- comparison

    /// Max |a - b| over all elements (shape must match).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mixed absolute/relative closeness, the rust analogue of
    /// `np.testing.assert_allclose(atol=tol, rtol=tol)`.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= tol + tol * b.abs().max(a.abs()))
    }

    /// Deterministic checksum (order-dependent FNV over bit patterns) for
    /// cross-layer regression pinning.
    pub fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &v in &self.data {
            // Canonicalise -0.0 so equal tensors hash equal.
            let bits = if v == 0.0 { 0 } else { v.to_bits() };
            h ^= bits as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }

    // -------------------------------------------------------------- helpers

    /// Unpack a rank-4 shape.
    #[inline]
    pub fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected rank-4, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    /// Unpack a rank-2 shape.
    #[inline]
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }
}

/// In-place slice relu — the pooled forwards' analogue of
/// [`Tensor::relu`]. One definition shared by the gan/seg slice paths,
/// so activation semantics cannot drift from the tensor path (which
/// would silently break pooled-vs-fresh bit-identity).
pub fn relu_inplace(xs: &mut [f32]) {
    for v in xs {
        *v = v.max(0.0);
    }
}

/// In-place slice tanh — see [`relu_inplace`].
pub fn tanh_inplace(xs: &mut [f32]) {
    for v in xs {
        *v = v.tanh();
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn layout_round_trip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[2, 3, 4, 5], &mut rng);
        let back = t.nhwc_to_nchw().nchw_to_nhwc();
        assert_eq!(t, back);
    }

    #[test]
    fn pad_spatial_places_content() {
        let t = Tensor::full(&[1, 2, 2, 1], 7.0);
        let p = t.pad_spatial(1, 2, 3, 0);
        assert_eq!(p.shape(), &[1, 5, 5, 1]);
        assert_eq!(p.at(&[0, 1, 3, 0]), 7.0);
        assert_eq!(p.at(&[0, 0, 3, 0]), 0.0);
        let total: f32 = p.data().iter().sum();
        assert_eq!(total, 4.0 * 7.0);
    }

    #[test]
    fn allclose_tolerates_small_error() {
        let a = Tensor::full(&[4], 1.0);
        let mut b = a.clone();
        b.data_mut()[2] = 1.0 + 5e-7;
        assert!(a.allclose(&b, 1e-5));
        b.data_mut()[2] = 1.1;
        assert!(!a.allclose(&b, 1e-5));
    }

    #[test]
    fn checksum_sensitive_to_order() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![2.0, 1.0]);
        assert_ne!(a.checksum(), b.checksum());
        assert_eq!(a.checksum(), a.clone().checksum());
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
