//! Byte-accurate access-stream replay of both deconvolution engines.
//!
//! `trace_layer` walks the *exact* loop structure of
//! [`crate::deconv::baseline`] / [`crate::deconv::huge2`] but, instead of
//! multiplying floats, feeds every load/store span into the cache
//! [`Hierarchy`]. This yields the paper's Fig.-8 metric (total memory
//! accesses, plus the cache/DRAM breakdown the paper's argument implies)
//! without needing ARM performance counters.
//!
//! GEMM inner-loop register traffic is excluded for both engines
//! identically; operand-panel traffic is replayed with the real blocked
//! reuse pattern (A panel re-read per N-panel, C re-touched per K-panel),
//! so what remains is precisely the *algorithmic* difference: the inflated
//! tensor, the column matrix, and the access coalescing.

use crate::config::LayerConfig;
use crate::deconv::{axis_pattern, polyphase_len, DilatedParams};

use super::cache::{Hierarchy, HierarchyStats};

const F: u64 = 4; // bytes per f32

// GEMM blocking constants mirrored from crate::gemm.
const KC: u64 = 256;
const NC: u64 = 1024;

/// Which engine's access stream to replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Baseline,
    Huge2,
    /// Kernel-segregated fused form (`deconv::segregated`): one
    /// per-pattern im2col + one fused GEMM instead of per-tap GEMMs.
    /// Dilated convs have no inserted zeros to segregate, so on the
    /// dilated path this replays the HUGE² stream (mirroring
    /// `plan::resolve_dilated`).
    Segregated,
}

/// Result of one replay.
#[derive(Debug, Clone, Copy, Default)]
pub struct AccessStats {
    pub hierarchy: HierarchyStats,
    /// Multiply-accumulates the engine performs (incl. zero-MACs for the
    /// baseline — that is the point).
    pub macs: u64,
    /// DRAM bytes (L2-miss lines × 64).
    pub dram_bytes: u64,
}

impl AccessStats {
    /// Component-wise sum — aggregates shard streams into a total.
    pub fn merge(&self, o: &AccessStats) -> AccessStats {
        AccessStats {
            hierarchy: HierarchyStats {
                scalar_accesses: self.hierarchy.scalar_accesses
                    + o.hierarchy.scalar_accesses,
                l1_hits: self.hierarchy.l1_hits + o.hierarchy.l1_hits,
                l1_misses: self.hierarchy.l1_misses
                    + o.hierarchy.l1_misses,
                l2_hits: self.hierarchy.l2_hits + o.hierarchy.l2_hits,
                l2_misses: self.hierarchy.l2_misses
                    + o.hierarchy.l2_misses,
            },
            macs: self.macs + o.macs,
            dram_bytes: self.dram_bytes + o.dram_bytes,
        }
    }
}

/// The access streams of one layer split the way the multi-threaded
/// engines split work (the autotuner's scoring unit, DESIGN.md §15).
#[derive(Debug, Clone, Copy)]
pub struct LayerTrace {
    /// Aggregate stream (serial portion + every shard) — the
    /// bytes-moved number the plan table reports.
    pub total: AccessStats,
    /// The single-threaded portion (polyphase scatter for MT transpose;
    /// the whole stream when `shards == 1`).
    pub serial: AccessStats,
    /// The heaviest shard, replayed on its own fresh hierarchy (the
    /// conservative no-inter-shard-reuse model of the critical path).
    /// Zero when `shards == 1`.
    pub shard_max: AccessStats,
    /// Worker shards the engine would spawn (1 = single-threaded).
    pub shards: usize,
}

impl LayerTrace {
    fn single(stats: AccessStats) -> LayerTrace {
        LayerTrace {
            total: stats,
            serial: stats,
            shard_max: AccessStats::default(),
            shards: 1,
        }
    }
}

/// Replay one Table-1 layer (batch 1) on a fresh TX2-like hierarchy.
pub fn trace_layer(layer: &LayerConfig, engine: EngineKind) -> AccessStats {
    let mut h = Hierarchy::tx2();
    let macs = match engine {
        EngineKind::Baseline => trace_transpose_baseline(layer, &mut h),
        EngineKind::Huge2 => trace_transpose_huge2(layer, &mut h),
        EngineKind::Segregated => trace_transpose_segregated(layer, &mut h),
    };
    let stats = h.stats();
    AccessStats { hierarchy: stats, macs, dram_bytes: stats.dram_bytes(64) }
}

/// Disjoint, page-aligned base addresses for the tensors of one layer.
struct Mem {
    x: u64,
    inflated: u64,
    col: u64,
    k: u64,
    out: u64,
    scratch: u64,
}

fn layout(layer: &LayerConfig) -> Mem {
    let (xi, ki, oi) = layer.sizes();
    let st = layer.stride;
    let (lo, hi) = layer.deconv_params().inflate_pad(layer.k);
    let ip = (layer.h - 1) * st + 1 + lo + hi;
    let inflated_elems = (ip * ip * layer.c_in) as u64;
    let ho = layer.h_out();
    let col_elems = (ho * ho * layer.k * layer.k * layer.c_in) as u64;
    let align = |x: u64| (x + 4095) / 4096 * 4096;
    let x = 0;
    let inflated = align(x + xi as u64 * F);
    let col = align(inflated + inflated_elems * F);
    let k = align(col + col_elems * F);
    let out = align(k + ki as u64 * F);
    let scratch = align(out + oi as u64 * F);
    Mem { x, inflated, col, k, out, scratch }
}

/// Replay the blocked-GEMM operand traffic: C[m×n] += A[m×k]·B[k×n].
fn trace_gemm(h: &mut Hierarchy, a: u64, b: u64, c: u64, m: u64, k: u64,
              n: u64) {
    trace_gemm_blocked(h, a, b, c, m, k, n, KC, NC);
}

/// [`trace_gemm`] with explicit cache-blocking factors — the autotuner
/// scores candidate `gemm::Tile`s by replaying the same operand traffic
/// under a different (kc, nc) split.
#[allow(clippy::too_many_arguments)]
fn trace_gemm_blocked(h: &mut Hierarchy, a: u64, b: u64, c: u64, m: u64,
                      k: u64, n: u64, kc: u64, nc: u64) {
    let n_panels = n.div_ceil(nc.max(1));
    let k_panels = k.div_ceil(kc.max(1));
    // A is re-read once per N panel (packing pass).
    for _ in 0..n_panels {
        for row in 0..m {
            h.touch_span(a + row * k * F, k * F);
        }
    }
    // B is packed once per (N,K) panel.
    for _ in 0..1 {
        for row in 0..k {
            h.touch_span(b + row * n * F, n * F);
        }
    }
    // C tiles are re-touched once per K panel (read-modify-write).
    for _ in 0..k_panels {
        for row in 0..m {
            h.touch_span(c + row * n * F, n * F);
        }
    }
}

/// Naive engine: inflate -> im2col -> one big GEMM. Returns MACs.
fn trace_transpose_baseline(layer: &LayerConfig, h: &mut Hierarchy) -> u64 {
    let mem = layout(layer);
    let (hh, c, n, r) = (layer.h as u64, layer.c_in as u64,
                         layer.c_out as u64, layer.k as u64);
    let st = layer.stride as u64;
    let (lo, _hi) = layer.deconv_params().inflate_pad(layer.k);
    let lo = lo as u64;
    let ho = layer.h_out() as u64;
    let ip = {
        let (l, hi2) = layer.deconv_params().inflate_pad(layer.k);
        (layer.h as u64 - 1) * st + 1 + l as u64 + hi2 as u64
    };

    // 1. zero-fill the inflated tensor (row spans), then scatter x into it
    for row in 0..ip {
        h.touch_span(mem.inflated + row * ip * c * F, ip * c * F);
    }
    for iy in 0..hh {
        h.touch_span(mem.x + iy * hh * c * F, hh * c * F); // read x row
        for ix in 0..hh {
            let dst = ((lo + iy * st) * ip + lo + ix * st) * c;
            h.touch_span(mem.inflated + dst * F, c * F); // strided write
        }
    }
    // 2. im2col over the inflated tensor: per output pos, per tap row,
    //    one contiguous (s·c) read + one contiguous write to col
    let taps_row = r; // kernel rows
    let rowspan = r * c; // s*c contiguous per tap row
    for oy in 0..ho {
        for ox in 0..ho {
            let col_row = (oy * ho + ox) * r * r * c;
            for m in 0..taps_row {
                let src = ((oy + m) * ip + ox) * c;
                h.touch_span(mem.inflated + src * F, rowspan * F);
                h.touch_span(mem.col + (col_row + m * r * c) * F,
                             rowspan * F);
            }
        }
    }
    // 3. GEMM: (ho·wo, r·s·c) @ (r·s·c, n)
    trace_gemm(h, mem.col, mem.k, mem.out, ho * ho, r * r * c, n);
    ho * ho * r * r * c * n
}

/// HUGE² engine: decompose -> per-pattern tap GEMMs on input views ->
/// polyphase scatter. Returns (effective) MACs.
fn trace_transpose_huge2(layer: &LayerConfig, h: &mut Hierarchy) -> u64 {
    let mem = layout(layer);
    let (hh, c, n, r) = (layer.h as u64, layer.c_in as u64,
                         layer.c_out as u64, layer.k);
    let st = layer.stride;
    let ho = layer.h_out();
    let mut macs = 0u64;

    // Kernel decomposition is a one-time model-load step (the serving
    // engine pre-decomposes; see `deconv::huge2::conv2d_transpose_with`),
    // so it is not part of the per-inference access stream — the baseline
    // likewise gets its HWIO kernel layout for free.
    let sub_k = mem.scratch;
    let sub_out = mem.scratch + r as u64 * r as u64 * c * n * F + 4096;

    // 2. per pattern, per output row, per tap: contiguous row-view GEMM
    for phi_y in 0..st {
        let ay = axis_pattern(r, st, layer.pad, phi_y);
        let qy = polyphase_len(ho, st, phi_y) as u64;
        for phi_x in 0..st {
            let ax = axis_pattern(r, st, layer.pad, phi_x);
            let qx = polyphase_len(ho, st, phi_x) as u64;
            if qy == 0 || qx == 0 || ay.taps == 0 || ax.taps == 0 {
                continue;
            }
            // Tap loops outer (matching deconv::huge2): the (C, N) tap
            // weight panel is streamed ONCE per tap and stays L2-resident
            // across the q_y row GEMMs, exactly like the blocked GEMM's
            // B-panel reuse the baseline trace is credited with.
            for t_y in 0..ay.taps as u64 {
                for t_x in 0..ax.taps as u64 {
                    // B: (c, n) tap weights, contiguous, once per tap
                    let tap = (t_y * ax.taps as u64 + t_x) * c * n;
                    h.touch_span(sub_k + tap * F, c * n * F);
                    for q_y in 0..qy {
                        let iy = q_y as i64 + t_y as i64 + ay.delta as i64;
                        let iy = iy.clamp(0, hh as i64 - 1) as u64;
                        // A: contiguous (qx·c) input row view
                        let a0 = (iy * hh) * c; // row base (t_x off ± pad)
                        h.touch_span(mem.x + a0 * F, qx * c * F);
                        // C: sub-out row, read-modify-write
                        h.touch_span(sub_out + q_y * qx * n * F,
                                     qx * n * F);
                        h.touch_span(sub_out + q_y * qx * n * F,
                                     qx * n * F);
                        macs += qx * c * n;
                    }
                }
            }
            // 3. polyphase scatter: read sub rows, strided n-span writes
            for q_y in 0..qy {
                h.touch_span(sub_out + q_y * qx * n * F, qx * n * F);
                let oy = phi_y as u64 + q_y * st as u64;
                for q_x in 0..qx {
                    let ox = phi_x as u64 + q_x * st as u64;
                    h.touch_span(mem.out + (oy * ho as u64 + ox) * n * F,
                                 n * F);
                }
            }
        }
    }
    macs
}

/// Kernel-segregated engine (`deconv::segregated`): per pattern, one
/// fused im2col gather into the column matrix, ONE (qy·qx, ty·tx·c) GEMM
/// against the pattern's packed sub-kernel, then the polyphase scatter.
/// Returns (effective) MACs — identical to HUGE²'s; the difference is
/// purely in the access stream (bigger column matrix, fewer GEMM
/// set-ups, deeper K per GEMM).
fn trace_transpose_segregated(layer: &LayerConfig, h: &mut Hierarchy)
                              -> u64 {
    let mem = layout(layer);
    let (hh, c, n, r) = (layer.h as u64, layer.c_in as u64,
                         layer.c_out as u64, layer.k);
    let st = layer.stride;
    let ho = layer.h_out();
    let mut macs = 0u64;
    // Packed per-pattern sub-kernels are a model-load artifact (SegPack),
    // so — like the HUGE² decomposition — they live in the scratch region
    // and their construction is not part of the per-inference stream.
    let sub_k = mem.scratch;
    let sub_out = mem.scratch + r as u64 * r as u64 * c * n * F + 4096;
    for phi_y in 0..st {
        let ay = axis_pattern(r, st, layer.pad, phi_y);
        let qy = polyphase_len(ho, st, phi_y) as u64;
        for phi_x in 0..st {
            let ax = axis_pattern(r, st, layer.pad, phi_x);
            let qx = polyphase_len(ho, st, phi_x) as u64;
            if qy == 0 || qx == 0 || ay.taps == 0 || ax.taps == 0 {
                continue;
            }
            let row_tx = ax.taps as u64 * c; // one tap-row gather span
            let kk = ay.taps as u64 * row_tx; // fused GEMM depth
            // fused im2col: per output row, per tap row, per output col:
            // contiguous (tx·c) read from the input row + write to col
            for q_y in 0..qy {
                for t_y in 0..ay.taps as u64 {
                    let iy = q_y as i64 + t_y as i64 + ay.delta as i64;
                    let iy = iy.clamp(0, hh as i64 - 1) as u64;
                    for q_x in 0..qx {
                        let src = (iy * hh + q_x) * c;
                        h.touch_span(mem.x + src * F, row_tx * F);
                        let crow = (q_y * qx + q_x) * kk + t_y * row_tx;
                        h.touch_span(mem.col + crow * F, row_tx * F);
                    }
                }
            }
            // ONE fused GEMM: (qy·qx, kk) @ (kk, n)
            trace_gemm(h, mem.col, sub_k, sub_out, qy * qx, kk, n);
            macs += qy * qx * kk * n;
            // polyphase scatter (same as HUGE²)
            for q_y in 0..qy {
                h.touch_span(sub_out + q_y * qx * n * F, qx * n * F);
                let oy = phi_y as u64 + q_y * st as u64;
                for q_x in 0..qx {
                    let ox = phi_x as u64 + q_x * st as u64;
                    h.touch_span(mem.out + (oy * ho as u64 + ox) * n * F,
                                 n * F);
                }
            }
        }
    }
    macs
}

/// Replay a transpose-conv layer under `engine` × `threads` and split the
/// stream the way the MT engines split work: patterns are chunked over
/// `threads.max(1).min(stride².max(1))` shards (mirroring
/// `deconv::parallel::conv2d_transpose_mt` / `segregated::
/// transpose_mt_into`), each shard replays its patterns' GEMM work on a
/// *fresh* hierarchy (conservative: no inter-shard cache reuse), and the
/// polyphase scatter stays serial. Baseline has no MT path, so it is
/// always a single shard. Border-pad assembly is excluded on all paths
/// (identical across HUGE²/Segregated variants, negligible vs the
/// baseline's modeled inflate).
pub fn trace_transpose(layer: &LayerConfig, engine: EngineKind,
                       threads: usize) -> LayerTrace {
    let st = layer.stride;
    let n_patterns = st * st;
    let shards = match engine {
        EngineKind::Baseline => 1,
        _ => threads.max(1).min(n_patterns.max(1)),
    };
    if shards <= 1 {
        return LayerTrace::single(trace_layer(layer, engine));
    }
    let mem = layout(layer);
    let (hh, c, n, r) = (layer.h as u64, layer.c_in as u64,
                         layer.c_out as u64, layer.k);
    let ho = layer.h_out();
    let max_qy = (0..st).map(|p| polyphase_len(ho, st, p)).max()
        .unwrap_or(0) as u64;
    let max_sub = max_qy * max_qy * n; // square layers: max_qx == max_qy
    let sub_k = mem.scratch;
    let sub0 = mem.scratch + r as u64 * r as u64 * c * n * F + 4096;
    let pats: Vec<(usize, usize)> = (0..st)
        .flat_map(|py| (0..st).map(move |px| (py, px)))
        .collect();
    let chunk = n_patterns.div_ceil(shards);
    let mut shard_stats: Vec<AccessStats> = Vec::new();
    for si in 0..shards {
        let lo = si * chunk;
        if lo >= n_patterns {
            break;
        }
        let hi = (lo + chunk).min(n_patterns);
        let mut h = Hierarchy::tx2();
        let mut macs = 0u64;
        for (off, &(phi_y, phi_x)) in pats[lo..hi].iter().enumerate() {
            let gi = (lo + off) as u64;
            let ay = axis_pattern(r, st, layer.pad, phi_y);
            let ax = axis_pattern(r, st, layer.pad, phi_x);
            let qy = polyphase_len(ho, st, phi_y) as u64;
            let qx = polyphase_len(ho, st, phi_x) as u64;
            if qy == 0 || qx == 0 || ay.taps == 0 || ax.taps == 0 {
                continue;
            }
            // each shard checked out its own sub-out slab
            let sub = sub0 + gi * max_sub * F;
            match engine {
                EngineKind::Huge2 => {
                    h.touch_span(sub, qy * qx * n * F); // checkout_zeroed
                    for t_y in 0..ay.taps as u64 {
                        for t_x in 0..ax.taps as u64 {
                            let tap = (t_y * ax.taps as u64 + t_x) * c * n;
                            h.touch_span(sub_k + tap * F, c * n * F);
                            for q_y in 0..qy {
                                let iy = q_y as i64 + t_y as i64
                                    + ay.delta as i64;
                                let iy = iy.clamp(0, hh as i64 - 1) as u64;
                                h.touch_span(mem.x + (iy * hh) * c * F,
                                             qx * c * F);
                                h.touch_span(sub + q_y * qx * n * F,
                                             qx * n * F);
                                h.touch_span(sub + q_y * qx * n * F,
                                             qx * n * F);
                                macs += qx * c * n;
                            }
                        }
                    }
                }
                EngineKind::Segregated => {
                    let row_tx = ax.taps as u64 * c;
                    let kk = ay.taps as u64 * row_tx;
                    for q_y in 0..qy {
                        for t_y in 0..ay.taps as u64 {
                            let iy = q_y as i64 + t_y as i64
                                + ay.delta as i64;
                            let iy = iy.clamp(0, hh as i64 - 1) as u64;
                            for q_x in 0..qx {
                                let src = (iy * hh + q_x) * c;
                                h.touch_span(mem.x + src * F, row_tx * F);
                                let crow = (q_y * qx + q_x) * kk
                                    + t_y * row_tx;
                                h.touch_span(mem.col + crow * F,
                                             row_tx * F);
                            }
                        }
                    }
                    trace_gemm(&mut h, mem.col, sub_k, sub, qy * qx, kk,
                               n);
                    macs += qy * qx * kk * n;
                }
                EngineKind::Baseline => unreachable!(),
            }
        }
        let s = h.stats();
        shard_stats.push(AccessStats {
            hierarchy: s,
            macs,
            dram_bytes: s.dram_bytes(64),
        });
    }
    // serial tail: the main thread scatters every pattern's sub-out
    let mut sh = Hierarchy::tx2();
    for (gi, &(phi_y, phi_x)) in pats.iter().enumerate() {
        let ay = axis_pattern(r, st, layer.pad, phi_y);
        let ax = axis_pattern(r, st, layer.pad, phi_x);
        let qy = polyphase_len(ho, st, phi_y) as u64;
        let qx = polyphase_len(ho, st, phi_x) as u64;
        if qy == 0 || qx == 0 || ay.taps == 0 || ax.taps == 0 {
            continue;
        }
        let sub = sub0 + gi as u64 * max_sub * F;
        for q_y in 0..qy {
            sh.touch_span(sub + q_y * qx * n * F, qx * n * F);
            let oy = phi_y as u64 + q_y * st as u64;
            for q_x in 0..qx {
                let ox = phi_x as u64 + q_x * st as u64;
                sh.touch_span(mem.out + (oy * ho as u64 + ox) * n * F,
                              n * F);
            }
        }
    }
    let ss = sh.stats();
    let serial = AccessStats {
        hierarchy: ss,
        macs: 0,
        dram_bytes: ss.dram_bytes(64),
    };
    finish_mt(serial, shard_stats)
}

/// Assemble a [`LayerTrace`] from the serial stream + per-shard streams.
/// The critical-path shard is picked by `macs + scalar_accesses` — both
/// are proportional to per-shard work, and chunked pattern splits are
/// uneven when `stride² % shards != 0`.
fn finish_mt(serial: AccessStats, shards: Vec<AccessStats>) -> LayerTrace {
    let shard_max = shards
        .iter()
        .copied()
        .max_by_key(|s| s.macs + s.hierarchy.scalar_accesses)
        .unwrap_or_default();
    let total = shards.iter().fold(serial, |acc, s| acc.merge(s));
    LayerTrace { total, serial, shard_max, shards: shards.len().max(1) }
}

/// Dilated-conv access replay (for the segmentation workloads).
pub fn trace_dilated(h_in: usize, c: usize, n: usize, r: usize,
                     p: &DilatedParams, engine: EngineKind) -> AccessStats {
    let mut h = Hierarchy::tx2();
    let ho = p.out_size(h_in, r) as u64;
    let (hh, c, n, r) = (h_in as u64, c as u64, n as u64, r as u64);
    let er = ((r - 1) * p.dilation as u64) + 1;
    let align = |x: u64| (x + 4095) / 4096 * 4096;
    let x0 = 0u64;
    let k0 = align(hh * hh * c * F);
    let dk0 = align(k0 + r * r * c * n * F);
    let col0 = align(dk0 + er * er * c * n * F);
    let out0 = align(col0 + ho * ho * er * er * c * F);
    let macs;
    match engine {
        EngineKind::Baseline => {
            // materialise the dilated kernel (zeros included)
            h.touch_span(k0, r * r * c * n * F);
            h.touch_span(dk0, er * er * c * n * F);
            // im2col over the effective window + GEMM
            for oy in 0..ho {
                for ox in 0..ho {
                    let crow = (oy * ho + ox) * er * er * c;
                    for m in 0..er {
                        let src = ((oy + m) * hh + ox) * c;
                        h.touch_span(x0 + src * F, er * c * F);
                        h.touch_span(col0 + (crow + m * er * c) * F,
                                     er * c * F);
                    }
                }
            }
            trace_gemm(&mut h, col0, dk0, out0, ho * ho, er * er * c, n);
            macs = ho * ho * er * er * c * n;
        }
        EngineKind::Huge2 | EngineKind::Segregated => {
            // tap-outer order (matching deconv::dilated): weights once/tap
            trace_dilated_rows(&mut h, hh, c, n, r, p, x0, k0, out0, ho,
                               0, ho);
            macs = ho * ho * r * r * c * n;
        }
    }
    let stats = h.stats();
    AccessStats { hierarchy: stats, macs, dram_bytes: stats.dram_bytes(64) }
}

/// The HUGE² dilated stream restricted to output rows `[oy0, oy1)` —
/// exactly the band one worker of `deconv::parallel::dilated_mt_into`
/// executes. `trace_dilated` replays `[0, ho)`; MT scoring replays each
/// band on its own fresh hierarchy.
#[allow(clippy::too_many_arguments)]
fn trace_dilated_rows(h: &mut Hierarchy, hh: u64, c: u64, n: u64, r: u64,
                      p: &DilatedParams, x0: u64, k0: u64, out0: u64,
                      ho: u64, oy0: u64, oy1: u64) {
    for t_r in 0..r {
        for t_c in 0..r {
            let tap = (t_r * r + t_c) * c * n;
            h.touch_span(k0 + tap * F, c * n * F);
            for oy in oy0..oy1 {
                let iy = oy * p.stride as u64 + t_r * p.dilation as u64;
                let a0 = (iy.min(hh - 1) * hh) * c;
                if p.stride == 1 {
                    h.touch_span(x0 + a0 * F, ho * c * F);
                } else {
                    h.touch_strided(x0 + a0 * F, ho,
                                    p.stride as u64 * c * F, c * F);
                }
                h.touch_span(out0 + oy * ho * n * F, ho * n * F);
                h.touch_span(out0 + oy * ho * n * F, ho * n * F);
                let _ = t_c;
            }
        }
    }
}

/// Replay a dilated-conv layer under `engine` × `threads`. The MT
/// engine shards output rows over `threads.min(ho.max(1))` bands
/// (mirroring `deconv::parallel::dilated_mt_into`); each band re-streams
/// the tap weights on its own fresh hierarchy. Baseline has no MT path.
pub fn trace_dilated_threads(h_in: usize, c: usize, n: usize, r: usize,
                             p: &DilatedParams, engine: EngineKind,
                             threads: usize) -> LayerTrace {
    let ho = p.out_size(h_in, r);
    let shards = match engine {
        EngineKind::Baseline => 1,
        _ => threads.max(1).min(ho.max(1)),
    };
    if shards <= 1 {
        return LayerTrace::single(trace_dilated(h_in, c, n, r, p, engine));
    }
    let ho = ho as u64;
    let (hh, c, n, r) = (h_in as u64, c as u64, n as u64, r as u64);
    let er = ((r - 1) * p.dilation as u64) + 1;
    let align = |x: u64| (x + 4095) / 4096 * 4096;
    let x0 = 0u64;
    let k0 = align(hh * hh * c * F);
    let dk0 = align(k0 + r * r * c * n * F);
    let col0 = align(dk0 + er * er * c * n * F);
    let out0 = align(col0 + ho * ho * er * er * c * F);
    let rows_per = ho.div_ceil(shards as u64);
    let mut shard_stats = Vec::new();
    for si in 0..shards as u64 {
        let oy0 = si * rows_per;
        if oy0 >= ho {
            break;
        }
        let oy1 = (oy0 + rows_per).min(ho);
        let mut h = Hierarchy::tx2();
        trace_dilated_rows(&mut h, hh, c, n, r, p, x0, k0, out0, ho, oy0,
                           oy1);
        let s = h.stats();
        shard_stats.push(AccessStats {
            hierarchy: s,
            macs: (oy1 - oy0) * ho * r * r * c * n,
            dram_bytes: s.dram_bytes(64),
        });
    }
    // workers write their out bands directly: no serial scatter
    finish_mt(AccessStats::default(), shard_stats)
}

/// Replay one standalone blocked GEMM (the plan's Project step) under
/// explicit (kc, nc) blocking — the autotuner's tile-candidate score.
pub fn trace_gemm_shape(m: usize, k: usize, n: usize, kc: usize,
                        nc: usize) -> AccessStats {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    let align = |x: u64| (x + 4095) / 4096 * 4096;
    let a0 = 0u64;
    let b0 = align(m * k * F);
    let c0 = align(b0 + k * n * F);
    let mut h = Hierarchy::tx2();
    trace_gemm_blocked(&mut h, a0, b0, c0, m, k, n, kc as u64, nc as u64);
    let stats = h.stats();
    AccessStats {
        hierarchy: stats,
        macs: m * k * n,
        dram_bytes: stats.dram_bytes(64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::table1;

    #[test]
    fn huge2_reduces_scalar_accesses_on_every_layer() {
        for layer in table1() {
            let base = trace_layer(&layer, EngineKind::Baseline);
            let fast = trace_layer(&layer, EngineKind::Huge2);
            assert!(fast.hierarchy.scalar_accesses
                        < base.hierarchy.scalar_accesses,
                    "{}: {} !< {}", layer.name,
                    fast.hierarchy.scalar_accesses,
                    base.hierarchy.scalar_accesses);
            assert!(fast.macs < base.macs, "{}", layer.name);
        }
    }

    #[test]
    fn reduction_in_paper_band() {
        // paper: 30-70% access reduction by untangling (+ decomposition)
        for layer in table1() {
            let base = trace_layer(&layer, EngineKind::Baseline);
            let fast = trace_layer(&layer, EngineKind::Huge2);
            let red = 1.0
                - fast.hierarchy.scalar_accesses as f64
                / base.hierarchy.scalar_accesses as f64;
            assert!(red > 0.25 && red < 0.95,
                    "{}: reduction {red:.2}", layer.name);
        }
    }

    #[test]
    fn mac_ratio_close_to_stride_squared() {
        let layer = &table1()[2];
        let base = trace_layer(layer, EngineKind::Baseline);
        let fast = trace_layer(layer, EngineKind::Huge2);
        let ratio = base.macs as f64 / fast.macs as f64;
        assert!(ratio > 3.0 && ratio < 4.5, "{ratio}");
    }

    #[test]
    fn segregated_stream_between_engines() {
        // same effective MACs as HUGE² (it computes the same products),
        // fewer scalar accesses than the baseline on every Table-1 layer
        for layer in table1() {
            let base = trace_layer(&layer, EngineKind::Baseline);
            let fast = trace_layer(&layer, EngineKind::Huge2);
            let seg = trace_layer(&layer, EngineKind::Segregated);
            assert_eq!(seg.macs, fast.macs, "{}", layer.name);
            assert!(seg.hierarchy.scalar_accesses
                        < base.hierarchy.scalar_accesses,
                    "{}", layer.name);
            // and the streams really differ (col-matrix traffic)
            assert_ne!(seg.hierarchy.scalar_accesses,
                       fast.hierarchy.scalar_accesses, "{}", layer.name);
        }
    }

    #[test]
    fn mt_transpose_shards_conserve_macs() {
        let layer = &table1()[2];
        for kind in [EngineKind::Huge2, EngineKind::Segregated] {
            let st = trace_layer(layer, kind);
            let mt = trace_transpose(layer, kind, 4);
            assert_eq!(mt.shards, 4.min(layer.stride * layer.stride));
            assert_eq!(mt.total.macs, st.macs);
            assert!(mt.shard_max.macs > 0);
            assert!(mt.shard_max.macs <= mt.total.macs);
            assert!(mt.serial.hierarchy.scalar_accesses > 0); // scatter
        }
        // baseline has no MT path: always one shard
        let b = trace_transpose(layer, EngineKind::Baseline, 4);
        assert_eq!(b.shards, 1);
        assert_eq!(b.total.macs,
                   trace_layer(layer, EngineKind::Baseline).macs);
    }

    #[test]
    fn mt_dilated_bands_conserve_macs() {
        let p = DilatedParams::new(2, 1, 0);
        let st = trace_dilated(17, 8, 8, 3, &p, EngineKind::Huge2);
        let mt = trace_dilated_threads(17, 8, 8, 3, &p,
                                       EngineKind::Huge2, 4);
        assert_eq!(mt.shards, 4);
        assert_eq!(mt.total.macs, st.macs);
        // weights are re-streamed per band: strictly more total accesses
        assert!(mt.total.hierarchy.scalar_accesses
                    > st.hierarchy.scalar_accesses);
    }

    #[test]
    fn dilated_baseline_pays_dilation_squared() {
        let p = DilatedParams::new(2, 1, 0);
        let base = trace_dilated(17, 8, 8, 3, &p, EngineKind::Baseline);
        let fast = trace_dilated(17, 8, 8, 3, &p, EngineKind::Huge2);
        assert!(base.macs > 2 * fast.macs);
        assert!(fast.hierarchy.scalar_accesses
                    < base.hierarchy.scalar_accesses);
    }
}
