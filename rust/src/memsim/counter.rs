//! Byte-accurate access-stream replay of both deconvolution engines.
//!
//! `trace_layer` walks the *exact* loop structure of
//! [`crate::deconv::baseline`] / [`crate::deconv::huge2`] but, instead of
//! multiplying floats, feeds every load/store span into the cache
//! [`Hierarchy`]. This yields the paper's Fig.-8 metric (total memory
//! accesses, plus the cache/DRAM breakdown the paper's argument implies)
//! without needing ARM performance counters.
//!
//! GEMM inner-loop register traffic is excluded for both engines
//! identically; operand-panel traffic is replayed with the real blocked
//! reuse pattern (A panel re-read per N-panel, C re-touched per K-panel),
//! so what remains is precisely the *algorithmic* difference: the inflated
//! tensor, the column matrix, and the access coalescing.

use crate::config::LayerConfig;
use crate::deconv::{axis_pattern, polyphase_len, DilatedParams};

use super::cache::{Hierarchy, HierarchyStats};

const F: u64 = 4; // bytes per f32

// GEMM blocking constants mirrored from crate::gemm.
const KC: u64 = 256;
const NC: u64 = 1024;

/// Which engine's access stream to replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Baseline,
    Huge2,
}

/// Result of one replay.
#[derive(Debug, Clone, Copy)]
pub struct AccessStats {
    pub hierarchy: HierarchyStats,
    /// Multiply-accumulates the engine performs (incl. zero-MACs for the
    /// baseline — that is the point).
    pub macs: u64,
    /// DRAM bytes (L2-miss lines × 64).
    pub dram_bytes: u64,
}

/// Replay one Table-1 layer (batch 1) on a fresh TX2-like hierarchy.
pub fn trace_layer(layer: &LayerConfig, engine: EngineKind) -> AccessStats {
    let mut h = Hierarchy::tx2();
    let macs = match engine {
        EngineKind::Baseline => trace_transpose_baseline(layer, &mut h),
        EngineKind::Huge2 => trace_transpose_huge2(layer, &mut h),
    };
    let stats = h.stats();
    AccessStats { hierarchy: stats, macs, dram_bytes: stats.dram_bytes(64) }
}

/// Disjoint, page-aligned base addresses for the tensors of one layer.
struct Mem {
    x: u64,
    inflated: u64,
    col: u64,
    k: u64,
    out: u64,
    scratch: u64,
}

fn layout(layer: &LayerConfig) -> Mem {
    let (xi, ki, oi) = layer.sizes();
    let st = layer.stride;
    let (lo, hi) = layer.deconv_params().inflate_pad(layer.k);
    let ip = (layer.h - 1) * st + 1 + lo + hi;
    let inflated_elems = (ip * ip * layer.c_in) as u64;
    let ho = layer.h_out();
    let col_elems = (ho * ho * layer.k * layer.k * layer.c_in) as u64;
    let align = |x: u64| (x + 4095) / 4096 * 4096;
    let x = 0;
    let inflated = align(x + xi as u64 * F);
    let col = align(inflated + inflated_elems * F);
    let k = align(col + col_elems * F);
    let out = align(k + ki as u64 * F);
    let scratch = align(out + oi as u64 * F);
    Mem { x, inflated, col, k, out, scratch }
}

/// Replay the blocked-GEMM operand traffic: C[m×n] += A[m×k]·B[k×n].
fn trace_gemm(h: &mut Hierarchy, a: u64, b: u64, c: u64, m: u64, k: u64,
              n: u64) {
    let n_panels = n.div_ceil(NC);
    let k_panels = k.div_ceil(KC);
    // A is re-read once per N panel (packing pass).
    for _ in 0..n_panels {
        for row in 0..m {
            h.touch_span(a + row * k * F, k * F);
        }
    }
    // B is packed once per (N,K) panel.
    for _ in 0..1 {
        for row in 0..k {
            h.touch_span(b + row * n * F, n * F);
        }
    }
    // C tiles are re-touched once per K panel (read-modify-write).
    for _ in 0..k_panels {
        for row in 0..m {
            h.touch_span(c + row * n * F, n * F);
        }
    }
}

/// Naive engine: inflate -> im2col -> one big GEMM. Returns MACs.
fn trace_transpose_baseline(layer: &LayerConfig, h: &mut Hierarchy) -> u64 {
    let mem = layout(layer);
    let (hh, c, n, r) = (layer.h as u64, layer.c_in as u64,
                         layer.c_out as u64, layer.k as u64);
    let st = layer.stride as u64;
    let (lo, _hi) = layer.deconv_params().inflate_pad(layer.k);
    let lo = lo as u64;
    let ho = layer.h_out() as u64;
    let ip = {
        let (l, hi2) = layer.deconv_params().inflate_pad(layer.k);
        (layer.h as u64 - 1) * st + 1 + l as u64 + hi2 as u64
    };

    // 1. zero-fill the inflated tensor (row spans), then scatter x into it
    for row in 0..ip {
        h.touch_span(mem.inflated + row * ip * c * F, ip * c * F);
    }
    for iy in 0..hh {
        h.touch_span(mem.x + iy * hh * c * F, hh * c * F); // read x row
        for ix in 0..hh {
            let dst = ((lo + iy * st) * ip + lo + ix * st) * c;
            h.touch_span(mem.inflated + dst * F, c * F); // strided write
        }
    }
    // 2. im2col over the inflated tensor: per output pos, per tap row,
    //    one contiguous (s·c) read + one contiguous write to col
    let taps_row = r; // kernel rows
    let rowspan = r * c; // s*c contiguous per tap row
    for oy in 0..ho {
        for ox in 0..ho {
            let col_row = (oy * ho + ox) * r * r * c;
            for m in 0..taps_row {
                let src = ((oy + m) * ip + ox) * c;
                h.touch_span(mem.inflated + src * F, rowspan * F);
                h.touch_span(mem.col + (col_row + m * r * c) * F,
                             rowspan * F);
            }
        }
    }
    // 3. GEMM: (ho·wo, r·s·c) @ (r·s·c, n)
    trace_gemm(h, mem.col, mem.k, mem.out, ho * ho, r * r * c, n);
    ho * ho * r * r * c * n
}

/// HUGE² engine: decompose -> per-pattern tap GEMMs on input views ->
/// polyphase scatter. Returns (effective) MACs.
fn trace_transpose_huge2(layer: &LayerConfig, h: &mut Hierarchy) -> u64 {
    let mem = layout(layer);
    let (hh, c, n, r) = (layer.h as u64, layer.c_in as u64,
                         layer.c_out as u64, layer.k);
    let st = layer.stride;
    let ho = layer.h_out();
    let mut macs = 0u64;

    // Kernel decomposition is a one-time model-load step (the serving
    // engine pre-decomposes; see `deconv::huge2::conv2d_transpose_with`),
    // so it is not part of the per-inference access stream — the baseline
    // likewise gets its HWIO kernel layout for free.
    let sub_k = mem.scratch;
    let sub_out = mem.scratch + r as u64 * r as u64 * c * n * F + 4096;

    // 2. per pattern, per output row, per tap: contiguous row-view GEMM
    for phi_y in 0..st {
        let ay = axis_pattern(r, st, layer.pad, phi_y);
        let qy = polyphase_len(ho, st, phi_y) as u64;
        for phi_x in 0..st {
            let ax = axis_pattern(r, st, layer.pad, phi_x);
            let qx = polyphase_len(ho, st, phi_x) as u64;
            if qy == 0 || qx == 0 || ay.taps == 0 || ax.taps == 0 {
                continue;
            }
            // Tap loops outer (matching deconv::huge2): the (C, N) tap
            // weight panel is streamed ONCE per tap and stays L2-resident
            // across the q_y row GEMMs, exactly like the blocked GEMM's
            // B-panel reuse the baseline trace is credited with.
            for t_y in 0..ay.taps as u64 {
                for t_x in 0..ax.taps as u64 {
                    // B: (c, n) tap weights, contiguous, once per tap
                    let tap = (t_y * ax.taps as u64 + t_x) * c * n;
                    h.touch_span(sub_k + tap * F, c * n * F);
                    for q_y in 0..qy {
                        let iy = q_y as i64 + t_y as i64 + ay.delta as i64;
                        let iy = iy.clamp(0, hh as i64 - 1) as u64;
                        // A: contiguous (qx·c) input row view
                        let a0 = (iy * hh) * c; // row base (t_x off ± pad)
                        h.touch_span(mem.x + a0 * F, qx * c * F);
                        // C: sub-out row, read-modify-write
                        h.touch_span(sub_out + q_y * qx * n * F,
                                     qx * n * F);
                        h.touch_span(sub_out + q_y * qx * n * F,
                                     qx * n * F);
                        macs += qx * c * n;
                    }
                }
            }
            // 3. polyphase scatter: read sub rows, strided n-span writes
            for q_y in 0..qy {
                h.touch_span(sub_out + q_y * qx * n * F, qx * n * F);
                let oy = phi_y as u64 + q_y * st as u64;
                for q_x in 0..qx {
                    let ox = phi_x as u64 + q_x * st as u64;
                    h.touch_span(mem.out + (oy * ho as u64 + ox) * n * F,
                                 n * F);
                }
            }
        }
    }
    macs
}

/// Dilated-conv access replay (for the segmentation workloads).
pub fn trace_dilated(h_in: usize, c: usize, n: usize, r: usize,
                     p: &DilatedParams, engine: EngineKind) -> AccessStats {
    let mut h = Hierarchy::tx2();
    let ho = p.out_size(h_in, r) as u64;
    let (hh, c, n, r) = (h_in as u64, c as u64, n as u64, r as u64);
    let er = ((r - 1) * p.dilation as u64) + 1;
    let align = |x: u64| (x + 4095) / 4096 * 4096;
    let x0 = 0u64;
    let k0 = align(hh * hh * c * F);
    let dk0 = align(k0 + r * r * c * n * F);
    let col0 = align(dk0 + er * er * c * n * F);
    let out0 = align(col0 + ho * ho * er * er * c * F);
    let macs;
    match engine {
        EngineKind::Baseline => {
            // materialise the dilated kernel (zeros included)
            h.touch_span(k0, r * r * c * n * F);
            h.touch_span(dk0, er * er * c * n * F);
            // im2col over the effective window + GEMM
            for oy in 0..ho {
                for ox in 0..ho {
                    let crow = (oy * ho + ox) * er * er * c;
                    for m in 0..er {
                        let src = ((oy + m) * hh + ox) * c;
                        h.touch_span(x0 + src * F, er * c * F);
                        h.touch_span(col0 + (crow + m * er * c) * F,
                                     er * c * F);
                    }
                }
            }
            trace_gemm(&mut h, col0, dk0, out0, ho * ho, er * er * c, n);
            macs = ho * ho * er * er * c * n;
        }
        EngineKind::Huge2 => {
            // tap-outer order (matching deconv::dilated): weights once/tap
            for t_r in 0..r {
                for t_c in 0..r {
                    let tap = (t_r * r + t_c) * c * n;
                    h.touch_span(k0 + tap * F, c * n * F);
                    for oy in 0..ho {
                        let iy = oy * p.stride as u64
                            + t_r * p.dilation as u64;
                        let a0 = (iy.min(hh - 1) * hh) * c;
                        if p.stride == 1 {
                            h.touch_span(x0 + a0 * F, ho * c * F);
                        } else {
                            h.touch_strided(x0 + a0 * F, ho,
                                            p.stride as u64 * c * F, c * F);
                        }
                        h.touch_span(out0 + oy * ho * n * F, ho * n * F);
                        h.touch_span(out0 + oy * ho * n * F, ho * n * F);
                        let _ = t_c;
                    }
                }
            }
            macs = ho * ho * r * r * c * n;
        }
    }
    let stats = h.stats();
    AccessStats { hierarchy: stats, macs, dram_bytes: stats.dram_bytes(64) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::table1;

    #[test]
    fn huge2_reduces_scalar_accesses_on_every_layer() {
        for layer in table1() {
            let base = trace_layer(&layer, EngineKind::Baseline);
            let fast = trace_layer(&layer, EngineKind::Huge2);
            assert!(fast.hierarchy.scalar_accesses
                        < base.hierarchy.scalar_accesses,
                    "{}: {} !< {}", layer.name,
                    fast.hierarchy.scalar_accesses,
                    base.hierarchy.scalar_accesses);
            assert!(fast.macs < base.macs, "{}", layer.name);
        }
    }

    #[test]
    fn reduction_in_paper_band() {
        // paper: 30-70% access reduction by untangling (+ decomposition)
        for layer in table1() {
            let base = trace_layer(&layer, EngineKind::Baseline);
            let fast = trace_layer(&layer, EngineKind::Huge2);
            let red = 1.0
                - fast.hierarchy.scalar_accesses as f64
                / base.hierarchy.scalar_accesses as f64;
            assert!(red > 0.25 && red < 0.95,
                    "{}: reduction {red:.2}", layer.name);
        }
    }

    #[test]
    fn mac_ratio_close_to_stride_squared() {
        let layer = &table1()[2];
        let base = trace_layer(layer, EngineKind::Baseline);
        let fast = trace_layer(layer, EngineKind::Huge2);
        let ratio = base.macs as f64 / fast.macs as f64;
        assert!(ratio > 3.0 && ratio < 4.5, "{ratio}");
    }

    #[test]
    fn dilated_baseline_pays_dilation_squared() {
        let p = DilatedParams::new(2, 1, 0);
        let base = trace_dilated(17, 8, 8, 3, &p, EngineKind::Baseline);
        let fast = trace_dilated(17, 8, 8, 3, &p, EngineKind::Huge2);
        assert!(base.macs > 2 * fast.macs);
        assert!(fast.hierarchy.scalar_accesses
                    < base.hierarchy.scalar_accesses);
    }
}
