//! Memory-system simulation — the substrate for the paper's Fig. 8 (left)
//! "memory access reduction" metric and the Fig. 7 (left) embedded-GPU
//! estimate.
//!
//! The authors measured a Jetson TX2; we have neither its ARM CPU
//! performance counters nor its GPU. Instead (DESIGN.md §2):
//!
//! * [`cache`] — a set-associative LRU cache simulator with TX2-like
//!   geometry (32 KiB L1 / 2 MiB shared L2, 64-byte lines).
//! * [`counter`] — replays the exact byte-access streams of both deconv
//!   algorithms (baseline inflate+im2col+GEMM vs HUGE² pattern GEMMs)
//!   through the cache hierarchy, at cache-line-granular span resolution.
//! * [`gpu_model`] — an analytical roofline of the 256-core Pascal
//!   embedded GPU fed by exact MAC/byte counts and coalescing factors.

pub mod cache;
pub mod counter;
pub mod gpu_model;

pub use cache::{Cache, CacheConfig, Hierarchy, HierarchyStats};
pub use counter::{
    trace_dilated, trace_dilated_threads, trace_gemm_shape, trace_layer,
    trace_transpose, AccessStats, EngineKind, LayerTrace,
};
pub use gpu_model::{GpuModel, GpuEstimate};
