//! Set-associative LRU cache simulator.
//!
//! Accesses are *span*-granular: `touch_span(addr, len)` walks the 64-byte
//! lines a contiguous access run covers, which models exactly the
//! coalescing effect HUGE²'s §4.2 layout argument is about — contiguous
//! C/N-dimension streams touch each line once; the baseline's strided,
//! zero-interleaved walks touch many lines per useful element.

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub assoc: usize,
    pub line_bytes: usize,
}

impl CacheConfig {
    /// Cortex-A57 L1D: 32 KiB, 2-way, 64-B lines.
    pub fn a57_l1() -> Self {
        CacheConfig { size_bytes: 32 << 10, assoc: 2, line_bytes: 64 }
    }

    /// TX2 shared L2: 2 MiB, 16-way, 64-B lines.
    pub fn tx2_l2() -> Self {
        CacheConfig { size_bytes: 2 << 20, assoc: 16, line_bytes: 64 }
    }

    pub fn num_sets(&self) -> usize {
        self.size_bytes / self.line_bytes / self.assoc
    }
}

/// One cache level with true-LRU replacement.
#[derive(Debug)]
pub struct Cache {
    cfg: CacheConfig,
    /// `sets[s]` holds up to `assoc` line tags, most-recent first.
    sets: Vec<Vec<u64>>,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = vec![Vec::with_capacity(cfg.assoc); cfg.num_sets()];
        Cache { cfg, sets, hits: 0, misses: 0 }
    }

    /// Access one line; returns true on hit.
    pub fn access_line(&mut self, line_addr: u64) -> bool {
        let set_idx = (line_addr as usize) % self.sets.len();
        let set = &mut self.sets[set_idx];
        if let Some(pos) = set.iter().position(|&t| t == line_addr) {
            let tag = set.remove(pos);
            set.insert(0, tag);
            self.hits += 1;
            true
        } else {
            if set.len() == self.cfg.assoc {
                set.pop();
            }
            set.insert(0, line_addr);
            self.misses += 1;
            false
        }
    }

    pub fn line_bytes(&self) -> usize {
        self.cfg.line_bytes
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// Aggregate statistics of a two-level hierarchy run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierarchyStats {
    /// Scalar (4-byte-element) loads+stores issued by the algorithm.
    pub scalar_accesses: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
}

impl HierarchyStats {
    /// Bytes that actually reached DRAM.
    pub fn dram_bytes(&self, line: usize) -> u64 {
        self.l2_misses * line as u64
    }
}

/// L1 -> L2 -> DRAM hierarchy with span-granular access.
#[derive(Debug)]
pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    pub scalar_accesses: u64,
}

impl Hierarchy {
    pub fn tx2() -> Self {
        Hierarchy {
            l1: Cache::new(CacheConfig::a57_l1()),
            l2: Cache::new(CacheConfig::tx2_l2()),
            scalar_accesses: 0,
        }
    }

    pub fn new(l1: CacheConfig, l2: CacheConfig) -> Self {
        Hierarchy { l1: Cache::new(l1), l2: Cache::new(l2), scalar_accesses: 0 }
    }

    /// Touch a contiguous byte span `[addr, addr+len)`.
    pub fn touch_span(&mut self, addr: u64, len: u64) {
        debug_assert!(len > 0);
        self.scalar_accesses += len / 4;
        let line = self.l1.line_bytes() as u64;
        let first = addr / line;
        let last = (addr + len - 1) / line;
        for la in first..=last {
            if !self.l1.access_line(la) {
                self.l2.access_line(la);
            }
        }
    }

    /// Touch `count` elements of `elem_bytes` spaced `stride_bytes` apart —
    /// the strided walk of a non-coalesced access pattern.
    pub fn touch_strided(&mut self, addr: u64, count: u64,
                         stride_bytes: u64, elem_bytes: u64) {
        if stride_bytes <= elem_bytes {
            // degenerate: actually contiguous
            return self.touch_span(addr, count * elem_bytes);
        }
        for i in 0..count {
            self.touch_span(addr + i * stride_bytes, elem_bytes);
        }
    }

    pub fn stats(&self) -> HierarchyStats {
        HierarchyStats {
            scalar_accesses: self.scalar_accesses,
            l1_hits: self.l1.hits,
            l1_misses: self.l1.misses,
            l2_hits: self.l2.hits,
            l2_misses: self.l2.misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_span_hits_after_first_touch() {
        let mut h = Hierarchy::tx2();
        h.touch_span(0, 64); // one line, miss
        h.touch_span(0, 64); // hit
        let s = h.stats();
        assert_eq!(s.l1_misses, 1);
        assert_eq!(s.l1_hits, 1);
    }

    #[test]
    fn span_counts_lines_once() {
        let mut h = Hierarchy::tx2();
        h.touch_span(0, 256); // 4 lines
        assert_eq!(h.stats().l1_misses, 4);
        assert_eq!(h.stats().scalar_accesses, 64);
    }

    #[test]
    fn strided_touches_more_lines_than_contiguous() {
        let mut a = Hierarchy::tx2();
        a.touch_span(0, 64 * 16);
        let mut b = Hierarchy::tx2();
        b.touch_strided(0, 16, 256, 4); // 16 elems, one per 4 lines
        assert!(b.stats().l1_misses >= a.stats().l1_misses,
                "strided {} vs contiguous {}", b.stats().l1_misses,
                a.stats().l1_misses);
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2-way set: touch 3 conflicting lines, re-touch the first -> miss
        let cfg = CacheConfig { size_bytes: 128, assoc: 2, line_bytes: 64 };
        let mut c = Cache::new(cfg);
        assert_eq!(cfg.num_sets(), 1);
        c.access_line(0);
        c.access_line(1);
        c.access_line(2); // evicts 0
        assert!(!c.access_line(0));
    }

    #[test]
    fn capacity_working_set_fits() {
        // working set smaller than L1: second pass all hits
        let mut h = Hierarchy::tx2();
        for _ in 0..2 {
            for i in 0..100 {
                h.touch_span(i * 64, 64);
            }
        }
        let s = h.stats();
        assert_eq!(s.l1_misses, 100);
        assert_eq!(s.l1_hits, 100);
    }
}
