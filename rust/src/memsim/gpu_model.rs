//! Analytical roofline model of the Jetson TX2's 256-core Pascal embedded
//! GPU — the substitute for the paper's Fig. 7 (left) hardware (DESIGN.md
//! §2: no CUDA device exists in this environment; the GPU-side speedup is
//! *estimated from first principles* and labelled as such everywhere it is
//! reported).
//!
//! Model: `t = max(t_compute, t_memory)` with
//! * `t_compute = 2·MACs / (peak_flops · occupancy)`
//! * `t_memory  = bytes / (bandwidth · coalescing)`
//!
//! The engine-dependent factors encode exactly the effects §3/§4 of the
//! paper argue about:
//! * the baseline executes every zero-MAC of the inflated tensor,
//!   suffers strided (uncoalesced) global loads over it, and serialises
//!   overlapping output accumulations;
//! * HUGE² executes only effective MACs, streams C/N-contiguous panels
//!   (fully coalesced), and its polyphase writes never conflict.

use crate::config::LayerConfig;
use crate::deconv::huge2::mac_counts;

/// Hardware + engine-efficiency parameters.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Peak f32 throughput (FLOP/s). TX2: 256 cores × 2 × 1.30 GHz.
    pub peak_flops: f64,
    /// DRAM bandwidth (B/s). TX2: 128-bit LPDDR4-3733 ≈ 59.7 GB/s.
    pub bandwidth: f64,
    /// SM occupancy the naive kernel sustains (atomic/overlap stalls).
    pub base_occupancy: f64,
    /// Coalescing efficiency of the naive zero-scatter / strided walks.
    pub base_coalescing: f64,
    /// SM occupancy of the untangled GEMM kernels.
    pub huge2_occupancy: f64,
    /// Per-GEMM launch + panel-setup overhead (s). HUGE² pays this once
    /// per kernel tap; it is what caps the speedup on the small deep
    /// layers (DC4/cGAN-DC2) at the paper's ~10× level.
    pub launch_overhead_s: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            peak_flops: 256.0 * 2.0 * 1.30e9,
            bandwidth: 59.7e9,
            // Calibration rationale (DESIGN.md §6): DarkNet's deconv
            // executes every zero-MAC of the inflated tensor with
            // read-modify-write output chains; CUDA kernels of this shape
            // sustain ~35 % of peak. Its zero-scatter writes touch one
            // useful 32-B sector per 128-B transaction (~1/4 coalescing).
            base_occupancy: 0.35,
            base_coalescing: 0.25,
            // Untangled taps are plain dense GEMM panels (cuBLAS-like).
            huge2_occupancy: 0.75,
            launch_overhead_s: 5.0e-6,
        }
    }
}

/// Per-engine time estimate for one layer.
#[derive(Debug, Clone, Copy)]
pub struct GpuEstimate {
    pub t_baseline_s: f64,
    pub t_huge2_s: f64,
    pub speedup: f64,
    /// true if the *baseline* is compute-bound on this layer (the paper's
    /// §4.1 "shallower layers are more compute-bounded").
    pub baseline_compute_bound: bool,
    /// true if the baseline is dominated by memory streams (§4.2
    /// "deeper deconvolution layers are data-bounded").
    pub baseline_memory_bound: bool,
}

impl GpuModel {
    /// Estimate one Table-1 layer at batch 1.
    ///
    /// Memory streams are unique-byte streams (large arrays don't fit the
    /// TX2 GPU's 512-KiB L2, so each materialised tensor is written and
    /// read from DRAM once); the coalescing penalty applies to the
    /// baseline's zero-scatter phase only.
    pub fn estimate(&self, layer: &LayerConfig) -> GpuEstimate {
        let p = layer.deconv_params();
        let (naive_macs, eff_macs) = mac_counts(
            layer.h, layer.h, layer.c_in, layer.c_out, layer.k, layer.k, &p);
        let (xi, ki, oi) = layer.sizes();

        let st = layer.stride;
        let (lo, hi) = p.inflate_pad(layer.k);
        let ip = (layer.h - 1) * st + 1 + lo + hi;
        let inflated = ip * ip * layer.c_in;
        let ho = layer.h_out();
        let col = ho * ho * layer.k * layer.k * layer.c_in;

        // Baseline: x read + inflated write (uncoalesced scatter) +
        // inflated read + col write + col read + k read + out write.
        let base_scatter_bytes = 4.0 * inflated as f64;
        let base_stream_bytes =
            4.0 * (xi + inflated + 2 * col + ki + oi) as f64;
        let t_base_mem = base_scatter_bytes
            / (self.bandwidth * self.base_coalescing)
            + base_stream_bytes / self.bandwidth;
        let t_base_cmp = 2.0 * naive_macs as f64
            / (self.peak_flops * self.base_occupancy);
        let t_base = t_base_mem.max(t_base_cmp);

        // HUGE²: x re-read once per tap row (ceil(k/stride) rows), k read,
        // out written once (disjoint polyphases) — all coalesced.
        let taps_axis = (layer.k as f64 / st as f64).ceil();
        let huge_bytes =
            4.0 * (xi as f64 * taps_axis + ki as f64 + oi as f64);
        let t_huge_mem = huge_bytes / self.bandwidth;
        let t_huge_cmp = 2.0 * eff_macs as f64
            / (self.peak_flops * self.huge2_occupancy);
        // one GEMM launch per kernel tap (r·s in total across patterns)
        let t_launch =
            (layer.k * layer.k) as f64 * self.launch_overhead_s;
        let t_huge = t_huge_mem.max(t_huge_cmp) + t_launch;

        GpuEstimate {
            t_baseline_s: t_base,
            t_huge2_s: t_huge,
            speedup: t_base / t_huge,
            baseline_compute_bound: t_base_cmp >= t_base_mem,
            baseline_memory_bound: t_base_mem > t_base_cmp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::table1;

    #[test]
    fn speedups_in_paper_band() {
        // paper Fig. 7 left: ~10x on the embedded GPU (per-layer spread)
        let model = GpuModel::default();
        for layer in table1() {
            let e = model.estimate(&layer);
            assert!(e.speedup > 3.0 && e.speedup < 25.0,
                    "{}: {:.1}x", layer.name, e.speedup);
        }
    }

    #[test]
    fn shallow_layers_compute_bound_deep_layers_memory_bound() {
        // paper §4.1/§4.2: shallow = compute-bound, deep = data-bound
        let model = GpuModel::default();
        let t = table1();
        let dc1 = model.estimate(&t[0]);
        let dc4 = model.estimate(&t[3]);
        assert!(dc1.baseline_compute_bound, "DC1 should be compute-bound");
        assert!(dc4.baseline_memory_bound, "DC4 should be memory-bound");
    }

    #[test]
    fn times_positive_and_finite() {
        let model = GpuModel::default();
        for layer in table1() {
            let e = model.estimate(&layer);
            assert!(e.t_baseline_s > 0.0 && e.t_baseline_s.is_finite());
            assert!(e.t_huge2_s > 0.0 && e.t_huge2_s.is_finite());
        }
    }
}
