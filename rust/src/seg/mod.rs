//! Segmentation model subsystem — the serving-side home of the paper's
//! *second* deconvolution family (dilated/atrous convolution, §2.1.2,
//! §3.2.2), mirroring what [`crate::gan`] is for the transposed family.
//!
//! A [`SegNet`] is assembled from [`SegLayerConfig`]s in [`crate::config`]
//! (sequential trunk → parallel atrous spatial pyramid, branches summed →
//! 1×1 classifier head — the DeepLab/ENet shape), with a **per-layer**
//! choice of baseline vs HUGE² untangled dilated conv and a per-layer
//! thread count. Like `gan::GenLayer`, every layer pre-decomposes at
//! load time: the `R·S` tap weight panels are packed into GEMM
//! micro-kernel layout once ([`dilated::pack_taps`]), so inference never
//! packs B.
//!
//! Serving contract (DESIGN.md §8): the forward pass is deterministic,
//! bit-identical across thread counts, and batch-composition-invariant
//! (each image in a batch is computed independently), so segmentation
//! requests record/replay under the same checksum discipline as GAN
//! requests.

use crate::config::{SegLayerConfig, SegNetConfig};
use crate::deconv::dilated::{self, DilatedTaps};
use crate::deconv::{baseline, parallel, Engine};
use crate::gan::Forward;
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

/// One dilated-conv layer with its weights and pre-packed tap panels
/// (packed once at model-load time, as a serving engine would do).
pub struct SegLayer {
    pub cfg: SegLayerConfig,
    pub kernel: Tensor,
    taps: DilatedTaps,
}

impl SegLayer {
    pub fn new(cfg: SegLayerConfig, kernel: Tensor) -> Self {
        assert_eq!(kernel.shape(), &[cfg.k, cfg.k, cfg.c_in, cfg.c_out]);
        let taps = dilated::pack_taps(&kernel);
        SegLayer { cfg, kernel, taps }
    }

    /// Forward one layer with an explicit engine choice (the per-config
    /// choice lives in `cfg.engine`; [`SegNet::forward`] applies it).
    pub fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        let p = self.cfg.params;
        match engine {
            Engine::Baseline => baseline::conv2d_dilated(x, &self.kernel, &p),
            Engine::Huge2 if self.cfg.threads > 1 => {
                parallel::conv2d_dilated_mt(x, &self.taps, &p,
                                            self.cfg.threads)
            }
            Engine::Huge2 => dilated::conv2d_dilated_with(x, &self.taps, &p),
        }
    }

    /// Slice-level forward for the pooled net path: `xd` is the
    /// `(b, h, h, c_in)` activation (dims from `cfg`), `out` the
    /// `(b, h_out, h_out, c_out)` destination; all scratch from `hnd`
    /// (the multi-threaded engine hands `hnd.workspace()` to its row
    /// shards).
    pub(crate) fn forward_into(&self, xd: &[f32], b: usize, engine: Engine,
                               out: &mut [f32], hnd: &mut WsHandle) {
        let p = self.cfg.params;
        let (ih, c_in) = (self.cfg.h, self.cfg.c_in);
        match engine {
            Engine::Baseline => baseline::conv2d_dilated_into(
                xd, b, ih, ih, c_in, &self.kernel, &p, out, hnd),
            Engine::Huge2 if self.cfg.threads > 1 => {
                parallel::dilated_mt_into(xd, b, ih, ih, c_in, &self.taps,
                                          &p, self.cfg.threads, out,
                                          hnd.workspace())
            }
            Engine::Huge2 => dilated::dilated_into(xd, b, ih, ih, c_in,
                                                   &self.taps, &p, out,
                                                   hnd),
        }
    }
}

/// A segmentation network: trunk, atrous pyramid, classifier head.
pub struct SegNet {
    pub cfg: SegNetConfig,
    pub trunk: Vec<SegLayer>,
    pub aspp: Vec<SegLayer>,
    pub head: SegLayer,
}

impl SegNet {
    /// Build with seeded 0.02·N(0,1) weights (bit-reproducible from
    /// `seed` — the trace header records it so replay can rebuild the
    /// exact net). Weight order: trunk, then ASPP branches, then head.
    pub fn new(cfg: &SegNetConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut mk = |c: &SegLayerConfig| {
            let k = Tensor::randn(&[c.k, c.k, c.c_in, c.c_out], &mut rng)
                .scale(0.02);
            SegLayer::new(c.clone(), k)
        };
        let trunk: Vec<SegLayer> = cfg.trunk.iter().map(&mut mk).collect();
        let aspp: Vec<SegLayer> = cfg.aspp.iter().map(&mut mk).collect();
        let head = mk(&cfg.head);
        assert!(!trunk.is_empty() && !aspp.is_empty(),
                "segnet needs a trunk and at least one ASPP branch");
        SegNet { cfg: cfg.clone(), trunk, aspp, head }
    }

    pub fn n_classes(&self) -> usize {
        self.cfg.n_classes
    }

    /// Single-image input shape `(1, H, W, C)` — what request payloads
    /// must carry ([`crate::coordinator::Model::native_seg`] validates
    /// against it).
    pub fn in_shape(&self) -> Vec<usize> {
        let f = &self.trunk[0].cfg;
        vec![1, f.h, f.h, f.c_in]
    }

    /// Logit tensor shape for batch `b`: `(b, Ho, Wo, n_classes)`.
    pub fn logits_shape(&self, b: usize) -> Vec<usize> {
        let h = self.head.cfg.h_out();
        vec![b, h, h, self.cfg.n_classes]
    }

    /// `x`: `(B, H, W, C)` → logits `(B, Ho, Wo, n_classes)`, using each
    /// layer's configured engine/threads.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with(x, None)
    }

    /// [`SegNet::forward`] with an engine override applied to every layer
    /// (`None` = per-layer config) — the cross-engine property tests and
    /// the CLI timing table use this.
    pub fn forward_with(&self, x: &Tensor, over: Option<Engine>) -> Tensor {
        let ws = Workspace::new();
        self.forward_ws(x, over, &mut ws.handle())
    }

    /// [`SegNet::forward_with`] drawing every intermediate activation and
    /// all engine scratch from a workspace handle — the steady-state
    /// serving path (bit-identical to the fresh-workspace wrapper;
    /// DESIGN.md §9).
    pub fn forward_ws(&self, x: &Tensor, over: Option<Engine>,
                      hnd: &mut WsHandle) -> Tensor {
        let b = x.shape()[0];
        let mut out = Tensor::zeros(&self.logits_shape(b));
        self.forward_into(x.data(), b, over, out.data_mut(), hnd);
        out
    }

    /// Slice-level forward: `xd` is the `(b, H, W, C)` input, `out` the
    /// `(b, Ho, Wo, n_classes)` logits destination (fully overwritten).
    /// Activations ping-pong between pooled slabs; the ASPP branches
    /// accumulate in place in config order (same left-to-right sum as
    /// the tensor path — replay determinism).
    pub fn forward_into(&self, xd: &[f32], b: usize, over: Option<Engine>,
                        out: &mut [f32], hnd: &mut WsHandle) {
        let pick = |l: &SegLayer| over.unwrap_or(l.cfg.engine);
        let elems = |c: &SegLayerConfig| b * c.h_out() * c.h_out() * c.c_out;
        // trunk: sequential ping-pong
        let mut cur = None;
        for l in &self.trunk {
            let mut nxt = hnd.checkout(elems(&l.cfg));
            match &cur {
                None => l.forward_into(xd, b, pick(l), &mut nxt, hnd),
                Some(prev) => l.forward_into(prev, b, pick(l), &mut nxt,
                                             hnd),
            }
            crate::tensor::relu_inplace(&mut nxt);
            if let Some(prev) = cur.replace(nxt) {
                hnd.checkin(prev);
            }
        }
        let trunk_out = cur.expect("segnet needs a trunk");
        // ASPP: parallel branches over the same input, summed in config
        // order (fixed order — replay determinism).
        let ae = elems(&self.aspp[0].cfg);
        let mut acc = hnd.checkout(ae);
        self.aspp[0].forward_into(&trunk_out, b, pick(&self.aspp[0]),
                                  &mut acc, hnd);
        let mut branch = hnd.checkout(ae);
        for l in &self.aspp[1..] {
            assert_eq!(elems(&l.cfg), ae, "ASPP branch shape mismatch");
            l.forward_into(&trunk_out, b, pick(l), &mut branch, hnd);
            for (a, y) in acc.iter_mut().zip(branch.iter()) {
                *a += *y;
            }
        }
        hnd.checkin(branch);
        hnd.checkin(trunk_out);
        crate::tensor::relu_inplace(&mut acc);
        self.head.forward_into(&acc, b, pick(&self.head), out, hnd);
        hnd.checkin(acc);
    }

    /// End-to-end inference: forward + per-pixel class argmax.
    pub fn predict(&self, x: &Tensor) -> Tensor {
        argmax_mask(&self.forward(x))
    }
}

impl Forward for SegNet {
    fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        self.forward_with(x, Some(engine))
    }

    fn out_shape(&self, b: usize) -> Vec<usize> {
        self.logits_shape(b)
    }
}

/// Measure one layer under both engines on `x` and format the shared
/// report cells `[baseline, huge2, speedup, max |Δ|]`. The `huge2
/// segment` subcommand and `examples/segment.rs` both build their
/// timing tables from this, so the measurement discipline (warmup,
/// sample count, speedup formula) cannot drift between them.
pub fn layer_timing_cells(l: &SegLayer, x: &Tensor) -> [String; 4] {
    use crate::bench_util::{fmt_dur, measure};
    let tb = measure(1, 5, || {
        std::hint::black_box(l.forward(x, Engine::Baseline));
    });
    let tf = measure(1, 5, || {
        std::hint::black_box(l.forward(x, Engine::Huge2));
    });
    let yb = l.forward(x, Engine::Baseline);
    let yf = l.forward(x, Engine::Huge2);
    [
        fmt_dur(tb.median),
        fmt_dur(tf.median),
        format!("{:.2}x", tb.median_s() / tf.median_s()),
        format!("{:.2e}", yf.max_abs_diff(&yb)),
    ]
}

/// Per-pixel class argmax: logits `(B, H, W, K)` → mask `(B, H, W, 1)` of
/// class indices as f32. Ties break to the **lowest** class index
/// (strict-`>` scan), so the mask is deterministic — a response checksum
/// over it is replayable.
pub fn argmax_mask(logits: &Tensor) -> Tensor {
    let (b, h, w, k) = logits.dims4();
    argmax_mask_from(logits.data(), b, h, w, k)
}

/// [`argmax_mask`] over a raw logits slice (the pooled worker path keeps
/// batch logits in a workspace slab; only the mask — the client-owned
/// response — is a fresh tensor).
pub fn argmax_mask_from(src: &[f32], b: usize, h: usize, w: usize,
                        k: usize) -> Tensor {
    assert!(k > 0);
    assert_eq!(src.len(), b * h * w * k, "logits size");
    let mut out = Tensor::zeros(&[b, h, w, 1]);
    for (pix, dst) in out.data_mut().iter_mut().enumerate() {
        let row = &src[pix * k..(pix + 1) * k];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        *dst = best as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{segnet, tiny_segnet};

    #[test]
    fn tiny_net_shapes() {
        let net = SegNet::new(&tiny_segnet(), 5);
        assert_eq!(net.in_shape(), vec![1, 9, 9, 2]);
        assert_eq!(net.logits_shape(3), vec![3, 9, 9, 3]);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&net.in_shape(), &mut rng);
        let logits = net.forward(&x);
        assert_eq!(logits.shape(), net.logits_shape(1).as_slice());
        let mask = net.predict(&x);
        assert_eq!(mask.shape(), &[1, 9, 9, 1]);
        let nc = net.n_classes() as f32;
        assert!(mask.data().iter().all(|&v| v >= 0.0 && v < nc
                                       && v.fract() == 0.0));
    }

    #[test]
    fn engines_agree_and_huge2_is_deterministic() {
        let net = SegNet::new(&tiny_segnet(), 7);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&net.in_shape(), &mut rng);
        let a = net.forward_with(&x, Some(Engine::Huge2));
        let b = net.forward_with(&x, Some(Engine::Baseline));
        assert!(a.allclose(&b, 1e-4), "diff {}", a.max_abs_diff(&b));
        let a2 = net.forward_with(&x, Some(Engine::Huge2));
        assert_eq!(a.checksum(), a2.checksum());
    }

    #[test]
    fn seeded_weights_reproduce() {
        let a = SegNet::new(&segnet(), 11);
        let b = SegNet::new(&segnet(), 11);
        assert_eq!(a.trunk[0].kernel.checksum(),
                   b.trunk[0].kernel.checksum());
        assert_eq!(a.head.kernel.checksum(), b.head.kernel.checksum());
        let c = SegNet::new(&segnet(), 12);
        assert_ne!(a.head.kernel.checksum(), c.head.kernel.checksum());
    }

    #[test]
    fn argmax_mask_breaks_ties_low() {
        let logits = Tensor::from_vec(&[1, 1, 2, 3],
                                      vec![1.0, 3.0, 3.0, 2.0, -1.0, 2.0]);
        let m = argmax_mask(&logits);
        assert_eq!(m.data(), &[1.0, 0.0]);
    }
}
