//! Segmentation model subsystem — the serving-side home of the paper's
//! *second* deconvolution family (dilated/atrous convolution, §2.1.2,
//! §3.2.2), mirroring what [`crate::gan`] is for the transposed family.
//!
//! A [`SegNet`] is assembled from [`SegLayerConfig`]s in [`crate::config`]
//! (sequential trunk → parallel atrous spatial pyramid, branches summed →
//! 1×1 classifier head — the DeepLab/ENet shape), with a **per-layer**
//! engine choice (the registry configs use [`Engine::Auto`], resolved by
//! the plan heuristic at load time) and a per-layer thread count. Like
//! `gan::GenLayer`, every layer pre-decomposes at load time: the `R·S`
//! tap weight panels are packed into GEMM micro-kernel layout once
//! ([`dilated::pack_taps`]), shared by `Arc` with every compiled
//! [`ExecPlan`] — inference never packs B, and the forward internals
//! live in the one plan executor (DESIGN.md §10).
//!
//! Serving contract (DESIGN.md §8): the forward pass is deterministic,
//! bit-identical across thread counts, and batch-composition-invariant
//! (each image in a batch is computed independently), so segmentation
//! requests record/replay under the same checksum discipline as GAN
//! requests.

use std::sync::Arc;

use crate::config::{SegLayerConfig, SegNetConfig};
use crate::deconv::dilated::{self, DilatedTaps};
use crate::deconv::Engine;
use crate::gan::Forward;
use crate::plan::{resolve_dilated, run_dilated_op, ExecPlan};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

/// One dilated-conv layer with its weights and pre-packed tap panels
/// (packed once at model-load time, `Arc`-shared with compiled plans).
pub struct SegLayer {
    pub cfg: SegLayerConfig,
    pub kernel: Arc<Tensor>,
    pub(crate) taps: Arc<DilatedTaps>,
}

impl SegLayer {
    pub fn new(cfg: SegLayerConfig, kernel: Tensor) -> Self {
        assert_eq!(kernel.shape(), &[cfg.k, cfg.k, cfg.c_in, cfg.c_out]);
        let taps = Arc::new(dilated::pack_taps(&kernel));
        SegLayer { cfg, kernel: Arc::new(kernel), taps }
    }

    /// Forward one layer with an explicit engine choice (`Auto` resolves
    /// through the plan heuristic; the per-config choice lives in
    /// `cfg.engine` and is applied by the compiled net plan).
    pub fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        let ws = Workspace::new();
        let hnd = &mut ws.handle();
        let p = self.cfg.params;
        let (b, h, w, c) = x.dims4();
        let (eng, threads) = resolve_dilated(
            engine, h, w, c, self.cfg.c_out, self.cfg.k, &p,
            self.cfg.threads);
        let ho = p.out_size(h, self.cfg.k);
        let wo = p.out_size(w, self.cfg.k);
        let mut out = Tensor::zeros(&[b, ho, wo, self.cfg.c_out]);
        run_dilated_op(x.data(), b, h, w, c, &self.kernel, &self.taps, &p,
                       eng, threads, out.data_mut(), hnd);
        out
    }
}

/// A segmentation network: trunk, atrous pyramid, classifier head,
/// compiled to an [`ExecPlan`] at load time.
pub struct SegNet {
    pub cfg: SegNetConfig,
    pub trunk: Vec<SegLayer>,
    pub aspp: Vec<SegLayer>,
    pub head: SegLayer,
    /// The load-time-compiled logits plan (per-layer config engines,
    /// `Auto` resolved); serving appends the argmax head.
    plan: ExecPlan,
}

impl SegNet {
    /// Build with seeded 0.02·N(0,1) weights (bit-reproducible from
    /// `seed` — the trace header records it so replay can rebuild the
    /// exact net). Weight order: trunk, then ASPP branches, then head.
    pub fn new(cfg: &SegNetConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut mk = |c: &SegLayerConfig| {
            let k = Tensor::randn(&[c.k, c.k, c.c_in, c.c_out], &mut rng)
                .scale(0.02);
            SegLayer::new(c.clone(), k)
        };
        let trunk: Vec<SegLayer> = cfg.trunk.iter().map(&mut mk).collect();
        let aspp: Vec<SegLayer> = cfg.aspp.iter().map(&mut mk).collect();
        let head = mk(&cfg.head);
        assert!(!trunk.is_empty() && !aspp.is_empty(),
                "segnet needs a trunk and at least one ASPP branch");
        let plan = ExecPlan::compile_seg(&trunk, &aspp, &head, None);
        SegNet { cfg: cfg.clone(), trunk, aspp, head, plan }
    }

    pub fn n_classes(&self) -> usize {
        self.cfg.n_classes
    }

    /// The load-time-compiled execution plan (logits; engine selection
    /// already resolved, all prepacking shared).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Single-image input shape `(1, H, W, C)` — what request payloads
    /// must carry ([`crate::coordinator::Model::native_seg`] validates
    /// against it).
    pub fn in_shape(&self) -> Vec<usize> {
        let f = &self.trunk[0].cfg;
        vec![1, f.h, f.h, f.c_in]
    }

    /// Logit tensor shape for batch `b`: `(b, Ho, Wo, n_classes)`.
    pub fn logits_shape(&self, b: usize) -> Vec<usize> {
        self.plan.out_shape(b)
    }

    /// `x`: `(B, H, W, C)` → logits `(B, Ho, Wo, n_classes)`, using each
    /// layer's configured engine/threads (the stored plan).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with(x, None)
    }

    /// [`SegNet::forward`] with an engine override applied to every layer
    /// (`None` = per-layer config) — the cross-engine property tests and
    /// the CLI timing table use this.
    pub fn forward_with(&self, x: &Tensor, over: Option<Engine>) -> Tensor {
        let ws = Workspace::new();
        self.forward_ws(x, over, &mut ws.handle())
    }

    /// [`SegNet::forward_with`] drawing every intermediate activation and
    /// all engine scratch from a workspace handle — the steady-state
    /// serving path (bit-identical to the fresh-workspace wrapper;
    /// DESIGN.md §9).
    pub fn forward_ws(&self, x: &Tensor, over: Option<Engine>,
                      hnd: &mut WsHandle) -> Tensor {
        let b = x.shape()[0];
        let mut out = Tensor::zeros(&self.logits_shape(b));
        self.forward_into(x.data(), b, over, out.data_mut(), hnd);
        out
    }

    /// Slice-level forward: `xd` is the `(b, H, W, C)` input, `out` the
    /// `(b, Ho, Wo, n_classes)` logits destination (fully overwritten).
    /// Thin wrapper over [`ExecPlan::run_into`] — the one place the
    /// forward internals (ping-pong, pyramid sum order, dispatch) live.
    /// Overrides the stored plan already resolves to run it directly
    /// (no per-call compile — the steady state stays allocation-free);
    /// only a genuinely different selection compiles a transient plan.
    pub fn forward_into(&self, xd: &[f32], b: usize, over: Option<Engine>,
                        out: &mut [f32], hnd: &mut WsHandle) {
        let stored = over == self.plan.requested()
            || matches!(over, Some(e) if self.plan.resolves_to(e));
        if stored {
            self.plan.run_into(xd, b, out, hnd);
        } else {
            ExecPlan::compile_seg(&self.trunk, &self.aspp, &self.head,
                                  over)
                .run_into(xd, b, out, hnd);
        }
    }

    /// End-to-end inference: forward + per-pixel class argmax.
    pub fn predict(&self, x: &Tensor) -> Tensor {
        argmax_mask(&self.forward(x))
    }
}

impl Forward for SegNet {
    fn forward(&self, x: &Tensor, engine: Engine) -> Tensor {
        self.forward_with(x, Some(engine))
    }

    fn out_shape(&self, b: usize) -> Vec<usize> {
        self.logits_shape(b)
    }
}

/// Measure one layer under both engines on `x` and format the shared
/// report cells `[baseline, huge2, speedup, max |Δ|]`. The `huge2
/// segment` subcommand and `examples/segment.rs` both build their
/// timing tables from this, so the measurement discipline (warmup,
/// sample count, speedup formula) cannot drift between them.
pub fn layer_timing_cells(l: &SegLayer, x: &Tensor) -> [String; 4] {
    use crate::bench_util::{fmt_dur, measure};
    let tb = measure(1, 5, || {
        std::hint::black_box(l.forward(x, Engine::Baseline));
    });
    let tf = measure(1, 5, || {
        std::hint::black_box(l.forward(x, Engine::Huge2));
    });
    let yb = l.forward(x, Engine::Baseline);
    let yf = l.forward(x, Engine::Huge2);
    [
        fmt_dur(tb.median),
        fmt_dur(tf.median),
        format!("{:.2}x", tb.median_s() / tf.median_s()),
        format!("{:.2e}", yf.max_abs_diff(&yb)),
    ]
}

/// Per-pixel class argmax: logits `(B, H, W, K)` → mask `(B, H, W, 1)` of
/// class indices as f32. Ties break to the **lowest** class index
/// (strict-`>` scan), so the mask is deterministic — a response checksum
/// over it is replayable.
pub fn argmax_mask(logits: &Tensor) -> Tensor {
    let (b, h, w, k) = logits.dims4();
    argmax_mask_from(logits.data(), b, h, w, k)
}

/// [`argmax_mask`] over a raw logits slice (plan executors keep batch
/// logits in a workspace slab; only the mask — the client-owned
/// response — is a fresh tensor).
pub fn argmax_mask_from(src: &[f32], b: usize, h: usize, w: usize,
                        k: usize) -> Tensor {
    let mut out = Tensor::zeros(&[b, h, w, 1]);
    argmax_into(src, b, h, w, k, out.data_mut());
    out
}

/// Slice-level argmax core (the plan's `Head::ArgmaxMask` op). `dst`
/// (length `b·h·w`) is fully overwritten.
pub(crate) fn argmax_into(src: &[f32], b: usize, h: usize, w: usize,
                          k: usize, dst: &mut [f32]) {
    assert!(k > 0);
    assert_eq!(src.len(), b * h * w * k, "logits size");
    assert_eq!(dst.len(), b * h * w, "mask size");
    for (pix, out) in dst.iter_mut().enumerate() {
        let row = &src[pix * k..(pix + 1) * k];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        *out = best as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{segnet, tiny_segnet};

    #[test]
    fn tiny_net_shapes() {
        let net = SegNet::new(&tiny_segnet(), 5);
        assert_eq!(net.in_shape(), vec![1, 9, 9, 2]);
        assert_eq!(net.logits_shape(3), vec![3, 9, 9, 3]);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&net.in_shape(), &mut rng);
        let logits = net.forward(&x);
        assert_eq!(logits.shape(), net.logits_shape(1).as_slice());
        let mask = net.predict(&x);
        assert_eq!(mask.shape(), &[1, 9, 9, 1]);
        let nc = net.n_classes() as f32;
        assert!(mask.data().iter().all(|&v| v >= 0.0 && v < nc
                                       && v.fract() == 0.0));
    }

    #[test]
    fn engines_agree_and_huge2_is_deterministic() {
        let net = SegNet::new(&tiny_segnet(), 7);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&net.in_shape(), &mut rng);
        let a = net.forward_with(&x, Some(Engine::Huge2));
        let b = net.forward_with(&x, Some(Engine::Baseline));
        assert!(a.allclose(&b, 1e-4), "diff {}", a.max_abs_diff(&b));
        let a2 = net.forward_with(&x, Some(Engine::Huge2));
        assert_eq!(a.checksum(), a2.checksum());
        // the stored per-layer-config plan stays within tolerance too
        let c = net.forward(&x);
        assert!(c.allclose(&a, 1e-4));
        assert_eq!(c.checksum(), net.forward(&x).checksum());
    }

    #[test]
    fn seeded_weights_reproduce() {
        let a = SegNet::new(&segnet(), 11);
        let b = SegNet::new(&segnet(), 11);
        assert_eq!(a.trunk[0].kernel.checksum(),
                   b.trunk[0].kernel.checksum());
        assert_eq!(a.head.kernel.checksum(), b.head.kernel.checksum());
        assert_eq!(a.plan().engine_digest(), b.plan().engine_digest());
        let c = SegNet::new(&segnet(), 12);
        assert_ne!(a.head.kernel.checksum(), c.head.kernel.checksum());
    }

    #[test]
    fn argmax_mask_breaks_ties_low() {
        let logits = Tensor::from_vec(&[1, 1, 2, 3],
                                      vec![1.0, 3.0, 3.0, 2.0, -1.0, 2.0]);
        let m = argmax_mask(&logits);
        assert_eq!(m.data(), &[1.0, 0.0]);
    }
}
