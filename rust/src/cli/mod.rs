//! Hand-rolled CLI (no clap in the vendor set).
//!
//! ```text
//! huge2 inspect                       # Table 1, MAC counts, artifacts
//! huge2 bench --layer dcgan_dc3       # one layer, both engines
//! huge2 plan --net segnet             # compiled plan: engines, threads,
//!                                     # prepacked bytes, ws high-water
//! huge2 plan --net dcgan --profile    # + observed per-layer costs
//!                                     # (--profile-runs N, --profile-out f)
//! huge2 tune --net dcgan --out tuned.bin
//!                                     # memsim-scored autotune: argmin
//!                                     # engine×threads×tile per layer,
//!                                     # persisted (--reference pins the
//!                                     # deterministic cost constants)
//! huge2 plan --net dcgan --tuned tuned.bin
//!                                     # heuristic-vs-tuned per layer +
//!                                     # predicted DRAM bytes column
//! huge2 serve --native --tuned tuned.bin
//!                                     # serve under the tuned plan
//!                                     # (--autotune tunes at load)
//! huge2 serve --model dcgan --rate 2 --requests 20
//! huge2 serve --native --stats-every 1 --profile-layers
//!                                     # periodic [stats] lines + per-layer
//!                                     # profile at shutdown
//! huge2 serve --native --dump-metrics # Prometheus-style exposition
//! huge2 serve --native --record t.jsonl
//! huge2 serve --native --record t.bin # .bin → compact binary codec
//! huge2 serve --task segment --record t.jsonl   # seg-net serving
//! huge2 serve --native --record t.bin --checkpoint-every 128
//!                                     # checkpoint cadence (0 = off)
//! huge2 segment --net segnet          # one-shot: timing table + mask
//! huge2 replay t.jsonl --timing fast  # verify recorded checksums
//! huge2 replay t.bin --window 2..5 --progress
//!                                     # replay a checkpoint-window slice
//! huge2 trace info t.bin              # format, header, windows, fps
//! huge2 trace convert t.jsonl t.bin   # lossless re-encode (either way)
//! huge2 trace fingerprints t.bin      # per-window fingerprint table
//! huge2 trace bisect t.bin            # first divergent window, O(log W)
//! huge2 reproduce                     # all paper tables (text form)
//! ```
//!
//! Grammar: `huge2 <subcommand> [positional...] [--key value | --flag]`.
//! Positionals (e.g. the replay trace path) must precede the first flag.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed `[positional...] --key value / --flag` arguments after the
/// subcommand.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    positionals: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`.
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut it = argv.iter();
        let subcommand = it
            .next()
            .ok_or_else(|| anyhow!("usage: huge2 <inspect|bench|plan|\
                                    tune|serve|segment|replay|trace|\
                                    reproduce> \
                                    [positional] [--key value]"))?
            .clone();
        let mut positionals = Vec::new();
        let mut flags = HashMap::new();
        let mut seen_flag = false;
        while let Some(arg) = it.next() {
            let key = match arg.strip_prefix("--") {
                Some(key) => key,
                None if !seen_flag => {
                    // leading bare tokens are positionals
                    positionals.push(arg.clone());
                    continue;
                }
                None => bail!("expected --flag, got {arg:?} \
                               (positionals must precede flags)"),
            };
            seen_flag = true;
            if key.is_empty() {
                bail!("empty flag name");
            }
            // value-less flags get "true"
            match it.clone().next() {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(key.to_string(), v.clone());
                    it.next();
                }
                _ => {
                    flags.insert(key.to_string(), "true".to_string());
                }
            }
        }
        Ok(Args { subcommand, positionals, flags })
    }

    /// `i`-th bare (non-flag) argument after the subcommand.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// Error if the command received more than `max` positionals — a
    /// typo'd flag (`serve native` for `serve --native`) must fail
    /// loudly, not be silently ignored.
    pub fn expect_positionals_at_most(&self, max: usize) -> Result<()> {
        if self.positionals.len() > max {
            bail!("unexpected argument {:?} (did you mean --{}?)",
                  self.positionals[max], self.positionals[max]);
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, \
                                      got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&argv("bench --layer dcgan_dc3 --iters 5 \
                                   --verbose")).unwrap();
        assert_eq!(a.subcommand, "bench");
        assert_eq!(a.get("layer"), Some("dcgan_dc3"));
        assert_eq!(a.get_usize("iters", 1).unwrap(), 5);
        assert!(a.has("verbose"));
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Args::parse(&[]).is_err());
        // bare token *after* a flag pair is an error, not a positional
        assert!(Args::parse(&argv("bench --layer x --iters 3 stray"))
            .is_err());
        let a = Args::parse(&argv("bench --iters foo")).unwrap();
        assert!(a.get_usize("iters", 1).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&argv("serve --verbose --rate 2.5")).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
    }

    #[test]
    fn positionals_precede_flags() {
        let a = Args::parse(&argv("replay trace.jsonl --timing fast"))
            .unwrap();
        assert_eq!(a.subcommand, "replay");
        assert_eq!(a.positional(0), Some("trace.jsonl"));
        assert_eq!(a.positional(1), None);
        assert_eq!(a.get("timing"), Some("fast"));
        // multiple positionals keep order
        let b = Args::parse(&argv("replay a.jsonl b.jsonl")).unwrap();
        assert_eq!(b.positional(0), Some("a.jsonl"));
        assert_eq!(b.positional(1), Some("b.jsonl"));
    }

    #[test]
    fn stray_positionals_are_rejected_on_demand() {
        // `serve native` (typo'd flag) parses, but the handler-side
        // check refuses it instead of silently ignoring the token
        let a = Args::parse(&argv("serve native")).unwrap();
        assert!(a.expect_positionals_at_most(0).is_err());
        let b = Args::parse(&argv("replay t.jsonl")).unwrap();
        assert!(b.expect_positionals_at_most(1).is_ok());
        let c = Args::parse(&argv("replay t.jsonl extra")).unwrap();
        assert!(c.expect_positionals_at_most(1).is_err());
    }
}
