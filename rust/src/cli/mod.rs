//! Hand-rolled CLI (no clap in the vendor set).
//!
//! ```text
//! huge2 inspect                       # Table 1, MAC counts, artifacts
//! huge2 bench --layer dcgan_dc3       # one layer, both engines
//! huge2 serve --model dcgan --rate 2 --requests 20
//! huge2 reproduce                     # all paper tables (text form)
//! ```

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed `--key value` / `--flag` arguments after the subcommand.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`.
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut it = argv.iter();
        let subcommand = it
            .next()
            .ok_or_else(|| anyhow!("usage: huge2 <inspect|bench|serve|\
                                    reproduce> [--key value]"))?
            .clone();
        let mut flags = HashMap::new();
        while let Some(arg) = it.next() {
            let key = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {arg:?}"))?;
            if key.is_empty() {
                bail!("empty flag name");
            }
            // value-less flags get "true"
            match it.clone().next() {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(key.to_string(), v.clone());
                    it.next();
                }
                _ => {
                    flags.insert(key.to_string(), "true".to_string());
                }
            }
        }
        Ok(Args { subcommand, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, \
                                      got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&argv("bench --layer dcgan_dc3 --iters 5 \
                                   --verbose")).unwrap();
        assert_eq!(a.subcommand, "bench");
        assert_eq!(a.get("layer"), Some("dcgan_dc3"));
        assert_eq!(a.get_usize("iters", 1).unwrap(), 5);
        assert!(a.has("verbose"));
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Args::parse(&[]).is_err());
        assert!(Args::parse(&argv("bench layer")).is_err());
        let a = Args::parse(&argv("bench --iters foo")).unwrap();
        assert!(a.get_usize("iters", 1).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&argv("serve --verbose --rate 2.5")).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
    }
}
