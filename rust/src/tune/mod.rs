//! Measured cost-model autotuner — memsim-scored plan selection
//! (DESIGN.md §15, ROADMAP item 4).
//!
//! `Engine::Auto` is two hard-coded shape thresholds. This module turns
//! engine choice into a measured argmin: every compute step's candidate
//! configurations — engine (Baseline / HUGE² / Segregated where
//! applicable) × thread count × GEMM tile — are scored by replaying
//! their exact access streams through the [`crate::memsim`] cache
//! hierarchy, converting the resulting MAC / L2-byte / DRAM-byte counts
//! to nanoseconds with a [`Calibration`] fitted once against real
//! microbenchmarks, and the cheapest candidate wins. Ties (and
//! anything not *strictly* cheaper) keep the heuristic's choice, so an
//! uninformative calibration degrades to exactly today's behaviour.
//!
//! The result is a [`TunedPlan`]: a small binary artifact (`HG2TUNED`)
//! persisted by `huge2 tune`, keyed by the heuristic plan's
//! engine-selection digest + ISA/numerics tier, and applied at serve
//! start via [`crate::plan::ExecPlan::with_tuning`] — so serving
//! start-up stays instant and the tuned selections fold into the plan
//! digest exactly like the FMA numerics term: a trace recorded under
//! one selection set fails loudly (never silently diverges) when
//! replayed under another.

use std::path::Path;
use std::sync::Arc;

use crate::bench_util::measure;
use crate::config::LayerConfig;
use crate::deconv::{huge2, DeconvParams, DilatedParams, Engine};
use crate::gemm::{active_isa, Tile};
use crate::memsim::{
    trace_dilated_threads, trace_gemm_shape, trace_transpose, AccessStats,
    EngineKind, LayerTrace,
};
use crate::plan::{
    host_threads, run_transpose_op, ExecPlan, PlanOp, PlanStep,
    PlanTuning, StepSelection, AUTO_THREADS,
};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::workspace::Workspace;

/// First 8 bytes of every persisted tuned plan.
pub const MAGIC: [u8; 8] = *b"HG2TUNED";

/// Artifact format version. Bump on any layout change; loaders fall
/// back to the heuristic (with a warning) on mismatch instead of
/// guessing at bytes.
pub const TUNED_VERSION: u32 = 1;

/// First 8 bytes of a measured-calibration cache file.
pub const CAL_MAGIC: [u8; 8] = *b"HG2CALIB";

/// Calibration-cache format version.
pub const CAL_VERSION: u32 = 1;

/// Nominal batch rows the Project step is scored at (the serving
/// coordinator's typical formed-batch size; the step is a dense GEMM
/// whose blocking preference is insensitive to small-m changes).
const TUNE_BATCH_ROWS: usize = 8;

/// Cache line size the memsim hierarchy models (bytes).
const LINE: u64 = 64;

/// Decode-side cap on step-name strings.
const MAX_STR: u64 = 1 << 12;

/// Decode-side cap on the step count.
const MAX_STEPS: u64 = 1 << 12;

// ------------------------------------------------------- calibration

/// Cost coefficients mapping memsim counts to nanoseconds:
///
/// ```text
/// ns(stream) = macs·ns_per_mac + l2_bytes·ns_per_l2_byte
///            + dram_bytes·ns_per_dram_byte
/// ns(layer)  = ns(serial) + ns(heaviest shard)
///            + shards·thread_spawn_ns   (when shards > 1)
/// ```
///
/// where `l2_bytes` is the bytes served from L2 (L1-miss lines that hit
/// L2 × 64) and `dram_bytes` the L2-miss lines × 64. [`reference`]
/// ships fixed, deterministic edge-CPU-plausible constants (the CI /
/// reproducibility mode); [`measured`] fits the three stream
/// coefficients to timed single-thread microbenchmarks of the real
/// engines by least squares and times the scoped-thread spawn overhead
/// directly.
///
/// [`reference`]: Calibration::reference
/// [`measured`]: Calibration::measured
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    pub ns_per_mac: f64,
    pub ns_per_l2_byte: f64,
    pub ns_per_dram_byte: f64,
    /// Per-shard spawn/join overhead of a scoped worker thread.
    pub thread_spawn_ns: f64,
    /// True when fitted from this host's microbenchmarks (vs the
    /// deterministic reference constants).
    pub measured: bool,
}

impl Calibration {
    /// Deterministic reference constants: ~4 GMAC/s scalar core,
    /// ~16 GB/s L2, ~4 GB/s DRAM, 15 µs per scoped thread spawn —
    /// the paper's Cortex-A57-class testbed, rounded. Same bytes on
    /// every host, so `huge2 tune --reference` is byte-deterministic.
    pub fn reference() -> Calibration {
        Calibration {
            ns_per_mac: 0.25,
            ns_per_l2_byte: 0.0625,
            ns_per_dram_byte: 0.25,
            thread_spawn_ns: 15_000.0,
            measured: false,
        }
    }

    /// Fit the three stream coefficients against timed single-thread
    /// runs of all three transpose engines on a handful of shapes
    /// (9 samples, 3 unknowns, least squares via normal equations),
    /// and time the scoped-spawn overhead directly. Falls back to the
    /// reference constants per-coefficient if the fit degenerates
    /// (non-finite or non-positive).
    pub fn measured() -> Calibration {
        // (h, c_in, c_out, k) at stride 2 / pad 1 — small enough to
        // keep `huge2 tune` in the seconds, large enough that the
        // GEMM/cache terms dominate the timer floor.
        const SHAPES: [(usize, usize, usize, usize); 3] =
            [(8, 64, 32, 4), (16, 32, 16, 4), (4, 128, 64, 4)];
        let ws = Workspace::new();
        let mut rows: Vec<[f64; 3]> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for (si, &(h, c_in, c_out, k)) in SHAPES.iter().enumerate() {
            let p = DeconvParams::new(2, 1, 0);
            let cfg = cal_layer(h, c_in, c_out, k, &p);
            let mut rng = Rng::new(90 + si as u64);
            let x = Tensor::randn(&[1, h, h, c_in], &mut rng);
            let kernel =
                Arc::new(Tensor::randn(&[k, k, c_in, c_out], &mut rng));
            let patterns = huge2::decompose(&kernel, &p);
            let ho = p.out_size(h, k);
            let mut out = vec![0.0f32; ho * ho * c_out];
            for eng in
                [Engine::Baseline, Engine::Huge2, Engine::Segregated]
            {
                let m = measure(1, 5, || {
                    run_transpose_op(x.data(), 1, h, h, c_in, &kernel,
                                     &patterns, k, &p, eng, 1, None,
                                     &mut out, &mut ws.handle());
                });
                let t = trace_layer_for(&cfg, eng);
                rows.push(stream_row(&t));
                ys.push(m.median_s() * 1e9);
            }
        }
        let reference = Calibration::reference();
        let fit = lstsq3(&rows, &ys);
        let pick = |v: f64, fallback: f64| {
            if v.is_finite() && v > 0.0 { v } else { fallback }
        };
        // scoped spawn/join of 4 no-op threads, per thread
        let spawn = measure(1, 5, || {
            std::thread::scope(|s| {
                for _ in 0..4 {
                    s.spawn(|| {});
                }
            });
        });
        Calibration {
            ns_per_mac: pick(fit[0], reference.ns_per_mac),
            ns_per_l2_byte: pick(fit[1], reference.ns_per_l2_byte),
            ns_per_dram_byte: pick(fit[2], reference.ns_per_dram_byte),
            thread_spawn_ns: pick(spawn.median_s() * 1e9 / 4.0,
                                  reference.thread_spawn_ns),
            measured: true,
        }
    }

    /// [`Calibration::measured`] with a warm-host cache: if `path`
    /// holds a calibration fitted on a host with the same
    /// [`host_fingerprint`] (ISA/numerics tier + core count), reuse it
    /// — `serve --autotune` start-up skips the microbenchmarks
    /// entirely. Otherwise fit fresh and refresh the file. Returns the
    /// calibration and whether the cache hit. Cache I/O problems are
    /// never fatal: a missing, corrupt, or foreign-host file simply
    /// re-measures (and a failed write leaves the next start-up cold).
    pub fn measured_cached(path: &Path) -> (Calibration, bool) {
        let fp = host_fingerprint();
        if let Ok(bytes) = std::fs::read(path) {
            if let Ok((cached_fp, cal)) = Self::decode_cache(&bytes) {
                if cached_fp == fp && cal.measured {
                    return (cal, true);
                }
            }
        }
        let cal = Calibration::measured();
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let _ = std::fs::write(path, cal.encode_cache(&fp));
        (cal, false)
    }

    /// Serialise for the calibration cache (deterministic bytes).
    pub fn encode_cache(&self, fingerprint: &str) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&CAL_MAGIC);
        put_varint(&mut buf, CAL_VERSION as u64);
        put_str(&mut buf, fingerprint);
        buf.push(self.measured as u8);
        for v in [self.ns_per_mac, self.ns_per_l2_byte,
                  self.ns_per_dram_byte, self.thread_spawn_ns]
        {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        buf
    }

    /// Decode a calibration-cache file into (host fingerprint,
    /// calibration). Corrupt input errors with a byte offset; callers
    /// treat any error as a cache miss.
    pub fn decode_cache(bytes: &[u8])
                        -> Result<(String, Calibration), String> {
        let mut r = Reader { bytes, pos: 0 };
        let magic = r.take(8)?;
        if magic != CAL_MAGIC {
            return Err(
                "bad magic at byte 0 (not a calibration cache)".into());
        }
        let version = r.varint()?;
        if version != CAL_VERSION as u64 {
            return Err(format!(
                "unsupported calibration cache version {version} (this \
                 build writes {CAL_VERSION})"
            ));
        }
        let fingerprint = r.str()?;
        let measured = r.byte()? != 0;
        let mut vals = [0.0f64; 4];
        for v in &mut vals {
            *v = r.raw_f64()?;
        }
        if r.pos != bytes.len() {
            return Err(format!(
                "{} trailing byte(s) at byte {}",
                bytes.len() - r.pos,
                r.pos
            ));
        }
        Ok((fingerprint, Calibration {
            ns_per_mac: vals[0],
            ns_per_l2_byte: vals[1],
            ns_per_dram_byte: vals[2],
            thread_spawn_ns: vals[3],
            measured,
        }))
    }

    /// Predicted nanoseconds for one access stream.
    pub fn predict_stats(&self, s: &AccessStats) -> f64 {
        let l2_bytes = s.hierarchy.l2_hits * LINE;
        s.macs as f64 * self.ns_per_mac
            + l2_bytes as f64 * self.ns_per_l2_byte
            + s.dram_bytes as f64 * self.ns_per_dram_byte
    }

    /// Predicted nanoseconds for one layer: the serial stream plus the
    /// critical-path shard, plus spawn overhead when sharded.
    pub fn predict(&self, t: &LayerTrace) -> f64 {
        let mut ns =
            self.predict_stats(&t.serial) + self.predict_stats(&t.shard_max);
        if t.shards > 1 {
            ns += self.thread_spawn_ns * t.shards as f64;
        }
        ns
    }
}

/// Host fingerprint the measured-calibration cache is keyed by:
/// ISA/numerics tier + core count. Fitted coefficients are only
/// portable to a host with the same SIMD tier (the microbenchmarks
/// time tier-specific kernels) and the same parallelism (the spawn
/// overhead and candidate thread set depend on it); anything finer
/// (exact CPU model) would under-share, anything coarser would apply
/// one host's memory constants to another's.
pub fn host_fingerprint() -> String {
    format!("{}/c{}", active_isa().name(), host_threads())
}

/// `[macs, l2_bytes, dram_bytes]` regressor row of one layer trace —
/// the serial + critical-shard stream the predictor charges for.
fn stream_row(t: &LayerTrace) -> [f64; 3] {
    let s = t.serial.merge(&t.shard_max);
    [s.macs as f64, (s.hierarchy.l2_hits * LINE) as f64,
     s.dram_bytes as f64]
}

/// Solve `argmin_θ ‖Xθ − y‖²` for 3 coefficients via the normal
/// equations and Gaussian elimination with partial pivoting. Returns
/// NaNs when the system is singular (caller falls back per
/// coefficient).
fn lstsq3(rows: &[[f64; 3]], ys: &[f64]) -> [f64; 3] {
    let mut ata = [[0.0f64; 3]; 3];
    let mut aty = [0.0f64; 3];
    for (r, &y) in rows.iter().zip(ys) {
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += r[i] * r[j];
            }
            aty[i] += r[i] * y;
        }
    }
    let mut m = [[0.0f64; 4]; 3];
    for i in 0..3 {
        m[i][..3].copy_from_slice(&ata[i]);
        m[i][3] = aty[i];
    }
    for col in 0..3 {
        let piv = (col..3)
            .max_by(|&a, &b| {
                m[a][col].abs().partial_cmp(&m[b][col].abs()).unwrap()
            })
            .unwrap();
        m.swap(col, piv);
        if m[col][col].abs() < 1e-30 {
            return [f64::NAN; 3];
        }
        for row in 0..3 {
            if row == col {
                continue;
            }
            let f = m[row][col] / m[col][col];
            for j in col..4 {
                m[row][j] -= f * m[col][j];
            }
        }
    }
    [m[0][3] / m[0][0], m[1][3] / m[1][1], m[2][3] / m[2][2]]
}

// ------------------------------------------------------- scoring

fn engine_kind(e: Engine) -> EngineKind {
    match e {
        Engine::Baseline => EngineKind::Baseline,
        Engine::Huge2 => EngineKind::Huge2,
        Engine::Segregated => EngineKind::Segregated,
        Engine::Auto => unreachable!("Auto is never a scored candidate"),
    }
}

/// Synthetic [`LayerConfig`] for a plan step's geometry (the memsim
/// counters are `LayerConfig`-driven; plan steps carry the same
/// fields).
fn cal_layer(h: usize, c_in: usize, c_out: usize, k: usize,
             p: &DeconvParams) -> LayerConfig {
    LayerConfig {
        name: "tuned",
        gan: "tuned",
        h,
        c_in,
        c_out,
        k,
        stride: p.stride,
        pad: p.pad,
        out_pad: p.out_pad,
    }
}

fn trace_layer_for(cfg: &LayerConfig, eng: Engine) -> LayerTrace {
    trace_transpose(cfg, engine_kind(eng), 1)
}

/// Candidate (engine, threads) set for a transposed-conv step. This is
/// where `Segregated` finally competes: the `Auto` heuristic never
/// selects it (to keep untuned digests stable), but the tuner's
/// candidate space always includes it.
pub fn transpose_candidates(host: usize) -> Vec<(Engine, usize)> {
    let mut cands = vec![(Engine::Baseline, 1)];
    for eng in [Engine::Huge2, Engine::Segregated] {
        for t in thread_set(host) {
            cands.push((eng, t));
        }
    }
    cands
}

/// Candidate (engine, threads) set for a dilated-conv step (no zeros
/// to segregate: Baseline vs HUGE² only).
pub fn dilated_candidates(host: usize) -> Vec<(Engine, usize)> {
    let mut cands = vec![(Engine::Baseline, 1)];
    for t in thread_set(host) {
        cands.push((Engine::Huge2, t));
    }
    cands
}

/// Candidate GEMM tiles for the Project step (default first — the
/// heuristic's choice).
pub fn project_tiles() -> Vec<Tile> {
    vec![
        Tile::DEFAULT,
        Tile { kc: 128, nc: 1024 },
        Tile { kc: 256, nc: 512 },
        Tile { kc: 128, nc: 512 },
        Tile { kc: 64, nc: 256 },
    ]
}

fn thread_set(host: usize) -> Vec<usize> {
    let mut set = vec![1usize];
    for t in [2, AUTO_THREADS.min(host.max(1))] {
        if t > 1 && !set.contains(&t) {
            set.push(t);
        }
    }
    set
}

/// Memsim-predicted DRAM bytes moved by one compiled step at batch 1
/// (`None` for ops without a modeled stream) — the `huge2 plan`
/// bytes-moved column. Needs no calibration: bytes are a pure
/// cache-model output.
pub fn step_bytes_moved(st: &PlanStep) -> Option<u64> {
    match &st.op {
        PlanOp::Project { in_dim, out_dim, .. } => {
            let tile = st.tile.unwrap_or(Tile::DEFAULT);
            Some(trace_gemm_shape(TUNE_BATCH_ROWS, *in_dim, *out_dim,
                                  tile.kc, tile.nc)
                .dram_bytes)
        }
        PlanOp::TransposeConv { k, params, h, c_in, c_out, .. } => {
            let cfg = cal_layer(*h, *c_in, *c_out, *k, params);
            let eng = st.engine?;
            Some(trace_transpose(&cfg, engine_kind(eng), st.threads)
                .total
                .dram_bytes)
        }
        PlanOp::DilatedConv { taps, params, h, c_in, c_out, .. } => {
            let eng = st.engine?;
            let kind = match eng {
                Engine::Baseline => EngineKind::Baseline,
                _ => EngineKind::Huge2,
            };
            Some(trace_dilated_threads(*h, *c_in, *c_out, taps.r, params,
                                       kind, st.threads)
                .total
                .dram_bytes)
        }
        PlanOp::Activation(_) | PlanOp::Head(_) => None,
    }
}

// ------------------------------------------------------- tuned plan

/// One step's tuned outcome (plus the heuristic's choice and score, so
/// `huge2 plan --tuned` can print heuristic-vs-tuned per layer).
#[derive(Debug, Clone, PartialEq)]
pub struct TunedStep {
    pub name: String,
    /// Tuned selection (`None` engine = non-compute step, untouched).
    pub engine: Option<Engine>,
    pub threads: usize,
    pub tile: Option<Tile>,
    pub predicted_ns: f64,
    /// Memsim DRAM bytes of the tuned selection (batch 1).
    pub predicted_dram: u64,
    /// What the compiled plan (the heuristic) had chosen.
    pub heuristic_engine: Option<Engine>,
    pub heuristic_threads: usize,
    pub heuristic_ns: f64,
}

impl TunedStep {
    /// Did the tuner pick something other than the heuristic?
    pub fn differs(&self) -> bool {
        self.engine != self.heuristic_engine
            || (self.engine.is_some()
                && self.threads != self.heuristic_threads)
            || self.tile.is_some()
    }
}

/// The persisted autotuning artifact: per-step argmin selections for
/// one compiled plan, keyed by that plan's digest + the ISA/numerics
/// tier it was scored under.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedPlan {
    /// Net name the plan was compiled for (CLI bookkeeping only).
    pub net: String,
    /// `active_isa().name()` at tune time — tile and engine preferences
    /// are ISA-dependent, and `avx2+fma` additionally implies the
    /// relaxed-numerics digest term.
    pub isa: String,
    /// Digest of the heuristic plan the tuning was computed against.
    pub base_digest: u64,
    /// Digest of the plan after applying the selections (what replay
    /// headers record when serving under this tuning).
    pub tuned_digest: u64,
    pub cal: Calibration,
    pub steps: Vec<TunedStep>,
}

/// Outcome of decoding a tuned-plan file.
#[derive(Debug, Clone, PartialEq)]
pub enum LoadedTuned {
    Tuned(TunedPlan),
    /// Recognised magic, unsupported version — the caller warns and
    /// falls back to the heuristic plan.
    VersionMismatch { found: u64 },
}

/// Score every step of `plan` over the full candidate space and return
/// the argmin selections. The heuristic's own (engine, threads) is
/// always scored first and only a *strictly* cheaper candidate
/// replaces it, so ties keep today's behaviour.
pub fn tune_plan(plan: &ExecPlan, net: &str, cal: &Calibration)
                 -> TunedPlan {
    let host = host_threads();
    let mut steps = Vec::with_capacity(plan.steps().len());
    for st in plan.steps() {
        steps.push(tune_step(st, cal, host));
    }
    let tuned_digest = plan
        .with_tuning(&tuning_of(&steps))
        .engine_digest();
    TunedPlan {
        net: net.to_string(),
        isa: active_isa().name().to_string(),
        base_digest: plan.engine_digest(),
        tuned_digest,
        cal: *cal,
        steps,
    }
}

fn tune_step(st: &PlanStep, cal: &Calibration, host: usize) -> TunedStep {
    let untouched = || TunedStep {
        name: st.name.clone(),
        engine: None,
        threads: 1,
        tile: None,
        predicted_ns: 0.0,
        predicted_dram: 0,
        heuristic_engine: None,
        heuristic_threads: 1,
        heuristic_ns: 0.0,
    };
    match &st.op {
        PlanOp::Activation(_) | PlanOp::Head(_) => untouched(),
        PlanOp::Project { in_dim, out_dim, .. } => {
            let score = |tile: Tile| {
                let s = trace_gemm_shape(TUNE_BATCH_ROWS, *in_dim,
                                         *out_dim, tile.kc, tile.nc);
                (cal.predict_stats(&s), s.dram_bytes)
            };
            let (h_ns, h_dram) = score(Tile::DEFAULT);
            let mut best = (Tile::DEFAULT, h_ns, h_dram);
            for tile in project_tiles() {
                let (ns, dram) = score(tile);
                if ns < best.1 {
                    best = (tile, ns, dram);
                }
            }
            TunedStep {
                name: st.name.clone(),
                engine: None,
                threads: 1,
                tile: (!best.0.is_default()).then_some(best.0),
                predicted_ns: best.1,
                predicted_dram: best.2,
                heuristic_engine: None,
                heuristic_threads: 1,
                heuristic_ns: h_ns,
            }
        }
        PlanOp::TransposeConv { k, params, h, c_in, c_out, .. } => {
            let cfg = cal_layer(*h, *c_in, *c_out, *k, params);
            let heuristic =
                (st.engine.expect("conv step has an engine"), st.threads);
            let score = |(eng, t): (Engine, usize)| {
                let tr = trace_transpose(&cfg, engine_kind(eng), t);
                (cal.predict(&tr), tr.total.dram_bytes)
            };
            let (h_ns, _) = score(heuristic);
            let mut best = (heuristic, h_ns);
            for cand in transpose_candidates(host) {
                if cand == heuristic {
                    continue;
                }
                let (ns, _) = score(cand);
                if ns < best.1 {
                    best = (cand, ns);
                }
            }
            let (_, dram) = score(best.0);
            TunedStep {
                name: st.name.clone(),
                engine: Some(best.0 .0),
                threads: best.0 .1,
                tile: None,
                predicted_ns: best.1,
                predicted_dram: dram,
                heuristic_engine: Some(heuristic.0),
                heuristic_threads: heuristic.1,
                heuristic_ns: h_ns,
            }
        }
        PlanOp::DilatedConv { taps, params, h, c_in, c_out, .. } => {
            let heuristic =
                (st.engine.expect("conv step has an engine"), st.threads);
            let score = |(eng, t): (Engine, usize)| {
                let kind = match eng {
                    Engine::Baseline => EngineKind::Baseline,
                    _ => EngineKind::Huge2,
                };
                let tr = trace_dilated_threads(*h, *c_in, *c_out, taps.r,
                                               params, kind, t);
                (cal.predict(&tr), tr.total.dram_bytes)
            };
            let (h_ns, _) = score(heuristic);
            let mut best = (heuristic, h_ns);
            for cand in dilated_candidates(host) {
                if cand == heuristic {
                    continue;
                }
                let (ns, _) = score(cand);
                if ns < best.1 {
                    best = (cand, ns);
                }
            }
            let (_, dram) = score(best.0);
            TunedStep {
                name: st.name.clone(),
                engine: Some(best.0 .0),
                threads: best.0 .1,
                tile: None,
                predicted_ns: best.1,
                predicted_dram: dram,
                heuristic_engine: Some(heuristic.0),
                heuristic_threads: heuristic.1,
                heuristic_ns: h_ns,
            }
        }
    }
}

fn tuning_of(steps: &[TunedStep]) -> PlanTuning {
    PlanTuning {
        selections: steps
            .iter()
            .enumerate()
            .filter(|(_, ts)| ts.engine.is_some() || ts.tile.is_some())
            .map(|(i, ts)| StepSelection {
                step: i,
                engine: ts.engine,
                threads: ts.threads,
                tile: ts.tile,
            })
            .collect(),
    }
}

impl TunedPlan {
    /// The per-step selections as a [`PlanTuning`] for
    /// [`ExecPlan::with_tuning`].
    pub fn tuning(&self) -> PlanTuning {
        tuning_of(&self.steps)
    }

    /// Number of steps whose tuned choice differs from the heuristic.
    pub fn n_differs(&self) -> usize {
        self.steps.iter().filter(|s| s.differs()).count()
    }

    /// Apply this tuning to the plan it was computed for, enforcing the
    /// artifact's keys: the ISA/numerics tier must match this process,
    /// the stored base digest must match `plan`'s digest (a stale
    /// artifact after a heuristic or model change fails loudly here),
    /// and the rebuilt plan's digest must match the stored tuned
    /// digest.
    pub fn apply(&self, plan: &ExecPlan) -> Result<ExecPlan, String> {
        let isa = active_isa().name();
        if self.isa != isa {
            return Err(format!(
                "tuned plan was tuned for ISA/numerics tier '{}' but \
                 this process runs '{}' — re-run `huge2 tune`",
                self.isa, isa
            ));
        }
        if self.base_digest != plan.engine_digest() {
            return Err(format!(
                "stale tuned plan: tuned against engine digest {:016x} \
                 but this build compiles {:016x} — re-run `huge2 tune`",
                self.base_digest,
                plan.engine_digest()
            ));
        }
        let tuned = plan.with_tuning(&self.tuning());
        if tuned.engine_digest() != self.tuned_digest {
            return Err(format!(
                "tuned plan digest mismatch: artifact says {:016x}, \
                 applying its selections compiles {:016x} — re-run \
                 `huge2 tune`",
                self.tuned_digest,
                tuned.engine_digest()
            ));
        }
        Ok(tuned)
    }

    // ------------------------------------------------------- codec

    /// Serialise (deterministic: same tuning → same bytes).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(128 + 64 * self.steps.len());
        buf.extend_from_slice(&MAGIC);
        put_varint(&mut buf, TUNED_VERSION as u64);
        put_str(&mut buf, &self.net);
        put_str(&mut buf, &self.isa);
        buf.extend_from_slice(&self.base_digest.to_le_bytes());
        buf.extend_from_slice(&self.tuned_digest.to_le_bytes());
        buf.push(self.cal.measured as u8);
        for v in [self.cal.ns_per_mac, self.cal.ns_per_l2_byte,
                  self.cal.ns_per_dram_byte, self.cal.thread_spawn_ns]
        {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        put_varint(&mut buf, self.steps.len() as u64);
        for st in &self.steps {
            put_str(&mut buf, &st.name);
            buf.push(engine_byte(st.engine));
            put_varint(&mut buf, st.threads as u64);
            match st.tile {
                Some(t) => {
                    buf.push(1);
                    put_varint(&mut buf, t.kc as u64);
                    put_varint(&mut buf, t.nc as u64);
                }
                None => buf.push(0),
            }
            buf.extend_from_slice(
                &st.predicted_ns.to_bits().to_le_bytes());
            put_varint(&mut buf, st.predicted_dram);
            buf.push(engine_byte(st.heuristic_engine));
            put_varint(&mut buf, st.heuristic_threads as u64);
            buf.extend_from_slice(
                &st.heuristic_ns.to_bits().to_le_bytes());
        }
        buf
    }

    /// Decode a tuned-plan file. Corrupt or truncated input fails with
    /// a byte offset; a recognised-but-unsupported version returns
    /// [`LoadedTuned::VersionMismatch`] so callers can warn and fall
    /// back to the heuristic.
    pub fn decode(bytes: &[u8]) -> Result<LoadedTuned, String> {
        let mut r = Reader { bytes, pos: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC {
            return Err(
                "bad magic at byte 0 (not a tuned-plan file)".into());
        }
        let version = r.varint()?;
        if version != TUNED_VERSION as u64 {
            return Ok(LoadedTuned::VersionMismatch { found: version });
        }
        let net = r.str()?;
        let isa = r.str()?;
        let base_digest = r.raw_u64()?;
        let tuned_digest = r.raw_u64()?;
        let measured = r.byte()? != 0;
        let mut cal_vals = [0.0f64; 4];
        for v in &mut cal_vals {
            *v = r.raw_f64()?;
        }
        let cal = Calibration {
            ns_per_mac: cal_vals[0],
            ns_per_l2_byte: cal_vals[1],
            ns_per_dram_byte: cal_vals[2],
            thread_spawn_ns: cal_vals[3],
            measured,
        };
        let n = r.len(MAX_STEPS, "step count")?;
        let mut steps = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let engine = r.engine()?;
            let threads = r.varint()? as usize;
            let tile = match r.byte()? {
                0 => None,
                1 => Some(Tile {
                    kc: r.varint()? as usize,
                    nc: r.varint()? as usize,
                }),
                b => {
                    return Err(r.err(&format!(
                        "invalid tile flag {b}")));
                }
            };
            let predicted_ns = r.raw_f64()?;
            let predicted_dram = r.varint()?;
            let heuristic_engine = r.engine()?;
            let heuristic_threads = r.varint()? as usize;
            let heuristic_ns = r.raw_f64()?;
            steps.push(TunedStep {
                name,
                engine,
                threads,
                tile,
                predicted_ns,
                predicted_dram,
                heuristic_engine,
                heuristic_threads,
                heuristic_ns,
            });
        }
        if r.pos != bytes.len() {
            return Err(format!(
                "{} trailing byte(s) at byte {}",
                bytes.len() - r.pos,
                r.pos
            ));
        }
        Ok(LoadedTuned::Tuned(TunedPlan {
            net,
            isa,
            base_digest,
            tuned_digest,
            cal,
            steps,
        }))
    }
}

fn engine_byte(e: Option<Engine>) -> u8 {
    match e {
        None => 0,
        Some(Engine::Baseline) => 1,
        Some(Engine::Huge2) => 2,
        Some(Engine::Segregated) => 3,
        Some(Engine::Auto) => 0, // never persisted; defensive
    }
}

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

/// Positioned byte reader with offset-carrying errors (the
/// `replay::binary` decode idiom).
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.pos)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.bytes.len() {
            return Err(format!(
                "unexpected end of file at byte {} (wanted {n} more \
                 byte(s) — truncated tuned plan?)",
                self.bytes.len()
            ));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn byte(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn varint(&mut self) -> Result<u64, String> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.byte()?;
            if shift == 63 && b > 1 {
                return Err(self.err("varint overflows u64"));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(self.err("varint too long"));
            }
        }
    }

    fn len(&mut self, cap: u64, what: &str) -> Result<usize, String> {
        let at = self.pos;
        let n = self.varint()?;
        if n > cap {
            return Err(format!(
                "implausible {what} length {n} at byte {at} (cap {cap})"
            ));
        }
        Ok(n as usize)
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.len(MAX_STR, "string")?;
        let at = self.pos;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| format!("invalid UTF-8 string at byte {at}"))
    }

    fn raw_u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn raw_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.raw_u64()?))
    }

    fn engine(&mut self) -> Result<Option<Engine>, String> {
        match self.byte()? {
            0 => Ok(None),
            1 => Ok(Some(Engine::Baseline)),
            2 => Ok(Some(Engine::Huge2)),
            3 => Ok(Some(Engine::Segregated)),
            b => Err(self.err(&format!("invalid engine byte {b}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::Generator;

    #[test]
    fn lstsq_recovers_exact_coefficients() {
        // y = 2·a + 3·b + 5·c, noiseless → exact recovery
        let rows = vec![[1.0, 0.0, 0.0], [0.0, 1.0, 0.0],
                        [0.0, 0.0, 1.0], [1.0, 1.0, 1.0],
                        [2.0, 1.0, 4.0]];
        let ys: Vec<f64> = rows
            .iter()
            .map(|r| 2.0 * r[0] + 3.0 * r[1] + 5.0 * r[2])
            .collect();
        let fit = lstsq3(&rows, &ys);
        assert!((fit[0] - 2.0).abs() < 1e-9, "{fit:?}");
        assert!((fit[1] - 3.0).abs() < 1e-9, "{fit:?}");
        assert!((fit[2] - 5.0).abs() < 1e-9, "{fit:?}");
        // singular system → NaNs (caller falls back)
        let bad = lstsq3(&[[1.0, 1.0, 1.0]; 3], &[1.0, 1.0, 1.0]);
        assert!(bad[0].is_nan());
    }

    #[test]
    fn candidate_space_includes_segregated() {
        let cands = transpose_candidates(4);
        assert!(cands.iter().any(|&(e, _)| e == Engine::Segregated),
                "Segregated must compete under tuning");
        assert!(cands.iter().any(|&(e, _)| e == Engine::Baseline));
        assert!(cands.iter().any(|&(e, t)| e == Engine::Huge2 && t > 1));
        assert_eq!(cands[0], (Engine::Baseline, 1));
        // dilated never offers Segregated (nothing to segregate)
        assert!(dilated_candidates(4)
            .iter()
            .all(|&(e, _)| e != Engine::Segregated));
    }

    #[test]
    fn tuned_plan_round_trips_and_is_deterministic() {
        let gen = Generator::tiny_cgan(5);
        let plan = gen.plan();
        let cal = Calibration::reference();
        let a = tune_plan(plan, "tiny_cgan", &cal);
        let b = tune_plan(plan, "tiny_cgan", &cal);
        assert_eq!(a, b, "reference tuning must be deterministic");
        let bytes = a.encode();
        assert_eq!(bytes, b.encode(), "byte-deterministic");
        match TunedPlan::decode(&bytes).unwrap() {
            LoadedTuned::Tuned(back) => assert_eq!(back, a),
            other => panic!("{other:?}"),
        }
        // applying to the plan it was tuned for honours the keys
        let tuned = a.apply(plan).unwrap();
        assert_eq!(tuned.engine_digest(), a.tuned_digest);
    }

    #[test]
    fn decode_rejects_corrupt_and_falls_back_on_version() {
        let gen = Generator::tiny_cgan(5);
        let a = tune_plan(gen.plan(), "tiny_cgan",
                          &Calibration::reference());
        let bytes = a.encode();
        // truncation → byte-offset error
        let err = TunedPlan::decode(&bytes[..bytes.len() - 3])
            .unwrap_err();
        assert!(err.contains("at byte"), "{err}");
        // corrupt magic → error
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        let err = TunedPlan::decode(&bad).unwrap_err();
        assert!(err.contains("bad magic"), "{err}");
        // version bump → clean fallback signal
        let mut v2 = bytes.clone();
        assert_eq!(v2[8], TUNED_VERSION as u8); // one-byte varint today
        v2[8] = 99;
        match TunedPlan::decode(&v2).unwrap() {
            LoadedTuned::VersionMismatch { found } => {
                assert_eq!(found, 99);
            }
            other => panic!("{other:?}"),
        }
        // trailing garbage → error
        let mut long = bytes.clone();
        long.push(0);
        let err = TunedPlan::decode(&long).unwrap_err();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn stale_digest_and_isa_fail_loudly() {
        let gen = Generator::tiny_cgan(5);
        let plan = gen.plan();
        let mut a = tune_plan(plan, "tiny_cgan",
                              &Calibration::reference());
        let good_isa = a.isa.clone();
        a.isa = "other-isa".to_string();
        let err = a.apply(plan).unwrap_err();
        assert!(err.contains("ISA"), "{err}");
        a.isa = good_isa;
        a.base_digest ^= 1;
        let err = a.apply(plan).unwrap_err();
        assert!(err.contains("stale"), "{err}");
    }

    #[test]
    fn calibration_cache_round_trips_and_keys_by_host() {
        let fp = host_fingerprint();
        assert!(fp.contains("/c"), "{fp}");
        // a distinctive fabricated calibration: if measured_cached
        // returns these exact values, it hit the cache (a real fit
        // could never reproduce them)
        let fake = Calibration {
            ns_per_mac: 123.5,
            ns_per_l2_byte: 17.25,
            ns_per_dram_byte: 99.75,
            thread_spawn_ns: 4242.0,
            measured: true,
        };
        let bytes = fake.encode_cache(&fp);
        let (fp2, back) = Calibration::decode_cache(&bytes).unwrap();
        assert_eq!(fp2, fp);
        assert_eq!(back, fake);
        // corrupt inputs are clean errors, not panics
        assert!(Calibration::decode_cache(&bytes[..5]).is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(Calibration::decode_cache(&bad)
            .unwrap_err()
            .contains("magic"));
        let mut long = bytes.clone();
        long.push(7);
        assert!(Calibration::decode_cache(&long)
            .unwrap_err()
            .contains("trailing"));

        let dir = std::env::temp_dir();
        let path = dir.join(format!("huge2_cal_cache_{}.bin",
                                    std::process::id()));
        // warm cache with a matching fingerprint: instant hit
        std::fs::write(&path, &bytes).unwrap();
        let (cal, hit) = Calibration::measured_cached(&path);
        assert!(hit, "matching fingerprint must hit");
        assert_eq!(cal, fake);
        // a foreign-host cache misses, re-measures, and refreshes the
        // file under this host's fingerprint
        std::fs::write(&path, fake.encode_cache("other-isa/c1"))
            .unwrap();
        let (cal, hit) = Calibration::measured_cached(&path);
        assert!(!hit, "foreign fingerprint must re-measure");
        assert!(cal.measured);
        let (fp3, cal3) = Calibration::decode_cache(
            &std::fs::read(&path).unwrap()).unwrap();
        assert_eq!(fp3, fp, "refreshed under this host's key");
        assert_eq!(cal3, cal);
        // and the very next call hits
        let (cal4, hit) = Calibration::measured_cached(&path);
        assert!(hit);
        assert_eq!(cal4, cal);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bytes_moved_column_covers_compute_steps() {
        let gen = Generator::tiny_cgan(5);
        for st in gen.plan().steps() {
            let bytes = step_bytes_moved(st);
            match st.op.kind() {
                "project" | "transpose-conv" => {
                    assert!(bytes.is_some_and(|b| b > 0), "{}", st.name);
                }
                _ => assert!(bytes.is_none(), "{}", st.name),
            }
        }
    }
}
