//! Checkout/checkin buffer pool — the zero-allocation hot-path substrate
//! (DESIGN.md §9).
//!
//! The paper's headline claim is a smaller memory footprint and fewer
//! memory accesses, yet a naive implementation re-allocates scratch on
//! every forward: GEMM packing panels, deconv sub-outputs and tap
//! buffers, im2col column matrices, padded batch latents. A [`Workspace`]
//! makes steady-state serving allocation-free: buffers are checked out of
//! a size-classed pool, used, and checked back in; after a warmup pass
//! every checkout is a pool hit and `bytes_allocated` stays flat — a
//! *testable invariant* (`tests/workspace_stack.rs`), not a hope.
//!
//! Design:
//!
//! * **Size classes** — slabs are `f32` boxes of power-of-two length
//!   (≥ [`MIN_CLASS`]); a checkout of `len` elements draws from class
//!   `len.next_power_of_two()` and exposes exactly `len` elements via
//!   [`WsBuf`]'s `Deref`. Rounding keeps the class count tiny and lets
//!   near-miss shapes (e.g. per-pattern polyphase buffers) share slabs.
//! * **Per-thread handles** — [`Workspace::handle`] returns a
//!   [`WsHandle`] holding a lock-free local cache; the shared pool's
//!   mutex is touched only on local-cache misses and at handle drop
//!   (which returns the cache to the pool). Scoped worker threads each
//!   create a handle from the same `&Workspace`.
//! * **Dirty reuse** — checked-out buffers contain whatever the previous
//!   user left. Every pooled compute path either fully overwrites its
//!   scratch before reading it (GEMM packing, im2col, tap A-assembly) or
//!   checks out zeroed ([`WsHandle::checkout_zeroed`]: padded inputs,
//!   zero-inflated tensors). The pooled-vs-fresh bit-identity property
//!   grid (`tests/prop_engines.rs`) enforces this with NaN poisoning:
//!   any path that reads stale bytes diverges loudly.
//! * **Counters** — atomic `bytes_allocated` / `checkouts` /
//!   `pool_hits` / `pool_misses` make "zero steady-state allocation" an
//!   assertable property: after warmup, `bytes_allocated` must not grow.

use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// Process-unique workspace identities (see [`WsBuf`]'s owner tag).
/// A monotonic id — not the workspace's address — so a buffer that
/// outlives its dropped workspace can never alias a newer one through
/// allocator address reuse.
static WORKSPACE_IDS: AtomicU64 = AtomicU64::new(1);

/// Smallest slab class (elements). 256 f32 = 1 KiB.
pub const MIN_CLASS: usize = 256;

/// Size class for a requested length: next power of two, floored at
/// [`MIN_CLASS`].
#[inline]
pub fn class_of(len: usize) -> usize {
    len.max(MIN_CLASS).next_power_of_two()
}

/// Point-in-time view of a workspace's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WorkspaceCounters {
    /// Total bytes of *fresh* slab allocations (cumulative; one increment
    /// per pool miss). Flat ⇔ the pool is serving every checkout.
    pub bytes_allocated: u64,
    /// Total checkouts (hits + misses).
    pub checkouts: u64,
    /// Checkouts served from a handle's local cache or the shared pool.
    pub pool_hits: u64,
    /// Checkouts that had to allocate a fresh slab.
    pub pool_misses: u64,
}

/// A size-classed pool of `f32` slabs shared by any number of
/// [`WsHandle`]s. `Sync`: the shared pool is mutex-guarded, counters are
/// atomic.
#[derive(Debug)]
pub struct Workspace {
    shared: Mutex<HashMap<usize, Vec<Box<[f32]>>>>,
    bytes_allocated: AtomicU64,
    checkouts: AtomicU64,
    pool_hits: AtomicU64,
    pool_misses: AtomicU64,
    /// Process-unique identity stamped into every [`WsBuf`] at
    /// checkout; [`WsHandle::checkin`] rejects mismatches.
    id: u64,
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

impl Workspace {
    pub fn new() -> Self {
        Workspace {
            shared: Mutex::new(HashMap::new()),
            bytes_allocated: AtomicU64::new(0),
            checkouts: AtomicU64::new(0),
            pool_hits: AtomicU64::new(0),
            pool_misses: AtomicU64::new(0),
            id: WORKSPACE_IDS.fetch_add(1, Relaxed),
        }
    }

    /// A checkout/checkin handle with a lock-free local cache. Create one
    /// per thread; drop returns its cached slabs to the shared pool.
    pub fn handle(&self) -> WsHandle<'_> {
        WsHandle { ws: self, local: HashMap::new(), checked_out_bytes: 0 }
    }

    /// Counter snapshot (atomics, `Relaxed` — exact once the engine is
    /// quiescent, monotone always).
    pub fn counters(&self) -> WorkspaceCounters {
        WorkspaceCounters {
            bytes_allocated: self.bytes_allocated.load(Relaxed),
            checkouts: self.checkouts.load(Relaxed),
            pool_hits: self.pool_hits.load(Relaxed),
            pool_misses: self.pool_misses.load(Relaxed),
        }
    }

    /// Bytes currently parked in the shared pool (excludes handles'
    /// local caches and checked-out buffers).
    pub fn pooled_bytes(&self) -> u64 {
        let shared = self.lock_shared();
        shared
            .values()
            .flat_map(|v| v.iter())
            .map(|s| (s.len() * 4) as u64)
            .sum()
    }

    /// Overwrite every slab in the shared pool with `v` (test hook: NaN
    /// poisoning proves pooled compute paths never read stale scratch —
    /// a forgotten overwrite propagates NaN into the output checksum).
    pub fn poison(&self, v: f32) {
        let mut shared = self.lock_shared();
        for slabs in shared.values_mut() {
            for s in slabs.iter_mut() {
                s.fill(v);
            }
        }
    }

    fn lock_shared(
        &self,
    ) -> std::sync::MutexGuard<'_, HashMap<usize, Vec<Box<[f32]>>>> {
        // A panicking checkout holder must not wedge every other worker:
        // the pool holds only plain slabs, so a poisoned lock is safe to
        // bypass.
        self.shared.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A checked-out slab exposing exactly the requested length. Contents
/// are **dirty** unless it came from [`WsHandle::checkout_zeroed`] —
/// callers must fully overwrite before reading (see module docs).
/// `Send`: moving a buffer across threads (e.g. a per-pattern sub-output
/// handed back for scatter) is fine; check it in to any handle of the
/// **same workspace** — the buffer is tagged with its workspace's
/// identity at checkout, and [`WsHandle::checkin`] rejects foreign
/// buffers (debug assert; in release the slab is freed rather than
/// pooled), so one pool's accounting can never absorb another pool's
/// slabs.
#[derive(Debug)]
pub struct WsBuf {
    slab: Box<[f32]>,
    len: usize,
    /// Process-unique id of the owning [`Workspace`] (not its address —
    /// immune to allocator address reuse), set at checkout.
    owner: u64,
}

impl Deref for WsBuf {
    type Target = [f32];

    #[inline]
    fn deref(&self) -> &[f32] {
        &self.slab[..self.len]
    }
}

impl DerefMut for WsBuf {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.slab[..self.len]
    }
}

/// Per-thread checkout/checkin handle (see [`Workspace::handle`]).
#[derive(Debug)]
pub struct WsHandle<'w> {
    ws: &'w Workspace,
    local: HashMap<usize, Vec<Box<[f32]>>>,
    /// Cumulative class bytes checked out through this handle (hits and
    /// misses alike). A plain field, not an atomic: the handle is
    /// per-thread, so the plan profiler can diff it around a step to
    /// attribute workspace traffic without hot-path synchronisation.
    checked_out_bytes: u64,
}

impl<'w> WsHandle<'w> {
    /// The pool this handle draws from (lets a single-threaded caller
    /// hand the same workspace to a multi-threaded engine).
    pub fn workspace(&self) -> &'w Workspace {
        self.ws
    }

    /// Cumulative class bytes checked out through this handle. Diff two
    /// readings to attribute workspace traffic to a region of code
    /// (used by the per-layer plan profiler).
    #[inline]
    pub fn checked_out_bytes(&self) -> u64 {
        self.checked_out_bytes
    }

    /// Check out `len` elements of **dirty** scratch.
    pub fn checkout(&mut self, len: usize) -> WsBuf {
        let class = class_of(len);
        self.checked_out_bytes += (class * 4) as u64;
        self.ws.checkouts.fetch_add(1, Relaxed);
        let mut reused = self.local.get_mut(&class).and_then(|v| v.pop());
        if reused.is_none() {
            reused =
                self.ws.lock_shared().get_mut(&class).and_then(|v| v.pop());
        }
        let slab = match reused {
            Some(s) => {
                self.ws.pool_hits.fetch_add(1, Relaxed);
                s
            }
            None => {
                self.ws.pool_misses.fetch_add(1, Relaxed);
                self.ws
                    .bytes_allocated
                    .fetch_add((class * 4) as u64, Relaxed);
                vec![0.0f32; class].into_boxed_slice()
            }
        };
        WsBuf { slab, len, owner: self.owner_id() }
    }

    /// Check out `len` elements zeroed (for buffers whose zeros are
    /// load-bearing: padded borders, zero-inflated tensors).
    pub fn checkout_zeroed(&mut self, len: usize) -> WsBuf {
        let mut buf = self.checkout(len);
        buf.fill(0.0);
        buf
    }

    /// Return a buffer to this handle's local cache.
    ///
    /// The buffer must have been checked out of the **same**
    /// [`Workspace`] this handle draws from: pooling a foreign slab
    /// would cross-pollute the two pools and break the
    /// `bytes_allocated`/`pooled_bytes` accounting the zero-alloc
    /// invariants are asserted on (DESIGN.md §9). A foreign checkin is
    /// a caller bug — debug builds panic; release builds refuse the
    /// slab (it is freed, both pools' accounting stays truthful).
    pub fn checkin(&mut self, buf: WsBuf) {
        debug_assert_eq!(
            buf.owner, self.owner_id(),
            "WsBuf checked into a different Workspace than it was \
             checked out of (cross-pool pollution; DESIGN.md §9)");
        if buf.owner != self.owner_id() {
            return; // foreign slab: drop it, never pool it
        }
        self.local.entry(buf.slab.len()).or_default().push(buf.slab);
    }

    /// The owning workspace's identity tag.
    #[inline]
    fn owner_id(&self) -> u64 {
        self.ws.id
    }
}

impl Drop for WsHandle<'_> {
    fn drop(&mut self) {
        if self.local.is_empty() {
            return;
        }
        let mut shared = self.ws.lock_shared();
        for (class, mut slabs) in self.local.drain() {
            shared.entry(class).or_default().append(&mut slabs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_rounding() {
        assert_eq!(class_of(0), MIN_CLASS);
        assert_eq!(class_of(1), MIN_CLASS);
        assert_eq!(class_of(256), 256);
        assert_eq!(class_of(257), 512);
        assert_eq!(class_of(100_000), 131_072);
    }

    #[test]
    fn checkout_len_and_reuse() {
        let ws = Workspace::new();
        let mut h = ws.handle();
        let mut a = h.checkout(300);
        assert_eq!(a.len(), 300);
        a[299] = 7.0;
        h.checkin(a);
        // same class (512) — must be a hit, and dirty
        let b = h.checkout(400);
        assert_eq!(b.len(), 400);
        let c = ws.counters();
        assert_eq!(c.checkouts, 2);
        assert_eq!(c.pool_misses, 1);
        assert_eq!(c.pool_hits, 1);
        assert_eq!(c.bytes_allocated, 512 * 4);
    }

    #[test]
    fn zeroed_checkout_zeros_requested_len() {
        let ws = Workspace::new();
        let mut h = ws.handle();
        let mut a = h.checkout(128);
        a.fill(9.0);
        h.checkin(a);
        let b = h.checkout_zeroed(64);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn handle_drop_returns_to_shared_pool() {
        let ws = Workspace::new();
        {
            let mut h = ws.handle();
            let a = h.checkout(1000);
            h.checkin(a);
        }
        assert_eq!(ws.pooled_bytes(), 1024 * 4);
        let mut h2 = ws.handle();
        let _b = h2.checkout(1024);
        let c = ws.counters();
        assert_eq!(c.pool_misses, 1, "second handle must hit the pool");
        assert_eq!(c.pool_hits, 1);
    }

    #[test]
    fn cross_thread_checkout() {
        let ws = Workspace::new();
        {
            let mut h = ws.handle();
            let b = h.checkout(5000);
            h.checkin(b);
        }
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let mut h = ws.handle();
                    let b = h.checkout(5000);
                    h.checkin(b);
                });
            }
        });
        let c = ws.counters();
        assert_eq!(c.checkouts, 3);
        // at most one extra slab: the two threads may or may not overlap
        assert!(c.pool_misses <= 2);
        assert!(c.pool_hits >= 1);
    }

    /// Foreign checkins are rejected: debug builds assert, release
    /// builds free the slab without pooling it — either way the two
    /// pools' accounting stays truthful.
    #[test]
    #[cfg_attr(debug_assertions,
               should_panic(expected = "different Workspace"))]
    fn foreign_checkin_is_rejected() {
        let ws_a = Workspace::new();
        let ws_b = Workspace::new();
        let mut ha = ws_a.handle();
        let mut hb = ws_b.handle();
        let buf = ha.checkout(512);
        hb.checkin(buf); // debug: panics here
        drop(hb);
        // release: the foreign slab must not have entered B's pool
        assert_eq!(ws_b.pooled_bytes(), 0,
                   "foreign slab pooled into the wrong workspace");
        #[cfg(debug_assertions)]
        unreachable!("debug_assert must reject the foreign checkin");
    }

    #[test]
    fn same_workspace_checkin_across_handles_is_fine() {
        // the sanctioned cross-thread pattern: checked out on one
        // handle, checked in on another handle of the SAME workspace
        let ws = Workspace::new();
        let mut h1 = ws.handle();
        let buf = h1.checkout(512);
        let mut h2 = ws.handle();
        h2.checkin(buf);
        drop(h1);
        drop(h2);
        assert_eq!(ws.pooled_bytes(), 512 * 4);
    }

    #[test]
    fn checked_out_bytes_counts_class_bytes_per_handle() {
        let ws = Workspace::new();
        let mut h = ws.handle();
        assert_eq!(h.checked_out_bytes(), 0);
        let a = h.checkout(300); // class 512
        assert_eq!(h.checked_out_bytes(), 512 * 4);
        h.checkin(a);
        let _b = h.checkout(400); // same class, pool hit — still counted
        assert_eq!(h.checked_out_bytes(), 2 * 512 * 4);
        // a second handle's tally is independent
        let mut h2 = ws.handle();
        let _c = h2.checkout(10); // class MIN_CLASS
        assert_eq!(h2.checked_out_bytes(), (MIN_CLASS * 4) as u64);
        assert_eq!(h.checked_out_bytes(), 2 * 512 * 4);
    }

    #[test]
    fn poison_marks_pooled_slabs() {
        let ws = Workspace::new();
        {
            let mut h = ws.handle();
            let b = h.checkout(256);
            h.checkin(b);
        }
        ws.poison(f32::NAN);
        let mut h = ws.handle();
        let b = h.checkout(256);
        assert!(b[0].is_nan(), "dirty checkout must expose poisoned bytes");
        let z = h.checkout_zeroed(256);
        assert!(z.iter().all(|&v| v == 0.0));
    }
}
