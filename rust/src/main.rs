//! `huge2` — the HUGE² edge serving engine CLI (leader entrypoint).

use anyhow::{anyhow, bail, Result};
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use huge2::bench_util::{fmt_dur, measure_budget, Table};
use huge2::cli::Args;
use huge2::config::{layer_by_name, segnet_by_name, table1, EngineConfig};
use huge2::coordinator::{Engine, Payload, Priority, ServeError};
use huge2::deconv::{baseline, huge2 as engine2, Engine as DeconvEngine};
use huge2::gan::Generator;
use huge2::memsim::{trace_layer, EngineKind, GpuModel};
use huge2::replay::{Recorder, ReplayOptions, Replayer, Timing,
                    TraceHeader, TraceSink, WindowMap,
                    DEFAULT_CHECKPOINT_EVERY};
use huge2::rng::Rng;
use huge2::runtime::RuntimeHandle;
use huge2::seg::SegNet;
use huge2::tensor::Tensor;
use huge2::trace::{self, poisson, Arrival};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("huge2: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    // central stray-positional rejection: `replay` takes one file,
    // `trace` an action plus up to two files
    let max_positionals = match args.subcommand.as_str() {
        "replay" => 1,
        "trace" => 3,
        _ => 0,
    };
    args.expect_positionals_at_most(max_positionals)?;
    match args.subcommand.as_str() {
        "inspect" => inspect(&args),
        "bench" => bench(&args),
        "plan" => plan_cmd(&args),
        "tune" => tune_cmd(&args),
        "serve" => serve(&args),
        "segment" => segment(&args),
        "replay" => replay(&args),
        "trace" => trace_cmd(&args),
        "reproduce" => reproduce(&args),
        other => bail!("unknown subcommand {other:?} \
                        (inspect|bench|plan|tune|serve|segment|replay|\
                         trace|reproduce)"),
    }
}

/// The serving plan the autotuner scores and serves under: for GAN nets
/// the generator's compiled plan, for seg nets the logits plan plus the
/// argmax head (the exact plan workers execute). `gan` aliases `dcgan`.
fn tuning_base_plan(net: &str, seed: u64)
                    -> Result<(huge2::plan::ExecPlan, String)> {
    let name = match net {
        "gan" => "dcgan",
        other => other,
    };
    let plan = match name {
        "dcgan" => Generator::dcgan(seed).plan().clone(),
        "cgan" => Generator::cgan(seed).plan().clone(),
        "tiny_cgan" => Generator::tiny_cgan(seed).plan().clone(),
        other => {
            let cfg = seg_net_cfg(other).map_err(|_| anyhow!(
                "unknown net {other:?} (dcgan|cgan|tiny_cgan|segnet|\
                 tiny_segnet)"))?;
            let n = SegNet::new(&cfg, seed);
            n.plan().with_argmax_head(n.n_classes())
        }
    };
    Ok((plan, name.to_string()))
}

/// Load a `--tuned <file>` artifact. Corrupt/truncated bytes are hard
/// errors (with the decode byte offset); an unsupported format version
/// warns and falls back to the heuristic plan (`None`).
fn load_tuned(args: &Args) -> Result<Option<huge2::tune::TunedPlan>> {
    let Some(path) = path_flag(args, "tuned")? else {
        return Ok(None);
    };
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow!("--tuned {path}: {e}"))?;
    match huge2::tune::TunedPlan::decode(&bytes)
        .map_err(|e| anyhow!("--tuned {path}: {e}"))?
    {
        huge2::tune::LoadedTuned::Tuned(t) => Ok(Some(t)),
        huge2::tune::LoadedTuned::VersionMismatch { found } => {
            eprintln!("warning: {path} is tuned-plan format v{found}; \
                       this build reads v{} — falling back to the \
                       heuristic plan", huge2::tune::TUNED_VERSION);
            Ok(None)
        }
    }
}

/// The calibration a command asked for: `--reference` pins the
/// deterministic constants (byte-identical artifacts across hosts);
/// otherwise fit against this host's timed microbenchmarks, memoized
/// on disk keyed by the host fingerprint (ISA tier + core count) so a
/// warm host skips the measurement entirely (`--recalibrate` forces a
/// fresh fit).
fn calibration_for(args: &Args) -> huge2::tune::Calibration {
    if args.has("reference") {
        return huge2::tune::Calibration::reference();
    }
    let cache = Path::new(args.get("artifacts").unwrap_or("artifacts"))
        .join("calibration.bin");
    if args.has("recalibrate") {
        let _ = std::fs::remove_file(&cache);
    }
    let (cal, warm) = huge2::tune::Calibration::measured_cached(&cache);
    if warm {
        println!("calibration cache hit ({}, host {}) — use \
                  --recalibrate to re-measure",
                 cache.display(), huge2::tune::host_fingerprint());
    } else {
        println!("calibrated cost model against timed microbenchmarks \
                  (cached to {} for host {}; --reference pins \
                  deterministic constants)",
                 cache.display(), huge2::tune::host_fingerprint());
    }
    cal
}

/// `huge2 tune --net <name> --out <file> [--reference]`: score every
/// compute step's candidate configurations (engine × threads × GEMM
/// tile) with the memsim cost model, pick the argmin per step, and
/// persist the [`huge2::tune::TunedPlan`] artifact (DESIGN.md §15).
fn tune_cmd(args: &Args) -> Result<()> {
    let net = args.get_or("net", "dcgan");
    let seed = args.get_usize("seed", 7)? as u64;
    let out = path_flag(args, "out")?.unwrap_or("tuned.bin");
    let (plan, net_name) = tuning_base_plan(&net, seed)?;
    let cal = calibration_for(args);
    println!("cost model: {:.3} ns/MAC, {:.4} ns/L2-byte, \
              {:.3} ns/DRAM-byte, {:.1} µs/thread-spawn ({})",
             cal.ns_per_mac, cal.ns_per_l2_byte, cal.ns_per_dram_byte,
             cal.thread_spawn_ns / 1e3,
             if cal.measured { "measured" } else { "reference" });
    let tuned = huge2::tune::tune_plan(&plan, &net_name, &cal);

    let mut t = Table::new(&["step", "op", "heuristic", "tuned",
                             "pred heur", "pred tuned"]);
    for (st, ts) in plan.steps().iter().zip(&tuned.steps) {
        t.row(&[
            st.name.clone(),
            st.op.kind().into(),
            selection_cell(ts.heuristic_engine, ts.heuristic_threads,
                           None),
            if ts.differs() {
                selection_cell(ts.engine, ts.threads, ts.tile)
            } else {
                "=".into()
            },
            pred_cell(ts.heuristic_ns),
            pred_cell(ts.predicted_ns),
        ]);
    }
    t.print();
    println!("tuned {} of {} step(s) away from the heuristic",
             tuned.n_differs(), tuned.steps.len());
    println!("digests: heuristic {:016x} → tuned {:016x} \
              (isa {})", tuned.base_digest, tuned.tuned_digest,
             tuned.isa);
    std::fs::write(out, tuned.encode())
        .map_err(|e| anyhow!("--out {out}: {e}"))?;
    println!("tuned plan written to {out} (serve: huge2 serve --tuned \
              {out}; inspect: huge2 plan --net {net_name} --tuned {out})");
    Ok(())
}

/// `engine xT [kcxnc]` cell for the tune/plan tables.
fn selection_cell(engine: Option<DeconvEngine>, threads: usize,
                  tile: Option<huge2::gemm::Tile>) -> String {
    let mut s = match engine {
        Some(e) => format!("{} x{threads}", e.name()),
        None => "-".into(),
    };
    if let Some(t) = tile {
        let cell = format!("tile {}x{}", t.kc, t.nc);
        if engine.is_some() {
            s.push(' ');
            s.push_str(&cell);
        } else {
            s = cell;
        }
    }
    s
}

/// Activations/heads have no modeled stream — their prediction is the
/// `-` fallback, not a number.
fn pred_cell(ns: f64) -> String {
    if ns > 0.0 {
        format!("{:.1}µs", ns / 1e3)
    } else {
        "-".into()
    }
}

/// Per-layer observed-cost table from an armed
/// [`huge2::plan::PlanProfile`] (DESIGN.md §12): one row per plan step
/// with run count, EWMA/mean/max wall time and peak workspace bytes.
/// Returns the sum of per-op mean times so callers can cross-check it
/// against the forward-stage span histogram.
fn print_profile_table(plan: &huge2::plan::ExecPlan) -> f64 {
    let prof = plan.profile();
    let mut t = Table::new(&["step", "op", "engine", "runs", "ewma",
                             "mean", "max", "ws peak"]);
    let mut sum_mean_us = 0.0f64;
    for (i, st) in plan.steps().iter().enumerate() {
        let p = prof.step(i);
        sum_mean_us += p.mean_us;
        t.row(&[
            st.name.clone(),
            st.op.kind().into(),
            st.engine.map(|e| e.name().to_string())
                .unwrap_or_else(|| "-".into()),
            p.count.to_string(),
            format!("{:.1}µs", p.ewma_us),
            format!("{:.1}µs", p.mean_us),
            format!("{}µs", p.max_us),
            if p.ws_bytes > 0 {
                format!("{:.1}KB", p.ws_bytes as f64 / 1024.0)
            } else {
                "-".into()
            },
        ]);
    }
    t.print();
    println!("per-op mean total: {sum_mean_us:.1}µs/run \
              ({} profiled run(s))", prof.runs());
    sum_mean_us
}

/// `huge2 plan --net <name>`: print the compiled execution plan — the
/// per-layer table of resolved engine, threads, prepacked bytes and
/// intermediate shape, plus the plan's workspace high-water mark and
/// engine-selection digest (DESIGN.md §10). With `--profile`, also run
/// the plan `--profile-runs` times through a pooled workspace and print
/// the observed per-layer cost table (optionally persisting the
/// digest-keyed report to `--profile-out`).
fn plan_cmd(args: &Args) -> Result<()> {
    use huge2::plan::{ExecPlan, PlanOp};

    let net = args.get_or("net", "dcgan");
    let seed = args.get_usize("seed", 7)? as u64;
    let batch = args.get_usize("batch", 1)?.max(1);
    let engine = match args.get_or("engine", "auto").as_str() {
        "auto" => DeconvEngine::Auto,
        "huge2" => DeconvEngine::Huge2,
        "baseline" => DeconvEngine::Baseline,
        "segregated" => DeconvEngine::Segregated,
        other => bail!("--engine expects auto|huge2|baseline|segregated, \
                        got {other:?}"),
    };
    let plan: ExecPlan = match net.as_str() {
        "dcgan" => ExecPlan::for_generator(&Generator::dcgan(seed), engine),
        "cgan" => ExecPlan::for_generator(&Generator::cgan(seed), engine),
        "tiny_cgan" => {
            ExecPlan::for_generator(&Generator::tiny_cgan(seed), engine)
        }
        name => {
            let cfg = seg_net_cfg(name).map_err(|_| anyhow!(
                "unknown net {name:?} (dcgan|cgan|tiny_cgan|segnet|\
                 tiny_segnet)"))?;
            let net = SegNet::new(&cfg, seed);
            // --engine auto keeps the per-layer config engines (the
            // registry default is Auto); explicit flags override all
            let over = (engine != DeconvEngine::Auto).then_some(engine);
            // the serving form: logits plan + argmax head
            ExecPlan::for_segnet(&net, over)
                .with_argmax_head(net.n_classes())
        }
    };

    // `--tuned <file>`: show the persisted autotuned selection next to
    // the heuristic per layer (the artifact's keys are enforced —
    // a stale or wrong-ISA file is a hard error, DESIGN.md §15)
    let tuned = load_tuned(args)?;
    let tuned = match &tuned {
        Some(t) => {
            t.apply(&plan).map_err(anyhow::Error::msg)?;
            Some(t)
        }
        None => None,
    };

    println!("{net} (seed {seed}): compiled execution plan, \
              {} steps\n", plan.steps().len());
    // every GEMM-backed step shares the process-wide microkernel tier
    let isa = huge2::gemm::active_isa().name();
    let mut cols = vec!["step", "op", "engine", "isa", "threads",
                        "out shape", "prepacked", "dram/req"];
    if tuned.is_some() {
        cols.push("tuned");
    }
    let mut t = Table::new(&cols);
    for (i, st) in plan.steps().iter().enumerate() {
        let is_compute = !matches!(st.op, PlanOp::Activation(_)
                                          | PlanOp::Head(_));
        let mut row = vec![
            st.name.clone(),
            st.op.kind().into(),
            st.engine.map(|e| e.name().to_string())
                .unwrap_or_else(|| "-".into()),
            if is_compute { isa.into() } else { "-".into() },
            if is_compute { st.threads.to_string() } else { "-".into() },
            format!("{}x{}x{}", st.out_shape[0], st.out_shape[1],
                    st.out_shape[2]),
            if st.prepacked_bytes > 0 {
                format!("{:.1}KB", st.prepacked_bytes as f64 / 1024.0)
            } else {
                "-".into()
            },
            // memsim-predicted DRAM bytes (batch 1); `-` where the op
            // has no modeled stream
            match huge2::tune::step_bytes_moved(st) {
                Some(b) => format!("{:.1}KB", b as f64 / 1024.0),
                None => "-".into(),
            },
        ];
        if let Some(tp) = tuned {
            row.push(match tp.steps.get(i) {
                Some(ts) if ts.differs() => {
                    selection_cell(ts.engine, ts.threads, ts.tile)
                }
                Some(_) => "=".into(),
                None => "-".into(),
            });
        }
        t.row(&row);
    }
    t.print();
    if let Some(tp) = tuned {
        println!("\ntuned plan: {} of {} step(s) differ from the \
                  heuristic; serving digest {:016x} (heuristic \
                  {:016x}, cal: {})",
                 tp.n_differs(), tp.steps.len(), tp.tuned_digest,
                 tp.base_digest,
                 if tp.cal.measured { "measured" } else { "reference" });
    }
    println!("\ninput: {} elems/request; output (batch {batch}): {:?}",
             plan.in_elems(), plan.out_shape(batch));
    println!("prepacked at load: {:.1}KB total (zero packing per \
              inference)", plan.prepacked_bytes() as f64 / 1024.0);
    println!("workspace high-water (batch {batch}): {:.1}KB pooled",
             plan.high_water_elems(batch) as f64 * 4.0 / 1024.0);
    println!("engine-selection digest: {:016x} (recorded in trace \
              headers; replay re-checks it)", plan.engine_digest());

    if args.has("profile") {
        let runs = args.get_usize("profile-runs", 8)?.max(1);
        plan.profile().set_enabled(true);
        let ws = huge2::workspace::Workspace::new();
        let mut hnd = ws.handle();
        let x = Tensor::randn(&[batch, plan.in_elems()],
                              &mut Rng::new(seed ^ 0x9e37_79b9));
        for _ in 0..runs {
            std::hint::black_box(plan.run(&x, &mut hnd));
        }
        println!("\nobserved per-layer costs ({runs} run(s), \
                  batch {batch}):");
        print_profile_table(&plan);
        if let Some(path) = path_flag(args, "profile-out")? {
            std::fs::write(path, plan.profile_report())?;
            println!("profile report ({} steps, digest-keyed) written \
                      to {path}", plan.steps().len());
        }
    }
    Ok(())
}

/// Print Table 1, per-layer MAC accounting and available artifacts.
fn inspect(args: &Args) -> Result<()> {
    println!("Table 1 — deconvolution layer configurations\n");
    let mut t = Table::new(&["layer", "gan", "input", "kernel", "stride",
                             "output", "naive MACs", "HUGE2 MACs", "ratio"]);
    for l in table1() {
        let (naive, eff) = engine2::mac_counts(
            l.h, l.h, l.c_in, l.c_out, l.k, l.k, &l.deconv_params());
        t.row(&[
            l.name.into(),
            l.gan.into(),
            format!("{0}x{0}x{1}", l.h, l.c_in),
            format!("{0}x{0}x{1},{2}", l.k, l.c_in, l.c_out),
            format!("{0}x{0}", l.stride),
            format!("{0}x{0}x{1}", l.h_out(), l.c_out),
            naive.to_string(),
            eff.to_string(),
            format!("{:.2}x", naive as f64 / eff as f64),
        ]);
    }
    t.print();

    let dir = std::path::PathBuf::from(args.get_or("artifacts",
                                                   "artifacts"));
    if dir.join("manifest.txt").exists() {
        let m = huge2::runtime::Manifest::load(&dir)?;
        println!("\n{} AOT artifacts in {}:", m.len(), dir.display());
        for name in m.names() {
            println!("  {name}");
        }
    } else {
        println!("\n(no artifacts at {}; run `make artifacts`)",
                 dir.display());
    }
    Ok(())
}

/// Benchmark one Table-1 layer, both engines.
fn bench(args: &Args) -> Result<()> {
    let name = args.get_or("layer", "dcgan_dc3");
    let layer = layer_by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown layer {name:?}"))?;
    let budget = Duration::from_secs_f64(args.get_f64("budget", 2.0)?);
    let mut rng = Rng::new(42);
    let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
    let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                          &mut rng);
    let p = layer.deconv_params();

    let base = measure_budget(budget, || {
        std::hint::black_box(baseline::conv2d_transpose(&x, &k, &p));
    });
    let patterns = engine2::decompose(&k, &p);
    let fast = measure_budget(budget, || {
        std::hint::black_box(engine2::conv2d_transpose_with(
            &x, &patterns, layer.k, layer.k, &p));
    });
    println!("{name}: baseline {} ±{:.0}%, huge2 {} ±{:.0}%  →  {:.2}x",
             fmt_dur(base.median), 100.0 * base.rel_spread(),
             fmt_dur(fast.median), 100.0 * fast.rel_spread(),
             base.median_s() / fast.median_s());
    // correctness cross-check while we're here
    let want = baseline::conv2d_transpose(&x, &k, &p);
    let got = engine2::conv2d_transpose(&x, &k, &p);
    println!("max |Δ| = {:.2e}", got.max_abs_diff(&want));
    Ok(())
}

/// A flag whose value must be a file path: value-less `--record`
/// parses as the sentinel "true", which must not silently become a
/// file named `true`.
fn path_flag<'a>(args: &'a Args, key: &str) -> Result<Option<&'a str>> {
    match args.get(key) {
        None => Ok(None),
        Some("true") => bail!("--{key} requires a file path"),
        Some(v) => Ok(Some(v)),
    }
}

/// `--config file.toml` supplies defaults; explicit flags override.
fn load_engine_cfg(args: &Args) -> Result<EngineConfig> {
    let base = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            EngineConfig::from_toml(&text)
                .map_err(|e| anyhow!("config {path}: {e}"))?
        }
        None => EngineConfig::default(),
    };
    Ok(EngineConfig {
        workers: args.get_usize("workers", base.workers)?,
        queue_depth: args.get_usize("queue-depth", base.queue_depth)?,
        max_batch: args.get_usize("max-batch", base.max_batch)?,
        batch_timeout_us: args.get_usize(
            "batch-timeout-us", base.batch_timeout_us as usize)? as u64,
        artifact_dir: args.get("artifacts")
            .map(str::to_string)
            .unwrap_or(base.artifact_dir.clone()),
        ..base
    })
}

/// Workload for a serve run: a saved fixture (`--arrivals f`) or
/// synthetic Poisson, optionally re-saved (`--save-arrivals f`).
fn load_workload(args: &Args, rate: f64, n: usize) -> Result<Vec<Arrival>> {
    let arrivals = match path_flag(args, "arrivals")? {
        Some(path) => {
            let tr = trace::load(Path::new(path))?;
            println!("arrival fixture {path}: {} requests", tr.len());
            tr
        }
        None => {
            let tr = poisson(rate, n, 99);
            println!("open-loop Poisson workload: rate={rate}/s, \
                      {n} requests");
            tr
        }
    };
    if let Some(path) = path_flag(args, "save-arrivals")? {
        trace::save(Path::new(path), &arrivals)?;
        println!("saved arrival fixture to {path}");
    }
    Ok(arrivals)
}

/// Periodic one-line stats reporter (`serve --stats-every <secs>`): a
/// thread snapshots the engine's metric registry every tick and prints
/// the windowed delta — throughput, outcome counts, in-flight depth and
/// stage p50s — without ever touching the serving hot path.
struct StatsReporter {
    tx: mpsc::Sender<()>,
    join: std::thread::JoinHandle<()>,
}

impl StatsReporter {
    fn stop(self) {
        let _ = self.tx.send(());
        let _ = self.join.join();
    }
}

fn spawn_stats(eng: &Engine, every: Duration) -> StatsReporter {
    let reg = eng.registry();
    let (tx, rx) = mpsc::channel::<()>();
    let join = std::thread::spawn(move || {
        let mut prev = reg.snapshot();
        let mut t_prev = Instant::now();
        loop {
            match rx.recv_timeout(every) {
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return;
                }
            }
            let cur = reg.snapshot();
            let dt = t_prev.elapsed().as_secs_f64().max(1e-9);
            t_prev = Instant::now();
            let d = cur.delta(&prev);
            let n = |k: &str| d.counters.get(k).copied().unwrap_or(0);
            let queue = d.merged_histogram("huge2_stage_queue_wait_us");
            let fwd = d.merged_histogram("huge2_stage_forward_us");
            println!(
                "[stats] {:6.1} req/s | completed={} rejected={} \
                 failed={} shed={} dropped={} | in_flight={} | \
                 p50 queue={} forward={}",
                n("huge2_completed_total") as f64 / dt,
                n("huge2_completed_total"),
                n("huge2_rejected_total"),
                n("huge2_failed_total"),
                n("huge2_shed_total"),
                n("huge2_dropped_total"),
                cur.gauges.get("huge2_in_flight").copied().unwrap_or(0),
                fmt_dur(Duration::from_micros(queue.quantile_us(0.5))),
                fmt_dur(Duration::from_micros(fwd.quantile_us(0.5))));
            // fleet serving: one sub-line per model that saw activity
            // this tick, from the labeled per-model counter series
            let mut models: Vec<&str> = d.counters.keys()
                .filter_map(|k| k
                    .strip_prefix("huge2_model_submitted_total{model=\"")
                    .and_then(|r| r.strip_suffix("\"}")))
                .collect();
            models.sort_unstable();
            // a single-model serve keeps the classic one-line output
            if models.len() < 2 {
                models.clear();
            }
            for m in models {
                let g = |what: &str| d.counters
                    .get(&format!(
                        "huge2_model_{what}_total{{model=\"{m}\"}}"))
                    .copied()
                    .unwrap_or(0);
                let total = g("submitted") + g("completed")
                    + g("rejected") + g("failed");
                if total == 0 {
                    continue;
                }
                println!("[stats]   {m}: submitted={} completed={} \
                          rejected={} failed={} shed={}",
                         g("submitted"), g("completed"), g("rejected"),
                         g("failed"), g("shed"));
            }
            prev = cur;
        }
    });
    StatsReporter { tx, join }
}

/// Observability options for a serve run (`--stats-every <secs>`,
/// `--profile-layers`, `--dump-metrics`), armed right after model
/// registration and settled by [`finish_serve`].
struct ServeObs {
    reporter: Option<StatsReporter>,
    profiled: Option<String>,
    dump_metrics: bool,
}

impl ServeObs {
    fn arm(args: &Args, eng: &Engine, model: &str) -> Result<Self> {
        let profiled = if args.has("profile-layers") {
            if !eng.enable_layer_profiling(model) {
                bail!("--profile-layers: model {model:?} has no \
                       compiled plan to profile (PJRT backend?)");
            }
            Some(model.to_string())
        } else {
            None
        };
        let every = args.get_f64("stats-every", 0.0)?;
        let reporter = (every > 0.0)
            .then(|| spawn_stats(eng, Duration::from_secs_f64(every)));
        Ok(ServeObs { reporter, profiled,
                      dump_metrics: args.has("dump-metrics") })
    }
}

/// Drain outcomes (responses *and* typed failures — every accepted
/// request terminates in exactly one), print throughput/latency/batching
/// plus the outcome-conservation counters, shut down, and — when
/// recording — save the trace (only after shutdown: workers have
/// flushed every batch/response/failure event into the sink by then).
fn finish_serve(eng: Engine,
                pending: Vec<std::sync::mpsc::Receiver<
                    huge2::coordinator::ServeResult>>,
                t0: Instant, record: Option<(&str, Arc<TraceSink>,
                                             TraceHeader)>,
                obs: ServeObs) -> Result<()> {
    let mut lat = Vec::new();
    let mut failed = 0usize;
    let mut shed = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(resp)) => lat.push(resp.latency),
            Ok(Err(ServeError::Shed { .. })) => {
                // displaced by a higher class after admission — counted
                // quietly (the summary line reports the total)
                shed += 1;
            }
            Ok(Err(e)) => {
                failed += 1;
                println!("  failed ({}): {e}", e.kind());
            }
            Err(_) => bail!("reply channel closed without a terminal \
                             outcome (engine bug)"),
        }
    }
    if shed > 0 {
        println!("  {shed} request(s) shed by priority admission");
    }
    let wall = t0.elapsed();
    if let Some(r) = obs.reporter {
        r.stop();
    }
    lat.sort_unstable();
    {
        use std::sync::atomic::Ordering::Relaxed;
        let c = &eng.counters;
        println!("outcomes: submitted={} completed={} rejected={} \
                  failed={} (shed={}, dropped={}, worker panics={})",
                 c.submitted.load(Relaxed), c.completed.load(Relaxed),
                 c.rejected.load(Relaxed), c.failed.load(Relaxed),
                 c.shed.load(Relaxed), c.dropped.load(Relaxed),
                 c.panics.load(Relaxed));
        let names = eng.model_names();
        if names.len() > 1 {
            for name in names {
                let Some(c) = eng.model_counters(name) else { continue };
                println!("  [{name}] submitted={} completed={} \
                          rejected={} failed={} shed={}",
                         c.submitted.load(Relaxed),
                         c.completed.load(Relaxed),
                         c.rejected.load(Relaxed),
                         c.failed.load(Relaxed), c.shed.load(Relaxed));
            }
        }
    }
    if let Some(res) = eng.residency() {
        println!("residency: {} KiB resident of {} budget, \
                  {} eviction(s), {} reload(s)",
                 res.resident_bytes() >> 10,
                 if res.budget_bytes() == 0 { "unlimited".to_string() }
                 else { format!("{} KiB", res.budget_bytes() >> 10) },
                 res.evictions(), res.reloads());
    }
    if eng.observability().on() {
        let snap = eng.metrics_snapshot();
        println!("stage latency (all tasks, all outcomes):");
        for stage in huge2::metrics::span::STAGES {
            let m = snap
                .merged_histogram(&format!("huge2_stage_{stage}_us"));
            if m.count() == 0 {
                continue;
            }
            println!("  {stage:<10} p50={} p95={} p99={} max={} (n={})",
                     fmt_dur(Duration::from_micros(m.quantile_us(0.5))),
                     fmt_dur(Duration::from_micros(m.quantile_us(0.95))),
                     fmt_dur(Duration::from_micros(m.quantile_us(0.99))),
                     fmt_dur(Duration::from_micros(m.max_us())),
                     m.count());
        }
    }
    if let Some(name) = &obs.profiled {
        if let Some(plan) = eng.model_plan(name) {
            println!("per-layer profile ({name}):");
            let sum_us = print_profile_table(&plan);
            let fwd = eng.metrics_snapshot()
                .merged_histogram("huge2_stage_forward_us");
            if fwd.count() > 0 {
                println!("cross-check: per-op means sum {sum_us:.1}µs \
                          vs forward-stage mean {:.1}µs per request",
                         fwd.mean_us());
            }
        }
    }
    if obs.dump_metrics {
        println!("# metrics exposition (huge2 serve --dump-metrics)");
        print!("{}", eng.metrics_text());
    }
    if lat.is_empty() {
        bail!("no successful responses ({failed} request(s) failed)");
    }
    println!("completed {} in {} → {:.2} req/s", lat.len(), fmt_dur(wall),
             lat.len() as f64 / wall.as_secs_f64());
    println!("latency p50={} p95={} max={}",
             fmt_dur(lat[lat.len() / 2]),
             fmt_dur(lat[(lat.len() * 95 / 100).min(lat.len() - 1)]),
             fmt_dur(*lat.last().unwrap()));
    println!("mean batch size {:.2}", eng.counters.mean_batch_size());
    // counter handles survive shutdown (it consumes the engine); the
    // Arcs read their final values once the workers have joined
    let fleet_counters = eng.counters.clone();
    let per_model: Vec<(String, Arc<huge2::metrics::Counters>)> = eng
        .model_names()
        .iter()
        .filter_map(|n| eng.model_counters(n)
            .map(|c| (n.to_string(), c)))
        .collect();
    eng.shutdown();
    if let Some((path, sink, header)) = record {
        let rec = Recorder::from_parts(header, sink);
        let n_events = rec.save(Path::new(path))?;
        println!("recorded {n_events} trace events to {path} \
                  (replay: huge2 replay {path} --timing fast)");
    }
    // outcome conservation (DESIGN.md §16): after shutdown every
    // submitted request has exactly one terminal outcome, per model
    // and fleet-wide — a violation is an engine bug, so fail loudly
    {
        use std::sync::atomic::Ordering::Relaxed;
        let check = |who: &str,
                     c: &huge2::metrics::Counters| -> Result<()> {
            let (s, co, r, f) = (c.submitted.load(Relaxed),
                                 c.completed.load(Relaxed),
                                 c.rejected.load(Relaxed),
                                 c.failed.load(Relaxed));
            if s != co + r + f {
                bail!("outcome conservation violated for {who}: \
                       submitted={s} != completed={co} + rejected={r} \
                       + failed={f}");
            }
            Ok(())
        };
        check("fleet", &fleet_counters)?;
        for (name, c) in &per_model {
            check(name, c)?;
        }
    }
    Ok(())
}

/// Install the recording sink for a serve run (when `--record` was
/// given): checkpointing every `--checkpoint-every` events (default
/// 256; 0 disables checkpoints — trace v4, DESIGN.md §13). Must run
/// before any model registers, so workers capture the sink.
fn record_sink(args: &Args, eng: &mut Engine,
               record_path: Option<&str>)
               -> Result<Option<Arc<TraceSink>>> {
    if record_path.is_none() {
        return Ok(None);
    }
    let every = args.get_usize("checkpoint-every",
                               DEFAULT_CHECKPOINT_EVERY)?;
    let s = Arc::new(TraceSink::with_checkpoints(every));
    eng.set_trace_sink(s.clone())?;
    Ok(Some(s))
}

/// Run the serving engine on a synthetic workload, optionally recording
/// a replayable trace. `--task generate` (default) serves latent→image;
/// `--task segment` serves image→mask through the same pipeline;
/// `--models a,b,...` serves a whole fleet of native nets at once
/// (DESIGN.md §16). `--record <path>` picks the on-disk trace format by
/// extension — `.bin` writes the compact binary codec, anything else
/// JSONL; readers always detect the format from the magic bytes, never
/// the extension.
fn serve(args: &Args) -> Result<()> {
    if args.get("models").is_some() {
        return serve_fleet(args);
    }
    match args.get_or("task", "generate").as_str() {
        "generate" => serve_generate(args),
        "segment" => serve_segment(args),
        other => bail!("--task expects 'generate' or 'segment', \
                        got {other:?}"),
    }
}

/// `--priority-default <class>`: the admission class single-model
/// serves submit under (fleet serves cycle classes; this sets the
/// first slot of the cycle).
fn priority_default(args: &Args) -> Result<Priority> {
    match args.get("priority-default") {
        None | Some("true") => Ok(Priority::default()),
        Some(v) => v.parse(),
    }
}

fn serve_generate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "dcgan");
    let rate = args.get_f64("rate", 2.0)?;
    let n = args.get_usize("requests", 20)?;
    let native = args.has("native");
    let seed = args.get_usize("seed", 7)? as u64;
    let cfg = load_engine_cfg(args)?;
    let record_path = path_flag(args, "record")?;

    let mut eng = Engine::new(cfg.clone());
    let sink = record_sink(args, &mut eng, record_path)?;
    let z_dim;
    if native {
        let gen = Arc::new(Generator::dcgan(seed));
        z_dim = gen.z_dim;
        match tuned_serving_plan(args, gen.plan(), "dcgan")? {
            Some(plan) => eng.register_native(
                huge2::coordinator::Model::native_with_plan(
                    &model, gen, 0, plan))?,
            None => eng.register_native(
                huge2::coordinator::Model::native(&model, gen, 0))?,
        }
        println!("serving {model} natively (pure-rust HUGE2 engine, \
                  gemm isa: {})", huge2::gemm::active_isa().name());
    } else {
        if args.get("tuned").is_some() || args.has("autotune") {
            bail!("--tuned/--autotune apply to compiled native plans; \
                   the PJRT backend has none (add --native)");
        }
        let rt = Arc::new(RuntimeHandle::spawn(
            cfg.artifact_dir.clone().into())?);
        eng.register_pjrt(&model, &format!("{model}_gen"), rt, 1, seed)?;
        z_dim = 100;
        println!("serving {model} via PJRT artifacts \
                  (JAX/Pallas HUGE2 kernels)");
    }

    let sobs = ServeObs::arm(args, &eng, &model)?;
    let priority = priority_default(args)?;
    let arrivals = load_workload(args, rate, n)?;
    let t0 = Instant::now();
    let mut rng = Rng::new(1);
    let mut pending = Vec::new();
    for a in &arrivals {
        let wait = a.at.saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let z: Vec<f32> = (0..z_dim).map(|_| rng.next_normal()).collect();
        match eng.submit_with(&model, Payload::latent(z, vec![]),
                              priority) {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("  rejected: {e}"),
        }
    }
    // the compiled plan's engine-selection digest (native; PJRT has no
    // plan) — replay re-checks it against its rebuilt engine
    let engine_digest = eng.plan_digest(&model)
        .map(|d| format!("{d:016x}"))
        .unwrap_or_default();
    let record = sink.map(|s| {
        (record_path.unwrap(), s, TraceHeader {
            model: model.clone(),
            backend: if native { "native" } else { "pjrt" }.into(),
            seed,
            z_dim,
            cond_dim: 0,
            task: "generate".into(),
            net: String::new(),
            engine_digest,
            fleet: Vec::new(),
        })
    });
    finish_serve(eng, pending, t0, record, sobs)
}

/// Resolve the plan a native serve should run under: `--tuned <file>`
/// applies a persisted [`huge2::tune::TunedPlan`] (key-checked: ISA +
/// digest, hard error when stale); `--autotune` tunes in-process at
/// load (calibrating per [`calibration_for`]); neither → `None`, the
/// model's heuristic-compiled plan.
fn tuned_serving_plan(args: &Args, base: &huge2::plan::ExecPlan,
                      net: &str)
                      -> Result<Option<huge2::plan::ExecPlan>> {
    let tuned = match load_tuned(args)? {
        Some(t) => Some(t),
        None if args.has("autotune") => {
            let cal = calibration_for(args);
            Some(huge2::tune::tune_plan(base, net, &cal))
        }
        None => return Ok(None),
    };
    let Some(t) = tuned else { return Ok(None) };
    let plan = t.apply(base).map_err(anyhow::Error::msg)?;
    println!("tuned plan: {} of {} step(s) differ from the heuristic \
              (digest {:016x} → {:016x})",
             t.n_differs(), t.steps.len(), t.base_digest,
             t.tuned_digest);
    Ok(Some(plan))
}

/// Resolve a `--net` / trace-header seg-net name against the registry.
fn seg_net_cfg(name: &str) -> Result<huge2::config::SegNetConfig> {
    segnet_by_name(name).ok_or_else(|| anyhow!(
        "unknown seg net {name:?} (segnet|tiny_segnet)"))
}

/// `huge2 serve --task segment`: serve the native segmentation net
/// (image requests in, class-argmax masks out), same workload/recording
/// surface as the generate path.
fn serve_segment(args: &Args) -> Result<()> {
    let net_name = args.get_or("net", "segnet");
    let model = args.get_or("model", net_name.as_str());
    let rate = args.get_f64("rate", 2.0)?;
    let n = args.get_usize("requests", 20)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let cfg = load_engine_cfg(args)?;
    let record_path = path_flag(args, "record")?;

    let net_cfg = seg_net_cfg(&net_name)?;
    let mut eng = Engine::new(cfg);
    let sink = record_sink(args, &mut eng, record_path)?;
    let net = Arc::new(SegNet::new(&net_cfg, seed));
    let in_shape = net.in_shape();
    let n_classes = net.n_classes();
    // the tuned artifact keys against the full serving plan (argmax
    // head included) — the exact plan the workers execute
    let base = net.plan().with_argmax_head(n_classes);
    match tuned_serving_plan(args, &base, &net_name)? {
        Some(plan) => eng.register_native(
            huge2::coordinator::Model::native_seg_with_plan(
                &model, net, plan))?,
        None => eng.register_native(
            huge2::coordinator::Model::native_seg(&model, net))?,
    }
    println!("serving {model} natively (HUGE2 untangled dilated convs, \
              gemm isa: {}, input {in_shape:?}, {n_classes} classes)",
             huge2::gemm::active_isa().name());

    let sobs = ServeObs::arm(args, &eng, &model)?;
    let priority = priority_default(args)?;
    let arrivals = load_workload(args, rate, n)?;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (i, a) in arrivals.iter().enumerate() {
        let wait = a.at.saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        // canonical synthesis: the per-request seed is all a recording
        // needs to rebuild this image bit-exactly (trace v2)
        let img_seed = seed ^ (i as u64 + 1)
            .wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let img = Tensor::randn(&in_shape, &mut Rng::new(img_seed));
        match eng.submit_with(&model, Payload::image(img, img_seed),
                              priority) {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("  rejected: {e}"),
        }
    }
    let engine_digest = eng.plan_digest(&model)
        .map(|d| format!("{d:016x}"))
        .unwrap_or_default();
    let record = sink.map(|s| {
        (record_path.unwrap(), s, TraceHeader {
            model: model.clone(),
            backend: "native".into(),
            seed,
            z_dim: 0,
            cond_dim: 0,
            task: "segment".into(),
            net: net_name.clone(),
            engine_digest,
            fleet: Vec::new(),
        })
    });
    finish_serve(eng, pending, t0, record, sobs)
}

/// What a fleet member's synthetic requests look like: GAN nets take
/// latent (+ optional condition) vectors, seg nets take images.
enum FleetInput {
    Latent { z_dim: usize, cond_dim: usize },
    Image { shape: Vec<usize> },
}

/// Register one fleet member by net-registry name — on the fleet path
/// the model name IS the net name, so a trace header's roster rebuilds
/// the exact same fleet from the names alone — and return its input
/// synthesis. Fleet members serve their heuristic-compiled plans
/// (`--tuned`/`--autotune` are single-model affordances).
fn register_fleet_model(eng: &mut Engine, name: &str, seed: u64)
                        -> Result<FleetInput> {
    match name {
        "dcgan" | "cgan" | "tiny_cgan" => {
            let (gen, cond_dim) = match name {
                "dcgan" => (Generator::dcgan(seed), 0),
                "cgan" => (Generator::cgan(seed), 10),
                _ => (Generator::tiny_cgan(seed), 0),
            };
            let z_dim = gen.z_dim;
            eng.register_native(huge2::coordinator::Model::native(
                name, Arc::new(gen), cond_dim))?;
            Ok(FleetInput::Latent { z_dim, cond_dim })
        }
        other => {
            let cfg = seg_net_cfg(other).map_err(|_| anyhow!(
                "unknown net {other:?} in --models \
                 (dcgan|cgan|tiny_cgan|segnet|tiny_segnet)"))?;
            let net = Arc::new(SegNet::new(&cfg, seed));
            let shape = net.in_shape();
            eng.register_native(huge2::coordinator::Model::native_seg(
                other, net))?;
            Ok(FleetInput::Image { shape })
        }
    }
}

/// `huge2 serve --models a,b,...`: the fleet coordinator path
/// (DESIGN.md §16). N native nets resident at once — under
/// `--resident-budget <MiB>` their prepacked weights share an LRU
/// byte budget, evicting/reloading as the workload touches them —
/// with arrivals cycled round-robin across models and across the
/// three priority classes (`--priority-default` sets the first slot
/// of the class cycle). Records trace v5: the header carries the
/// fleet roster with per-model engine digests, arrivals carry their
/// class, and shed/evict/reload decisions are first-class events.
fn serve_fleet(args: &Args) -> Result<()> {
    let spec = args.get("models").unwrap_or_default();
    let mut names: Vec<String> = spec
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    names.sort_unstable();
    names.dedup();
    if names.len() < 2 {
        bail!("--models expects at least two distinct net names \
               (e.g. --models tiny_cgan,tiny_segnet), got {spec:?}");
    }
    let rate = args.get_f64("rate", 4.0)?;
    let n = args.get_usize("requests", 40)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let budget_mb = args.get_usize("resident-budget", 0)?;
    let cfg = load_engine_cfg(args)?;
    let record_path = path_flag(args, "record")?;

    let mut eng = Engine::new(cfg);
    let sink = record_sink(args, &mut eng, record_path)?;
    // budget before registration: workers capture the LRU manager
    eng.set_resident_budget(budget_mb << 20)?;
    let mut inputs = Vec::with_capacity(names.len());
    for name in &names {
        inputs.push(register_fleet_model(&mut eng, name, seed)?);
    }
    println!("serving fleet [{}] natively (gemm isa: {}; resident \
              budget: {})",
             names.join(", "), huge2::gemm::active_isa().name(),
             if budget_mb > 0 { format!("{budget_mb} MiB, LRU") }
             else { "unlimited".into() });

    let sobs = ServeObs::arm(args, &eng, &names[0])?;
    let classes = [priority_default(args)?, Priority::Batch,
                   Priority::Background];
    let arrivals = load_workload(args, rate, n)?;
    let t0 = Instant::now();
    let mut rng = Rng::new(1);
    let mut pending = Vec::new();
    let mut refused = 0usize;
    for (i, a) in arrivals.iter().enumerate() {
        let wait = a.at.saturating_sub(t0.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let m = i % names.len();
        let payload = match &inputs[m] {
            FleetInput::Latent { z_dim, cond_dim } => {
                let z = (0..*z_dim).map(|_| rng.next_normal()).collect();
                let cond =
                    (0..*cond_dim).map(|_| rng.next_normal()).collect();
                Payload::latent(z, cond)
            }
            FleetInput::Image { shape } => {
                let img_seed = seed ^ (i as u64 + 1)
                    .wrapping_mul(0x9e37_79b9_7f4a_7c15);
                Payload::image(Tensor::randn(shape,
                                             &mut Rng::new(img_seed)),
                               img_seed)
            }
        };
        match eng.submit_with(&names[m], payload,
                              classes[i % classes.len()]) {
            Ok(rx) => pending.push(rx),
            Err(ServeError::Shed { .. }) => refused += 1,
            Err(e) => println!("  rejected: {e}"),
        }
    }
    if refused > 0 {
        println!("  {refused} request(s) shed at admission");
    }
    // header: the lexicographically first member is the primary (its
    // digest sits in engine_digest, as in single-model traces); the
    // rest ride in the fleet roster with their own digests
    let digest_of = |name: &str| eng
        .plan_digest(name)
        .map(|d| format!("{d:016x}"))
        .unwrap_or_default();
    let (z_dim, cond_dim, task, net) = match &inputs[0] {
        FleetInput::Latent { z_dim, cond_dim } =>
            (*z_dim, *cond_dim, "generate", String::new()),
        FleetInput::Image { .. } =>
            (0, 0, "segment", names[0].clone()),
    };
    let record = sink.map(|s| {
        (record_path.unwrap(), s, TraceHeader {
            model: names[0].clone(),
            backend: "native".into(),
            seed,
            z_dim,
            cond_dim,
            task: task.into(),
            net,
            engine_digest: digest_of(&names[0]),
            fleet: names[1..]
                .iter()
                .map(|n| (n.clone(), digest_of(n)))
                .collect(),
        })
    });
    finish_serve(eng, pending, t0, record, sobs)
}

/// Rebuild a serving engine matching a trace header — the same task,
/// backend, net and weight seed the recording served. Shared by
/// `replay` and `trace bisect`.
fn engine_for_header(h: &TraceHeader, args: &Args) -> Result<Engine> {
    let base = EngineConfig::default();
    let cfg = EngineConfig {
        workers: args.get_usize("workers", base.workers)?,
        max_batch: args.get_usize("max-batch", base.max_batch)?,
        batch_timeout_us: args.get_usize(
            "batch-timeout-us", base.batch_timeout_us as usize)? as u64,
        artifact_dir: args.get("artifacts")
            .map(str::to_string)
            .unwrap_or(base.artifact_dir.clone()),
        ..base
    };
    let mut eng = Engine::new(cfg.clone());
    // replay honors --resident-budget so recorded eviction pressure can
    // be reproduced (residency is a load decision, not part of the
    // deterministic contract — outputs replay identically either way)
    if args.get("resident-budget").is_some() || !h.fleet.is_empty() {
        eng.set_resident_budget(
            args.get_usize("resident-budget", 0)? << 20)?;
    }
    if !h.fleet.is_empty() {
        // fleet trace (v5): on the fleet path model names ARE net
        // names, so the roster rebuilds from the header alone; the
        // replayer then gates every member's digest
        register_fleet_model(&mut eng, &h.model, h.seed)?;
        for (name, _) in &h.fleet {
            register_fleet_model(&mut eng, name, h.seed)?;
        }
        return Ok(eng);
    }
    match (h.task.as_str(), h.backend.as_str()) {
        ("generate", "native") => {
            let gen = Arc::new(Generator::dcgan(h.seed));
            if gen.z_dim != h.z_dim || h.cond_dim != 0 {
                bail!("trace wants z_dim {} / cond_dim {}, native DCGAN \
                       generator has z_dim {}",
                      h.z_dim, h.cond_dim, gen.z_dim);
            }
            // `--tuned <file>` replays under the tuned plan — the
            // digest gate then enforces that the trace was *recorded*
            // under the same selections (stale tunings fail loudly)
            match tuned_serving_plan(args, gen.plan(), "dcgan")? {
                Some(plan) => eng.register_native(
                    huge2::coordinator::Model::native_with_plan(
                        &h.model, gen, h.cond_dim, plan))?,
                None => eng.register_native(
                    huge2::coordinator::Model::native(
                        &h.model, gen, h.cond_dim))?,
            }
        }
        ("generate", "pjrt") => {
            let rt = Arc::new(RuntimeHandle::spawn(
                cfg.artifact_dir.clone().into())?);
            let latent_inputs = if h.cond_dim > 0 { 2 } else { 1 };
            eng.register_pjrt(&h.model, &format!("{}_gen", h.model), rt,
                              latent_inputs, h.seed)?;
        }
        ("segment", "native") => {
            // the header names the seg-net config + weight seed — the
            // exact net rebuilds from the trace file alone
            let net_cfg = seg_net_cfg(&h.net)?;
            let net = Arc::new(SegNet::new(&net_cfg, h.seed));
            let base = net.plan().with_argmax_head(net.n_classes());
            match tuned_serving_plan(args, &base, &h.net)? {
                Some(plan) => eng.register_native(
                    huge2::coordinator::Model::native_seg_with_plan(
                        &h.model, net, plan))?,
                None => eng.register_native(
                    huge2::coordinator::Model::native_seg(
                        &h.model, net))?,
            }
        }
        (task, backend) => bail!(
            "trace has unsupported task/backend {task:?}/{backend:?}"),
    }
    Ok(eng)
}

/// Parse `--window A..B` (end-exclusive window range; a bare `W` means
/// `W..W+1`). Bounds are validated against the trace by the replayer.
fn parse_window(args: &Args)
                -> Result<Option<std::ops::Range<usize>>> {
    let Some(spec) = args.get("window") else {
        return Ok(None);
    };
    let bad = || anyhow!(
        "--window expects A..B or a single window index, got {spec:?}");
    let r = match spec.split_once("..") {
        Some((a, b)) => {
            let a: usize = a.trim().parse().map_err(|_| bad())?;
            let b: usize = b.trim().parse().map_err(|_| bad())?;
            a..b
        }
        None => {
            let w: usize = spec.trim().parse().map_err(|_| bad())?;
            w..w + 1
        }
    };
    Ok(Some(r))
}

/// Re-drive a recorded trace through a freshly built engine and verify
/// every recorded output checksum (exit non-zero on divergence, naming
/// the first mismatching event). `--window A..B` replays just that
/// checkpoint-window slice; `--progress` prints a line per window
/// crossed; on divergence the divergent window's last events are
/// excerpted flight-recorder style.
fn replay(args: &Args) -> Result<()> {
    let path = args
        .positional(0)
        .or(path_flag(args, "trace")?)
        .ok_or_else(|| anyhow!("usage: huge2 replay <trace> \
                                [--timing faithful|fast] \
                                [--window A..B] [--progress]"))?
        .to_string();
    let timing: Timing = args.get_or("timing", "fast").parse()?;
    let rp = Replayer::load(Path::new(&path))?;
    let h = rp.header().clone();
    let wm = rp.windows();
    println!("trace {path}: model {:?} on {} backend (seed {}), \
              {} events, {} arrivals, {} window(s)",
             h.model, h.backend, h.seed, rp.events().len(),
             rp.arrival_count(), wm.count());

    let eng = engine_for_header(&h, args)?;
    let opts = ReplayOptions {
        window: parse_window(args)?,
        progress: args.has("progress"),
    };
    match &opts.window {
        Some(w) => println!("replaying windows {}..{} of {} with \
                             --timing {}...",
                            w.start, w.end, wm.count(), timing.as_str()),
        None => println!("replaying with --timing {}...",
                         timing.as_str()),
    }
    let report = rp.run_with(&eng, timing, &opts)?;
    eng.shutdown();
    println!("{}", report.summary());
    if let Some(hint) = &report.hint {
        println!("hint: {hint}");
    }
    match report.first_divergence() {
        None => {
            println!("replay OK: every recorded outcome reproduced");
            Ok(())
        }
        Some(d) => {
            let w = wm.window_of_event(d.event_index());
            println!("{}", huge2::replay::window::excerpt(
                rp.events(), wm.window_events(w), 8));
            bail!("replay diverged: {d}")
        }
    }
}

/// `huge2 trace <info|convert|fingerprints|bisect>` — trace-file
/// tooling over both on-disk formats (always detected by magic).
fn trace_cmd(args: &Args) -> Result<()> {
    let action = args
        .positional(0)
        .ok_or_else(|| anyhow!(
            "usage: huge2 trace <info|convert|compact|fingerprints|\
             bisect> <file> [...]"))?
        .to_string();
    match action.as_str() {
        "info" => trace_info(args),
        "convert" => trace_convert(args),
        "compact" => trace_compact(args),
        "fingerprints" => trace_fingerprints(args),
        "bisect" => trace_bisect(args),
        other => bail!("unknown trace action {other:?} \
                        (info|convert|compact|fingerprints|bisect)"),
    }
}

/// The `<file>` positional shared by every `trace` action.
fn trace_file_arg(args: &Args, usage: &str) -> Result<String> {
    Ok(args
        .positional(1)
        .ok_or_else(|| anyhow!("usage: huge2 trace {usage}"))?
        .to_string())
}

/// `huge2 trace info <file>`: format, header, event counts by kind,
/// window structure and fingerprint status.
fn trace_info(args: &Args) -> Result<()> {
    let path = trace_file_arg(args, "info <file>")?;
    let p = Path::new(&path);
    let fmt = if huge2::replay::binary::sniff_is_binary(p)? {
        "binary"
    } else {
        "jsonl"
    };
    let bytes = std::fs::metadata(p)?.len();
    let (h, events) = huge2::replay::binary::read_trace_auto(p)?;
    println!("{path}: {fmt} trace, {bytes} bytes, {} events",
             events.len());
    println!("header: model {:?} task {} backend {} seed {} z_dim {} \
              net {:?} engine_digest {:?}",
             h.model, h.task, h.backend, h.seed, h.z_dim, h.net,
             h.engine_digest);
    if !h.fleet.is_empty() {
        println!("fleet roster (+primary): {}",
                 h.fleet
                     .iter()
                     .map(|(n, d)| format!("{n} ({d})"))
                     .collect::<Vec<_>>()
                     .join(", "));
    }
    let mut kinds: std::collections::BTreeMap<&str, usize> =
        Default::default();
    for e in &events {
        *kinds.entry(e.body.kind()).or_default() += 1;
    }
    for (k, n) in kinds {
        println!("  {k:<16} {n}");
    }
    let wm = WindowMap::of(&events);
    println!("{} checkpoint(s) → {} replay window(s)",
             wm.checkpoint_count(), wm.count());
    match huge2::replay::window::verify_fingerprints(&events) {
        Ok(()) => {
            println!("fingerprints: OK");
            Ok(())
        }
        Err(e) => bail!("fingerprints: {e}"),
    }
}

/// `huge2 trace convert <in> <out>`: losslessly re-encode a trace; the
/// output format is picked by the output extension (`.bin` → binary,
/// anything else → JSONL).
fn trace_convert(args: &Args) -> Result<()> {
    let src = trace_file_arg(args, "convert <in> <out>")?;
    let dst = args
        .positional(2)
        .ok_or_else(|| anyhow!("usage: huge2 trace convert <in> <out>"))?
        .to_string();
    let (h, events) = huge2::replay::binary::read_trace_auto(
        Path::new(&src))?;
    let out = Path::new(&dst);
    if out.extension().is_some_and(|e| e == "bin") {
        huge2::replay::binary::write_trace(out, &h, &events)?;
    } else {
        huge2::replay::codec::write_trace(out, &h, &events)?;
    }
    let in_bytes = std::fs::metadata(&src)?.len();
    let out_bytes = std::fs::metadata(out)?.len();
    println!("{src} ({in_bytes} B) → {dst} ({out_bytes} B), \
              {} events, {:.2}x",
             events.len(), in_bytes as f64 / out_bytes as f64);
    Ok(())
}

/// `huge2 trace compact <in> <out> [--keep-every K]`: prune a trace's
/// checkpoints, keeping every K-th (merging the windows between) and
/// re-folding the fingerprint chain so the survivors still verify —
/// long soak traces shrink without losing replayability (coarser
/// `--window` granularity is the only cost).
fn trace_compact(args: &Args) -> Result<()> {
    let src = trace_file_arg(
        args, "compact <in> <out> [--keep-every K]")?;
    let dst = args
        .positional(2)
        .ok_or_else(|| anyhow!(
            "usage: huge2 trace compact <in> <out> [--keep-every K]"))?
        .to_string();
    let keep = args.get_usize("keep-every", 4)?;
    let (h, events) = huge2::replay::binary::read_trace_auto(
        Path::new(&src))?;
    huge2::replay::window::verify_fingerprints(&events)
        .map_err(|e| anyhow!("{src}: {e}"))?;
    let before = WindowMap::of(&events).checkpoint_count();
    let compacted =
        huge2::replay::window::compact_checkpoints(&events, keep)
            .map_err(anyhow::Error::msg)?;
    // the rebuilt chain must verify before we write anything
    huge2::replay::window::verify_fingerprints(&compacted)
        .map_err(|e| anyhow!("compacted chain broken (bug): {e}"))?;
    let after = WindowMap::of(&compacted).checkpoint_count();
    let out = Path::new(&dst);
    if out.extension().is_some_and(|e| e == "bin") {
        huge2::replay::binary::write_trace(out, &h, &compacted)?;
    } else {
        huge2::replay::codec::write_trace(out, &h, &compacted)?;
    }
    println!("{src}: {} events, {before} checkpoint(s) → {dst}: \
              {} events, {after} checkpoint(s) (kept every {keep}; \
              fingerprint chain re-verified)",
             events.len(), compacted.len());
    Ok(())
}

/// `huge2 trace fingerprints <file>`: the per-window fingerprint/chain
/// table (what `bisect` binary-searches over).
fn trace_fingerprints(args: &Args) -> Result<()> {
    let path = trace_file_arg(args, "fingerprints <file>")?;
    let (_, events) = huge2::replay::binary::read_trace_auto(
        Path::new(&path))?;
    huge2::replay::window::verify_fingerprints(&events)
        .map_err(|e| anyhow!("{path}: {e}"))?;
    let wm = WindowMap::of(&events);
    if wm.checkpoint_count() == 0 {
        println!("{path}: no checkpoints (recorded without \
                  --checkpoint-every, or pre-v4) — one implicit window \
                  over all {} events", events.len());
        return Ok(());
    }
    let mut t = Table::new(&["window", "events", "fingerprint", "chain"]);
    for w in 0..wm.count() {
        let r = wm.window_events(w);
        let (fp, chain) = match &events[r.end - 1].body {
            huge2::replay::EventBody::Checkpoint(c) => {
                (format!("{:016x}", c.fingerprint),
                 format!("{:016x}", c.chain))
            }
            // the trailing window is still open: no closing checkpoint
            _ => ("-".into(), "-".into()),
        };
        t.row(&[w.to_string(), format!("{}..{}", r.start, r.end),
                fp, chain]);
    }
    t.print();
    println!("{} window(s), fingerprints OK", wm.count());
    Ok(())
}

/// `huge2 trace bisect <file>`: localize the first divergent window in
/// O(log W) window replays. Checkpoint-less (v1–v3) traces get
/// checkpoints synthesized in memory first (`--checkpoint-every`).
fn trace_bisect(args: &Args) -> Result<()> {
    let path = trace_file_arg(args, "bisect <file>")?;
    let timing: Timing = args.get_or("timing", "fast").parse()?;
    let loaded = Replayer::load(Path::new(&path))?;
    let h = loaded.header().clone();
    let rp = if loaded.windows().checkpoint_count() == 0 {
        let every = args.get_usize("checkpoint-every",
                                   DEFAULT_CHECKPOINT_EVERY)?.max(1);
        println!("trace has no checkpoints; synthesizing one every \
                  {every} events for bisection");
        Replayer::from_parts(
            h.clone(),
            huge2::replay::window::insert_checkpoints(
                loaded.events(), every))
    } else {
        loaded
    };
    let wm = rp.windows();
    println!("bisecting {} window(s) ({} events) with --timing {}...",
             wm.count(), rp.events().len(), timing.as_str());
    let eng = engine_for_header(&h, args)?;
    let br = rp.bisect(&eng, timing)?;
    eng.shutdown();
    match br.divergent {
        None => {
            println!("bisect clean: all {} window(s) reproduce \
                      ({} replay(s))", br.windows, br.replays);
            Ok(())
        }
        Some(w) => {
            println!("{}", br.report.summary());
            let r = wm.window_events(w);
            println!("{}", huge2::replay::window::excerpt(
                rp.events(), r.clone(), 8));
            bail!("first divergent window: {w} of {} (events \
                   {}..{}), localized in {} window replay(s)",
                  br.windows, r.start, r.end, br.replays)
        }
    }
}

/// One-shot segmentation: build a seg net, run one image through both
/// engines with a per-layer timing table, print the mask summary.
fn segment(args: &Args) -> Result<()> {
    let net_name = args.get_or("net", "segnet");
    let seed = args.get_usize("seed", 7)? as u64;
    let img_seed = args.get_usize("image-seed", 11)? as u64;
    let net_cfg = seg_net_cfg(&net_name)?;
    let net = SegNet::new(&net_cfg, seed);
    let x = Tensor::randn(&net.in_shape(), &mut Rng::new(img_seed));
    println!("{net_name}: input {:?}, {} classes, {} trunk + {} ASPP \
              layers\n", net.in_shape(), net.n_classes(),
             net.trunk.len(), net.aspp.len());

    // per-layer baseline vs HUGE² timing on the real activations
    let mut t = Table::new(&["layer", "dilation", "baseline", "huge2",
                             "speedup", "max |Δ|"]);
    let mut row = |l: &huge2::seg::SegLayer, x: &Tensor| {
        let [base, fast, speedup, diff] =
            huge2::seg::layer_timing_cells(l, x);
        t.row(&[
            l.cfg.name.into(),
            format!("d={}", l.cfg.params.dilation),
            base,
            fast,
            speedup,
            diff,
        ]);
    };
    let mut h = x.clone();
    for l in &net.trunk {
        row(l, &h);
        h = l.forward(&h, DeconvEngine::Huge2).relu();
    }
    let mut aspp_sum: Option<Tensor> = None;
    for l in &net.aspp {
        row(l, &h);
        let y = l.forward(&h, DeconvEngine::Huge2);
        aspp_sum = Some(match aspp_sum {
            None => y,
            Some(a) => a.add(&y),
        });
    }
    // the head's real activation is the relu'd branch sum
    let h = aspp_sum.unwrap().relu();
    row(&net.head, &h);
    t.print();

    // end-to-end: both engines agree, then the actual product — a mask
    let logits_b = net.forward_with(&x, Some(DeconvEngine::Baseline));
    let logits_f = net.forward_with(&x, Some(DeconvEngine::Huge2));
    println!("\nend-to-end max |Δ| = {:.2e}",
             logits_f.max_abs_diff(&logits_b));
    let mask = huge2::seg::argmax_mask(&logits_f);
    let mut hist = vec![0usize; net.n_classes()];
    for &v in mask.data() {
        hist[v as usize] += 1;
    }
    println!("mask {:?} (checksum {:#018x}); class histogram: {hist:?}",
             mask.shape(), mask.checksum());
    Ok(())
}

/// Print all the paper's tables/figures (analytic + simulated parts).
fn reproduce(args: &Args) -> Result<()> {
    println!("== Fig 8 (left): memory-access reduction (cache-sim) ==\n");
    let mut t = Table::new(&["layer", "baseline accesses", "huge2 accesses",
                             "reduction", "baseline DRAM", "huge2 DRAM"]);
    for l in table1() {
        let b = trace_layer(&l, EngineKind::Baseline);
        let h = trace_layer(&l, EngineKind::Huge2);
        t.row(&[
            l.name.into(),
            b.hierarchy.scalar_accesses.to_string(),
            h.hierarchy.scalar_accesses.to_string(),
            format!("{:.1}%", 100.0 * (1.0 - h.hierarchy.scalar_accesses
                                       as f64
                                       / b.hierarchy.scalar_accesses as f64)),
            format!("{}KB", b.dram_bytes / 1024),
            format!("{}KB", h.dram_bytes / 1024),
        ]);
    }
    t.print();

    println!("\n== Fig 7 (left): embedded-GPU speedup (roofline \
              ESTIMATE; no CUDA device — see DESIGN.md §2) ==\n");
    let model = GpuModel::default();
    let mut t = Table::new(&["layer", "t_baseline", "t_huge2", "speedup",
                             "baseline bound"]);
    for l in table1() {
        let e = model.estimate(&l);
        t.row(&[
            l.name.into(),
            format!("{:.2}ms", e.t_baseline_s * 1e3),
            format!("{:.2}ms", e.t_huge2_s * 1e3),
            format!("{:.1}x", e.speedup),
            if e.baseline_compute_bound { "compute" } else { "memory" }
                .into(),
        ]);
    }
    t.print();
    println!("\nFig 7 (right) CPU speedups: run `cargo bench --bench \
              fig7_speedup`");
    println!("Fig 8 (right) training speedups: `cargo bench --bench \
              fig8_training`");
    Ok(())
}
