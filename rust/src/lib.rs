//! # HUGE² — a Highly Untangled Generative-model Engine for Edge-computing
//!
//! Reproduction of Shi et al. (cs.LG 2019): accelerating the two
//! "deconvolutions" that dominate generative models and semantic
//! segmentation — **transposed convolution** and **dilated convolution** —
//! by (1) decomposing kernels into stride-parity *patterns*, (2)
//! *untangling* each pattern into a set of 1×1 convolutions (plain GEMMs),
//! and (3) scattering the disjoint polyphase results into the output.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) express the same
//!   decomposition for the TPU MXU; compiled AOT to HLO text.
//! * **L2** — JAX models (`python/compile/model.py`): DCGAN / cGAN
//!   generators, discriminator, a full GAN train step.
//! * **L3** — this crate: a pure-Rust implementation of both the naive
//!   DarkNet-style baseline and the HUGE² algorithm (for the paper's CPU
//!   experiments), a cache/roofline simulator (for the memory-access and
//!   embedded-GPU experiments), and an edge serving engine (router,
//!   dynamic batcher, worker pool) that executes the AOT artifacts through
//!   the PJRT C API.
//!
//! Quickstart:
//!
//! ```no_run
//! use huge2::config::table1;
//! use huge2::deconv::{baseline, huge2 as engine};
//! use huge2::tensor::Tensor;
//! use huge2::rng::Rng;
//!
//! let layer = &table1()[2]; // DCGAN DC3
//! let mut rng = Rng::new(7);
//! let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
//! let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out], &mut rng);
//! let slow = baseline::conv2d_transpose(&x, &k, &layer.deconv_params());
//! let fast = engine::conv2d_transpose(&x, &k, &layer.deconv_params());
//! assert!(slow.allclose(&fast, 1e-4));
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod deconv;
pub mod gan;
pub mod gemm;
pub mod im2col;
pub mod memsim;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod trace;
pub mod bench_util;
