//! # HUGE² — a Highly Untangled Generative-model Engine for Edge-computing
//!
//! Reproduction of Shi et al. (cs.LG 2019): accelerating the two
//! "deconvolutions" that dominate generative models and semantic
//! segmentation — **transposed convolution** and **dilated convolution** —
//! by (1) decomposing kernels into stride-parity *patterns*, (2)
//! *untangling* each pattern into a set of 1×1 convolutions (plain GEMMs),
//! and (3) scattering the disjoint polyphase results into the output.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) express the same
//!   decomposition for the TPU MXU; compiled AOT to HLO text.
//! * **L2** — JAX models (`python/compile/model.py`): DCGAN / cGAN
//!   generators, discriminator, a full GAN train step.
//! * **L3** — this crate: a pure-Rust implementation of both the naive
//!   DarkNet-style baseline and the HUGE² algorithm (for the paper's CPU
//!   experiments), a cache/roofline simulator (for the memory-access and
//!   embedded-GPU experiments), and an edge serving engine (router,
//!   dynamic batcher, worker pool) that executes the AOT artifacts through
//!   the PJRT C API.
//!
//! Quickstart:
//!
//! ```no_run
//! use huge2::config::table1;
//! use huge2::deconv::{baseline, huge2 as engine};
//! use huge2::tensor::Tensor;
//! use huge2::rng::Rng;
//!
//! let layer = &table1()[2]; // DCGAN DC3
//! let mut rng = Rng::new(7);
//! let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
//! let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out], &mut rng);
//! let slow = baseline::conv2d_transpose(&x, &k, &layer.deconv_params());
//! let fast = engine::conv2d_transpose(&x, &k, &layer.deconv_params());
//! assert!(slow.allclose(&fast, 1e-4));
//! ```
//!
//! A third engine, [`deconv::segregated`] (kernel-segregated transposed
//! convolution — same parity decomposition, but one fused im2col + GEMM
//! per pattern instead of per-tap GEMMs), is selectable explicitly via
//! [`deconv::Engine::Segregated`] / `--engine segregated`. All GEMM-backed
//! paths dispatch their micro-kernel per ISA at runtime
//! ([`gemm::active_isa`]): portable scalar everywhere, AVX2
//! (bit-identical to scalar) where detected, and an opt-in AVX2+FMA tier
//! (`HUGE2_GEMM_FMA=1`, ulp-bounded, digest-gated); `HUGE2_FORCE_SCALAR=1`
//! pins the scalar kernel (DESIGN.md §14).
//!
//! ## Compiled plans (load-time engine selection)
//!
//! Every natively served model compiles to a [`plan::ExecPlan`] at
//! load: one layer IR (project / transpose-conv / dilated-conv /
//! activation / head) whose per-layer engine is resolved once —
//! including [`deconv::Engine::Auto`], the shape/thread heuristic —
//! with all prepacking `Arc`-shared and every intermediate shape plus
//! the workspace high-water mark precomputed. Model forwards and the
//! serving workers are thin wrappers over [`plan::ExecPlan::run_into`]:
//!
//! ```no_run
//! use huge2::gan::{Engine, Generator};
//! use huge2::plan::ExecPlan;
//! use huge2::rng::Rng;
//! use huge2::tensor::Tensor;
//! use huge2::workspace::Workspace;
//!
//! let gen = Generator::dcgan(7);
//! let plan = gen.plan();                 // compiled at load, Auto-resolved
//! for step in plan.steps() {
//!     println!("{:16} {:14} {:?} x{}", step.name, step.op.kind(),
//!              step.engine.map(|e| e.name()), step.threads);
//! }
//! println!("high-water {}B, digest {:016x}",
//!          4 * plan.high_water_elems(1), plan.engine_digest());
//! let z = Tensor::randn(&[1, 100], &mut Rng::new(1));
//! let ws = Workspace::new();
//! let img = plan.run(&z, &mut ws.handle());    // the serving fast path
//! // explicit engines compile transient plans (no re-packing):
//! let same = gen.forward(&z, Engine::Auto);
//! assert_eq!(img.checksum(), same.checksum());
//! # let _ = ExecPlan::for_generator(&gen, Engine::Baseline);
//! ```
//!
//! CLI: `huge2 plan --net <dcgan|cgan|tiny_cgan|segnet|tiny_segnet>`
//! prints the per-layer table (engine, threads, prepacked bytes,
//! predicted DRAM bytes, shapes) plus the plan's workspace high-water
//! mark and digest.
//!
//! ## Tuning quickstart (measured cost-model autotuner)
//!
//! `Auto` is a fixed heuristic; the [`tune`] module replaces it with a
//! measured argmin (DESIGN.md §15). Every compute step's candidates —
//! engine (Baseline / HUGE² / Segregated) × threads × GEMM tile — are
//! scored by replaying their exact access streams through the
//! [`memsim`] cache model, converted to nanoseconds with a
//! [`tune::Calibration`] (fixed reference constants, or fitted once
//! against timed microbenchmarks of the real engines), and the
//! cheapest strictly-better candidate wins. The result persists as a
//! [`tune::TunedPlan`] keyed by plan digest + ISA tier, and applying
//! it folds the selections into the digest — so replay gates stale
//! tunings loudly:
//!
//! ```no_run
//! use huge2::gan::Generator;
//! use huge2::tune::{Calibration, LoadedTuned, TunedPlan, tune_plan};
//!
//! let gen = Generator::dcgan(7);
//! let cal = Calibration::reference();     // or Calibration::measured()
//! let tuned = tune_plan(gen.plan(), "dcgan", &cal);
//! println!("{} of {} steps re-tuned", tuned.n_differs(),
//!          tuned.steps.len());
//! std::fs::write("tuned.bin", tuned.encode())?;
//!
//! // at serve start: load, key-check, apply
//! match TunedPlan::decode(&std::fs::read("tuned.bin")?)
//!     .map_err(anyhow::Error::msg)?
//! {
//!     LoadedTuned::Tuned(t) => {
//!         let plan = t.apply(gen.plan()).map_err(anyhow::Error::msg)?;
//!         println!("serving under digest {:016x}", plan.engine_digest());
//!     }
//!     LoadedTuned::VersionMismatch { found } => {
//!         eprintln!("tuned-plan v{found} unsupported; using heuristic");
//!     }
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! CLI: `huge2 tune --net dcgan --out tuned.bin [--reference]` writes
//! the artifact (`--reference` is byte-deterministic across hosts);
//! `huge2 plan --net dcgan --tuned tuned.bin` prints heuristic-vs-tuned
//! per layer; `huge2 serve --tuned tuned.bin` (or `--autotune`) serves
//! under it, and `huge2 replay` verifies traces against whichever plan
//! is active.
//!
//! ## Segmentation quickstart
//!
//! The serving pipeline is **multi-task**: alongside latent→image GAN
//! requests, the engine serves image→mask segmentation through the same
//! queue/batcher/worker stack (see [`seg`]). A [`seg::SegNet`] is built
//! from dilated-conv layer configs, pre-decomposes (tap-packs) its
//! kernels at load time and compiles its plan (the worker executes the
//! plan + argmax head uniformly with the GAN path):
//!
//! ```no_run
//! use std::sync::Arc;
//! use huge2::config::{tiny_segnet, EngineConfig};
//! use huge2::coordinator::{Engine, Model};
//! use huge2::rng::Rng;
//! use huge2::seg::SegNet;
//! use huge2::tensor::Tensor;
//!
//! let net = Arc::new(SegNet::new(&tiny_segnet(), 7));
//! let img = Tensor::randn(&net.in_shape(), &mut Rng::new(11));
//! let mut eng = Engine::new(EngineConfig::default());
//! eng.register_native(Model::native_seg("segnet", net))?;
//! let resp = eng.segment("segnet", img, 11)?;   // (1, H, W, 1) mask
//! println!("mask {:?} in {:?}", resp.output.shape(), resp.latency);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! CLI: `huge2 serve --task segment [--record t.jsonl]` serves the net,
//! `huge2 segment` runs a one-shot baseline-vs-HUGE² timing table + mask.
//!
//! ## Fault containment (typed per-request outcomes)
//!
//! Every accepted request terminates in **exactly one** observable
//! outcome: the reply channel carries
//! `Result<Response, ServeError>` — a typed taxonomy
//! (`Validation` / `Backpressure` / `BatchFailed` / `Shutdown`) instead
//! of a silently closed channel (DESIGN.md §11). A malformed row fails
//! alone while the rest of its batch executes; a panicking worker is
//! supervised (`catch_unwind`), fails its batch with `BatchFailed`, and
//! keeps draining — the pool never shrinks. The counters conserve:
//! `submitted == completed + rejected + failed` once drained.
//!
//! ```no_run
//! use huge2::config::EngineConfig;
//! use huge2::coordinator::{Engine, Model, Payload, ServeError};
//! use huge2::gan::Generator;
//! # use std::sync::Arc;
//! let mut eng = Engine::new(EngineConfig::default());
//! eng.register_native(Model::native(
//!     "dcgan", Arc::new(Generator::dcgan(7)), 0))?;
//! match eng.submit("dcgan", Payload::latent(vec![0.0; 100], vec![])) {
//!     Err(ServeError::Backpressure) => { /* transient: retry or shed */ }
//!     Err(e) => eprintln!("refused ({}): {e}", e.kind()),
//!     Ok(rx) => match rx.recv()? {
//!         Ok(resp) => println!("image {:?}", resp.output.shape()),
//!         Err(e) => eprintln!("failed ({}): {e}", e.kind()),
//!     },
//! }
//! let c = &eng.counters;
//! assert_eq!(c.in_flight(), 0); // submitted == completed+rejected+failed
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Failures are replay outcomes too: trace format v3 records a `Failed`
//! event per failed request, and `replay` verifies failure determinism
//! (by `ServeError::kind`) exactly like it verifies output checksums.
//!
//! ## Record / replay quickstart
//!
//! Serving runs are **recordable and deterministically replayable**
//! (see [`replay`]): every non-deterministic workload input (arrival
//! offsets, request ids, latents) is captured to a JSONL trace along
//! with a checksum of every output, and a replay re-drives the exact
//! workload, verifying the engine reproduces each image bit-for-bit.
//!
//! ```no_run
//! use std::sync::Arc;
//! use huge2::config::EngineConfig;
//! use huge2::coordinator::{Engine, Model};
//! use huge2::gan::Generator;
//! use huge2::replay::{Recorder, Replayer, Timing, TraceHeader};
//!
//! // --- record a serve session ---
//! let gen = Arc::new(Generator::dcgan(7));
//! let rec = Recorder::new(TraceHeader {
//!     model: "dcgan".into(),
//!     backend: "native".into(),
//!     seed: 7,
//!     z_dim: 100,
//!     cond_dim: 0,
//!     task: "generate".into(),
//!     net: String::new(),
//!     // pins the plan's per-layer engine choices; replay re-checks it
//!     engine_digest: format!("{:016x}", gen.plan().engine_digest()),
//!     // single-model run; `huge2 serve --models ...` fills the roster
//!     fleet: Vec::new(),
//! });
//! let mut eng = Engine::new(EngineConfig::default());
//! eng.set_trace_sink(rec.sink())?;
//! eng.register_native(Model::native("dcgan", gen, 0))?;
//! eng.generate("dcgan", vec![0.0; 100], vec![])?;
//! eng.shutdown();
//! rec.save(std::path::Path::new("t.jsonl"))?;
//!
//! // --- replay it and verify zero divergence ---
//! let rp = Replayer::load(std::path::Path::new("t.jsonl"))?;
//! let mut eng = Engine::new(EngineConfig::default());
//! eng.register_native(Model::native(
//!     "dcgan", Arc::new(Generator::dcgan(rp.header().seed)), 0))?;
//! let report = rp.run(&eng, Timing::Fast)?;
//! assert!(report.is_clean(), "{}", report.first_divergence().unwrap());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The same flow is wired end-to-end in the CLI:
//! `huge2 serve --native --record t.jsonl`, then
//! `huge2 replay t.jsonl --timing fast` (exits non-zero on divergence,
//! naming the first mismatching event).
//!
//! ## Trace tooling quickstart (binary codec, windows, bisection)
//!
//! Traces scale past "one short run" with trace format v4
//! (DESIGN.md §13). Saving to a `.bin` path writes a compact **binary
//! codec** (magic `HG2TRACE`, varint fields, raw f32 bits — several
//! times smaller than JSONL); loading always sniffs the magic, so both
//! formats replay interchangeably and `huge2 trace convert` re-encodes
//! losslessly in either direction. A sink built with
//! [`replay::TraceSink::with_checkpoints`] appends periodic
//! **checkpoint** events — a verifiable fold of the stream so far
//! (pending request ids, outcome counters, a per-window FNV-1a
//! fingerprint over deterministic payload/outcome bits, and a chained
//! fingerprint across windows) plus a metrics snapshot backfilled by
//! the engine. Checkpoints split a trace into **windows** that replay
//! independently:
//!
//! ```no_run
//! use std::path::Path;
//! use huge2::replay::{ReplayOptions, Replayer, Timing};
//! # use huge2::config::EngineConfig;
//! # use huge2::coordinator::{Engine, Model};
//! # use huge2::gan::Generator;
//! # use std::sync::Arc;
//!
//! let rp = Replayer::load(Path::new("t.bin"))?; // verifies fingerprints
//! # let mut eng = Engine::new(EngineConfig::default());
//! # eng.register_native(Model::native(
//! #     "dcgan", Arc::new(Generator::dcgan(rp.header().seed)), 0))?;
//! println!("{} windows", rp.windows().count());
//! // replay just windows 2..5 (state rebuilt from checkpoint 2):
//! let report = rp.run_with(&eng, Timing::Fast, &ReplayOptions {
//!     window: Some(2..5),
//!     progress: true,
//! })?;
//! assert!(report.is_clean());
//! // or localize the first divergent window in O(log W) replays:
//! let bi = rp.bisect(&eng, Timing::Fast)?;
//! println!("divergent window: {:?} ({} replays)",
//!          bi.divergent, bi.replays);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! CLI: `huge2 serve --native --record t.bin --checkpoint-every 256`,
//! then `huge2 trace info t.bin`, `huge2 trace convert t.bin t.jsonl`,
//! `huge2 trace fingerprints t.bin`,
//! `huge2 replay t.bin --window 2..5 --progress`, and
//! `huge2 trace bisect t.bin` (synthesizes checkpoints in memory for
//! pre-v4 traces). Long soaks shrink with
//! `huge2 trace compact big.bin small.bin --keep-every 4` — checkpoint
//! pruning that re-folds the fingerprint chain so the survivors still
//! verify.
//!
//! ## Fleet serving quickstart (priorities, admission, residency)
//!
//! One engine serves **N models at once** (DESIGN.md §16): each model
//! gets its own bounded queue and worker pool behind a shared
//! admission controller. Requests carry a
//! [`coordinator::Priority`] class — `Interactive` (default), `Batch`,
//! or `Background` — that the batcher orders by (class first, then the
//! EDF deadline anchored at *original* arrival, so carried-over rows
//! under continuous batching never lose their place). Under
//! backpressure a full queue **sheds** its lowest class first to admit
//! a higher one: the victim's receiver gets
//! `ServeError::Shed { class }`, a typed refusal distinct from
//! `Backpressure` (queue full, nothing shed-worthy below you) and the
//! other [`coordinator::ServeError`] kinds — `Validation`,
//! `UnknownModel`, `BatchFailed`, `WorkerPanic`, `Shutdown`. With
//! [`coordinator::Engine::set_resident_budget`], prepacked weights
//! share an LRU byte budget: before each batch the worker makes its
//! model resident, evicting least-recently-used peers; a reloaded plan
//! must reproduce its pinned engine digest, so eviction is pure
//! telemetry (`Evict`/`Reload` trace events), never a numerics event.
//! Whatever happens, conservation holds per model and fleet-wide:
//! `submitted == completed + rejected + failed` (`shed` ⊆ rejected).
//!
//! ```no_run
//! use std::sync::Arc;
//! use huge2::config::EngineConfig;
//! use huge2::coordinator::{Engine, Model, Payload, Priority};
//! use huge2::gan::Generator;
//! use huge2::seg::SegNet;
//!
//! let mut eng = Engine::new(EngineConfig::default());
//! eng.set_resident_budget(8 << 20)?;        // before register()
//! eng.register_native(Model::native(
//!     "tiny_cgan", Arc::new(Generator::tiny_cgan(7)), 0))?;
//! eng.register_native(Model::native_seg(
//!     "tiny_segnet",
//!     Arc::new(SegNet::new(&huge2::config::tiny_segnet(), 7))))?;
//! let rx = eng.submit_with("tiny_cgan",
//!                          Payload::latent(vec![0.0; 8], vec![]),
//!                          Priority::Background)?;
//! let _ = rx.recv();                        // may be Err(Shed{..})
//! if let Some(res) = eng.residency() {
//!     println!("{} evictions, {} reloads, {}B resident",
//!              res.evictions(), res.reloads(), res.resident_bytes());
//! }
//! eng.shutdown();
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! CLI: `huge2 serve --models tiny_cgan,tiny_segnet --resident-budget 4
//! --priority-default interactive --record fleet.bin` — the trace
//! (format v5) carries each arrival's class, every shed/evict/reload
//! decision, and a fleet roster of `(model, digest)` pairs that replay
//! re-gates before re-driving the workload.
//!
//! ## Observability quickstart (stage spans, profiler, snapshots)
//!
//! The engine instruments itself (DESIGN.md §12): every request is
//! stamped at its lifecycle boundaries and the spans land in per-stage
//! latency histograms keyed by `(task, outcome)` — so `queue_wait`,
//! `batch_form`, `gather`, `forward` and `reply` are separately
//! quantile-able, and completed requests never pollute failed-request
//! tails. A lock-free **flight recorder** keeps the last N span events
//! and is dumped by worker supervision on panic, correlating events by
//! request id. All series live in one [`metrics::MetricsRegistry`]:
//! atomic snapshots, windowed deltas between snapshots, and a
//! Prometheus-style text exposition. Armed by default
//! (`EngineConfig::instrument`); when off, every hook is one branch on
//! a `bool`.
//!
//! ```no_run
//! use huge2::config::EngineConfig;
//! use huge2::coordinator::{Engine, Model};
//! use huge2::gan::Generator;
//! # use std::sync::Arc;
//! let mut eng = Engine::new(EngineConfig::default());
//! eng.register_native(Model::native(
//!     "dcgan", Arc::new(Generator::dcgan(7)), 0))?;
//! eng.enable_layer_profiling("dcgan");      // per-PlanOp wall time
//! let before = eng.metrics_snapshot();
//! eng.generate("dcgan", vec![0.0; 100], vec![])?;
//! let delta = eng.metrics_snapshot().delta(&before);
//! let fwd = delta.merged_histogram("huge2_stage_forward_us");
//! println!("forward p95 {}µs over {} request(s)",
//!          fwd.quantile_us(0.95), fwd.count());
//! print!("{}", eng.metrics_text());         // scrape surface
//! // per-layer observed costs, keyed by the engine-selection digest:
//! print!("{}", eng.model_plan("dcgan").unwrap().profile_report());
//! // recent span events, correlated by request id:
//! print!("{}", eng.observability().flight.excerpt(16));
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! CLI: `huge2 serve --native --stats-every 1 --profile-layers` prints
//! periodic `[stats]` lines and a per-layer profile table at shutdown;
//! `huge2 plan --net dcgan --profile` profiles a plan offline;
//! `--dump-metrics` prints the full exposition.
//!
//! ## Workspace quickstart (zero-allocation hot path)
//!
//! Every hot-path entry point has a pooled twin — `sgemm_with(ws, …)`
//! at the GEMM layer, `*_ws(…, handle)` on the deconv engines and model
//! forwards — that draws all scratch (packing panels, padded inputs,
//! sub-outputs, intermediate activations) from a [`workspace::Workspace`]
//! instead of allocating. Results are bit-identical; after a warmup
//! pass the pool serves every checkout and `bytes_allocated` stays
//! flat (DESIGN.md §9). The serving engine does this internally per
//! worker thread — [`coordinator::Engine::workspace_counters`] exposes
//! the proof.
//!
//! ```no_run
//! use huge2::gan::{Engine, Generator};
//! use huge2::rng::Rng;
//! use huge2::tensor::Tensor;
//! use huge2::workspace::Workspace;
//!
//! let gen = Generator::tiny_cgan(7);
//! let z = Tensor::randn(&[4, 8], &mut Rng::new(1));
//! let ws = Workspace::new();
//! let mut h = ws.handle();
//! let warm = gen.forward_ws(&z, Engine::Huge2, &mut h);   // allocates
//! let steady = gen.forward_ws(&z, Engine::Huge2, &mut h); // pool hits
//! assert_eq!(warm.checksum(), steady.checksum());
//! let c = ws.counters();
//! println!("{} checkouts, {} misses, {} B allocated",
//!          c.checkouts, c.pool_misses, c.bytes_allocated);
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod deconv;
pub mod gan;
pub mod gemm;
pub mod im2col;
pub mod memsim;
pub mod metrics;
pub mod plan;
pub mod replay;
pub mod rng;
pub mod runtime;
pub mod seg;
pub mod tensor;
pub mod trace;
pub mod tune;
pub mod bench_util;
pub mod workspace;
