//! Blocked single-precision GEMM — the shared compute core.
//!
//! Both deconvolution engines (the DarkNet-style baseline and HUGE²)
//! funnel all their multiply-adds through this one GEMM, so the measured
//! baseline-vs-HUGE² ratio isolates the *algorithmic* difference the paper
//! claims (zero-skipping + access coalescing), not a difference in GEMM
//! quality (DESIGN.md §2).
//!
//! Structure: classic Goto-style three-level blocking
//!   * `KC × NC` panel of B packed row-major by NR-wide slivers,
//!   * `MC × KC` panel of A packed column-major by MR-tall slivers,
//!   * an `MR × NR` register micro-kernel (4 × 16 f32 — two ymm vectors
//!     wide, eight ymm accumulators tall on AVX2).
//!
//! The full-tile micro-kernel is ISA-dispatched ([`Isa`], resolved once
//! per process by [`active_isa`]): a portable scalar kernel, an AVX2
//! kernel (`mul` + `add` intrinsics — **bit-identical** to scalar, same
//! per-element rounding in the same k-order), and an opt-in AVX2+FMA
//! kernel (`HUGE2_GEMM_FMA=1`; one rounding per multiply-add, so results
//! are ulp-bounded rather than bit-equal — the relaxation is folded into
//! the plan digest; DESIGN.md §14). `HUGE2_FORCE_SCALAR=1` pins the
//! scalar kernel everywhere (the CI fallback job). Edge tiles (partial
//! rows/cols) always run the scalar kernel — they touch only tile
//! boundaries and keep every tier bit-exact there. The NR-sliver packing
//! already lays B out as contiguous 16-float rows, i.e. two aligned-free
//! `loadu` vectors per k step.
//!
//! `sgemm_parallel` shards the M dimension over `std::thread::scope`
//! (the vendored crate set has no rayon).
//!
//! Every entry point has a `*_with(ws, …)` twin that draws its packing
//! panels from a [`crate::workspace::Workspace`] instead of allocating —
//! the packing routines fully overwrite the panel region they use, so
//! dirty pool buffers are safe (DESIGN.md §9). The no-workspace names
//! are thin wrappers over a fresh workspace and stay bit-identical.

use crate::workspace::{Workspace, WsHandle};
use std::sync::OnceLock;

/// Micro-tile rows.
const MR: usize = 4;
/// Micro-tile cols (4 × f32x4 or 2 × f32x8 vectors).
const NR: usize = 16;
/// L2-ish block of K.
const KC: usize = 256;
/// L3-ish block of M.
const MC: usize = 128;
/// Panel width of N.
const NC: usize = 1024;

/// Runtime cache-blocking override — the autotuner's GEMM knob.
///
/// `kc`/`nc` replace the compile-time `KC`/`NC` panel factors for one
/// call. Only the plan's Project step takes a runtime tile: the deconv
/// engines run against [`PackedB`], whose panel offsets were baked at
/// pack time under the default blocking. Values are clamped (via
/// [`Tile::clamped`]) to at most the defaults so the workspace
/// high-water accounting (`sgemm_scratch_elems`) stays an upper bound.
///
/// A non-default `kc` regroups the K-panel partial sums — a different
/// FP accumulation order — so tuned tiles fold into the plan digest
/// exactly like the FMA numerics term (DESIGN.md §15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    /// K-panel depth (default 256).
    pub kc: usize,
    /// N-panel width (default 1024).
    pub nc: usize,
}

impl Tile {
    /// The compile-time blocking every untiled entry point uses.
    pub const DEFAULT: Tile = Tile { kc: KC, nc: NC };

    /// True when this tile is exactly the default blocking (no digest
    /// term, no behavioural difference from `sgemm_with`).
    pub fn is_default(&self) -> bool {
        *self == Self::DEFAULT
    }

    /// Clamp into `[NR, default]` on both axes — the range the
    /// workspace accounting covers.
    pub fn clamped(self) -> Tile {
        Tile { kc: self.kc.clamp(NR, KC), nc: self.nc.clamp(NR, NC) }
    }
}

/// Instruction-set tier the full-tile micro-kernel dispatches to.
///
/// `Scalar` and `Avx2` are bit-identical (same per-element rounding in
/// the same k-order); `Avx2Fma` contracts each multiply-add to one
/// rounding and is therefore only ulp-bounded against the other two —
/// it is opt-in and digest-gated (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernel — the fallback on every architecture and
    /// the `HUGE2_FORCE_SCALAR=1` override.
    Scalar,
    /// AVX2 `mul`+`add` intrinsics. Bit-identical to [`Isa::Scalar`].
    Avx2,
    /// AVX2 with fused multiply-add (`vfmadd231ps`). Opt-in via
    /// `HUGE2_GEMM_FMA=1`; relaxes bit-identity to an ulp bound.
    Avx2Fma,
}

impl Isa {
    /// Stable lowercase name (CLI plan table, bench labels, digest tag).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx2Fma => "avx2+fma",
        }
    }

    /// True when this tier's results may differ bitwise from the scalar
    /// kernel (FMA contraction). Plans fold this into their digest so a
    /// trace recorded under one numerics regime never silently replays
    /// under another.
    pub fn relaxed_numerics(self) -> bool {
        matches!(self, Isa::Avx2Fma)
    }
}

/// Every tier usable on this host, scalar first (always present).
/// On non-x86_64 targets this is `[Scalar]`.
pub fn available_isas() -> Vec<Isa> {
    let mut v = vec![Isa::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            v.push(Isa::Avx2);
            if is_x86_feature_detected!("fma") {
                v.push(Isa::Avx2Fma);
            }
        }
    }
    v
}

/// The tier every GEMM in the process dispatches to, resolved once:
/// `HUGE2_FORCE_SCALAR=1` pins [`Isa::Scalar`]; otherwise the best
/// detected tier, where [`Isa::Avx2Fma`] additionally requires the
/// `HUGE2_GEMM_FMA=1` opt-in (it relaxes bit-identity). Cached in a
/// `OnceLock` — per-call tier selection goes through [`sgemm_isa`].
pub fn active_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        let on = |key: &str| std::env::var(key).as_deref() == Ok("1");
        if on("HUGE2_FORCE_SCALAR") {
            return Isa::Scalar;
        }
        let avail = available_isas();
        if on("HUGE2_GEMM_FMA") && avail.contains(&Isa::Avx2Fma) {
            Isa::Avx2Fma
        } else if avail.contains(&Isa::Avx2) {
            Isa::Avx2
        } else {
            Isa::Scalar
        }
    })
}

/// C[m×n] (+)= A[m×k] · B[k×n], all row-major contiguous.
///
/// If `accumulate` is false, C is overwritten; otherwise added into.
pub fn sgemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
             c: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), m * k, "A size");
    sgemm_strided(m, n, k, a, k, b, c, accumulate);
}

/// [`sgemm`] drawing its packing panels from a workspace handle.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with(ws: &mut WsHandle, m: usize, n: usize, k: usize,
                  a: &[f32], b: &[f32], c: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), m * k, "A size");
    sgemm_strided_with(ws, m, n, k, a, k, b, c, accumulate);
}

/// `sgemm` with an explicit row stride for A (`lda >= k` elements).
///
/// This is what lets the HUGE² engine run its untangled tap-GEMMs
/// *directly on views of the input tensor* — e.g. a (Wo, C) row of a
/// stride-`st` dilated conv is A with `lda = st·C` — with zero im2col-style
/// copying. The packing routine absorbs the stride.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_strided(m: usize, n: usize, k: usize, a: &[f32], lda: usize,
                     b: &[f32], c: &mut [f32], accumulate: bool) {
    let ws = Workspace::new();
    sgemm_strided_with(&mut ws.handle(), m, n, k, a, lda, b, c, accumulate);
}

/// [`sgemm_strided`] drawing its packing panels from a workspace handle.
/// Dirty buffers are safe: `pack_a`/`pack_b` fully overwrite (including
/// the zero padding of edge slivers) exactly the region the macro kernel
/// reads.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_strided_with(ws: &mut WsHandle, m: usize, n: usize, k: usize,
                          a: &[f32], lda: usize, b: &[f32], c: &mut [f32],
                          accumulate: bool) {
    sgemm_strided_core(ws, active_isa(), m, n, k, a, lda, b, c, accumulate);
}

/// [`sgemm`] forced onto a specific ISA tier — the test/bench seam.
/// The process-wide [`active_isa`] is cached in a `OnceLock`, so the
/// SIMD-vs-scalar equivalence grids and the microkernel bench phase pick
/// tiers per call through this instead. Panics if `isa` is not in
/// [`available_isas`] on this host.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_isa(isa: Isa, m: usize, n: usize, k: usize, a: &[f32],
                 b: &[f32], c: &mut [f32], accumulate: bool) {
    assert!(available_isas().contains(&isa),
            "isa {} unavailable on this host", isa.name());
    assert_eq!(a.len(), m * k, "A size");
    let ws = Workspace::new();
    sgemm_strided_core(&mut ws.handle(), isa, m, n, k, a, k, b, c,
                       accumulate);
}

#[allow(clippy::too_many_arguments)]
fn sgemm_strided_core(ws: &mut WsHandle, isa: Isa, m: usize, n: usize,
                      k: usize, a: &[f32], lda: usize, b: &[f32],
                      c: &mut [f32], accumulate: bool) {
    sgemm_strided_tiled_core(ws, isa, m, n, k, a, lda, b, c, accumulate,
                             Tile::DEFAULT);
}

/// [`sgemm_with`] under an explicit cache-blocking [`Tile`] — the tuned
/// Project-step path. `Tile::DEFAULT` is bit-identical to `sgemm_with`.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_tiled_with(ws: &mut WsHandle, m: usize, n: usize, k: usize,
                        a: &[f32], b: &[f32], c: &mut [f32],
                        accumulate: bool, tile: Tile) {
    assert_eq!(a.len(), m * k, "A size");
    sgemm_strided_tiled_core(ws, active_isa(), m, n, k, a, k, b, c,
                             accumulate, tile);
}

#[allow(clippy::too_many_arguments)]
fn sgemm_strided_tiled_core(ws: &mut WsHandle, isa: Isa, m: usize,
                            n: usize, k: usize, a: &[f32], lda: usize,
                            b: &[f32], c: &mut [f32], accumulate: bool,
                            tile: Tile) {
    assert!(lda >= k, "lda {lda} < k {k}");
    assert!(m == 0 || a.len() >= (m - 1) * lda + k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");
    if !accumulate {
        c.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let Tile { kc: kc_blk, nc: nc_blk } = tile.clamped();

    let mut packed_a = ws.checkout(MC * kc_blk);
    let mut packed_b = ws.checkout(kc_blk * nc_blk.min(round_up(n, NR)));

    for jc in (0..n).step_by(nc_blk) {
        let nc = nc_blk.min(n - jc);
        for pc in (0..k).step_by(kc_blk) {
            let kc = kc_blk.min(k - pc);
            pack_b(&mut packed_b, b, k, n, pc, jc, kc, nc);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                pack_a(&mut packed_a, a, lda, ic, pc, mc, kc);
                macro_kernel(isa, &packed_a, &packed_b, c, n, ic, jc, mc,
                             nc, kc);
            }
        }
    }
    ws.checkin(packed_a);
    ws.checkin(packed_b);
}

/// B packed once into micro-kernel layout — for weight matrices that are
/// static across calls (the HUGE² tap panels: decompose once at model
/// load, then every inference skips the per-call `pack_b` entirely).
///
/// Layout: for each NC panel (`jc`), for each KC panel (`pc`), the
/// NR-sliver packing `pack_b` produces — the exact stream order
/// `sgemm_strided` consumes.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    data: Vec<f32>,
    /// Offset of each (jc, pc) panel in `data`.
    panels: Vec<(usize, usize, usize)>, // (jc, pc, offset)
}

impl PackedB {
    /// Bytes held by the packed panels (the plan's "prepacked bytes"
    /// accounting: what model load paid so inference never packs B).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Pack a row-major `(k, n)` B.
    pub fn pack(k: usize, n: usize, b: &[f32]) -> Self {
        assert_eq!(b.len(), k * n);
        let mut data = Vec::new();
        let mut panels = Vec::new();
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            let nc_padded = round_up(nc, NR);
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                panels.push((jc, pc, data.len()));
                let start = data.len();
                data.resize(start + kc * nc_padded, 0.0);
                pack_b(&mut data[start..], b, k, n, pc, jc, kc, nc);
            }
        }
        PackedB { k, n, data, panels }
    }

    fn panel(&self, jc: usize, pc: usize) -> &[f32] {
        let (_, _, off) = *self
            .panels
            .iter()
            .find(|&&(j, p, _)| j == jc && p == pc)
            .expect("panel");
        &self.data[off..]
    }
}

/// `sgemm_strided` against a pre-packed B: skips all B packing at call
/// time. C[m×n] (+)= A[m×k]·B.
pub fn sgemm_prepacked(m: usize, a: &[f32], lda: usize, b: &PackedB,
                       c: &mut [f32], accumulate: bool) {
    let ws = Workspace::new();
    sgemm_prepacked_with(&mut ws.handle(), m, a, lda, b, c, accumulate);
}

/// [`sgemm_prepacked`] drawing its A panel from a workspace handle — the
/// form every per-tap GEMM in the untangled engines uses, so row-level
/// calls stop allocating entirely.
pub fn sgemm_prepacked_with(ws: &mut WsHandle, m: usize, a: &[f32],
                            lda: usize, b: &PackedB, c: &mut [f32],
                            accumulate: bool) {
    let (k, n) = (b.k, b.n);
    assert!(lda >= k);
    assert!(m == 0 || a.len() >= (m - 1) * lda + k, "A size");
    assert_eq!(c.len(), m * n, "C size");
    if !accumulate {
        c.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let isa = active_isa();
    let mut packed_a = ws.checkout(MC * KC);
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            let pb = b.panel(jc, pc);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                pack_a(&mut packed_a, a, lda, ic, pc, mc, kc);
                macro_kernel(isa, &packed_a, pb, c, n, ic, jc, mc, nc, kc);
            }
        }
    }
    ws.checkin(packed_a);
}

/// C[k×n] (+)= Aᵀ · B where A is [m×k] row-major (so Aᵀ is k×m) and
/// B is [m×n]. Rank-1-update formulation — the weight-gradient taps
/// (paper §3.2.3) are exactly this shape: dK_tap (C×N) += Xᵀ(C×M)·dY(M×N).
pub fn sgemm_at(m: usize, n: usize, k: usize, a: &[f32], lda: usize,
                b: &[f32], c: &mut [f32], accumulate: bool) {
    assert!(m == 0 || a.len() >= (m - 1) * lda + k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    if !accumulate {
        c.fill(0.0);
    }
    for q in 0..m {
        let arow = &a[q * lda..q * lda + k];
        let brow = &b[q * n..(q + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Multi-threaded `sgemm`: shards rows of C across `threads`.
pub fn sgemm_parallel(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                      c: &mut [f32], accumulate: bool, threads: usize) {
    let ws = Workspace::new();
    sgemm_parallel_with(&ws, m, n, k, a, b, c, accumulate, threads);
}

/// [`sgemm_parallel`] over a shared workspace: each shard thread draws
/// its packing panels through its own per-thread handle.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_parallel_with(ws: &Workspace, m: usize, n: usize, k: usize,
                           a: &[f32], b: &[f32], c: &mut [f32],
                           accumulate: bool, threads: usize) {
    let threads = threads.max(1).min(m.max(1));
    if threads == 1 || m * n * k < 64 * 64 * 64 {
        return sgemm_with(&mut ws.handle(), m, n, k, a, b, c, accumulate);
    }
    let rows_per = m.div_ceil(threads);
    // Split C into disjoint row bands; each thread runs a private sgemm.
    let mut bands: Vec<&mut [f32]> = Vec::with_capacity(threads);
    let mut rest = c;
    let mut starts = Vec::with_capacity(threads);
    let mut start = 0;
    while start < m {
        let rows = rows_per.min(m - start);
        let (band, tail) = rest.split_at_mut(rows * n);
        bands.push(band);
        starts.push(start);
        rest = tail;
        start += rows;
    }
    std::thread::scope(|s| {
        for (band, &row0) in bands.into_iter().zip(&starts) {
            let rows = band.len() / n;
            let a_band = &a[row0 * k..(row0 + rows) * k];
            s.spawn(move || {
                let mut h = ws.handle();
                sgemm_with(&mut h, rows, n, k, a_band, b, band, accumulate);
            });
        }
    });
}

#[inline]
fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Workspace elements one `sgemm_with`/`sgemm_strided_with` call checks
/// out (A panel + B panel) — the plan's workspace high-water accounting
/// (DESIGN.md §10) mirrors the checkouts in the GEMM body exactly.
pub fn sgemm_scratch_elems(n: usize) -> usize {
    MC * KC + KC * NC.min(round_up(n, NR))
}

/// Workspace elements one `sgemm_prepacked_with` call checks out (A
/// panel only — B was packed at model load).
pub fn prepacked_scratch_elems() -> usize {
    MC * KC
}

/// Pack an `mc × kc` panel of A into MR-tall column-major slivers.
fn pack_a(dst: &mut [f32], a: &[f32], lda: usize, ic: usize, pc: usize,
          mc: usize, kc: usize) {
    let mut w = 0;
    for i0 in (0..mc).step_by(MR) {
        let rows = MR.min(mc - i0);
        for p in 0..kc {
            for i in 0..MR {
                dst[w] = if i < rows {
                    a[(ic + i0 + i) * lda + pc + p]
                } else {
                    0.0
                };
                w += 1;
            }
        }
    }
}

/// Pack a `kc × nc` panel of B into NR-wide row-major slivers.
fn pack_b(dst: &mut [f32], b: &[f32], _ldb_rows: usize, ldb: usize,
          pc: usize, jc: usize, kc: usize, nc: usize) {
    let mut w = 0;
    for j0 in (0..nc).step_by(NR) {
        let cols = NR.min(nc - j0);
        for p in 0..kc {
            let src = (pc + p) * ldb + jc + j0;
            for j in 0..NR {
                dst[w] = if j < cols { b[src + j] } else { 0.0 };
                w += 1;
            }
        }
    }
}

/// Drive the micro-kernel over one (mc × nc) block. Full MR×NR tiles
/// dispatch on `isa`; edge tiles (partial rows/cols) always run the
/// scalar kernel, so every tier is bit-exact at tile boundaries.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(isa: Isa, pa: &[f32], pb: &[f32], c: &mut [f32],
                ldc: usize, ic: usize, jc: usize, mc: usize, nc: usize,
                kc: usize) {
    for (jt, j0) in (0..nc).step_by(NR).enumerate() {
        let cols = NR.min(nc - j0);
        let bp = &pb[jt * kc * NR..(jt + 1) * kc * NR];
        for (it, i0) in (0..mc).step_by(MR).enumerate() {
            let rows = MR.min(mc - i0);
            let ap = &pa[it * kc * MR..(it + 1) * kc * MR];
            if rows == MR && cols == NR {
                match isa {
                    Isa::Scalar => micro_kernel_full(
                        ap, bp, c, ldc, ic + i0, jc + j0, kc),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: `isa` comes from `available_isas` /
                    // `active_isa`, which only offer these tiers after
                    // `is_x86_feature_detected!` confirmed the features.
                    Isa::Avx2 => unsafe {
                        micro_kernel_avx2(ap, bp, c, ldc, ic + i0,
                                          jc + j0, kc)
                    },
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: as above — Avx2Fma is only offered when
                    // both "avx2" and "fma" were detected at runtime.
                    Isa::Avx2Fma => unsafe {
                        micro_kernel_avx2_fma(ap, bp, c, ldc, ic + i0,
                                              jc + j0, kc)
                    },
                    #[cfg(not(target_arch = "x86_64"))]
                    _ => micro_kernel_full(ap, bp, c, ldc, ic + i0,
                                           jc + j0, kc),
                }
            } else {
                micro_kernel_edge(ap, bp, c, ldc, ic + i0, jc + j0, kc,
                                  rows, cols);
            }
        }
    }
}

/// Full MR×NR register tile, portable scalar form. Rust does not
/// contract `a*b + c` to FMA, so this is exact IEEE mul-then-add per
/// element in a fixed k-order — the bit-identity reference every other
/// tier is measured against.
#[inline]
fn micro_kernel_full(ap: &[f32], bp: &[f32], c: &mut [f32], ldc: usize,
                     row: usize, col: usize, kc: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    let mut aoff = 0;
    let mut boff = 0;
    for _ in 0..kc {
        let a0 = ap[aoff];
        let a1 = ap[aoff + 1];
        let a2 = ap[aoff + 2];
        let a3 = ap[aoff + 3];
        let bv = &bp[boff..boff + NR];
        for j in 0..NR {
            let b = bv[j];
            acc[0][j] += a0 * b;
            acc[1][j] += a1 * b;
            acc[2][j] += a2 * b;
            acc[3][j] += a3 * b;
        }
        aoff += MR;
        boff += NR;
    }
    for i in 0..MR {
        let dst = &mut c[(row + i) * ldc + col..(row + i) * ldc + col + NR];
        for j in 0..NR {
            dst[j] += acc[i][j];
        }
    }
}

/// AVX2 full tile: NR=16 is two ymm vectors, MR=4 broadcasts → eight
/// ymm accumulators (+ two B loads + one broadcast = 11 of 16 ymm).
/// Separate `mul` and `add` keep one rounding per operation in the same
/// k-order as the scalar kernel, so the result is **bit-identical** to
/// [`micro_kernel_full`].
///
/// # Safety
/// Caller must have verified `is_x86_feature_detected!("avx2")`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn micro_kernel_avx2(ap: &[f32], bp: &[f32], c: &mut [f32],
                            ldc: usize, row: usize, col: usize, kc: usize) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = _mm256_loadu_ps(b);
        let b1 = _mm256_loadu_ps(b.add(8));
        for (i, lane) in acc.iter_mut().enumerate() {
            let ai = _mm256_set1_ps(*a.add(i));
            lane[0] = _mm256_add_ps(lane[0], _mm256_mul_ps(ai, b0));
            lane[1] = _mm256_add_ps(lane[1], _mm256_mul_ps(ai, b1));
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    for (i, lane) in acc.iter().enumerate() {
        let dst = c[(row + i) * ldc + col..].as_mut_ptr();
        _mm256_storeu_ps(dst,
                         _mm256_add_ps(_mm256_loadu_ps(dst), lane[0]));
        let hi = dst.add(8);
        _mm256_storeu_ps(hi, _mm256_add_ps(_mm256_loadu_ps(hi), lane[1]));
    }
}

/// AVX2+FMA full tile: identical structure to [`micro_kernel_avx2`] but
/// each multiply-add contracts to `vfmadd231ps` — one rounding instead
/// of two, so results are ulp-bounded against scalar rather than
/// bit-equal. Only reachable via the `HUGE2_GEMM_FMA=1` opt-in, which
/// also tags the plan digest (DESIGN.md §14).
///
/// # Safety
/// Caller must have verified `is_x86_feature_detected!("avx2")` and
/// `is_x86_feature_detected!("fma")`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn micro_kernel_avx2_fma(ap: &[f32], bp: &[f32], c: &mut [f32],
                                ldc: usize, row: usize, col: usize,
                                kc: usize) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = _mm256_loadu_ps(b);
        let b1 = _mm256_loadu_ps(b.add(8));
        for (i, lane) in acc.iter_mut().enumerate() {
            let ai = _mm256_set1_ps(*a.add(i));
            lane[0] = _mm256_fmadd_ps(ai, b0, lane[0]);
            lane[1] = _mm256_fmadd_ps(ai, b1, lane[1]);
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    for (i, lane) in acc.iter().enumerate() {
        let dst = c[(row + i) * ldc + col..].as_mut_ptr();
        _mm256_storeu_ps(dst,
                         _mm256_add_ps(_mm256_loadu_ps(dst), lane[0]));
        let hi = dst.add(8);
        _mm256_storeu_ps(hi, _mm256_add_ps(_mm256_loadu_ps(hi), lane[1]));
    }
}

/// Edge tile (partial rows/cols).
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel_edge(ap: &[f32], bp: &[f32], c: &mut [f32], ldc: usize,
                     row: usize, col: usize, kc: usize, rows: usize,
                     cols: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let bv = &bp[p * NR..p * NR + NR];
        for i in 0..rows {
            let a = ap[p * MR + i];
            for j in 0..cols {
                acc[i][j] += a * bv[j];
            }
        }
    }
    for i in 0..rows {
        for j in 0..cols {
            c[(row + i) * ldc + col + j] += acc[i][j];
        }
    }
}

/// Reference GEMM (textbook triple loop) — the oracle for property tests.
pub fn sgemm_naive(m: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                   c: &mut [f32], accumulate: bool) {
    if !accumulate {
        c.fill(0.0);
    }
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn check(m: usize, n: usize, k: usize, threads: usize) {
        let mut rng = Rng::new((m * 31 + n * 7 + k) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let mut want = vec![0.0; m * n];
        sgemm_naive(m, n, k, &a, &b, &mut want, false);
        let mut got = vec![0.0; m * n];
        if threads == 1 {
            sgemm(m, n, k, &a, &b, &mut got, false);
        } else {
            sgemm_parallel(m, n, k, &a, &b, &mut got, false, threads);
        }
        let err = got
            .iter()
            .zip(&want)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3 * (k as f32).sqrt(), "err={err} m={m} n={n} k={k}");
    }

    #[test]
    fn small_shapes() {
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (4, 16, 8), (5, 17, 9)] {
            check(m, n, k, 1);
        }
    }

    #[test]
    fn tile_boundaries() {
        for &(m, n, k) in &[
            (MR, NR, KC),
            (MR + 1, NR + 1, KC + 1),
            (MC, NR, KC),
            (MC + 3, 2 * NR + 5, KC + 7),
        ] {
            check(m, n, k, 1);
        }
    }

    #[test]
    fn big_block() {
        check(200, 130, 300, 1);
    }

    #[test]
    fn parallel_matches() {
        check(257, 129, 65, 4);
        check(64, 64, 64, 3);
    }

    #[test]
    fn prepacked_matches_sgemm() {
        let mut rng = Rng::new(9);
        for &(m, n, k) in &[(1, 1, 1), (4, 16, 8), (5, 17, 300),
                             (130, 40, 70), (3, 1100, 80)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
            let mut want = vec![0.0; m * n];
            sgemm(m, n, k, &a, &b, &mut want, false);
            let pb = PackedB::pack(k, n, &b);
            let mut got = vec![1.0; m * n];
            sgemm_prepacked(m, &a, k, &pb, &mut got, false);
            let err = got.iter().zip(&want)
                .map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(err < 1e-4 * (k as f32).sqrt(),
                    "err={err} m={m} n={n} k={k}");
        }
    }

    #[test]
    fn prepacked_strided_a() {
        let mut rng = Rng::new(10);
        let (m, n, k, lda) = (7, 9, 5, 12);
        let a: Vec<f32> = (0..m * lda).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let mut want = vec![0.0; m * n];
        sgemm_strided(m, n, k, &a[..(m - 1) * lda + k], lda, &b, &mut want,
                      false);
        let pb = PackedB::pack(k, n, &b);
        let mut got = vec![0.0; m * n];
        sgemm_prepacked(m, &a[..(m - 1) * lda + k], lda, &pb, &mut got,
                        false);
        assert_eq!(got, want);
    }

    #[test]
    fn isa_tiers_match_naive() {
        for isa in available_isas() {
            for &(m, n, k) in &[(1, 1, 1), (4, 16, 8), (5, 17, 9),
                                 (130, 40, 70), (64, 64, 300)] {
                let mut rng = Rng::new((m * 131 + n * 17 + k) as u64);
                let a: Vec<f32> =
                    (0..m * k).map(|_| rng.next_normal()).collect();
                let b: Vec<f32> =
                    (0..k * n).map(|_| rng.next_normal()).collect();
                let mut want = vec![0.0; m * n];
                sgemm_naive(m, n, k, &a, &b, &mut want, false);
                let mut got = vec![0.0; m * n];
                sgemm_isa(isa, m, n, k, &a, &b, &mut got, false);
                let err = got.iter().zip(&want)
                    .map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
                assert!(err < 1e-3 * (k as f32).sqrt(),
                        "isa={} err={err} m={m} n={n} k={k}",
                        isa.name());
            }
        }
    }

    #[test]
    fn avx2_bit_identical_to_scalar() {
        if !available_isas().contains(&Isa::Avx2) {
            return; // host without AVX2: nothing to compare
        }
        let mut rng = Rng::new(42);
        for &(m, n, k) in &[(4, 16, 8), (MR, NR, KC), (MC + 3, 2 * NR + 5,
                             KC + 7), (200, 130, 300)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
            let mut scalar = vec![0.0; m * n];
            sgemm_isa(Isa::Scalar, m, n, k, &a, &b, &mut scalar, false);
            let mut avx2 = vec![0.0; m * n];
            sgemm_isa(Isa::Avx2, m, n, k, &a, &b, &mut avx2, false);
            assert_eq!(scalar, avx2, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn scalar_always_available_and_first() {
        let isas = available_isas();
        assert_eq!(isas[0], Isa::Scalar);
        assert!(isas.contains(&active_isa())
                || active_isa() == Isa::Scalar);
        assert!(!Isa::Scalar.relaxed_numerics());
        assert!(!Isa::Avx2.relaxed_numerics());
        assert!(Isa::Avx2Fma.relaxed_numerics());
    }

    #[test]
    fn tiled_matches_naive_and_default_is_bit_identical() {
        let mut rng = Rng::new(77);
        let (m, n, k) = (130, 1100, 300);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let mut want = vec![0.0; m * n];
        sgemm(m, n, k, &a, &b, &mut want, false);
        let ws = Workspace::new();
        // default tile: bit-identical to the untiled entry point
        let mut got = vec![0.0; m * n];
        sgemm_tiled_with(&mut ws.handle(), m, n, k, &a, &b, &mut got,
                         false, Tile::DEFAULT);
        assert_eq!(got, want);
        // non-default tiles: numerically equivalent (different K-panel
        // partial-sum grouping, hence only an ulp-style bound)
        for tile in [Tile { kc: 128, nc: 512 }, Tile { kc: 64, nc: 1024 },
                     Tile { kc: 256, nc: 256 }] {
            let mut t = vec![0.0; m * n];
            sgemm_tiled_with(&mut ws.handle(), m, n, k, &a, &b, &mut t,
                             false, tile);
            let err = t.iter().zip(&want)
                .map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(err < 1e-3 * (k as f32).sqrt(),
                    "err={err} tile={tile:?}");
        }
        // clamping pins out-of-range tiles into the accounted range
        let c = Tile { kc: 1, nc: 1 << 20 }.clamped();
        assert_eq!(c, Tile { kc: NR, nc: NC });
        assert!(Tile::DEFAULT.is_default());
        assert!(!Tile { kc: 128, nc: 1024 }.is_default());
    }

    #[test]
    fn accumulate_adds() {
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        let mut c = vec![10.0; 4];
        sgemm(2, 2, 2, &a, &b, &mut c, true);
        assert_eq!(c, vec![12.0; 4]);
        sgemm(2, 2, 2, &a, &b, &mut c, false);
        assert_eq!(c, vec![2.0; 4]);
    }
}
