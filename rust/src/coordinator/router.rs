//! Model registry + multi-task request routing.
//!
//! A [`Model`] describes one servable network — a GAN generator
//! ([`Task::Generate`]: latent in, image out) or a segmentation net
//! ([`Task::Segment`]: image in, class-argmax mask out) — its input
//! geometry, its weights (owned by the engine — the AOT artifacts take
//! weights as runtime inputs so one compiled module serves any
//! checkpoint), and the batch buckets that were compiled ahead of time.
//! The router maps a request's model name to the per-model queue; the
//! request's [`Payload`] must match the model's task
//! ([`Model::validate`]).

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use super::error::{ServeError, ServeResult};

use crate::gan::Generator;
use crate::metrics::span::SpanStamps;
use crate::plan::ExecPlan;
use crate::replay::event::ArrivalPayload;
use crate::rng::Rng;
use crate::runtime::RuntimeHandle;
use crate::seg::SegNet;
use crate::tensor::Tensor;

/// What a model computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Latent (+ optional conditioning one-hot) → generated image.
    Generate,
    /// Image tensor → per-pixel class-argmax mask.
    Segment,
}

impl Task {
    /// Wire name (trace headers, `--task` flag).
    pub fn as_str(&self) -> &'static str {
        match self {
            Task::Generate => "generate",
            Task::Segment => "segment",
        }
    }

    /// Index into the stage-metrics `task` label axis
    /// ([`crate::metrics::span::TASKS`]).
    pub fn index(&self) -> usize {
        match self {
            Task::Generate => 0,
            Task::Segment => 1,
        }
    }
}

impl std::str::FromStr for Task {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "generate" => Ok(Task::Generate),
            "segment" => Ok(Task::Segment),
            other => Err(anyhow::anyhow!(
                "task must be 'generate' or 'segment', got {other:?}")),
        }
    }
}

/// Priority class of a request — the admission controller's and
/// batcher's scheduling axis (DESIGN.md §16). Ordering is by
/// [`Priority::rank`]: `Interactive` outranks `Batch` outranks
/// `Background`. Under backpressure the controller sheds strictly by
/// class (a higher-priority arrival may displace the youngest
/// lower-class request from a full queue), and the continuous batcher
/// seats higher classes first when more rows are ready than fit in one
/// batch.
///
/// The class is carried on trace arrivals (trace format v5; v1–v4
/// arrivals decode as the default `Interactive`), so a replay re-drives
/// the exact recorded priority mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive foreground work (the default class).
    #[default]
    Interactive,
    /// Throughput work: shed before `Interactive` under load.
    Batch,
    /// Best-effort work: first to shed, last to batch.
    Background,
}

impl Priority {
    /// Scheduling rank: 0 is the highest priority. Lower rank wins batch
    /// seats; higher rank sheds first.
    pub fn rank(&self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
            Priority::Background => 2,
        }
    }

    /// Wire name (trace arrivals, `--priority-default` flag).
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }

    /// Inverse of [`Priority::rank`] (trace decode of the binary codec's
    /// class byte).
    pub fn from_rank(rank: u8) -> Option<Self> {
        match rank {
            0 => Some(Priority::Interactive),
            1 => Some(Priority::Batch),
            2 => Some(Priority::Background),
            _ => None,
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            "background" => Ok(Priority::Background),
            other => Err(anyhow::anyhow!(
                "priority must be 'interactive', 'batch' or \
                 'background', got {other:?}")),
        }
    }
}

/// What a request carries — the task-specific input.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Latent vector plus cGAN class one-hot (empty if unconditional).
    Latent { z: Vec<f32>, cond: Vec<f32> },
    /// `(1, H, W, C)` input image. `seed` is the provenance tag of the
    /// canonical synthesis (`Tensor::randn(shape, Rng::new(seed))`): the
    /// recorder stores `(shape, seed, checksum)` instead of raw pixels
    /// (trace format v2, DESIGN.md §8), and replay regenerates the image
    /// from it, verifying the checksum.
    Image { tensor: Tensor, seed: u64 },
}

impl Payload {
    pub fn latent(z: Vec<f32>, cond: Vec<f32>) -> Self {
        Payload::Latent { z, cond }
    }

    pub fn image(tensor: Tensor, seed: u64) -> Self {
        Payload::Image { tensor, seed }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Latent { .. } => "latent",
            Payload::Image { .. } => "image",
        }
    }

    /// The trace-event form of this payload, with the recordability
    /// check folded in (the image tensor is hashed exactly once): an
    /// image payload must BE the canonical synthesis of its seed,
    /// because the trace stores only (shape, seed, checksum) and replay
    /// rebuilds the tensor from them (DESIGN.md §8). Failing here — at
    /// the fault site — beats recording a trace whose every replay
    /// aborts with a reconstruction mismatch. Costs one regeneration per
    /// image request, only while recording.
    pub fn to_recordable_arrival(&self) -> Result<ArrivalPayload> {
        let arrival = self.to_arrival();
        if let ArrivalPayload::Image { shape, seed, checksum } = &arrival {
            let canon = Tensor::randn(shape, &mut Rng::new(*seed));
            if canon.checksum() != *checksum {
                bail!("image payload is not the canonical synthesis of \
                       seed {seed} (Tensor::randn over its shape) — it \
                       cannot be recorded for replay; see DESIGN.md §8");
            }
        }
        Ok(arrival)
    }

    /// The trace-event form of this payload: latents are captured
    /// bit-exactly; images are captured as (shape, seed, checksum).
    pub fn to_arrival(&self) -> ArrivalPayload {
        match self {
            Payload::Latent { z, cond } => ArrivalPayload::Latent {
                z: z.clone(),
                cond: cond.clone(),
            },
            Payload::Image { tensor, seed } => ArrivalPayload::Image {
                shape: tensor.shape().to_vec(),
                seed: *seed,
                checksum: tensor.checksum(),
            },
        }
    }
}

/// One inference request: the task payload plus reply plumbing.
///
/// The reply channel carries the request's single terminal outcome —
/// `Ok(Response)` or a typed [`ServeError`] (DESIGN.md §11). A client
/// that observes a closed channel without either is witnessing an
/// engine bug, not a failure mode.
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    /// Priority class: the admission controller sheds lower classes
    /// first under backpressure, the batcher seats higher classes first
    /// (DESIGN.md §16).
    pub priority: Priority,
    pub enqueued: Instant,
    /// Lifecycle stamps for stage-span latency attribution
    /// (DESIGN.md §12). `Copy`, carried in-line — no allocation.
    pub stamps: SpanStamps,
    pub reply: mpsc::Sender<ServeResult>,
}

/// The task output plus serving telemetry.
pub struct Response {
    pub id: u64,
    /// [`Task::Generate`]: `(1, H, W, C)` image in [-1, 1].
    /// [`Task::Segment`]: `(1, H, W, 1)` class-index mask.
    pub output: Tensor,
    /// Queue wait + execution, from submit to reply.
    pub latency: std::time::Duration,
    /// Requests fused into the executing batch.
    pub batch_size: usize,
    /// Compiled bucket the batch ran in.
    pub bucket: usize,
}

/// How a model executes.
pub enum Backend {
    /// AOT JAX/Pallas artifact through the PJRT runtime service (the
    /// production path). Weights are bound in the service thread under
    /// the model's name.
    Pjrt(Arc<RuntimeHandle>),
    /// Pure-Rust HUGE² GAN generator (fallback / CPU-bench path).
    Native(Arc<Generator>),
    /// Pure-Rust HUGE² segmentation net (dilated-conv path).
    NativeSeg(Arc<SegNet>),
}

/// A servable network.
pub struct Model {
    pub name: String,
    pub task: Task,
    /// Artifact name prefix; bucket `b` resolves to `{prefix}_b{b}`.
    pub artifact_prefix: String,
    pub z_dim: usize,
    /// Conditioning one-hot width (0 = unconditional).
    pub cond_dim: usize,
    /// Single-image input shape `(1, H, W, C)` for [`Task::Segment`];
    /// empty for [`Task::Generate`] (input geometry is z_dim/cond_dim).
    pub in_shape: Vec<usize>,
    pub buckets: Vec<usize>,
    pub backend: Backend,
    /// Single-request output shape `(1, H, W, C)`.
    pub out_shape: Vec<usize>,
    /// The compiled serving plan (native backends; `None` for PJRT).
    /// Workers execute this uniformly — for the seg model it already
    /// ends in the argmax head, so `run_into` yields the client-ready
    /// output for **both** tasks (DESIGN.md §10).
    ///
    /// Behind a `RwLock` for weight residency (DESIGN.md §16): the LRU
    /// residency manager may *evict* the plan under byte-budget
    /// pressure and rebuild it on the next batch; workers take a cheap
    /// `Arc` handle per batch, so an eviction never invalidates an
    /// executing forward pass.
    plan: RwLock<Option<Arc<ExecPlan>>>,
    /// Rebuilds the serving plan after an eviction (native backends;
    /// `None` pins the model resident — PJRT weights live in the
    /// runtime service, not the workspace budget).
    rebuild: Option<Box<dyn Fn() -> ExecPlan + Send + Sync>>,
    /// Engine-selection digest pinned at registration: a rebuilt plan
    /// must reproduce it exactly, or the reload is refused (a silent
    /// engine-selection drift would invalidate every recorded trace).
    pinned_digest: Option<u64>,
    /// Prepacked-weight footprint of the serving plan (bytes) — the
    /// unit of the residency manager's byte-budget accounting.
    plan_bytes: usize,
    /// Fault-injection test hook (the supervision analogue of
    /// [`crate::workspace::Workspace::poison`]): when armed, the next
    /// batch a worker executes for this model panics once.
    panic_next_batch: AtomicBool,
}

impl Model {
    /// Build a PJRT-served model from its manifest entry: weight shapes
    /// are read from the bucket-1 artifact spec, seeded from `seed`
    /// (DCGAN-style 0.02·N(0,1)) and bound resident in the runtime
    /// service; `latent_inputs` is 1 for DCGAN (z) and 2 for cGAN
    /// (z, one-hot).
    pub fn from_artifacts(name: &str, prefix: &str,
                          runtime: Arc<RuntimeHandle>,
                          latent_inputs: usize, buckets: &[usize],
                          seed: u64) -> Result<Self> {
        let spec = runtime
            .manifest()
            .get(&format!("{prefix}_b{}", buckets[0]))?
            .clone();
        if spec.inputs.len() <= latent_inputs {
            bail!("{prefix}: expected weight inputs after {latent_inputs} \
                   latent inputs");
        }
        let z_dim = *spec.inputs[0].dims.last().unwrap();
        let cond_dim = if latent_inputs == 2 {
            *spec.inputs[1].dims.last().unwrap()
        } else {
            0
        };
        let mut rng = Rng::new(seed);
        let weights: Vec<Tensor> = spec.inputs[latent_inputs..]
            .iter()
            .map(|ts| Tensor::randn(&ts.dims, &mut rng).scale(0.02))
            .collect();
        runtime.bind(name, weights)?;
        // pre-compile every bucket so first requests don't pay XLA compile
        for b in buckets {
            runtime.warm(&format!("{prefix}_b{b}"))?;
        }
        let out_dims = &spec.outputs[0].dims;
        let out_shape = vec![1, out_dims[1], out_dims[2], out_dims[3]];
        Ok(Model {
            name: name.to_string(),
            task: Task::Generate,
            artifact_prefix: prefix.to_string(),
            z_dim,
            cond_dim,
            in_shape: Vec::new(),
            buckets: buckets.to_vec(),
            backend: Backend::Pjrt(runtime),
            out_shape,
            plan: RwLock::new(None),
            rebuild: None,
            pinned_digest: None,
            plan_bytes: 0,
            panic_next_batch: AtomicBool::new(false),
        })
    }

    /// Build a natively-served generator (pure-Rust HUGE² engine). The
    /// model adopts the generator's load-time-compiled [`ExecPlan`]
    /// (engine selection resolved, all prepacking done).
    pub fn native(name: &str, gen: Arc<Generator>, cond_dim: usize) -> Self {
        let out = gen.out_shape(1);
        let z_total = gen.proj.shape()[0];
        let plan = gen.plan().clone();
        let rebuild_gen = gen.clone();
        Model {
            name: name.to_string(),
            task: Task::Generate,
            artifact_prefix: String::new(),
            z_dim: z_total - cond_dim,
            cond_dim,
            in_shape: Vec::new(),
            buckets: vec![usize::MAX], // native path takes any batch size
            backend: Backend::Native(gen),
            out_shape: out,
            pinned_digest: Some(plan.engine_digest()),
            plan_bytes: plan.prepacked_bytes(),
            plan: RwLock::new(Some(Arc::new(plan))),
            rebuild: Some(Box::new(move || rebuild_gen.plan().clone())),
            panic_next_batch: AtomicBool::new(false),
        }
    }

    /// [`Model::native`] but serving under an explicitly provided plan
    /// instead of the generator's heuristic-compiled one — the tuned
    /// serving path (`huge2 serve --tuned`): the caller applies a
    /// [`crate::tune::TunedPlan`] to `gen.plan()` and registers the
    /// result. The plan must compute the same network (same steps/
    /// shapes); only engine/thread/tile selections may differ.
    pub fn native_with_plan(name: &str, gen: Arc<Generator>,
                            cond_dim: usize, plan: ExecPlan) -> Self {
        let mut m = Model::native(name, gen, cond_dim);
        m.pinned_digest = Some(plan.engine_digest());
        m.plan_bytes = plan.prepacked_bytes();
        // An explicitly supplied (tuned) plan has no source net to
        // re-derive it from; the rebuild closure re-clones it (cheap —
        // prepacked state is Arc-shared), so eviction for this model is
        // accounting-only.
        let keep = plan.clone();
        m.rebuild = Some(Box::new(move || keep.clone()));
        m.plan = RwLock::new(Some(Arc::new(plan)));
        m
    }

    /// Build a natively-served segmentation model: image requests in,
    /// class-argmax masks out. The serving plan is the net's compiled
    /// logits plan plus the argmax head — registration is load time,
    /// not inference time.
    pub fn native_seg(name: &str, net: Arc<SegNet>) -> Self {
        let plan = net.plan().with_argmax_head(net.n_classes());
        let mut m = Model::native_seg_with_plan(name, net.clone(), plan);
        // the seg plan re-derives from its net, so eviction really
        // drops this model's argmax-headed serving plan
        m.rebuild = Some(Box::new(move || {
            net.plan().with_argmax_head(net.n_classes())
        }));
        m
    }

    /// [`Model::native_seg`] but serving under an explicitly provided
    /// plan (argmax head already appended) instead of the heuristic-
    /// compiled one — the tuned serving path, mirroring
    /// [`Model::native_with_plan`].
    pub fn native_seg_with_plan(name: &str, net: Arc<SegNet>,
                                plan: ExecPlan) -> Self {
        let in_shape = net.in_shape();
        let out_shape = plan.out_shape(1);
        let keep = plan.clone();
        Model {
            name: name.to_string(),
            task: Task::Segment,
            artifact_prefix: String::new(),
            z_dim: 0,
            cond_dim: 0,
            in_shape,
            buckets: vec![usize::MAX],
            backend: Backend::NativeSeg(net),
            out_shape,
            pinned_digest: Some(plan.engine_digest()),
            plan_bytes: plan.prepacked_bytes(),
            plan: RwLock::new(Some(Arc::new(plan))),
            rebuild: Some(Box::new(move || keep.clone())),
            panic_next_batch: AtomicBool::new(false),
        }
    }

    /// A shared handle on the compiled serving plan (native backends;
    /// `None` for PJRT **or while evicted**). Workers take one handle
    /// per batch — the handle keeps an executing forward pass valid
    /// across a concurrent eviction.
    pub fn plan_handle(&self) -> Option<Arc<ExecPlan>> {
        self.plan.read().unwrap().clone()
    }

    /// Is the serving plan currently resident? PJRT models report
    /// `false` (their weights live in the runtime service, outside the
    /// residency budget — see [`Model::is_evictable`]).
    pub fn is_resident(&self) -> bool {
        self.plan.read().unwrap().is_some()
    }

    /// Can the residency manager evict this model? True only for native
    /// backends with a rebuild path.
    pub fn is_evictable(&self) -> bool {
        self.rebuild.is_some()
    }

    /// Prepacked-weight footprint of the serving plan (bytes); the
    /// residency manager's accounting unit. 0 for PJRT.
    pub fn plan_bytes(&self) -> usize {
        self.plan_bytes
    }

    /// Engine-selection digest pinned at registration (native backends).
    pub fn pinned_digest(&self) -> Option<u64> {
        self.pinned_digest
    }

    /// Drop the resident plan (residency manager only). Returns the
    /// bytes released, or `None` when the model was not resident or has
    /// no rebuild path (PJRT models are never evicted).
    pub(crate) fn evict_plan(&self) -> Option<usize> {
        if self.rebuild.is_none() {
            return None;
        }
        self.plan
            .write()
            .unwrap()
            .take()
            .map(|_| self.plan_bytes)
    }

    /// Make the plan resident, rebuilding after an eviction. The
    /// rebuilt plan must reproduce the digest pinned at registration —
    /// a mismatch means engine selection drifted between build and
    /// reload, and the reload is refused rather than silently serving a
    /// different plan. Returns the handle plus whether a rebuild
    /// happened (the residency manager records a `Reload` trace event
    /// when it did).
    pub(crate) fn ensure_plan(&self)
                              -> std::result::Result<(Arc<ExecPlan>, bool),
                                                     String> {
        if let Some(p) = self.plan_handle() {
            return Ok((p, false));
        }
        let rebuild = self.rebuild.as_ref().ok_or_else(|| {
            format!("{}: no serving plan and no rebuild path", self.name)
        })?;
        let mut g = self.plan.write().unwrap();
        // a racing worker may have reloaded while we waited on the lock
        if let Some(p) = g.as_ref() {
            return Ok((p.clone(), false));
        }
        let plan = rebuild();
        if let Some(want) = self.pinned_digest {
            let got = plan.engine_digest();
            if got != want {
                return Err(format!(
                    "{}: reloaded plan digest {got:016x} != pinned \
                     {want:016x} — engine selection drifted across \
                     eviction; refusing to serve it", self.name));
            }
        }
        let p = Arc::new(plan);
        *g = Some(p.clone());
        Ok((p, true))
    }

    /// Smallest compiled bucket that fits `n` (native: exactly `n`).
    pub fn bucket_for(&self, n: usize) -> usize {
        if matches!(self.backend,
                    Backend::Native(_) | Backend::NativeSeg(_)) {
            return n;
        }
        *self
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }

    /// Validate a request payload against the model's task and input
    /// geometry. The typed error feeds straight into the reject path
    /// (`ServeError::kind() == "validation"`).
    pub fn validate(&self, payload: &Payload)
                    -> std::result::Result<(), ServeError> {
        let fail = |msg: String| Err(ServeError::Validation(msg));
        match (self.task, payload) {
            (Task::Generate, Payload::Latent { z, cond }) => {
                if z.len() != self.z_dim {
                    return fail(format!(
                        "{}: z has {} dims, model wants {}", self.name,
                        z.len(), self.z_dim));
                }
                if cond.len() != self.cond_dim {
                    return fail(format!(
                        "{}: cond has {} dims, model wants {}", self.name,
                        cond.len(), self.cond_dim));
                }
                Ok(())
            }
            (Task::Segment, Payload::Image { tensor, .. }) => {
                if tensor.shape() != self.in_shape.as_slice() {
                    return fail(format!(
                        "{}: image has shape {:?}, model wants {:?}",
                        self.name, tensor.shape(), self.in_shape));
                }
                Ok(())
            }
            (task, p) => fail(format!(
                "{}: task {task:?} cannot serve a {} payload", self.name,
                p.kind())),
        }
    }

    /// Fault-injection test hook: arm a one-shot panic in whichever
    /// worker executes this model's next batch. Supervision must catch
    /// it, fail the batch's requests with
    /// [`ServeError::BatchFailed`], and keep the worker draining —
    /// `tests/fault_stack.rs` pins all three (DESIGN.md §11).
    pub fn inject_panic_next_batch(&self) {
        self.panic_next_batch.store(true, Ordering::SeqCst);
    }

    /// Consume an armed injection (worker-side; one panic per arming).
    pub(crate) fn take_injected_panic(&self) -> bool {
        self.panic_next_batch.swap(false, Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{cgan_layers, tiny_segnet};

    fn tiny_native() -> Model {
        let mut rng = Rng::new(1);
        let gen = Generator::new(cgan_layers(), 8, 2, &mut rng);
        Model::native("tiny", Arc::new(gen), 2)
    }

    fn lat(z: usize, cond: usize) -> Payload {
        Payload::latent(vec![0.0; z], vec![0.0; cond])
    }

    #[test]
    fn native_model_geometry() {
        let m = tiny_native();
        assert_eq!(m.task, Task::Generate);
        assert_eq!(m.z_dim, 8);
        assert_eq!(m.cond_dim, 2);
        assert_eq!(m.out_shape, vec![1, 32, 32, 3]);
        assert_eq!(m.bucket_for(5), 5);
    }

    #[test]
    fn validate_rejects_bad_latents() {
        let m = tiny_native();
        assert!(m.validate(&lat(8, 2)).is_ok());
        assert!(m.validate(&lat(7, 2)).is_err());
        assert!(m.validate(&lat(8, 0)).is_err());
    }

    #[test]
    fn seg_model_geometry_and_validation() {
        let net = Arc::new(SegNet::new(&tiny_segnet(), 3));
        let m = Model::native_seg("seg", net.clone());
        assert_eq!(m.task, Task::Segment);
        assert_eq!(m.in_shape, vec![1, 9, 9, 2]);
        assert_eq!(m.out_shape, vec![1, 9, 9, 1]);
        assert_eq!(m.bucket_for(3), 3);
        let good = Payload::image(Tensor::zeros(&net.in_shape()), 1);
        assert!(m.validate(&good).is_ok());
        let bad = Payload::image(Tensor::zeros(&[1, 8, 9, 2]), 1);
        assert!(m.validate(&bad).is_err());
        // cross-task payloads are rejected on both sides
        assert!(m.validate(&lat(8, 0)).is_err());
        assert!(tiny_native().validate(&good).is_err());
    }

    #[test]
    fn task_wire_names_round_trip() {
        for t in [Task::Generate, Task::Segment] {
            assert_eq!(t.as_str().parse::<Task>().unwrap(), t);
        }
        assert!("nope".parse::<Task>().is_err());
    }

    #[test]
    fn priority_ranks_and_wire_names() {
        let all = [Priority::Interactive, Priority::Batch,
                   Priority::Background];
        for (i, p) in all.iter().enumerate() {
            assert_eq!(p.rank() as usize, i);
            assert_eq!(Priority::from_rank(p.rank()), Some(*p));
            assert_eq!(p.as_str().parse::<Priority>().unwrap(), *p);
        }
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::from_rank(9), None);
        assert!("urgent".parse::<Priority>().is_err());
    }

    #[test]
    fn eviction_and_reload_reproduce_the_digest() {
        let m = tiny_native();
        assert!(m.is_resident());
        assert!(m.is_evictable());
        assert!(m.plan_bytes() > 0);
        let digest = m.pinned_digest().unwrap();
        let freed = m.evict_plan().unwrap();
        assert_eq!(freed, m.plan_bytes());
        assert!(!m.is_resident());
        assert!(m.plan_handle().is_none());
        // second eviction is a no-op
        assert_eq!(m.evict_plan(), None);
        let (plan, reloaded) = m.ensure_plan().unwrap();
        assert!(reloaded);
        assert_eq!(plan.engine_digest(), digest);
        assert!(m.is_resident());
        // already-resident ensure is a cheap handle clone
        let (_, reloaded) = m.ensure_plan().unwrap();
        assert!(!reloaded);
    }

    #[test]
    fn seg_model_reload_reproduces_the_digest() {
        let net = Arc::new(SegNet::new(&tiny_segnet(), 3));
        let m = Model::native_seg("seg", net);
        let digest = m.pinned_digest().unwrap();
        m.evict_plan().unwrap();
        let (plan, reloaded) = m.ensure_plan().unwrap();
        assert!(reloaded);
        assert_eq!(plan.engine_digest(), digest);
    }

    #[test]
    fn pjrt_model_from_manifest() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let rt = Arc::new(RuntimeHandle::spawn(dir).unwrap());
        let m = Model::from_artifacts("dcgan", "dcgan_gen", rt, 1,
                                      &[1, 4], 42).unwrap();
        assert_eq!(m.z_dim, 100);
        assert_eq!(m.cond_dim, 0);
        assert_eq!(m.out_shape, vec![1, 64, 64, 3]);
        assert_eq!(m.bucket_for(2), 4);
        assert_eq!(m.bucket_for(100), 4);
    }
}
