//! Model registry + request routing.
//!
//! A [`Model`] describes one servable generator: its latent geometry, its
//! weights (owned by the engine — the AOT artifacts take weights as
//! runtime inputs so one compiled module serves any checkpoint), and the
//! batch buckets that were compiled ahead of time. The router maps a
//! request's model name to the per-model queue.

use anyhow::{bail, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::gan::Generator;
use crate::rng::Rng;
use crate::runtime::RuntimeHandle;
use crate::tensor::Tensor;

/// One inference request: a latent (plus optional conditioning one-hot).
pub struct Request {
    pub id: u64,
    pub z: Vec<f32>,
    /// cGAN class one-hot (len == cond_dim) or empty.
    pub cond: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The generated image plus serving telemetry.
pub struct Response {
    pub id: u64,
    /// `(1, H, W, C)` image in [-1, 1].
    pub image: Tensor,
    /// Queue wait + execution, from submit to reply.
    pub latency: std::time::Duration,
    /// Requests fused into the executing batch.
    pub batch_size: usize,
    /// Compiled bucket the batch ran in.
    pub bucket: usize,
}

/// How a model executes.
pub enum Backend {
    /// AOT JAX/Pallas artifact through the PJRT runtime service (the
    /// production path). Weights are bound in the service thread under
    /// the model's name.
    Pjrt(Arc<RuntimeHandle>),
    /// Pure-Rust HUGE² engine (fallback / CPU-bench path).
    Native(Arc<Generator>),
}

/// A servable generator.
pub struct Model {
    pub name: String,
    /// Artifact name prefix; bucket `b` resolves to `{prefix}_b{b}`.
    pub artifact_prefix: String,
    pub z_dim: usize,
    /// Conditioning one-hot width (0 = unconditional).
    pub cond_dim: usize,
    pub buckets: Vec<usize>,
    pub backend: Backend,
    /// Single-image output shape `(1, H, W, C)`.
    pub out_shape: Vec<usize>,
}

impl Model {
    /// Build a PJRT-served model from its manifest entry: weight shapes
    /// are read from the bucket-1 artifact spec, seeded from `seed`
    /// (DCGAN-style 0.02·N(0,1)) and bound resident in the runtime
    /// service; `latent_inputs` is 1 for DCGAN (z) and 2 for cGAN
    /// (z, one-hot).
    pub fn from_artifacts(name: &str, prefix: &str,
                          runtime: Arc<RuntimeHandle>,
                          latent_inputs: usize, buckets: &[usize],
                          seed: u64) -> Result<Self> {
        let spec = runtime
            .manifest()
            .get(&format!("{prefix}_b{}", buckets[0]))?
            .clone();
        if spec.inputs.len() <= latent_inputs {
            bail!("{prefix}: expected weight inputs after {latent_inputs} \
                   latent inputs");
        }
        let z_dim = *spec.inputs[0].dims.last().unwrap();
        let cond_dim = if latent_inputs == 2 {
            *spec.inputs[1].dims.last().unwrap()
        } else {
            0
        };
        let mut rng = Rng::new(seed);
        let weights: Vec<Tensor> = spec.inputs[latent_inputs..]
            .iter()
            .map(|ts| Tensor::randn(&ts.dims, &mut rng).scale(0.02))
            .collect();
        runtime.bind(name, weights)?;
        // pre-compile every bucket so first requests don't pay XLA compile
        for b in buckets {
            runtime.warm(&format!("{prefix}_b{b}"))?;
        }
        let out_dims = &spec.outputs[0].dims;
        let out_shape = vec![1, out_dims[1], out_dims[2], out_dims[3]];
        Ok(Model {
            name: name.to_string(),
            artifact_prefix: prefix.to_string(),
            z_dim,
            cond_dim,
            buckets: buckets.to_vec(),
            backend: Backend::Pjrt(runtime),
            out_shape,
        })
    }

    /// Build a natively-served model (pure-Rust HUGE² engine).
    pub fn native(name: &str, gen: Arc<Generator>, cond_dim: usize) -> Self {
        let out = gen.out_shape(1);
        let z_total = gen.proj.shape()[0];
        Model {
            name: name.to_string(),
            artifact_prefix: String::new(),
            z_dim: z_total - cond_dim,
            cond_dim,
            buckets: vec![usize::MAX], // native path takes any batch size
            backend: Backend::Native(gen),
            out_shape: out,
        }
    }

    /// Smallest compiled bucket that fits `n` (native: exactly `n`).
    pub fn bucket_for(&self, n: usize) -> usize {
        if matches!(self.backend, Backend::Native(_)) {
            return n;
        }
        *self
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }

    /// Validate a request against the model's latent geometry.
    pub fn validate(&self, z: &[f32], cond: &[f32]) -> Result<()> {
        if z.len() != self.z_dim {
            bail!("{}: z has {} dims, model wants {}", self.name, z.len(),
                  self.z_dim);
        }
        if cond.len() != self.cond_dim {
            bail!("{}: cond has {} dims, model wants {}", self.name,
                  cond.len(), self.cond_dim);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::cgan_layers;

    fn tiny_native() -> Model {
        let mut rng = Rng::new(1);
        let gen = Generator::new(cgan_layers(), 8, 2, &mut rng);
        Model::native("tiny", Arc::new(gen), 2)
    }

    #[test]
    fn native_model_geometry() {
        let m = tiny_native();
        assert_eq!(m.z_dim, 8);
        assert_eq!(m.cond_dim, 2);
        assert_eq!(m.out_shape, vec![1, 32, 32, 3]);
        assert_eq!(m.bucket_for(5), 5);
    }

    #[test]
    fn validate_rejects_bad_latents() {
        let m = tiny_native();
        assert!(m.validate(&[0.0; 8], &[0.0; 2]).is_ok());
        assert!(m.validate(&[0.0; 7], &[0.0; 2]).is_err());
        assert!(m.validate(&[0.0; 8], &[]).is_err());
    }

    #[test]
    fn pjrt_model_from_manifest() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let rt = Arc::new(RuntimeHandle::spawn(dir).unwrap());
        let m = Model::from_artifacts("dcgan", "dcgan_gen", rt, 1,
                                      &[1, 4], 42).unwrap();
        assert_eq!(m.z_dim, 100);
        assert_eq!(m.cond_dim, 0);
        assert_eq!(m.out_shape, vec![1, 64, 64, 3]);
        assert_eq!(m.bucket_for(2), 4);
        assert_eq!(m.bucket_for(100), 4);
    }
}
