//! Dynamic batcher: fuse queued requests into one forward pass.
//!
//! The batcher is payload-agnostic — it partitions a single model's
//! queue, and queues are per-model, so a batch never mixes tasks; the
//! worker's task dispatch happens after the batch is closed.
//!
//! Policy (the standard serving trade-off): a batch closes when it
//! reaches `max_batch` *or* `batch_timeout` has elapsed since its first
//! request — bounded tail latency under light load, full batches under
//! heavy load. The batch then routes to the smallest compiled batch
//! bucket that fits (`EngineConfig::bucket_for`), padding with zero
//! latents if needed.

use std::time::{Duration, Instant};

use super::queue::BoundedQueue;

/// Collect the next batch from `q`.
///
/// Blocks for the first request; then keeps admitting until `max_batch`
/// or `timeout` past the *first* request's arrival in the batch window.
/// `arrival` extracts that arrival timestamp (the coordinator passes
/// the request's `enqueued` instant) — anchoring the deadline at
/// arrival, not pop, is what makes the tail-latency bound hold under
/// backlog: a request that already waited its full window in the queue
/// ships immediately with whatever is queued behind it, instead of
/// paying a *second* window inside the batcher. Items already in the
/// queue are always admitted without waiting (an expired deadline only
/// stops the batcher from *sleeping* for stragglers).
///
/// `on_pop` fires once per item, at the instant the item leaves the
/// queue — the coordinator uses it to stamp `SpanStamps::popped` and
/// record the flight-recorder `popped` event while the pop time is
/// exact (stamping after the batch closes would fold batch-formation
/// wait into queue wait).
/// Returns `None` when the queue is closed and drained.
pub fn next_batch<T>(q: &BoundedQueue<T>, max_batch: usize,
                     timeout: Duration,
                     arrival: impl Fn(&T) -> Instant,
                     mut on_pop: impl FnMut(&mut T)) -> Option<Vec<T>> {
    debug_assert!(max_batch > 0);
    let mut first = q.pop()?;
    on_pop(&mut first);
    let deadline = arrival(&first) + timeout;
    let mut batch = vec![first];
    while batch.len() < max_batch {
        match q.pop_until(deadline) {
            Ok(Some(mut item)) => {
                on_pop(&mut item);
                batch.push(item);
            }
            Ok(None) => break,          // window expired
            Err(()) => break,           // closed; ship what we have
        }
    }
    Some(batch)
}

/// Continuous, priority-aware batch formation (DESIGN.md §16) — the
/// fleet coordinator's replacement for the strict window-then-execute
/// loop of [`next_batch`].
///
/// `carry` is the worker-local spillover from the previous call: rows
/// that were popped but not seated because a higher class filled the
/// batch. Each call drains `carry` first, then admits queued rows —
/// probing up to `2 × max_batch` so a burst arriving *while the
/// previous batch executed* seeds the next batch immediately instead
/// of waiting out a fresh window. When more rows are ready than fit,
/// the `max_batch` best by `(class rank, arrival)` ship now and the
/// rest go back into `carry` for the very next call.
///
/// The deadline anchors at the **earliest arrival among all
/// candidates** — critically, a carried-over row keeps its *original*
/// arrival anchor rather than re-anchoring per batch, so no request
/// ever waits two windows: a row that spilled with an expired window
/// makes the next batch ship without sleeping at all.
///
/// Returns `None` only when the queue is closed and drained **and**
/// `carry` is empty — a worker that spilled rows always gets one more
/// batch to deliver them, which is what keeps the conservation
/// invariant exact at shutdown.
pub fn form_batch<T>(q: &BoundedQueue<T>, carry: &mut Vec<T>,
                     max_batch: usize, timeout: Duration,
                     arrival: impl Fn(&T) -> Instant,
                     rank: impl Fn(&T) -> u8,
                     mut on_pop: impl FnMut(&mut T)) -> Option<Vec<T>> {
    debug_assert!(max_batch > 0);
    let probe = max_batch.saturating_mul(2);
    let mut cand: Vec<T> = std::mem::take(carry);
    if cand.is_empty() {
        let mut first = q.pop()?;
        on_pop(&mut first);
        cand.push(first);
    }
    // earliest-arrival anchor across carry and the fresh head: the
    // satellite fix — re-anchoring at the carried row's *pop* (or at
    // the new head's arrival) would make a spilled request wait a
    // second full window.
    let anchor = cand.iter().map(&arrival).min().expect("cand nonempty");
    let deadline = anchor + timeout;
    while cand.len() < probe {
        match q.pop_until(deadline) {
            Ok(Some(mut item)) => {
                on_pop(&mut item);
                cand.push(item);
            }
            Ok(None) => break,          // window expired
            Err(()) => break,           // closed; ship what we have
        }
    }
    if cand.len() > max_batch {
        // seat by (class rank, arrival): higher classes first, FIFO
        // within a class; the stable sort keeps pop order on ties
        cand.sort_by(|a, b| {
            (rank(a), arrival(a)).cmp(&(rank(b), arrival(b)))
        });
        carry.extend(cand.drain(max_batch..));
    }
    Some(cand)
}

/// Statistics helper: ideal batch sizes for an arrival trace — used by
/// the serving bench to sanity-check the batcher against the theoretical
/// optimum for a given (rate, timeout, max_batch). The window boundary
/// is **exclusive**, matching [`next_batch`]'s deadline semantics
/// (`pop_until` stops waiting the instant `now >= deadline`, so an
/// arrival exactly at `first + timeout` opens the next batch).
pub fn ideal_batches(arrivals_us: &[u64], max_batch: usize,
                     timeout_us: u64) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < arrivals_us.len() {
        let window_end = arrivals_us[i] + timeout_us;
        let mut j = i + 1;
        while j < arrivals_us.len()
            && j - i < max_batch
            && arrivals_us[j] < window_end
        {
            j += 1;
        }
        out.push(j - i);
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Plain payloads arrive "now" — pop-time and arrival-time deadlines
    /// coincide, which is exactly the un-backlogged case.
    fn now<T>(_: &T) -> Instant {
        Instant::now()
    }

    #[test]
    fn batches_up_to_max() {
        let q = BoundedQueue::new(64);
        for i in 0..10 {
            q.try_push(i).unwrap();
        }
        let b = next_batch(&q, 4, Duration::from_millis(5), now, |_| {})
            .unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&q, 4, Duration::from_millis(5), now, |_| {})
            .unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn timeout_ships_partial_batch() {
        let q = BoundedQueue::new(64);
        q.try_push(1).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&q, 8, Duration::from_millis(20), now, |_| {})
            .unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let q = Arc::new(BoundedQueue::new(64));
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.try_push(2).unwrap();
        });
        let b = next_batch(&q, 8, Duration::from_millis(50), now, |_| {})
            .unwrap();
        t.join().unwrap();
        assert_eq!(b, vec![1, 2]);
    }

    /// The backlog regression (DESIGN.md §11): a request that already
    /// sat out its window in the queue must not pay a second window
    /// inside the batcher — with an arrival-anchored deadline in the
    /// past, the batcher ships immediately instead of sleeping.
    #[test]
    fn stale_arrival_ships_without_a_second_window() {
        let q: BoundedQueue<(Instant, u32)> = BoundedQueue::new(8);
        let long_ago = Instant::now() - Duration::from_millis(200);
        q.try_push((long_ago, 1)).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&q, 8, Duration::from_millis(100),
                           |it: &(Instant, u32)| it.0, |_| {}).unwrap();
        assert_eq!(b.len(), 1);
        // pop-time anchoring would sleep the full 100ms here
        assert!(t0.elapsed() < Duration::from_millis(50),
                "expired window must not be waited out again: {:?}",
                t0.elapsed());
    }

    /// Even past its deadline, a batch admits everything already queued
    /// (no waiting involved) — backlog drains at full batch sizes.
    #[test]
    fn expired_window_still_drains_queued_backlog() {
        let q: BoundedQueue<(Instant, u32)> = BoundedQueue::new(8);
        let long_ago = Instant::now() - Duration::from_millis(200);
        for i in 0..5 {
            q.try_push((long_ago, i)).unwrap();
        }
        let b = next_batch(&q, 4, Duration::from_millis(100),
                           |it: &(Instant, u32)| it.0, |_| {}).unwrap();
        assert_eq!(b.iter().map(|it| it.1).collect::<Vec<_>>(),
                   vec![0, 1, 2, 3]);
    }

    #[test]
    fn closed_queue_returns_none() {
        let q: BoundedQueue<i32> = BoundedQueue::new(4);
        q.close();
        assert!(next_batch(&q, 4, Duration::from_millis(1), now, |_| {})
            .is_none());
    }

    #[test]
    fn close_mid_window_ships_partial() {
        let q = Arc::new(BoundedQueue::new(4));
        q.try_push(7).unwrap();
        let q2 = q.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.close();
        });
        let b = next_batch(&q, 8, Duration::from_secs(5), now, |_| {})
            .unwrap();
        assert_eq!(b, vec![7]);
    }

    /// Satellite regression (ISSUE 10): a carried-over row keeps its
    /// *original* arrival anchor. Re-anchoring per batch would make a
    /// spilled request wait two windows; with the original anchor long
    /// expired, the next batch ships immediately.
    #[test]
    fn carried_row_keeps_its_original_anchor() {
        let q: BoundedQueue<(Instant, u8, u32)> = BoundedQueue::new(16);
        let long_ago = Instant::now() - Duration::from_millis(200);
        let mut carry = vec![(long_ago, 0u8, 7u32)];
        let t0 = Instant::now();
        let b = form_batch(&q, &mut carry, 4, Duration::from_millis(100),
                           |it| it.0, |it| it.1, |_| {}).unwrap();
        assert_eq!(b.iter().map(|it| it.2).collect::<Vec<_>>(), vec![7]);
        assert!(carry.is_empty());
        // a fresh (re-anchored) window would sleep ~100ms here
        assert!(t0.elapsed() < Duration::from_millis(50),
                "carried row waited a second window: {:?}", t0.elapsed());
    }

    /// Over-probe spills the lowest classes into carry; the spill ships
    /// in the immediately following batch, still anchored at its own
    /// arrival.
    #[test]
    fn priority_seats_first_and_spill_carries_over() {
        let q: BoundedQueue<(Instant, u8, u32)> = BoundedQueue::new(16);
        let t = Instant::now() - Duration::from_millis(50);
        // 3 background rows queued first, then 2 interactive
        for (i, rank) in [(0u32, 2u8), (1, 2), (2, 2), (3, 0), (4, 0)] {
            q.try_push((t + Duration::from_micros(i as u64), rank, i))
                .unwrap();
        }
        let mut carry = Vec::new();
        let b = form_batch(&q, &mut carry, 3, Duration::from_millis(10),
                           |it| it.0, |it| it.1, |_| {}).unwrap();
        // interactive rows seated first despite arriving later
        assert_eq!(b.iter().map(|it| it.2).collect::<Vec<_>>(),
                   vec![3, 4, 0]);
        assert_eq!(carry.iter().map(|it| it.2).collect::<Vec<_>>(),
                   vec![1, 2]);
        // spill ships next, without a fresh window sleep
        let t0 = Instant::now();
        let b = form_batch(&q, &mut carry, 3, Duration::from_millis(100),
                           |it| it.0, |it| it.1, |_| {}).unwrap();
        assert_eq!(b.iter().map(|it| it.2).collect::<Vec<_>>(),
                   vec![1, 2]);
        assert!(carry.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    /// `None` only once the queue is closed *and* the carry is
    /// delivered — spilled rows are never lost at shutdown.
    #[test]
    fn closed_queue_still_ships_the_carry() {
        let q: BoundedQueue<(Instant, u8, u32)> = BoundedQueue::new(4);
        q.close();
        let mut carry = vec![(Instant::now(), 1u8, 9u32)];
        let b = form_batch(&q, &mut carry, 4, Duration::from_millis(5),
                           |it| it.0, |it| it.1, |_| {}).unwrap();
        assert_eq!(b.iter().map(|it| it.2).collect::<Vec<_>>(), vec![9]);
        assert!(form_batch(&q, &mut carry, 4, Duration::from_millis(5),
                           |it| it.0, |it| it.1, |_| {}).is_none());
    }

    #[test]
    fn ideal_batches_partition_trace() {
        let arrivals = vec![0, 1, 2, 100, 101, 300];
        let b = ideal_batches(&arrivals, 2, 10);
        assert_eq!(b, vec![2, 1, 2, 1]);
        assert_eq!(b.iter().sum::<usize>(), arrivals.len());
    }

    /// The boundary is exclusive, matching `pop_until`'s `now >=
    /// deadline` cutoff: an arrival exactly at `first + timeout` opens
    /// the next batch.
    #[test]
    fn ideal_batches_boundary_is_exclusive() {
        assert_eq!(ideal_batches(&[0, 10], 8, 10), vec![1, 1]);
        assert_eq!(ideal_batches(&[0, 9], 8, 10), vec![2]);
    }
}
