//! Bounded MPMC queue with backpressure — the engine's admission point.
//!
//! Hand-rolled on `Mutex` + `Condvar` (no tokio in the vendor set).
//! `try_push` never blocks: when the queue is full the request is
//! *rejected* so an overloaded edge device sheds load instead of building
//! an unbounded backlog (the coordinator's backpressure contract).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

#[derive(Debug)]
struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer queue.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue at capacity — caller should shed load.
    Full(T),
    /// Queue closed — engine is shutting down.
    Closed(T),
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; rejects when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        self.try_push_then(item, |_| {})
    }

    /// `try_push`, invoking `on_push(depth_after)` under the queue lock
    /// on success. Because consumers cannot pop until the lock is
    /// released, anything `on_push` publishes (e.g. a trace event) is
    /// ordered strictly before any consumer-side observation of the
    /// item — and `depth_after` is exact, not racing concurrent pops.
    pub fn try_push_then(&self, item: T, on_push: impl FnOnce(usize))
                         -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        on_push(g.items.len());
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Priority-aware admission (DESIGN.md §16): like
    /// [`BoundedQueue::try_push_then`], but when the queue is full, a
    /// queued item for which `lower(queued, &item)` holds — i.e. one of
    /// strictly lower priority than the incoming item — may be
    /// *displaced* to make room. The **youngest** such item is chosen
    /// (scanning from the back), so FIFO fairness within a class is
    /// preserved and the displaced item is the one that has invested
    /// the least wait.
    ///
    /// Returns `Ok(Some(victim))` when admission displaced a queued
    /// item (the caller owes the victim a shed outcome), `Ok(None)` on
    /// a plain push, and `Err(Full)` when the queue is full of
    /// equal-or-higher-priority work.
    pub fn try_push_displace(
        &self,
        item: T,
        lower: impl Fn(&T, &T) -> bool,
        on_push: impl FnOnce(usize),
    ) -> Result<Option<T>, PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        let mut victim = None;
        if g.items.len() >= self.capacity {
            let Some(idx) = g.items
                .iter()
                .rposition(|queued| lower(queued, &item))
            else {
                return Err(PushError::Full(item));
            };
            victim = g.items.remove(idx);
        }
        g.items.push_back(item);
        on_push(g.items.len());
        drop(g);
        self.not_empty.notify_one();
        Ok(victim)
    }

    /// Blocking pop; `None` when the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.items.pop_front() {
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline; `Ok(None)` on timeout.
    pub fn pop_until(&self, deadline: Instant) -> Result<Option<T>, ()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.items.pop_front() {
                return Ok(Some(x));
            }
            if g.closed {
                return Err(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (ng, res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap();
            g = ng;
            if res.timed_out() && g.items.is_empty() {
                if g.closed {
                    return Err(());
                }
                return Ok(None);
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().items.pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers start failing, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::{Duration, Duration as D};

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn rejects_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        q.try_pop();
        q.try_push(3).unwrap();
    }

    #[test]
    fn displacement_sheds_the_youngest_lower_class() {
        // items are (rank, id); lower priority == greater rank
        let lower = |q: &(u8, u32), inc: &(u8, u32)| q.0 > inc.0;
        let q: BoundedQueue<(u8, u32)> = BoundedQueue::new(3);
        q.try_push((0, 1)).unwrap();
        q.try_push((2, 2)).unwrap();
        q.try_push((2, 3)).unwrap();
        // full of equal-or-higher work for an incoming background row
        assert!(matches!(
            q.try_push_displace((2, 4), lower, |_| {}),
            Err(PushError::Full((2, 4)))));
        // an interactive arrival displaces the *youngest* background row
        let victim =
            q.try_push_displace((0, 5), lower, |_| {}).unwrap();
        assert_eq!(victim, Some((2, 3)));
        assert_eq!(q.len(), 3);
        // full of interactive: even interactive can no longer displace
        let v = q.try_push_displace((0, 6), lower, |_| {}).unwrap();
        assert_eq!(v, Some((2, 2)));
        assert!(matches!(
            q.try_push_displace((0, 7), lower, |_| {}),
            Err(PushError::Full((0, 7)))));
        // drain order: displacement preserved FIFO among survivors
        assert_eq!(q.pop(), Some((0, 1)));
        assert_eq!(q.pop(), Some((0, 5)));
        assert_eq!(q.pop(), Some((0, 6)));
    }

    #[test]
    fn displacement_respects_close() {
        let q: BoundedQueue<(u8, u32)> = BoundedQueue::new(1);
        q.close();
        assert!(matches!(
            q.try_push_displace((0, 1), |a, b| a.0 > b.0, |_| {}),
            Err(PushError::Closed((0, 1)))));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(q.try_push(2), Err(PushError::Closed(2)));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_until_times_out() {
        let q: BoundedQueue<i32> = BoundedQueue::new(4);
        let r = q.pop_until(Instant::now() + D::from_millis(10));
        assert_eq!(r, Ok(None));
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(BoundedQueue::new(64));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                loop {
                    match q2.try_push(i) {
                        Ok(()) => break,
                        Err(PushError::Full(_)) => std::thread::yield_now(),
                        Err(PushError::Closed(_)) => panic!("closed"),
                    }
                }
            }
            q2.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(42).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(42));
    }
}
