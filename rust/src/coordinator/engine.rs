//! The serving engine facade: register models, submit requests, collect
//! responses, observe metrics, shut down cleanly.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::EngineConfig;
use crate::metrics::flight::{FlightRecorder, Stage, SUBMIT_LANE};
use crate::metrics::registry::{MetricsRegistry, MetricsSnapshot};
use crate::metrics::span::{SpanStamps, StageMetrics};
use crate::metrics::{Counters, Histogram};
use crate::plan::ExecPlan;
use crate::replay::event::EventBody;
use crate::replay::recorder::TraceSink;
use crate::workspace::{Workspace, WorkspaceCounters};

use super::error::{ServeError, ServeResult};
use super::queue::{BoundedQueue, PushError};
use super::residency::Residency;
use super::router::{Model, Payload, Priority, Request, Response};
use super::worker::spawn_workers;

struct ModelRuntime {
    model: Arc<Model>,
    queue: Arc<BoundedQueue<Request>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Per-model outcome counters (DESIGN.md §16): the conservation
    /// invariant holds for each model independently, over the requests
    /// that resolved to it (unknown-model rejects are fleet-only).
    counters: Arc<Counters>,
}

/// The engine's observability bundle (DESIGN.md §12): the per-stage
/// latency histogram grid and the flight recorder, behind one armed
/// flag. Built once per engine from `EngineConfig::instrument` and
/// shared with every worker by `Arc`; when disarmed, every hot-path
/// hook is a single branch on a plain `bool` — the same
/// null-check cost model as the trace sink.
pub struct Observability {
    /// Per-stage latency histograms keyed by `(task, outcome)`.
    pub stages: StageMetrics,
    /// Lock-free ring of recent span events, dumped on worker panic.
    pub flight: FlightRecorder,
    enabled: bool,
}

impl Observability {
    /// Build the bundle and register its stage series in `reg`.
    pub fn new(reg: &MetricsRegistry, flight_capacity: usize,
               enabled: bool) -> Arc<Self> {
        Arc::new(Observability {
            stages: StageMetrics::new(reg),
            flight: FlightRecorder::new(flight_capacity),
            enabled,
        })
    }

    /// Whether instrumentation is armed (fixed at engine construction).
    #[inline]
    pub fn on(&self) -> bool {
        self.enabled
    }
}

/// The HUGE² edge serving engine (multi-task: image generation and
/// semantic segmentation share the queue → batcher → worker pipeline).
///
/// Every accepted request terminates in exactly one observable outcome
/// on its reply channel — `Ok(Response)` or a typed
/// [`ServeError`] (DESIGN.md §11); submit-time refusals return the
/// `ServeError` directly:
///
/// ```no_run
/// use huge2::config::EngineConfig;
/// use huge2::coordinator::{Engine, Payload, ServeError};
/// # use std::sync::Arc;
/// # use huge2::runtime::RuntimeHandle;
/// let rt = Arc::new(RuntimeHandle::spawn("artifacts".into())?);
/// let mut engine = Engine::new(EngineConfig::default());
/// engine.register_pjrt("dcgan", "dcgan_gen", rt, 1, 42)?;
/// match engine.submit("dcgan", Payload::latent(vec![0.0; 100],
///                                              vec![])) {
///     Ok(rx) => match rx.recv()? {
///         Ok(resp) => println!("image {:?} in {:?}",
///                              resp.output.shape(), resp.latency),
///         Err(e) => eprintln!("request failed ({}): {e}", e.kind()),
///     },
///     Err(ServeError::Backpressure) => { /* shed or retry */ }
///     Err(e) => return Err(e.into()),
/// }
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct Engine {
    cfg: EngineConfig,
    models: HashMap<String, ModelRuntime>,
    next_id: AtomicU64,
    pub counters: Arc<Counters>,
    /// Batch execution time (per batch).
    pub exec_hist: Arc<Histogram>,
    /// Record/replay hook: when set, every arrival/enqueue/reject (here)
    /// and batch/response (workers) is appended to the trace.
    sink: Option<Arc<TraceSink>>,
    /// Checkpoint-metrics pump: a helper thread that fills registry
    /// snapshots into checkpoint events a beat after the sink appends
    /// them. The indirection is a lock-order requirement — see
    /// [`TraceSink::backfill_metrics`]. Present only when the installed
    /// sink checkpoints.
    ckpt_pump: Option<(mpsc::Sender<()>, std::thread::JoinHandle<()>)>,
    /// Shared buffer pool; every worker thread holds a per-thread handle
    /// over it, so steady-state batch execution is allocation-free
    /// (DESIGN.md §9). [`Engine::workspace_counters`] exposes the proof.
    workspace: Arc<Workspace>,
    /// Metric catalogue: every engine series (outcome counters, stage
    /// histograms, workspace/flight counters, per-model queue gauges),
    /// snapshot-able and Prometheus-exposable (DESIGN.md §12).
    registry: Arc<MetricsRegistry>,
    /// Stage spans + flight recorder, shared with every worker.
    obs: Arc<Observability>,
    /// LRU weight-residency manager (DESIGN.md §16): present once
    /// [`Engine::set_resident_budget`] arms it; workers call
    /// `ensure` before every batch.
    residency: Option<Arc<Residency>>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let counters = Arc::new(Counters::new());
        let exec_hist = Arc::new(Histogram::new());
        let workspace = Arc::new(Workspace::new());
        let registry = Arc::new(MetricsRegistry::new());
        let obs = Observability::new(&registry, cfg.flight_capacity,
                                     cfg.instrument);
        Self::register_engine_metrics(&registry, &counters, &exec_hist,
                                      &workspace, &obs);
        Engine {
            cfg,
            models: HashMap::new(),
            next_id: AtomicU64::new(0),
            counters,
            exec_hist,
            sink: None,
            ckpt_pump: None,
            workspace,
            registry,
            obs,
            residency: None,
        }
    }

    /// Adapt the pre-existing atomics (outcome counters, workspace
    /// counters, flight totals, the batch-execution histogram) into
    /// registry series — closures over shared `Arc`s, no restructuring.
    fn register_engine_metrics(reg: &MetricsRegistry,
                               counters: &Arc<Counters>,
                               exec_hist: &Arc<Histogram>,
                               workspace: &Arc<Workspace>,
                               obs: &Arc<Observability>) {
        use std::sync::atomic::Ordering::Relaxed;
        let c = counters.clone();
        reg.counter_fn("huge2_submitted_total",
                       move || c.submitted.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_completed_total",
                       move || c.completed.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_rejected_total",
                       move || c.rejected.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_failed_total",
                       move || c.failed.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_dropped_total",
                       move || c.dropped.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_worker_panics_total",
                       move || c.panics.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_batches_total",
                       move || c.batches.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_batched_requests_total",
                       move || c.batched_requests.load(Relaxed));
        let c = counters.clone();
        reg.counter_fn("huge2_shed_total",
                       move || c.shed.load(Relaxed));
        let c = counters.clone();
        reg.gauge_fn("huge2_in_flight", move || c.in_flight());
        reg.register_histogram("huge2_batch_exec_us", exec_hist.clone());
        let ws = workspace.clone();
        reg.counter_fn("huge2_workspace_bytes_allocated",
                       move || ws.counters().bytes_allocated);
        let ws = workspace.clone();
        reg.counter_fn("huge2_workspace_checkouts_total",
                       move || ws.counters().checkouts);
        let ws = workspace.clone();
        reg.counter_fn("huge2_workspace_pool_hits_total",
                       move || ws.counters().pool_hits);
        let ws = workspace.clone();
        reg.counter_fn("huge2_workspace_pool_misses_total",
                       move || ws.counters().pool_misses);
        let o = obs.clone();
        reg.counter_fn("huge2_flight_events_total",
                       move || o.flight.pushed());
        let o = obs.clone();
        reg.counter_fn("huge2_flight_overwrites_total",
                       move || o.flight.overwrites());
    }

    /// The engine's metric catalogue (shared handle; see
    /// [`MetricsRegistry`]).
    pub fn registry(&self) -> Arc<MetricsRegistry> {
        self.registry.clone()
    }

    /// Atomic point-in-time snapshot of every registered series.
    /// Successive snapshots support windowed rates via
    /// [`MetricsSnapshot::delta`].
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.registry.snapshot()
    }

    /// Prometheus-style text exposition of the current snapshot — the
    /// scrape surface.
    pub fn metrics_text(&self) -> String {
        self.registry.snapshot().to_prometheus()
    }

    /// The stage-span + flight-recorder bundle (DESIGN.md §12).
    pub fn observability(&self) -> &Arc<Observability> {
        &self.obs
    }

    /// Arm per-layer plan profiling for a registered native model
    /// (DESIGN.md §12): every subsequent `run_into` records per-op wall
    /// time, engine, threads and workspace bytes into the plan's
    /// [`crate::plan::PlanProfile`]. Returns `false` for unknown models
    /// and PJRT backends (no compiled plan to profile).
    pub fn enable_layer_profiling(&self, model: &str) -> bool {
        match self.models.get(model)
                  .and_then(|mr| mr.model.plan_handle()) {
            Some(p) => {
                p.profile().set_enabled(true);
                true
            }
            None => false,
        }
    }

    /// A registered native model's compiled plan (`None` for unknown
    /// models, PJRT backends, and currently-evicted models) — profile
    /// and report access. The handle is a shared `Arc`: it stays valid
    /// even if the residency manager evicts the model afterwards.
    pub fn model_plan(&self, model: &str) -> Option<Arc<ExecPlan>> {
        self.models.get(model).and_then(|mr| mr.model.plan_handle())
    }

    /// Snapshot of the shared workspace's allocation counters. After the
    /// per-worker warmup batches, `bytes_allocated` must stay flat — the
    /// zero-steady-state-allocation invariant
    /// (`tests/workspace_stack.rs`).
    pub fn workspace_counters(&self) -> WorkspaceCounters {
        self.workspace.counters()
    }

    /// Engine-selection digest of a registered native model's compiled
    /// plan (`None` for unknown models and PJRT backends). Recorded in
    /// trace headers and re-checked by [`crate::replay::Replayer::run`]
    /// so `Engine::Auto` replays deterministically even if the
    /// heuristic changed between builds (DESIGN.md §10).
    pub fn plan_digest(&self, model: &str) -> Option<u64> {
        self.models.get(model).and_then(|mr| mr.model.pinned_digest())
    }

    /// Install a recording sink (see [`crate::replay`]). Must be called
    /// before any model is registered — workers capture the sink when
    /// they are spawned.
    pub fn set_trace_sink(&mut self, sink: Arc<TraceSink>) -> Result<()> {
        if !self.models.is_empty() {
            bail!("set_trace_sink must be called before any register()");
        }
        if sink.checkpoint_every() > 0 {
            // A checkpointing sink appends checkpoints with *empty*
            // metrics (record() runs inside a queue lock; taking a
            // registry snapshot there would cycle the lock order, since
            // gauge closures read queue depths). This pump fills them in
            // from outside any lock: snapshot first, then the sink lock
            // — strictly sequential acquisitions.
            let (tx, rx) = mpsc::channel::<()>();
            let s = sink.clone();
            let reg = self.registry.clone();
            let handle = std::thread::spawn(move || loop {
                let stop = !matches!(
                    rx.recv_timeout(Duration::from_millis(20)),
                    Err(mpsc::RecvTimeoutError::Timeout));
                if s.wants_metrics() {
                    let snap = reg.snapshot();
                    s.backfill_metrics(&snap);
                }
                if stop {
                    // sender dropped: one final sweep just happened
                    // above, with all workers already joined
                    break;
                }
            });
            self.ckpt_pump = Some((tx, handle));
        }
        if let Some(res) = &self.residency {
            res.set_sink(Some(sink.clone()));
        }
        self.sink = Some(sink);
        Ok(())
    }

    /// Arm LRU weight residency (DESIGN.md §16): native models' prepacked
    /// plans share `bytes` of budget; before each batch the worker makes
    /// its model resident, evicting least-recently-used peers first.
    /// `0` means unlimited (nothing evicted) — but still tracks usage.
    /// Must be called before any model is registered, for the same
    /// reason as [`Engine::set_trace_sink`]: workers capture the manager
    /// when spawned.
    pub fn set_resident_budget(&mut self, bytes: usize) -> Result<()> {
        if !self.models.is_empty() {
            bail!("set_resident_budget must be called before register()");
        }
        let res = Arc::new(Residency::new(bytes));
        res.set_sink(self.sink.clone());
        let r = res.clone();
        self.registry.gauge_fn("huge2_resident_bytes",
                               move || r.resident_bytes() as i64);
        let r = res.clone();
        self.registry.counter_fn("huge2_evictions_total",
                                 move || r.evictions());
        let r = res.clone();
        self.registry.counter_fn("huge2_reloads_total",
                                 move || r.reloads());
        self.residency = Some(res);
        Ok(())
    }

    /// The residency manager, when armed (eviction/reload observability).
    pub fn residency(&self) -> Option<&Arc<Residency>> {
        self.residency.as_ref()
    }

    /// Register a PJRT-served model (see [`Model::from_artifacts`]).
    pub fn register_pjrt(&mut self, name: &str, prefix: &str,
                         runtime: Arc<crate::runtime::RuntimeHandle>,
                         latent_inputs: usize, seed: u64) -> Result<()> {
        let model = Model::from_artifacts(
            name, prefix, runtime, latent_inputs,
            &self.cfg.batch_buckets.clone(), seed)?;
        self.register(model)
    }

    /// Register a natively-served model.
    pub fn register_native(&mut self, model: Model) -> Result<()> {
        self.register(model)
    }

    fn register(&mut self, model: Model) -> Result<()> {
        if self.models.contains_key(&model.name) {
            bail!("model {:?} already registered", model.name);
        }
        let name = model.name.clone();
        let model = Arc::new(model);
        let queue = Arc::new(BoundedQueue::new(self.cfg.queue_depth));
        let q = queue.clone();
        self.registry.gauge_fn(
            &format!("huge2_queue_depth{{model=\"{name}\"}}"),
            move || q.len() as i64);
        let counters = Arc::new(Counters::new());
        Self::register_model_metrics(&self.registry, &name, &counters);
        if let Some(res) = &self.residency {
            res.register(model.clone());
        }
        let workers = spawn_workers(
            model.clone(), queue.clone(), self.cfg.clone(),
            self.counters.clone(), counters.clone(),
            self.exec_hist.clone(), self.sink.clone(),
            self.workspace.clone(), self.obs.clone(),
            self.residency.clone(), self.cfg.workers);
        self.models.insert(
            name, ModelRuntime { model, queue, workers, counters });
        Ok(())
    }

    /// Labeled per-model outcome series (DESIGN.md §16): one set per
    /// registered model, same conservation algebra as the fleet-wide
    /// counters.
    fn register_model_metrics(reg: &MetricsRegistry, name: &str,
                              counters: &Arc<Counters>) {
        use std::sync::atomic::Ordering::Relaxed;
        let series: [(&str, fn(&Counters) -> u64); 5] = [
            ("submitted", |c| c.submitted.load(Relaxed)),
            ("completed", |c| c.completed.load(Relaxed)),
            ("rejected", |c| c.rejected.load(Relaxed)),
            ("failed", |c| c.failed.load(Relaxed)),
            ("shed", |c| c.shed.load(Relaxed)),
        ];
        for (what, get) in series {
            let c = counters.clone();
            reg.counter_fn(
                &format!("huge2_model_{what}_total{{model=\"{name}\"}}"),
                move || get(&c));
        }
        let c = counters.clone();
        reg.gauge_fn(&format!("huge2_model_in_flight{{model=\"{name}\"}}"),
                     move || c.in_flight());
    }

    /// A registered model's outcome counters (per-model conservation
    /// surface; `None` for unknown models).
    pub fn model_counters(&self, model: &str) -> Option<Arc<Counters>> {
        self.models.get(model).map(|mr| mr.counters.clone())
    }

    pub fn model_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.models.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Submit a request (any task). Returns the reply channel — which
    /// delivers the request's single terminal outcome, `Ok(Response)`
    /// or a typed [`ServeError`] — or the `ServeError` directly when
    /// admission itself refuses: [`ServeError::Validation`] (unknown
    /// model, wrong task, bad geometry, unrecordable payload),
    /// [`ServeError::Backpressure`] (queue full — retry later or shed)
    /// or [`ServeError::Shutdown`].
    ///
    /// Counter contract (DESIGN.md §11): every call increments
    /// `submitted`; an `Err` here increments `rejected`; an accepted
    /// request later increments exactly one of `completed`/`failed` —
    /// so `submitted == completed + rejected + failed` once drained.
    pub fn submit(&self, model: &str, payload: Payload)
                  -> std::result::Result<mpsc::Receiver<ServeResult>,
                                         ServeError> {
        self.submit_with(model, payload, Priority::default())
    }

    /// [`Engine::submit`] with an explicit priority class (DESIGN.md
    /// §16). Admission is priority-aware: when `model`'s queue is full,
    /// a higher-class arrival *displaces* the youngest queued request of
    /// a strictly lower class — the victim's terminal outcome is
    /// [`ServeError::Shed`] through its reply channel — while a
    /// lower-class arrival into a full queue is shed directly
    /// (`Err(Shed)` here). Only an `Interactive` arrival that finds the
    /// queue full of equal-or-higher work still sees the classic
    /// [`ServeError::Backpressure`]. Every shed is also counted in
    /// `rejected`, so conservation is unchanged.
    pub fn submit_with(&self, model: &str, payload: Payload,
                       priority: Priority)
                       -> std::result::Result<mpsc::Receiver<ServeResult>,
                                              ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let stamps = SpanStamps::now();
        if self.obs.on() {
            self.obs.flight.record(id, Stage::Submitted, SUBMIT_LANE);
        }
        if let Some(s) = &self.sink {
            // The workload's non-deterministic input: latents captured
            // bit-exactly, images as (shape, seed, checksum) — trace v2.
            // An unreplayable input must not enter the trace: it is
            // rejected here (recorded as a Reject, no arrival event) so
            // the fault surfaces at record time, not at every replay.
            match payload.to_recordable_arrival() {
                Ok(arrival) => s.record(EventBody::RequestArrival {
                    id,
                    model: model.to_string(),
                    payload: arrival,
                    priority,
                }),
                Err(e) => {
                    return Err(self.reject(
                        None, id,
                        ServeError::Validation(format!("{e:#}"))));
                }
            }
        }
        let mr = match self.models.get(model) {
            Some(mr) => mr,
            None => {
                return Err(self.reject(None, id, ServeError::Validation(
                    format!("unknown model {model:?} (have {:?})",
                            self.model_names()))));
            }
        };
        mr.counters.submitted.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = mr.model.validate(&payload) {
            return Err(self.reject(Some(&mr.counters), id, e));
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { id, payload, priority,
                            enqueued: Instant::now(), stamps, reply: tx };
        // Enqueue is recorded under the queue lock: the trace can never
        // show a worker's BatchFormed/Response for an id before its
        // Enqueue, and `depth` is exact.
        let push = mr.queue.try_push_displace(
            req,
            |queued, inc| queued.priority.rank() > inc.priority.rank(),
            |depth| {
                if self.obs.on() {
                    self.obs.flight.record(id, Stage::Enqueued,
                                           SUBMIT_LANE);
                }
                if let Some(s) = &self.sink {
                    s.record(EventBody::Enqueue { id, depth });
                }
            });
        match push {
            Ok(None) => Ok(rx),
            Ok(Some(victim)) => {
                // Admission displaced a queued lower-class request: the
                // incoming row is enqueued, the victim is shed.
                let err = self.shed(&mr.counters, victim.id,
                                    victim.priority);
                if victim.reply.send(Err(err)).is_err() {
                    self.counters.dropped.fetch_add(1, Ordering::Relaxed);
                    mr.counters.dropped.fetch_add(1, Ordering::Relaxed);
                }
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                if priority == Priority::Interactive {
                    Err(self.reject(Some(&mr.counters), id,
                                    ServeError::Backpressure))
                } else {
                    Err(self.shed(&mr.counters, id, priority))
                }
            }
            Err(PushError::Closed(_)) => {
                Err(self.reject(Some(&mr.counters), id,
                                ServeError::Shutdown))
            }
        }
    }

    /// Count the submit-time refusal (fleet-wide and, when the request
    /// resolved to a model, per-model), record a `Reject` trace event
    /// (when recording), and pass the typed error through unchanged.
    fn reject(&self, model_counters: Option<&Counters>, id: u64,
              err: ServeError) -> ServeError {
        self.counters.rejected.fetch_add(1, Ordering::Relaxed);
        if let Some(mc) = model_counters {
            mc.rejected.fetch_add(1, Ordering::Relaxed);
        }
        if self.obs.on() {
            self.obs.flight.record(id, Stage::Rejected, SUBMIT_LANE);
        }
        if let Some(s) = &self.sink {
            s.record(EventBody::Reject { id, reason: err.to_string() });
        }
        err
    }

    /// Count a priority shed — a `rejected` outcome plus the `shed`
    /// telemetry subset — and record the folded `Shed` trace event.
    fn shed(&self, model_counters: &Counters, id: u64, class: Priority)
            -> ServeError {
        for c in [&*self.counters, model_counters] {
            c.rejected.fetch_add(1, Ordering::Relaxed);
            c.shed.fetch_add(1, Ordering::Relaxed);
        }
        if self.obs.on() {
            self.obs.flight.record(id, Stage::Rejected, SUBMIT_LANE);
        }
        if let Some(s) = &self.sink {
            s.record(EventBody::Shed { id, class });
        }
        ServeError::Shed { class }
    }

    /// Wait out a reply channel, flattening the typed outcome into
    /// `anyhow` for the blocking conveniences. A closed channel without
    /// an outcome is an engine bug by contract — supervision always
    /// delivers one — and is reported as such.
    fn wait(rx: mpsc::Receiver<ServeResult>) -> Result<Response> {
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(e.into()),
            Err(_) => Err(anyhow!(
                "reply channel closed without a terminal outcome \
                 (engine bug: worker supervision must always reply)")),
        }
    }

    /// Blocking convenience: submit a latent + wait for the image. A
    /// failed request surfaces the typed [`ServeError`] (downcastable
    /// from the returned `anyhow::Error`).
    pub fn generate(&self, model: &str, z: Vec<f32>, cond: Vec<f32>)
                    -> Result<Response> {
        Self::wait(self.submit(model, Payload::latent(z, cond))?)
    }

    /// Blocking convenience: submit an image + wait for the mask. `seed`
    /// is the image's synthesis-provenance tag (see [`Payload::Image`]).
    /// A failed request surfaces the typed [`ServeError`].
    pub fn segment(&self, model: &str, image: crate::tensor::Tensor,
                   seed: u64) -> Result<Response> {
        Self::wait(self.submit(model, Payload::image(image, seed))?)
    }

    /// Fault-injection test hook (see
    /// [`Model::inject_panic_next_batch`]): the next batch a worker
    /// executes for `model` panics once; supervision catches it, fails
    /// the batch's requests with [`ServeError::BatchFailed`], and the
    /// worker keeps draining. Returns `false` for unknown models.
    pub fn inject_worker_panic(&self, model: &str) -> bool {
        match self.models.get(model) {
            Some(mr) => {
                mr.model.inject_panic_next_batch();
                true
            }
            None => false,
        }
    }

    /// Current depth of a model's queue (observability).
    pub fn queue_depth(&self, model: &str) -> Option<usize> {
        self.models.get(model).map(|m| m.queue.len())
    }

    /// Drain queues and join workers, then the checkpoint pump (its
    /// exit path does a final metrics sweep, so every checkpoint the
    /// workers appended ends up filled).
    pub fn shutdown(mut self) {
        for (_, mr) in self.models.iter() {
            mr.queue.close();
        }
        for (_, mr) in self.models.drain() {
            for w in mr.workers {
                let _ = w.join();
            }
        }
        if let Some((tx, h)) = self.ckpt_pump.take() {
            drop(tx);
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for (_, mr) in self.models.iter() {
            mr.queue.close();
        }
        for (_, mut mr) in self.models.drain() {
            for w in mr.workers.drain(..) {
                let _ = w.join();
            }
        }
        if let Some((tx, h)) = self.ckpt_pump.take() {
            drop(tx);
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny_segnet;
    use crate::coordinator::ServeError;
    use crate::gan::Generator;
    use crate::rng::Rng;
    use crate::seg::SegNet;
    use crate::tensor::Tensor;

    fn lat(z: usize) -> Payload {
        Payload::latent(vec![0.0; z], vec![])
    }

    fn native_engine(workers: usize, queue_depth: usize) -> Engine {
        let cfg = EngineConfig {
            workers,
            queue_depth,
            max_batch: 4,
            batch_timeout_us: 500,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        // small native cGAN-geometry generator (fast on CPU)
        let gen = Generator::tiny_cgan(5);
        e.register_native(super::super::router::Model::native(
            "tiny", Arc::new(gen), 0)).unwrap();
        e
    }

    #[test]
    fn generate_round_trip() {
        let e = native_engine(1, 16);
        let mut rng = Rng::new(6);
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        let r = e.generate("tiny", z, vec![]).unwrap();
        assert_eq!(r.output.shape(), &[1, 32, 32, 3]);
        assert!(r.output.data().iter().all(|v| v.abs() <= 1.0));
        assert!(r.batch_size >= 1);
    }

    #[test]
    fn unknown_model_rejected() {
        let e = native_engine(1, 16);
        assert!(e.submit("nope", lat(8)).is_err());
    }

    #[test]
    fn malformed_latent_rejected() {
        let e = native_engine(1, 16);
        assert!(e.submit("tiny", lat(7)).is_err());
        assert!(e
            .submit("tiny", Payload::latent(vec![0.0; 8], vec![1.0]))
            .is_err());
    }

    #[test]
    fn segment_round_trip_and_task_mismatch() {
        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 4,
            batch_timeout_us: 500,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let net = Arc::new(SegNet::new(&tiny_segnet(), 3));
        let n_classes = net.n_classes() as f32;
        let in_shape = net.in_shape();
        e.register_native(super::super::router::Model::native_seg(
            "seg", net)).unwrap();
        let img = Tensor::randn(&in_shape, &mut Rng::new(4));
        let r = e.segment("seg", img, 4).unwrap();
        assert_eq!(r.output.shape(), &[1, 9, 9, 1]);
        assert!(r.output.data().iter()
            .all(|&v| v >= 0.0 && v < n_classes && v.fract() == 0.0));
        // a latent payload must be rejected by the seg model
        assert!(e.submit("seg", lat(8)).is_err());
        e.shutdown();
    }

    #[test]
    fn concurrent_submitters_all_answered() {
        let e = Arc::new(native_engine(2, 128));
        let mut handles = Vec::new();
        for t in 0..4 {
            let e = e.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..8 {
                    let z: Vec<f32> =
                        (0..8).map(|_| rng.next_normal()).collect();
                    let r = e.generate("tiny", z, vec![]).unwrap();
                    assert_eq!(r.output.shape(), &[1, 32, 32, 3]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(e.counters.completed.load(Relaxed), 32);
        assert_eq!(e.counters.submitted.load(Relaxed), 32);
        // batching happened under concurrency (not all singletons) —
        // statistical, but with 4 threads × 500µs windows it always holds
        assert!(e.counters.mean_batch_size() >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_flooded() {
        // 0-worker trick: register, then flood a 4-deep queue
        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 2,
            max_batch: 1,
            batch_timeout_us: 1,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let gen = Generator::tiny_cgan(7);
        e.register_native(super::super::router::Model::native(
            "m", Arc::new(gen), 0)).unwrap();
        // flood faster than one worker can drain a 2-deep queue
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for _ in 0..200 {
            match e.submit("m", lat(8)) {
                Ok(rx) => receivers.push(rx),
                Err(err) => {
                    // queue-full refusals are *typed* now
                    assert_eq!(err, ServeError::Backpressure);
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        // accepted requests still complete (Ok outcome, not a failure)
        for rx in receivers {
            rx.recv().unwrap().unwrap();
        }
    }

    #[test]
    fn priority_admission_sheds_background_not_interactive() {
        use crate::coordinator::Priority;
        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 2,
            max_batch: 1,
            batch_timeout_us: 1,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let gen = Generator::tiny_cgan(7);
        e.register_native(super::super::router::Model::native(
            "m", Arc::new(gen), 0)).unwrap();
        let mut shed_at_submit = 0;
        let mut receivers = Vec::new();
        for i in 0..200 {
            let pri = if i % 2 == 0 {
                Priority::Background
            } else {
                Priority::Interactive
            };
            match e.submit_with("m", lat(8), pri) {
                Ok(rx) => receivers.push(rx),
                Err(ServeError::Shed { class }) => {
                    // only the lower class is ever shed at submit
                    assert_eq!(class, Priority::Background);
                    shed_at_submit += 1;
                }
                Err(ServeError::Backpressure) => {}
                Err(other) => panic!("unexpected refusal: {other}"),
            }
        }
        assert!(shed_at_submit > 0, "expected direct sheds under flood");
        // every accepted request still terminates — either Ok, or a
        // Shed delivered through the channel when it was displaced by
        // an interactive arrival (never the other way around)
        let mut shed_displaced = 0;
        for rx in receivers {
            match rx.recv().unwrap() {
                Ok(_) => {}
                Err(ServeError::Shed { class }) => {
                    assert_eq!(class, Priority::Background);
                    shed_displaced += 1;
                }
                Err(other) => panic!("unexpected outcome: {other}"),
            }
        }
        let _ = shed_displaced; // displacement is load-dependent
        use std::sync::atomic::Ordering::Relaxed;
        assert!(e.counters.shed.load(Relaxed) > 0);
        // conservation: fleet-wide and per-model (single model: equal)
        assert_eq!(e.counters.in_flight(), 0);
        let mc = e.model_counters("m").unwrap();
        assert_eq!(mc.in_flight(), 0);
        assert_eq!(mc.submitted.load(Relaxed),
                   e.counters.submitted.load(Relaxed));
        assert_eq!(mc.shed.load(Relaxed),
                   e.counters.shed.load(Relaxed));
    }

    #[test]
    fn fleet_residency_evicts_and_still_serves() {
        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 2,
            batch_timeout_us: 200,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let gen = Arc::new(Generator::tiny_cgan(5));
        let net = Arc::new(SegNet::new(&tiny_segnet(), 3));
        let in_shape = net.in_shape();
        let m_gen = super::super::router::Model::native(
            "gen", gen, 0);
        let m_seg = super::super::router::Model::native_seg(
            "seg", net);
        // budget fits exactly one of the two plans at a time
        let budget = m_gen.plan_bytes().max(m_seg.plan_bytes());
        e.set_resident_budget(budget).unwrap();
        e.register_native(m_gen).unwrap();
        e.register_native(m_seg).unwrap();
        let mut rng = Rng::new(21);
        for _ in 0..4 {
            let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
            e.generate("gen", z, vec![]).unwrap();
            let img = Tensor::randn(&in_shape, &mut Rng::new(4));
            e.segment("seg", img, 4).unwrap();
        }
        let res = e.residency().unwrap();
        assert!(res.evictions() >= 1, "alternating under a one-plan \
                 budget must evict");
        assert!(res.reloads() >= 1);
        assert!(res.resident_bytes() <= budget);
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(e.counters.completed.load(Relaxed), 8);
        for m in ["gen", "seg"] {
            let mc = e.model_counters(m).unwrap();
            assert_eq!(mc.completed.load(Relaxed), 4, "{m}");
            assert_eq!(mc.in_flight(), 0, "{m}");
        }
        e.shutdown();
    }

    #[test]
    fn trace_sink_captures_request_lifecycle() {
        use crate::replay::recorder::TraceSink;

        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 4,
            batch_timeout_us: 500,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let sink = Arc::new(TraceSink::new());
        e.set_trace_sink(sink.clone()).unwrap();
        let gen = Generator::tiny_cgan(5);
        e.register_native(super::super::router::Model::native(
            "tiny", Arc::new(gen), 0)).unwrap();
        // the sink cannot be swapped once workers have captured it
        assert!(e.set_trace_sink(Arc::new(TraceSink::new())).is_err());

        let mut rng = Rng::new(6);
        for _ in 0..3 {
            let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
            e.generate("tiny", z, vec![]).unwrap();
        }
        assert!(e.submit("missing", lat(8)).is_err());
        e.shutdown();

        let evs = sink.snapshot();
        let n = |k: &str| {
            evs.iter().filter(|ev| ev.body.kind() == k).count()
        };
        assert_eq!(n("arrival"), 4);
        assert_eq!(n("enqueue"), 3);
        assert_eq!(n("reject"), 1);
        assert_eq!(n("response"), 3);
        assert!(n("batch_formed") >= 1);
        assert_eq!(n("batch_formed"), n("batch_executed"));
        for w in evs.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "monotone timestamps");
        }
    }

    #[test]
    fn checkpoint_pump_backfills_metrics_by_shutdown() {
        use crate::replay::recorder::TraceSink;
        use crate::replay::{window, EventBody as EB};

        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 4,
            batch_timeout_us: 500,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        // tiny cadence so a short run crosses several windows
        let sink = Arc::new(TraceSink::with_checkpoints(4));
        e.set_trace_sink(sink.clone()).unwrap();
        let gen = Generator::tiny_cgan(5);
        e.register_native(super::super::router::Model::native(
            "tiny", Arc::new(gen), 0)).unwrap();
        let mut rng = Rng::new(6);
        for _ in 0..6 {
            let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
            e.generate("tiny", z, vec![]).unwrap();
        }
        e.shutdown();
        // shutdown joined the pump, whose exit path sweeps: no
        // checkpoint may be left with empty metrics
        assert!(!sink.wants_metrics());
        let evs = sink.snapshot();
        let ckpts: Vec<_> = evs
            .iter()
            .filter_map(|ev| match &ev.body {
                EB::Checkpoint(c) => Some(c),
                _ => None,
            })
            .collect();
        assert!(!ckpts.is_empty(), "run long enough to checkpoint");
        for c in &ckpts {
            assert!(c.metrics.counters.contains_key(
                        "huge2_submitted_total"),
                    "checkpoint seq {} has empty metrics", c.seq);
        }
        // checkpoints verify: metrics are outside the fingerprint
        window::verify_fingerprints(&evs).unwrap();
    }

    #[test]
    fn metrics_surface_exposes_stage_series_and_gauges() {
        let e = native_engine(1, 16);
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
            e.generate("tiny", z, vec![]).unwrap();
        }
        let snap = e.metrics_snapshot();
        assert_eq!(snap.counters["huge2_submitted_total"], 3);
        assert_eq!(snap.counters["huge2_completed_total"], 3);
        assert_eq!(snap.gauges["huge2_in_flight"], 0, "drained");
        assert_eq!(snap.gauges["huge2_queue_depth{model=\"tiny\"}"], 0);
        // every stage saw every completed request exactly once
        for stage in crate::metrics::span::STAGES {
            let m = snap
                .merged_histogram(&format!("huge2_stage_{stage}_us"));
            assert_eq!(m.count(), 3, "stage {stage}");
        }
        let text = e.metrics_text();
        assert!(text.contains("huge2_submitted_total 3"), "{text}");
        assert!(text.contains("huge2_queue_depth{model=\"tiny\"}"),
                "{text}");
        assert!(text.contains("huge2_batch_exec_us{quantile=\"0.5\"}"),
                "{text}");
        // flight recorder holds the full 8-stage chain per request
        assert_eq!(e.observability().flight.pushed(), 3 * 8);
        // windowed delta: one more request shows up alone
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        e.generate("tiny", z, vec![]).unwrap();
        let d = e.metrics_snapshot().delta(&snap);
        assert_eq!(d.counters["huge2_completed_total"], 1);
        assert_eq!(
            d.merged_histogram("huge2_stage_forward_us").count(), 1);
    }

    #[test]
    fn disabled_instrumentation_records_nothing() {
        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 4,
            batch_timeout_us: 500,
            instrument: false,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let gen = Generator::tiny_cgan(5);
        e.register_native(super::super::router::Model::native(
            "tiny", Arc::new(gen), 0)).unwrap();
        let mut rng = Rng::new(11);
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        e.generate("tiny", z, vec![]).unwrap();
        assert!(!e.observability().on());
        assert_eq!(e.observability().flight.pushed(), 0);
        let snap = e.metrics_snapshot();
        assert_eq!(
            snap.merged_histogram("huge2_stage_forward_us").count(), 0);
        // plain outcome counters still work — only spans are gated
        assert_eq!(snap.counters["huge2_completed_total"], 1);
    }

    #[test]
    fn layer_profiling_arms_through_the_engine() {
        let e = native_engine(1, 16);
        assert!(!e.enable_layer_profiling("missing"));
        assert!(e.enable_layer_profiling("tiny"));
        let mut rng = Rng::new(12);
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        e.generate("tiny", z, vec![]).unwrap();
        let plan = e.model_plan("tiny").unwrap();
        assert_eq!(plan.profile().runs(), 1);
        let report = plan.profile_report();
        assert!(report.starts_with("# huge2 plan profile v1 digest="),
                "{report}");
    }
}
