//! The serving engine facade: register models, submit requests, collect
//! responses, observe metrics, shut down cleanly.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::EngineConfig;
use crate::metrics::{Counters, Histogram};

use super::queue::{BoundedQueue, PushError};
use super::router::{Model, Request, Response};
use super::worker::spawn_workers;

struct ModelRuntime {
    model: Arc<Model>,
    queue: Arc<BoundedQueue<Request>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// The HUGE² edge serving engine.
///
/// ```no_run
/// use huge2::config::EngineConfig;
/// use huge2::coordinator::Engine;
/// # use std::sync::Arc;
/// # use huge2::runtime::RuntimeHandle;
/// let rt = Arc::new(RuntimeHandle::spawn("artifacts".into())?);
/// let mut engine = Engine::new(EngineConfig::default());
/// engine.register_pjrt("dcgan", "dcgan_gen", rt, 1, 42)?;
/// let rx = engine.submit("dcgan", vec![0.0; 100], vec![])?;
/// let resp = rx.recv()?;
/// println!("image {:?} in {:?}", resp.image.shape(), resp.latency);
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct Engine {
    cfg: EngineConfig,
    models: HashMap<String, ModelRuntime>,
    next_id: AtomicU64,
    pub counters: Arc<Counters>,
    /// Batch execution time (per batch).
    pub exec_hist: Arc<Histogram>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        Engine {
            cfg,
            models: HashMap::new(),
            next_id: AtomicU64::new(0),
            counters: Arc::new(Counters::new()),
            exec_hist: Arc::new(Histogram::new()),
        }
    }

    /// Register a PJRT-served model (see [`Model::from_artifacts`]).
    pub fn register_pjrt(&mut self, name: &str, prefix: &str,
                         runtime: Arc<crate::runtime::RuntimeHandle>,
                         latent_inputs: usize, seed: u64) -> Result<()> {
        let model = Model::from_artifacts(
            name, prefix, runtime, latent_inputs,
            &self.cfg.batch_buckets.clone(), seed)?;
        self.register(model)
    }

    /// Register a natively-served model.
    pub fn register_native(&mut self, model: Model) -> Result<()> {
        self.register(model)
    }

    fn register(&mut self, model: Model) -> Result<()> {
        if self.models.contains_key(&model.name) {
            bail!("model {:?} already registered", model.name);
        }
        let name = model.name.clone();
        let model = Arc::new(model);
        let queue = Arc::new(BoundedQueue::new(self.cfg.queue_depth));
        let workers = spawn_workers(
            model.clone(), queue.clone(), self.cfg.clone(),
            self.counters.clone(), self.exec_hist.clone(),
            self.cfg.workers);
        self.models
            .insert(name, ModelRuntime { model, queue, workers });
        Ok(())
    }

    pub fn model_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.models.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Submit a generation request. Returns the response channel, or an
    /// error if the model is unknown, the latent malformed, or the queue
    /// full (backpressure — the caller should retry later or shed).
    pub fn submit(&self, model: &str, z: Vec<f32>, cond: Vec<f32>)
                  -> Result<mpsc::Receiver<Response>> {
        let mr = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model:?} \
                                    (have {:?})", self.model_names()))?;
        mr.model.validate(&z, &cond)?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            z,
            cond,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        match mr.queue.try_push(req) {
            Ok(()) => Ok(rx),
            Err(PushError::Full(_)) => {
                self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                bail!("queue full for {model:?} (backpressure)")
            }
            Err(PushError::Closed(_)) => bail!("engine shutting down"),
        }
    }

    /// Blocking convenience: submit + wait.
    pub fn generate(&self, model: &str, z: Vec<f32>, cond: Vec<f32>)
                    -> Result<Response> {
        let rx = self.submit(model, z, cond)?;
        rx.recv().map_err(|_| anyhow!("worker dropped the request \
                                       (batch execution failed)"))
    }

    /// Current depth of a model's queue (observability).
    pub fn queue_depth(&self, model: &str) -> Option<usize> {
        self.models.get(model).map(|m| m.queue.len())
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) {
        for (_, mr) in self.models.iter() {
            mr.queue.close();
        }
        for (_, mr) in self.models.drain() {
            for w in mr.workers {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for (_, mr) in self.models.iter() {
            mr.queue.close();
        }
        for (_, mut mr) in self.models.drain() {
            for w in mr.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::cgan_layers;
    use crate::gan::Generator;
    use crate::rng::Rng;

    fn native_engine(workers: usize, queue_depth: usize) -> Engine {
        let cfg = EngineConfig {
            workers,
            queue_depth,
            max_batch: 4,
            batch_timeout_us: 500,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let mut rng = Rng::new(5);
        // small native cGAN-geometry generator (fast on CPU)
        let mut cfgs = cgan_layers();
        for l in &mut cfgs {
            l.c_in /= 8;
            if l.c_out > 3 {
                l.c_out /= 8;
            }
        }
        cfgs[1].c_in = cfgs[0].c_out;
        let gen = Generator::new(cfgs, 8, 0, &mut rng);
        e.register_native(super::super::router::Model::native(
            "tiny", Arc::new(gen), 0)).unwrap();
        e
    }

    #[test]
    fn generate_round_trip() {
        let e = native_engine(1, 16);
        let mut rng = Rng::new(6);
        let z: Vec<f32> = (0..8).map(|_| rng.next_normal()).collect();
        let r = e.generate("tiny", z, vec![]).unwrap();
        assert_eq!(r.image.shape(), &[1, 32, 32, 3]);
        assert!(r.image.data().iter().all(|v| v.abs() <= 1.0));
        assert!(r.batch_size >= 1);
    }

    #[test]
    fn unknown_model_rejected() {
        let e = native_engine(1, 16);
        assert!(e.submit("nope", vec![0.0; 8], vec![]).is_err());
    }

    #[test]
    fn malformed_latent_rejected() {
        let e = native_engine(1, 16);
        assert!(e.submit("tiny", vec![0.0; 7], vec![]).is_err());
        assert!(e.submit("tiny", vec![0.0; 8], vec![1.0]).is_err());
    }

    #[test]
    fn concurrent_submitters_all_answered() {
        let e = Arc::new(native_engine(2, 128));
        let mut handles = Vec::new();
        for t in 0..4 {
            let e = e.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..8 {
                    let z: Vec<f32> =
                        (0..8).map(|_| rng.next_normal()).collect();
                    let r = e.generate("tiny", z, vec![]).unwrap();
                    assert_eq!(r.image.shape(), &[1, 32, 32, 3]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(e.counters.completed.load(Relaxed), 32);
        assert_eq!(e.counters.submitted.load(Relaxed), 32);
        // batching happened under concurrency (not all singletons) —
        // statistical, but with 4 threads × 500µs windows it always holds
        assert!(e.counters.mean_batch_size() >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_flooded() {
        // 0-worker trick: register, then flood a 4-deep queue
        let cfg = EngineConfig {
            workers: 1,
            queue_depth: 2,
            max_batch: 1,
            batch_timeout_us: 1,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg);
        let mut rng = Rng::new(7);
        let mut cfgs = cgan_layers();
        for l in &mut cfgs {
            l.c_in /= 4;
            if l.c_out > 3 {
                l.c_out /= 4;
            }
        }
        cfgs[1].c_in = cfgs[0].c_out;
        let gen = Generator::new(cfgs, 8, 0, &mut rng);
        e.register_native(super::super::router::Model::native(
            "m", Arc::new(gen), 0)).unwrap();
        // flood faster than one worker can drain a 2-deep queue
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for _ in 0..200 {
            match e.submit("m", vec![0.0; 8], vec![]) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        // accepted requests still complete
        for rx in receivers {
            rx.recv().unwrap();
        }
    }
}
