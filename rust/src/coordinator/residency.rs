//! LRU prepacked-weight residency under a shared byte budget
//! (DESIGN.md §16).
//!
//! A fleet of N native models rarely fits its prepacked weights in an
//! edge device's memory at once. The residency manager keeps every
//! model *registered* but only some *resident*: before a worker
//! executes a batch, [`Residency::ensure`] makes that model's plan
//! resident — evicting the least-recently-used peers until the fleet's
//! resident prepacked bytes (plus the incoming plan) fit the budget —
//! and returns the plan handle the batch executes against. Handles are
//! `Arc`s, so evicting a model mid-batch never invalidates an executing
//! forward pass; the bytes are released when the last in-flight batch
//! finishes.
//!
//! Determinism contract: a reloaded plan must reproduce the
//! engine-selection digest pinned at registration
//! ([`Model::ensure_plan`] refuses the reload otherwise), so eviction
//! and reload can never change a single output byte — which is what
//! lets evict/reload be recorded as *telemetry* trace events
//! (DESIGN.md §7: scheduling detail is recorded, not pinned; a replay
//! is free to evict differently, and its outputs still verify).
//!
//! PJRT models hold weights in the runtime service, outside the
//! workspace budget: they are never evicted and `ensure` is a no-op
//! for them. Models registered via an explicit tuned plan keep a
//! rebuild closure that re-clones the plan (prepacked state is
//! Arc-shared), so their eviction is accounting-only — the budget
//! ledger stays exact either way.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::plan::ExecPlan;
use crate::replay::event::EventBody;
use crate::replay::recorder::TraceSink;

use super::router::Model;

#[derive(Debug)]
struct Slot {
    model: Arc<Model>,
    /// LRU tick of the last `ensure` for this model (0 = never used).
    last_use: u64,
}

/// The fleet's residency manager: one per engine, shared by every
/// worker thread.
pub struct Residency {
    /// Prepacked-weight byte budget across all resident native models
    /// (0 = unlimited; nothing is ever evicted).
    budget: usize,
    tick: AtomicU64,
    slots: Mutex<HashMap<String, Slot>>,
    sink: Mutex<Option<Arc<TraceSink>>>,
    evictions: AtomicU64,
    reloads: AtomicU64,
}

impl Residency {
    pub fn new(budget_bytes: usize) -> Self {
        Residency {
            budget: budget_bytes,
            tick: AtomicU64::new(0),
            slots: Mutex::new(HashMap::new()),
            sink: Mutex::new(None),
            evictions: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Install (or clear) the trace sink evict/reload events go to.
    pub fn set_sink(&self, sink: Option<Arc<TraceSink>>) {
        *self.sink.lock().unwrap() = sink;
    }

    /// Track a registered model. Registration does not enforce the
    /// budget — the first batch's `ensure` does, so eviction order is
    /// driven by use, not registration order.
    pub fn register(&self, model: Arc<Model>) {
        self.slots
            .lock()
            .unwrap()
            .insert(model.name.clone(), Slot { model, last_use: 0 });
    }

    /// Total prepacked bytes of currently-resident evictable models.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.model.is_evictable() && s.model.is_resident())
            .map(|s| s.model.plan_bytes())
            .sum()
    }

    /// Evictions performed so far (monotonic).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Reloads performed so far (monotonic).
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Make `model`'s plan resident and return the handle the batch
    /// should execute against (`None` for PJRT models — their weights
    /// are not budget-managed). Touches the LRU clock, evicts
    /// least-recently-used peers while the budget is exceeded, and
    /// records `Evict`/`Reload` trace events. Errs only when a rebuilt
    /// plan fails the pinned-digest check — the caller must fail the
    /// batch, not serve a drifted plan.
    pub fn ensure(&self, model: &Model)
                  -> Result<Option<Arc<ExecPlan>>, String> {
        if !model.is_evictable() {
            return Ok(None);
        }
        let mut slots = self.slots.lock().unwrap();
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(s) = slots.get_mut(model.name.as_str()) {
            s.last_use = tick;
        }
        if let Some(p) = model.plan_handle() {
            // already resident: still enforce (registration may have
            // left the fleet over budget)
            self.evict_to_budget(&mut slots, model, 0);
            return Ok(Some(p));
        }
        self.evict_to_budget(&mut slots, model, model.plan_bytes());
        let (plan, reloaded) = model.ensure_plan()?;
        if reloaded {
            self.reloads.fetch_add(1, Ordering::Relaxed);
            if let Some(sink) = self.sink.lock().unwrap().as_ref() {
                sink.record(EventBody::Reload {
                    model: model.name.clone(),
                    bytes: model.plan_bytes() as u64,
                    digest: plan.engine_digest(),
                });
            }
        }
        Ok(Some(plan))
    }

    /// Evict LRU peers of `keep` until resident bytes + `incoming` fit
    /// the budget. Stops (overcommitting) when no evictable peer
    /// remains — a single over-budget model must still serve.
    fn evict_to_budget(&self, slots: &mut HashMap<String, Slot>,
                       keep: &Model, incoming: usize) {
        if self.budget == 0 {
            return;
        }
        loop {
            let used: usize = slots
                .values()
                .filter(|s| {
                    s.model.is_evictable() && s.model.is_resident()
                })
                .map(|s| s.model.plan_bytes())
                .sum();
            if used + incoming <= self.budget {
                return;
            }
            let victim = slots
                .values()
                .filter(|s| {
                    s.model.name != keep.name
                        && s.model.is_evictable()
                        && s.model.is_resident()
                })
                .min_by_key(|s| s.last_use)
                .map(|s| s.model.clone());
            let Some(v) = victim else { return };
            if let Some(bytes) = v.evict_plan() {
                self.evictions.fetch_add(1, Ordering::Relaxed);
                if let Some(sink) = self.sink.lock().unwrap().as_ref() {
                    sink.record(EventBody::Evict {
                        model: v.name.clone(),
                        bytes: bytes as u64,
                    });
                }
            }
        }
    }
}

impl std::fmt::Debug for Residency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Residency")
            .field("budget", &self.budget)
            .field("evictions", &self.evictions())
            .field("reloads", &self.reloads())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{cgan_layers, tiny_segnet};
    use crate::gan::Generator;
    use crate::rng::Rng;
    use crate::seg::SegNet;

    fn gen_model(name: &str) -> Arc<Model> {
        let mut rng = Rng::new(1);
        let gen = Generator::new(cgan_layers(), 8, 2, &mut rng);
        Arc::new(Model::native(name, Arc::new(gen), 2))
    }

    fn seg_model(name: &str) -> Arc<Model> {
        let net = Arc::new(SegNet::new(&tiny_segnet(), 3));
        Arc::new(Model::native_seg(name, net))
    }

    #[test]
    fn unlimited_budget_never_evicts() {
        let res = Residency::new(0);
        let a = gen_model("a");
        let b = seg_model("b");
        res.register(a.clone());
        res.register(b.clone());
        assert!(res.ensure(&a).unwrap().is_some());
        assert!(res.ensure(&b).unwrap().is_some());
        assert_eq!(res.evictions(), 0);
        assert!(a.is_resident() && b.is_resident());
    }

    #[test]
    fn tight_budget_evicts_lru_and_reloads_with_digest() {
        let a = gen_model("a");
        let b = seg_model("b");
        // budget fits exactly one of the two plans at a time
        let budget = a.plan_bytes().max(b.plan_bytes());
        let res = Residency::new(budget);
        res.register(a.clone());
        res.register(b.clone());
        let da = a.pinned_digest().unwrap();
        let db = b.pinned_digest().unwrap();
        // a serves first: b (LRU, never used) is evicted
        assert!(res.ensure(&a).unwrap().is_some());
        assert!(a.is_resident());
        assert!(!b.is_resident());
        assert_eq!(res.evictions(), 1);
        // b serves next: a is evicted, b reloads, digest must hold
        let pb = res.ensure(&b).unwrap().unwrap();
        assert_eq!(pb.engine_digest(), db);
        assert!(!a.is_resident());
        assert!(b.is_resident());
        assert_eq!(res.evictions(), 2);
        assert_eq!(res.reloads(), 1);
        // and back: a reloads with its own digest intact
        let pa = res.ensure(&a).unwrap().unwrap();
        assert_eq!(pa.engine_digest(), da);
        assert_eq!(res.reloads(), 2);
        assert!(res.resident_bytes() <= budget);
    }

    #[test]
    fn evict_and_reload_are_trace_events() {
        let a = gen_model("a");
        let b = seg_model("b");
        let res = Residency::new(a.plan_bytes().max(b.plan_bytes()));
        let sink = Arc::new(TraceSink::new());
        res.set_sink(Some(sink.clone()));
        res.register(a.clone());
        res.register(b.clone());
        res.ensure(&a).unwrap();
        res.ensure(&b).unwrap();
        let evs = sink.snapshot();
        let evicts = evs
            .iter()
            .filter(|e| matches!(e.body, EventBody::Evict { .. }))
            .count();
        let reloads: Vec<_> = evs
            .iter()
            .filter_map(|e| match &e.body {
                EventBody::Reload { model, digest, .. } => {
                    Some((model.clone(), *digest))
                }
                _ => None,
            })
            .collect();
        assert_eq!(evicts, 2, "{evs:?}");
        assert_eq!(reloads,
                   vec![("b".to_string(), b.pinned_digest().unwrap())]);
    }
}
