//! Typed per-request failure outcomes (DESIGN.md §11).
//!
//! The engine's fault-containment contract: **every accepted request
//! terminates in exactly one observable outcome** — an `Ok(Response)` or
//! an `Err(ServeError)` on its reply channel. Nothing is ever dropped
//! silently: a failed batch, a malformed row discovered at gather time,
//! even a panicking worker all reply with a typed error instead of
//! closing the channel. The outcome conservation invariant
//! `submitted == completed + rejected + failed` is assertable over
//! [`crate::metrics::Counters`] once the engine is drained
//! (`tests/fault_stack.rs` pins it under a concurrent fault-injection
//! soak).

use std::fmt;

use super::router::{Priority, Response};

/// Why a request did not produce a [`Response`].
///
/// The variant set is the error *taxonomy*, deliberately small and
/// stable: [`ServeError::kind`] is recorded in `Failed` trace events
/// (trace format v3) and compared by the replayer's failure-determinism
/// check, so adding a variant is a wire-format decision, not just an
/// API one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The payload was rejected: unknown model, wrong task, bad
    /// geometry at submit, or a malformed row discovered during batch
    /// gather (in which case only the offending request fails — the
    /// rest of its batch still executes).
    Validation(String),
    /// The model's queue was full. Transient by construction: the
    /// caller should retry later or shed load (the replayer's fast mode
    /// drains one in-flight response and retries).
    Backpressure,
    /// The batch containing this request failed to execute — a backend
    /// error or a caught worker panic. The message names the cause.
    BatchFailed(String),
    /// The engine is shutting down; the queue no longer admits.
    Shutdown,
    /// Shed by the admission controller under backpressure: either this
    /// request's priority class lost to a full queue of higher-priority
    /// work, or it was displaced from the queue by a later,
    /// higher-priority arrival. `class` is the shed request's own
    /// priority. Like `Backpressure`, transient by construction — but
    /// priority-aware: an `Interactive` request is never shed while a
    /// lower class occupies its queue.
    Shed { class: Priority },
}

impl ServeError {
    /// Stable wire tag of the failure class — the `"kind"` field of a
    /// `Failed` trace event. Replay verifies failure determinism by
    /// kind (messages may carry run-specific detail; the class may
    /// not change between record and replay).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Validation(_) => "validation",
            ServeError::Backpressure => "backpressure",
            ServeError::BatchFailed(_) => "batch_failed",
            ServeError::Shutdown => "shutdown",
            ServeError::Shed { .. } => "shed",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Validation(msg) => write!(f, "validation: {msg}"),
            ServeError::Backpressure => {
                write!(f, "queue full (backpressure)")
            }
            ServeError::BatchFailed(msg) => {
                write!(f, "batch failed: {msg}")
            }
            ServeError::Shutdown => write!(f, "engine shutting down"),
            ServeError::Shed { class } => {
                write!(f, "shed under load (class={})", class.as_str())
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What a reply channel carries: the request's single terminal outcome.
pub type ServeResult = std::result::Result<Response, ServeError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_distinct_and_stable() {
        let all = [
            ServeError::Validation("x".into()),
            ServeError::Backpressure,
            ServeError::BatchFailed("y".into()),
            ServeError::Shutdown,
            ServeError::Shed { class: Priority::Background },
        ];
        let mut kinds: Vec<&str> = all.iter().map(|e| e.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), all.len());
        // wire tags are frozen: trace v3 `Failed` events store them
        assert_eq!(ServeError::Backpressure.kind(), "backpressure");
        assert_eq!(ServeError::Shutdown.kind(), "shutdown");
        assert_eq!(ServeError::Validation(String::new()).kind(),
                   "validation");
        assert_eq!(ServeError::BatchFailed(String::new()).kind(),
                   "batch_failed");
        assert_eq!(ServeError::Shed { class: Priority::Batch }.kind(),
                   "shed");
    }

    #[test]
    fn display_carries_the_message() {
        let e = ServeError::BatchFailed("worker panicked: boom".into());
        assert!(e.to_string().contains("boom"));
        let v = ServeError::Validation("z has 7 dims".into());
        assert!(v.to_string().contains("7 dims"));
    }
}
