//! Layer-3 coordinator: the HUGE² edge serving engine.
//!
//! Shape (vLLM-router-like, scaled to edge inference). The pipeline is
//! **multi-task**: a request carries a [`Payload`] (latent → image, or
//! image → segmentation mask), every model declares its [`Task`], and
//! workers dispatch on the model's backend.
//!
//! ```text
//!  clients ──submit(Payload)──> [BoundedQueue]  (backpressure: reject)
//!                          │
//!                    [dynamic batcher]  (max_batch OR deadline)
//!                          │
//!                    [worker threads] ──> PJRT artifact / native
//!                          │              generator / native seg net
//!                          │              (each batch under catch_unwind)
//!               Result<Response, ServeError> — exactly one
//!               terminal outcome per accepted request
//! ```
//!
//! * [`queue`] — bounded MPMC admission queue.
//! * [`batcher`] — deadline/size batching policy (payload-agnostic:
//!   queues are per-model, so a batch never mixes tasks).
//! * [`router`] — model registry (PJRT artifacts, native generators,
//!   native segmentation nets) + payload/task validation.
//! * [`worker`] — batch fusion, bucket padding, per-task execution,
//!   reply scatter under `catch_unwind` supervision.
//! * [`error`] — the typed failure taxonomy ([`ServeError`]): every
//!   accepted request terminates in exactly one `Ok(Response)` /
//!   `Err(ServeError)` outcome (DESIGN.md §11).
//! * [`engine`] — the public facade.

pub mod batcher;
pub mod engine;
pub mod error;
pub mod queue;
pub mod residency;
pub mod router;
pub mod worker;

pub use engine::{Engine, Observability};
pub use error::{ServeError, ServeResult};
pub use queue::{BoundedQueue, PushError};
pub use residency::Residency;
pub use router::{Backend, Model, Payload, Priority, Request, Response,
                 Task};
