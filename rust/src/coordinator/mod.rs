//! Layer-3 coordinator: the HUGE² edge serving engine.
//!
//! Shape (vLLM-router-like, scaled to edge inference):
//!
//! ```text
//!  clients ──submit──> [BoundedQueue]  (backpressure: reject when full)
//!                          │
//!                    [dynamic batcher]  (max_batch OR deadline)
//!                          │
//!                    [worker threads] ──> PJRT artifact / native engine
//!                          │
//!                      responses (+ latency, batch telemetry)
//! ```
//!
//! * [`queue`] — bounded MPMC admission queue.
//! * [`batcher`] — deadline/size batching policy.
//! * [`router`] — model registry (PJRT artifacts or native generators).
//! * [`worker`] — batch fusion, bucket padding, execution, reply scatter.
//! * [`engine`] — the public facade.

pub mod batcher;
pub mod engine;
pub mod queue;
pub mod router;
pub mod worker;

pub use engine::{Backpressure, Engine};
pub use queue::{BoundedQueue, PushError};
pub use router::{Backend, Model, Request, Response};
